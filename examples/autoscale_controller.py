"""The paper's Infrastructure Optimization Controller driving an
accelerator fleet: demand vectors come from the framework's OWN dry-run
rooflines (repro.core.workloads), the controller replans under the
incremental-adoption churn bound as load fluctuates, and a failure event
triggers an elastic replan + mesh rebuild.

  PYTHONPATH=src python examples/autoscale_controller.py
  (richer demands if benchmarks/artifacts/dryrun/*.json exist)
"""
import glob
import json
import os

import numpy as np

from repro.core import make_tpu_catalog
from repro.core.workloads import JobSpec, demand_from_job
from repro.distributed.elastic import ElasticFleet

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "artifacts", "dryrun")


def job_from_artifacts() -> JobSpec:
    """Prefer a real dry-run record (the roofline-to-allocator integration);
    fall back to a representative 104B training job."""
    for p in sorted(glob.glob(os.path.join(ART, "*train_4k__16x16.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            print(f"[controller] demand from dry-run artifact: {r['cell']}")
            return JobSpec(name=r["cell"], hlo_flops=r["flops"] * r["devices"],
                           hlo_bytes=r["bytes_accessed"],
                           collective_bytes=r["collective_bytes"],
                           bytes_per_device=r["bytes_per_device"],
                           devices=r["devices"], step_budget_s=1.0)
    print("[controller] no artifacts found — using synthetic 104B job")
    return JobSpec(name="train-104b", hlo_flops=2.5e16, hlo_bytes=1e14,
                   collective_bytes=5e12, bytes_per_device=8e9, devices=256)


def main():
    job = job_from_artifacts()
    d = demand_from_job(job)
    print(f"[controller] demand: chips={d[0]:.0f} hbm={d[1]:.0f}GB "
          f"ici={d[2]:.0f}GB/s ram={d[3]:.0f}GB")

    fleet = ElasticFleet(job, delta_max=64.0)
    plan = fleet.initial_plan()
    cat = make_tpu_catalog()

    def show(tag, plan):
        used = np.nonzero(plan.counts)[0]
        mix = ", ".join(f"{int(plan.counts[j])}x{cat.instances[j].name}"
                        for j in used)
        print(f"[{tag}] chips={plan.total_chips} cost=${plan.cost_per_hour:.0f}/hr"
              f" mesh={plan.mesh_shape}  [{mix}]")

    show("initial", plan)

    # diurnal load: replan each tick under the churn bound
    for t, scale in enumerate([1.0, 1.3, 1.8, 1.4, 0.8, 0.6, 1.0]):
        plan = fleet.replan_for_demand(scale)
        st = fleet.controller.history[-1]
        print(f"tick {t}: load x{scale:3.1f} -> chips={plan.total_chips:4d} "
              f"cost=${plan.cost_per_hour:7.0f}/hr churn={st.churn:.0f} "
              f"sat={st.metrics.satisfied}")

    # failure: 25% of the fleet dies -> bounded replan restores capacity
    failed = np.ceil(fleet.controller.x_current * 0.25)
    print(f"[failure] losing {int(failed.sum())} allocation units")
    plan = fleet.replan_after_failure(failed)
    show("replanned", plan)
    print(f"[controller] total churn over run: "
          f"{fleet.controller.total_churn():.0f} units")


if __name__ == "__main__":
    main()

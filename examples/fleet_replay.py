"""Trace-driven fleet replay: four tenant clusters with different demand
shapes (diurnal web, flash-crowd launch, steady ramp, weekly enterprise)
replayed through the Infrastructure Optimization Controller with warm starts
and bounded churn, against the Cluster Autoscaler baseline on the SAME
traces. Uses the BATCHED engine: every tick steps all tenants through one
solve_fleet / solve_fleet_step call per shape bucket (docs/fleet.md).
Horizons are RAGGED — the launch event ends before the fleet horizon, so
that tenant freezes mid-replay and stops accruing cost/churn.

  PYTHONPATH=src python examples/fleet_replay.py
"""
import numpy as np

from repro.core import Catalog, make_cloud_catalog
from repro.fleet import TenantSpec, make_trace, replay_fleet

T = 16  # ticks (hours)


def main():
    # trimmed catalog keeps the example fast on one CPU
    cat = Catalog(make_cloud_catalog().instances[::20])
    print(f"[fleet] catalog: {cat.n} instance types, "
          f"providers {cat.providers}")

    tenants = [
        TenantSpec(name="web-diurnal",
                   trace=make_trace("diurnal", np.array([8, 16, 4, 100.0]), T,
                                    seed=1, amplitude=0.4)),
        TenantSpec(name="launch-flashcrowd",
                   trace=make_trace("flash_crowd",
                                    np.array([4, 8, 2, 50.0]), 3 * T // 4,
                                    seed=2, burst_scale=3.0),  # ragged: ends early
                   delta_max=16.0),     # allow faster reaction to the spike
        TenantSpec(name="adoption-ramp",
                   trace=make_trace("ramp", np.array([6, 24, 3, 150.0]), T,
                                    seed=3, end_scale=2.5)),
        TenantSpec(name="enterprise-weekly",
                   trace=make_trace("weekly", np.array([16, 64, 6, 300.0]), T,
                                    seed=4)),
    ]

    out = replay_fleet(cat, tenants, run_ca_baseline=True,
                       ca_expander="random", replay_mode="batched")

    print(f"\n{'tenant':22s} {'cost $':>9s} {'CA $':>9s} {'save':>6s} "
          f"{'SLO!':>4s} {'churn':>7s} {'util%':>6s} {'prov':>4s}")
    for r in out.tenants:
        m, ca = r.metrics, r.ca_metrics
        save = 100 * (ca.cost_integral - m.cost_integral) / ca.cost_integral
        print(f"{m.name:22s} {m.cost_integral:9.2f} {ca.cost_integral:9.2f} "
              f"{save:5.1f}% {m.slo_violation_ticks:4d} {m.total_churn:7.1f} "
              f"{m.mean_utilization_pct:6.1f} {m.mean_fragmentation:4.1f}")

    print("\n[fleet aggregate]")
    print(out.metrics.summary())


if __name__ == "__main__":
    main()

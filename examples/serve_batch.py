"""Batched serving driver: prefill a batch of prompts, then decode tokens
autoregressively with the KV/state cache — the serve-side end-to-end path
(reduced config on CPU; the same code path the decode_32k / long_500k
dry-run cells lower at production shapes).

  PYTHONPATH=src python examples/serve_batch.py --arch mixtral-8x22b --tokens 24
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import decode_step, init_model, prefill, split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params, _ = split(init_model(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_frontend_tokens, cfg.d_frontend)),
            jnp.float32)

    s_max = P + args.tokens
    t0 = time.time()
    logits, caches = prefill(cfg, params, batch, s_max=s_max)
    print(f"[serve] prefill {B}x{P} tokens in {time.time()-t0:.2f}s "
          f"(cache capacity {min(s_max, cfg.window) if cfg.window else s_max})")

    step_fn = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, caches = step_fn(params, caches, tok, jnp.asarray(P + i))
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits / args.temperature, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"[serve] decoded {args.tokens-1} steps x {B} seqs in {dt:.2f}s "
          f"({B*(args.tokens-1)/dt:.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {gen[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()

"""End-to-end training driver: train a reduced assigned-architecture LM on
the synthetic pipeline with AdamW, checkpointing, restart, and (simulated)
failure injection — the full production loop at CPU scale.

  PYTHONPATH=src python examples/train_lm.py --arch qwen1.5-4b --steps 300
  PYTHONPATH=src python examples/train_lm.py --arch rwkv6-7b --steps 50 \
      --inject-failure 30     # dies at step 30, restarts from checkpoint

Any of the 10 assigned archs work (--full uses the real config — needs a
real cluster; the default reduced config trains ~1-3M params on CPU).
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.checkpoint import checkpoint as ckpt
from repro.models import init_model, loss_fn, split
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="simulate a crash at this step (once)")
    ap.add_argument("--full", action="store_true",
                    help="use the FULL config (cluster-scale)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    cfg = cfg.scaled(loss_chunk=min(64, args.seq))
    print(f"[train] arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)

    params, _ = split(init_model(cfg, jax.random.PRNGKey(0)))
    opt_state = adamw.init(params)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] params: {n_params/1e6:.2f}M")

    # restart path: resume from the newest committed checkpoint
    restored = ckpt.load_latest(args.ckpt_dir, {"p": params, "o": opt_state})
    start_step = 0
    if restored is not None:
        start_step, tree, extra = restored
        params, opt_state = tree["p"], tree["o"]
        print(f"[train] restored checkpoint @ step {start_step} "
              f"(loss was {extra.get('loss', float('nan')):.3f})")

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state, params)
        return params, opt_state, loss, om["grad_norm"]

    saver = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=2)
    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        if args.inject_failure and step == args.inject_failure \
                and not os.environ.get("REPRO_RESTARTED"):
            print(f"[train] *** injected failure at step {step} — "
                  "restart this script to resume from the checkpoint ***")
            raise SystemExit(42)
        b = data.shard_batch(step, 0, 1)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss, gnorm = train_step(params, opt_state, batch)
        losses.append(float(loss))
        if step % 20 == 0 or step == args.steps - 1:
            rate = (step - start_step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss={float(loss):7.4f}  "
                  f"gnorm={float(gnorm):6.2f}  {rate:5.2f} it/s")
        if step > 0 and step % args.ckpt_every == 0:
            saver.save(step, {"p": params, "o": opt_state},
                       extra={"loss": float(loss)})
    saver.wait()
    ckpt.save(args.ckpt_dir, args.steps, {"p": params, "o": opt_state},
              extra={"loss": losses[-1]})
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"[train] loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()

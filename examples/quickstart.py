"""Quickstart: the paper in 40 lines — build the catalogs, take a scenario,
run the Kubernetes Cluster Autoscaler baseline and the convex-optimization
allocator, compare cost/utilization/fragmentation.

  PYTHONPATH=src python examples/quickstart.py [--scenario s4_memory]
"""
import argparse

import numpy as np

from repro.core import (build_scenarios, evaluate, make_cloud_catalog,
                        optimize, simulate_cluster_autoscaler)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="s4_memory")
    ap.add_argument("--bnb", action="store_true",
                    help="polish with branch-and-bound")
    args = ap.parse_args()

    catalog = make_cloud_catalog()          # 940 Azure-like + 940 Linode-like
    scenario = {s.name: s for s in build_scenarios(catalog)}[args.scenario]
    print(f"scenario: {scenario.title}")
    print(f"demand:   cpu={scenario.demand[0]:.0f} mem={scenario.demand[1]:.0f}GB "
          f"net={scenario.demand[2]:.0f} storage={scenario.demand[3]:.0f}GB")

    ca = simulate_cluster_autoscaler(catalog, scenario.pools, scenario.demand)
    ca_metrics = evaluate(catalog, ca.counts, scenario.demand)
    print(f"\nCluster Autoscaler : ${ca_metrics.total_cost:.3f}/hr  "
          f"util={ca_metrics.utilization_pct:.1f}%  "
          f"over={ca_metrics.overprovision_pct:.0f}%  "
          f"types={ca_metrics.instance_diversity}")

    res = optimize(catalog, scenario, n_starts=6, use_bnb=args.bnb)
    m = res.metrics
    print(f"Convex optimization: ${m.total_cost:.3f}/hr  "
          f"util={m.utilization_pct:.1f}%  over={m.overprovision_pct:.0f}%  "
          f"types={m.instance_diversity}")
    save = 100 * (ca_metrics.total_cost - m.total_cost) / ca_metrics.total_cost
    print(f"\nsavings: {save:.1f}%")
    used = np.nonzero(res.counts)[0]
    print("chosen instances:")
    for j in used:
        it = catalog.instances[j]
        print(f"  {int(res.counts[j])} x {it.name:22s} "
              f"({it.cpu:.0f} vCPU, {it.mem_gb:.0f}GB, ${it.hourly_price}/hr)")


if __name__ == "__main__":
    main()

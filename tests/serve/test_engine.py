"""ServeEngine: tenant lifecycle over fixed batch lanes, mid-session
join/depart isolation, staleness accounting, and the enforced anytime
budget under an injectable clock (ISSUE tentpole, repro.serve)."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog
from repro.serve import ServeEngine

D0 = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def catalog():
    return Catalog(make_cloud_catalog().instances[::40])


def _fake_clock(step_ms=4.0):
    fake = SimpleNamespace(t=0.0)

    def clock():
        fake.t += step_ms / 1e3
        return fake.t

    return clock


def test_lifecycle_errors(catalog):
    eng = ServeEngine(catalog, 2)
    eng.register("a")
    with pytest.raises(ValueError, match="already registered"):
        eng.register("a")
    eng.register("b")
    with pytest.raises(ValueError, match="at capacity"):
        eng.register("c")
    with pytest.raises(KeyError, match="unknown tenant"):
        eng.submit("zz", D0)
    eng.depart("b")
    assert eng.tenants() == ["a"]
    with pytest.raises(ValueError):
        ServeEngine(catalog, 0)


@pytest.mark.slow
def test_departed_lane_is_reused_with_fresh_state(catalog):
    """A joiner reuses the departed tenant's lane (capacity conserved, no
    batch reshaping) but starts from a cold multistart solve — no warm
    state leaks across the tenancy change."""
    eng = ServeEngine(catalog, 2)
    lane_b = eng.register("b", demand=D0 * 0.5)
    eng.register("a", demand=D0)
    eng.tick()
    eng.depart("b")
    assert eng.register("c", demand=D0 * 0.7) == lane_b
    recs = eng.tick()
    rec_c = next(r for r in recs if r.tenant == "c")
    assert rec_c.cold and rec_c.staleness == 0
    assert eng.allocation("c") is not None


@pytest.mark.slow
def test_join_depart_does_not_perturb_other_lanes(catalog):
    """Mid-session churn isolation: tenant a's decisions must be
    bit-identical whether or not ANOTHER lane's tenancy changed —
    vmap lanes are independent and the compiled batch shape is fixed."""
    def session(churn: bool):
        eng = ServeEngine(catalog, 3)
        eng.register("a", demand=D0)
        eng.register("b", demand=D0 * 0.5)
        eng.tick()
        for t in range(3):
            if churn and t == 1:
                eng.depart("b")
                eng.register("c", demand=D0 * 0.8)
            eng.submit("a", D0 * (1.0 + 0.02 * (t + 1)))
            if "b" in eng.tenants():
                eng.submit("b", D0 * 0.5)
            eng.tick()
        return [s.counts for s in
                eng._lanes[eng._by_name["a"]].controller.history]

    plain, churned = session(False), session(True)
    assert len(plain) == len(churned) == 4
    for a, b in zip(plain, churned):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_staleness_counts_ticks_since_last_decision(catalog):
    eng = ServeEngine(catalog, 1)
    eng.register("a", demand=D0)
    eng.tick()                      # t=0: cold decision
    eng.tick()                      # t=1: no demand -> no decision
    eng.tick()                      # t=2: idle again
    eng.submit("a", D0 * 1.05)
    recs = eng.tick()               # t=3: decided after 3 idle ticks
    assert [r.staleness for r in recs] == [3]
    assert eng.summary().max_staleness == 3
    # idle ticks produce no records but still advance the counter
    assert eng.tick_count == 4 and len(eng.records) == 2


@pytest.mark.slow
def test_deadline_truncates_warm_solve_deterministically(catalog):
    """With the injectable clock burning 4ms per reading, a 10ms tick
    budget must truncate the warm batched solve: the decision reports
    ``deadline_hit`` with a small iteration count, and the engine's
    summary reports the truncation and miss rates."""
    eng = ServeEngine(catalog, 2, deadline_ms=10.0, chunk_iters=8,
                      clock=_fake_clock(4.0))
    eng.register("a", demand=D0)
    eng.tick()
    eng.submit("a", D0 * 1.5)
    recs = eng.tick()
    assert len(recs) == 1
    assert recs[0].deadline_hit
    assert 0 < recs[0].solver_iters <= 16
    s = eng.summary()
    assert s.truncated_rate == 0.5          # 1 of 2 decisions truncated
    assert s.miss_rate > 0                  # fake clock blows the budget
    assert s.deadline_ms == 10.0


@pytest.mark.slow
def test_no_deadline_serves_untruncated(catalog):
    eng = ServeEngine(catalog, 2)
    eng.register("a", demand=D0)
    eng.tick()
    eng.submit("a", D0 * 1.1)
    recs = eng.tick()
    assert not recs[0].deadline_hit
    assert eng.summary().truncated_rate == 0.0


@pytest.mark.slow
def test_health_monitor_observes_decisions(catalog):
    from repro.obs import HealthMonitor

    clock = _fake_clock(2.0)
    mon = HealthMonitor(deadline_ms=1.0, kkt_every=0, clock=clock)
    eng = ServeEngine(catalog, 2, clock=clock, health=mon)
    eng.register("a", demand=D0)
    eng.tick()                       # first sighting of the cold tick key
    eng.submit("a", D0 * 1.05)
    eng.tick()                       # first sighting of the warm tick key
    eng.submit("a", D0 * 1.1)
    eng.tick()                       # steady state: budgeted (and missed)
    rep = mon.report()
    assert rep.ticks_observed == 3
    assert rep.compile_excluded_ticks == 2
    assert rep.deadline_miss_ticks == 1


@pytest.mark.slow
def test_main_demo_runs(capsys):
    from repro.serve.__main__ import run_demo

    eng = run_demo(lanes=2, ticks=4, deadline_ms=None, verbose=True)
    out = capsys.readouterr().out
    assert "latency p50/p99" in out
    assert eng.summary().decisions > 0

"""Dry-run smoke: one fast cell must lower+compile on BOTH production meshes
in a subprocess (the 512-device XLA flag must not leak into this process).
Also validates the JSON record schema the roofline benchmark consumes."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cell_compiles(tmp_path, mesh):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-7b", "--shape", "decode_32k",
         "--mesh", mesh, "--out-dir", str(tmp_path)],
        capture_output=True, text=True, cwd=ROOT,
        env={**os.environ, "PYTHONPATH": "src"}, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    mesh_name = "16x16" if mesh == "single" else "2x16x16"
    rec = json.load(open(tmp_path / f"rwkv6-7b__decode_32k__{mesh_name}.json"))
    assert rec["status"] == "ok"
    assert rec["devices"] == (256 if mesh == "single" else 512)
    for key in ("flops", "bytes_accessed", "collective_bytes",
                "bytes_per_device", "roofline", "model_flops_per_device"):
        assert key in rec, key
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                           "collective_s")
    # an O(1)-state decode must fit comfortably
    assert rec["bytes_per_device"] < 16 * 2**30


def test_shape_skip_rules():
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, cell_applicable
    long = SHAPES["long_500k"]
    assert cell_applicable(get_config("rwkv6-7b"), long) is None
    assert cell_applicable(get_config("jamba-1.5-large-398b"), long) is None
    assert cell_applicable(get_config("mixtral-8x22b"), long) is None
    for arch in ("nemotron-4-15b", "qwen1.5-4b", "command-r-plus-104b",
                 "granite-34b", "llama4-maverick-400b-a17b",
                 "musicgen-medium", "internvl2-26b"):
        assert cell_applicable(get_config(arch), long) is not None
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in ("rwkv6-7b", "mixtral-8x22b"):
            assert cell_applicable(get_config(arch), SHAPES[shape]) is None


def test_input_specs_no_allocation():
    """input_specs must be pure ShapeDtypeStructs (never device arrays)."""
    import jax
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, input_specs
    cfg = get_config("mixtral-8x22b")
    for name, shape in SHAPES.items():
        specs = input_specs(cfg, shape)
        for leaf in jax.tree_util.tree_leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), (name, type(leaf))


def test_logical_rules_divisibility():
    """spec_for skips indivisible assignments, letting later dims claim the
    mesh axis (the mixtral-experts case)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 8), ("data", "model"))
        rules = shd.base_rules(mesh)
        # 6 experts do not divide 8 -> mlp gets the model axis instead
        spec = shd.spec_for(("expert", "embed", "mlp"), rules, mesh,
                            shape=(6, 64, 128))
        assert spec == P(None, "data", "model"), spec
        # 8 experts divide -> expert keeps it, mlp skipped
        spec = shd.spec_for(("expert", "embed", "mlp"), rules, mesh,
                            shape=(8, 64, 128))
        assert spec == P("model", "data", None), spec
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-1500:]
    assert "OK" in r.stdout

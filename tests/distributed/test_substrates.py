"""Substrate tests: data determinism/resharding, checkpoint atomicity +
restart, gradient compression numerics, straggler policies, supervisor
restart loop, elastic fleet replanning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_data_pipeline_deterministic_and_reshardable():
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=16, seed=7)
    ds = SyntheticLM(cfg)
    a = ds.global_batch(3)
    b = ds.global_batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # resharding invariance: 4 shards of the step == the global batch
    parts = [ds.shard_batch(3, s, 4)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), a["tokens"])
    # different num_shards sees the same stream
    parts2 = [ds.shard_batch(3, s, 2)["tokens"] for s in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts2), a["tokens"])
    # labels are next tokens
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_checkpoint_roundtrip_and_restart(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "opt": {"m": np.ones(5), "step": np.asarray(7)}}
    ckpt.save(str(tmp_path), 10, tree, extra={"loss": 1.5})
    tree2 = {k: (jax.tree_util.tree_map(np.zeros_like, v) if isinstance(v, dict)
                 else np.zeros_like(v)) for k, v in tree.items()}
    step, loaded, extra = ckpt.load_latest(str(tmp_path), tree2)
    assert step == 10 and extra["loss"] == 1.5
    np.testing.assert_array_equal(loaded["w"], tree["w"])
    # newer checkpoint wins
    ckpt.save(str(tmp_path), 20, tree)
    step, _, _ = ckpt.load_latest(str(tmp_path), tree2)
    assert step == 20
    # uncommitted (partial) checkpoints are ignored
    os.makedirs(tmp_path / "step_00000030", exist_ok=True)
    step, _, _ = ckpt.load_latest(str(tmp_path), tree2)
    assert step == 20


def test_async_checkpointer(tmp_path):
    from repro.checkpoint.checkpoint import AsyncCheckpointer, load_latest
    ac = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": np.ones((4, 4), np.float32)}
    for s in (1, 2, 3):
        ac.save(s, {"w": tree["w"] * s})
    ac.wait()
    step, loaded, _ = load_latest(str(tmp_path), tree)
    assert step == 3
    np.testing.assert_array_equal(loaded["w"], tree["w"] * 3)
    # GC kept only 2
    assert len([d for d in os.listdir(tmp_path) if d.startswith("step_")]) == 2


def test_grad_compression_error_feedback_converges():
    """Compressed channel with error feedback: the RUNNING SUM of
    dequantized grads tracks the running sum of true grads (unbiasedness)."""
    from repro.optim.grad_compress import compress_decompress
    rng = np.random.default_rng(0)
    g_true_sum = np.zeros(300, np.float32)
    g_seen_sum = np.zeros(300, np.float32)
    err = jnp.zeros(300, jnp.float32)
    for t in range(50):
        g = jnp.asarray(rng.normal(0, 1, 300), jnp.float32)
        deq, err = compress_decompress(g, err)
        g_true_sum += np.asarray(g)
        g_seen_sum += np.asarray(deq)
    # residual bounded by one quantization step, not growing with t
    resid = np.abs(g_true_sum - g_seen_sum).max()
    assert resid <= np.abs(np.asarray(err)).max() + 1e-5
    assert resid < 0.2


@pytest.mark.slow
def test_compressed_psum_multidevice_subprocess():
    """Real psum over 4 host devices in a child process (tests must not
    force device count in THIS process)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.grad_compress import compressed_psum
        from repro.launch.mesh import make_mesh
        from repro.distributed.sharding import shard_map_compat
        mesh = make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1, (4, 256)), jnp.float32)
        err0 = jnp.zeros((4, 256), jnp.float32)
        def f(g, e):
            out, err = compressed_psum(g, e, "data")
            return out, err
        fm = shard_map_compat(f, mesh=mesh, in_specs=(P("data"), P("data")),
                              out_specs=(P("data"), P("data")))
        out, err = fm(g, err0)
        true = np.asarray(g).sum(0)
        got = np.asarray(out)[0]
        rel = np.abs(got - true).max() / (np.abs(true).max() + 1e-9)
        assert rel < 0.05, rel
        print("OK", rel)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__)))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_straggler_policies():
    from repro.distributed.fault_tolerance import (StragglerMonitor,
                                                   simulate_step_times)
    rng = np.random.default_rng(1)
    mon_wait = StragglerMonitor(n_workers=16, policy="wait")
    mon_dead = StragglerMonitor(n_workers=16, policy="deadline")
    t_wait = t_dead = 0.0
    for _ in range(50):
        times = simulate_step_times(rng, 16, straggle_prob=0.08)
        t_wait += mon_wait.effective_step_time(times)
        t_dead += mon_dead.effective_step_time(times)
    # deadline policy must beat synchronous waiting under stragglers
    assert t_dead < t_wait
    plan = mon_dead.plan(np.array([1.0] * 15 + [50.0]))
    assert plan["included"].sum() == 15
    assert abs(plan["renorm"] - 16 / 15) < 1e-9


def test_supervisor_restart_loop(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    from repro.distributed.fault_tolerance import (SupervisorConfig,
                                                   TrainingSupervisor)
    state = {"w": np.zeros(4, np.float32)}
    fails = {"n": 0}

    def train_fn(start_step, num_shards):
        step = start_step
        while step < 60:
            step += 1
            state["w"] += 1.0
            if step % 20 == 0:
                ckpt.save(str(tmp_path), step, state)
            if step == 33 and fails["n"] == 0:
                fails["n"] += 1
                raise RuntimeError("host_down")
        return step

    sup = TrainingSupervisor(SupervisorConfig(), str(tmp_path))
    final = sup.run(train_fn, total_steps=60, initial_shards=4)
    assert final == 60
    assert sup.restarts == 1
    assert sup.events[0].step == 20  # resume point = last committed ckpt


def test_elastic_fleet_replans():
    from repro.core.workloads import JobSpec
    from repro.distributed.elastic import ElasticFleet
    job = JobSpec(name="train-104b", hlo_flops=2.5e16, hlo_bytes=1e14,
                  collective_bytes=5e12, bytes_per_device=8e9, devices=256,
                  step_budget_s=1.0)
    fleet = ElasticFleet(job, delta_max=64.0)
    plan = fleet.initial_plan()
    assert plan.total_chips >= 64          # compute demand needs real chips
    assert plan.cost_per_hour > 0
    # kill 30% of the fleet -> replan restores capacity
    failed = np.ceil(fleet.controller.x_current * 0.3)
    plan2 = fleet.replan_after_failure(failed)
    assert plan2.total_chips >= plan.total_chips * 0.6
    assert plan2.mesh_shape[1] == 16


@pytest.mark.slow
def test_pipeline_parallel_subprocess():
    """GPipe schedule on a 4-stage host-device mesh matches sequential."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline_parallel import pipeline_apply
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("pipe",))
        rng = np.random.default_rng(0)
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        Ws = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (n_micro, mb, d)), jnp.float32)
        def stage(W, x):
            return jnp.tanh(x @ W)
        out = pipeline_apply(stage, Ws, x, mesh=mesh, n_stages=n_stages)
        # sequential reference
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ Ws[s])
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__)))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout

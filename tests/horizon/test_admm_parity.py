"""ADMM horizon engine: parity and certificate battery.

The consensus-ADMM engine (``repro.horizon.admm``) is a second, structurally
different solver for the SAME time-expanded program the adaptive engine
minimizes monolithically. That redundancy is the test asset: every property
here pins ADMM against an independent implementation path, so a bug in
either engine breaks an equivalence instead of shifting a benchmark number.

The battery, in order of strictness:

* equal-budget objective parity — at matched per-tick compute
  (``admm_iters * inner_steps == steps``) the two engines land within a
  bounded relative merit gap of each other on random windows across
  H ∈ {4, 8, 16}.  (Measured: ADMM is typically a few percent BETTER;
  the bound only needs to catch divergence/sign bugs, which blow past it
  by an order of magnitude.)
* committed-tick agreement — after ``round_committed`` the plans agree to
  integer rounding granularity (measured: exactly; asserted: L-inf <= 1).
* residual certificates — the ``ADMMTrace`` primal/dual residual
  trajectories actually decrease to tolerance and agree with the final
  ``ADMMDiag`` certificate.
* batched ≡ sequential — the vmapped fleet step reproduces sequential
  per-lane solves BIT-exactly on a ragged mixed-catalog fleet (the
  branch-free z-update exists precisely to keep this contract; a
  line-searched z-update breaks it in the last ulps).
* H=1 reduction — a one-tick window has no coupling to split on, so the
  admm config must reproduce ``solve_incremental`` bit-for-bit.
* replay reachability — ``solver="admm"`` must be drivable end-to-end from
  ``replay_fleet`` in BOTH replay engines (pins the config-plumbed-but-
  unreachable bug class) and must surface ``ADMMTrace`` captures there.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog, solve_incremental
from repro.fleet import TenantSpec, replay_fleet
from repro.fleet.traces import diurnal_trace, ramp_trace
from repro.horizon import (ADMMDiag, ADMMTrace, HorizonProblem,
                           HorizonSolverConfig, admm_residual_history,
                           expand_problems, round_committed,
                           solve_horizon_fleet_step, solve_horizon_info)
from repro.horizon.problem import tick_problem
from repro.horizon.solver import _horizon_merit_fns
from repro.obs import admm_trace_summary
from repro.testing import make_toy_problem

# Equal per-tick compute: the adaptive engine gets `steps` iterations on the
# monolithic (H, n) program; ADMM spends admm_iters outer sweeps of
# inner_steps prox iterations on every tick block (vmapped), so the per-tick
# budgets match at admm_iters * inner_steps == steps.
ADAPTIVE = HorizonSolverConfig(solver="adaptive", steps=600)
ADMM = HorizonSolverConfig(solver="admm", admm_iters=30, inner_steps=20)
assert ADMM.admm_iters * ADMM.inner_steps == ADAPTIVE.steps

SEEDS = (0, 1, 2)
DELTA = 8.0


def _window(seed: int, H: int):
    """A demand-varied lookahead window of random per-tick catalogs."""
    return expand_problems([make_toy_problem(seed=seed + 3 * h,
                                             demand_scale=1.0 + 0.05 * h)
                            for h in range(H)])


def _solve_pair(seed: int, H: int, trace: bool = False):
    hp = _window(seed, H)
    xc = jnp.full(hp.problem.c.shape[1], 1.0, jnp.float32)
    ra = solve_horizon_info(hp, xc, DELTA, cfg=ADAPTIVE)
    rm = solve_horizon_info(hp, xc, DELTA, cfg=ADMM, capture_trace=trace)
    return hp, xc, ra, rm


@st.composite
def _window_cases(draw):
    """Composite strategy: a random-catalog window spec (seed, H) — seeds
    span the measured toy-catalog pool, H the satellite's {4, 8, 16}."""
    return draw(st.integers(0, 2)), draw(st.sampled_from((4, 8, 16)))


@settings(max_examples=4)
@given(case=_window_cases())
def test_equal_budget_objective_parity(case):
    """At matched compute, ADMM's window merit lands within a bounded
    relative gap of the adaptive engine's (measured ~[-0.08, -0.02]: the
    splitting is typically BETTER; the bound catches divergence, which
    overshoots it tenfold)."""
    seed, H = case
    hp, xc, ra, rm = _solve_pair(seed, H)
    merit, _, _ = _horizon_merit_fns(hp, xc,
                                     jnp.asarray(DELTA, jnp.float32),
                                     ADAPTIVE.penalty_w,
                                     ADAPTIVE.delta_penalty_w)
    Ja, Jm = float(merit(ra.plan)), float(merit(rm.plan))
    rel = (Jm - Ja) / (1.0 + abs(Ja))
    assert abs(rel) <= 0.15, (H, seed, Ja, Jm, rel)


@pytest.mark.parametrize("H", [4, 8, 16])
def test_committed_ints_match_to_rounding_granularity(H):
    """The committed (rounded) tick agrees across engines within rounding
    granularity — measured exactly equal; one unit of slack tolerated for
    knife-edge rounding ties."""
    for seed in SEEDS:
        hp, _, ra, rm = _solve_pair(seed, H)
        p0 = tick_problem(hp, 0)
        ia = round_committed(p0, ra.plan[0], True)
        im = round_committed(p0, rm.plan[0], True)
        assert int(jnp.max(jnp.abs(ia - im))) <= 1, (H, seed, ia, im)


@pytest.mark.parametrize("H", [4, 8, 16])
def test_residuals_decrease_and_match_diag(H):
    """The ADMMTrace residual trajectories must actually certify
    convergence: both residuals end well below where they start (measured
    >= 20x drop; asserted 4x), and the trace's final row IS the ADMMDiag
    certificate the untraced path gauges."""
    for seed in SEEDS:
        _, _, _, rm = _solve_pair(seed, H, trace=True)
        assert isinstance(rm.trace, ADMMTrace)
        assert isinstance(rm.diag, ADMMDiag)
        primal, dual = admm_residual_history(rm.trace)
        assert primal.shape[0] == int(rm.diag.admm_iters)
        assert primal[-1] <= 0.25 * primal[0], (H, seed, primal)
        assert dual[-1] <= 0.25 * dual[0], (H, seed, dual)
        assert np.isclose(primal[-1], float(rm.diag.primal_res), atol=1e-6)
        assert np.isclose(dual[-1], float(rm.diag.dual_res), atol=1e-6)
        s = admm_trace_summary(rm.trace)
        assert s["admm_iters"] == int(rm.diag.admm_iters)
        assert s["inner_total"] > 0


@pytest.mark.parametrize("delta_max", [1e3, 6.0])
def test_batched_matches_sequential_on_ragged_fleet(delta_max):
    """The vmapped fleet step reproduces sequential per-lane ADMM solves
    BIT-exactly (plans AND rounded commits) on a mixed-catalog fleet with a
    frozen (ragged-trace) lane — with both a slack and a binding churn
    bound. This is the contract the branch-free z-update buys: any
    data-dependent accept/reject in the consensus update would bifurcate on
    batched-vs-sequential ulp noise and break exact equality."""
    lane_seeds = [[5, 9, 2, 7], [13, 4, 19, 8], [1, 3, 18, 27]]
    lanes = [expand_problems([make_toy_problem(seed=s) for s in ss])
             for ss in lane_seeds]
    n = lanes[0].problem.c.shape[1]
    xc = jnp.stack([jnp.full(n, float(i), jnp.float32) for i in range(3)])
    active = np.array([True, False, True])
    batched = HorizonProblem(
        jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                               *(l.problem for l in lanes)),
        lanes[0].coupling_w, lanes[0].coupling_eps)
    fr = solve_horizon_fleet_step(batched, xc, delta_max, active=active,
                                  cfg=ADMM)
    assert isinstance(fr.diag, ADMMDiag)
    for i, l in enumerate(lanes):
        if not active[i]:
            np.testing.assert_array_equal(np.asarray(fr.x_int[i]),
                                          np.asarray(xc[i]))
            assert int(fr.iters[i]) == 0
            continue
        sq = solve_horizon_info(l, xc[i], delta_max, cfg=ADMM)
        np.testing.assert_array_equal(np.asarray(fr.plan[i]),
                                      np.asarray(sq.plan))
        xi = round_committed(tick_problem(l, 0), sq.plan[0], True)
        np.testing.assert_array_equal(np.asarray(fr.x_int[i]),
                                      np.asarray(xi))


def test_h1_reduces_to_solve_incremental():
    """A one-tick window has nothing to split: solver='admm' at H=1 must be
    solve_incremental bit-for-bit (same merit triple, same engine), with no
    residual certificate to report."""
    for seed in (5, 13):
        prob = make_toy_problem(seed=seed)
        hp = expand_problems([prob])
        xc = jnp.full(prob.n, 1.0, jnp.float32)
        r = solve_horizon_info(hp, xc, 6.0, cfg=ADMM)
        x_myo = solve_incremental(prob, xc, 6.0)
        np.testing.assert_array_equal(np.asarray(r.plan[0]),
                                      np.asarray(x_myo))
        assert r.diag is None


@pytest.mark.slow
def test_admm_reachable_from_replay_fleet_both_engines():
    """Pins the config-plumbed-but-unreachable bug class: an MPC replay
    configured with solver='admm' must actually run the ADMM engine in BOTH
    replay engines — proven by the ADMMTrace captures coming back — and the
    two engines must still agree on every committed integer allocation."""
    cat = Catalog(make_cloud_catalog().instances[::40])
    base = np.array([8.0, 16.0, 4.0, 100.0])
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(base, 4, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(base * 0.5, 3, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   delta_max=4.0),
    ]
    kw = dict(run_ca_baseline=False, controller="mpc", horizon=3,
              forecaster="last_value", solver_config=ADMM,
              capture_solver_trace=True)
    seq = replay_fleet(cat, specs, replay_mode="sequential", **kw)
    bat = replay_fleet(cat, specs, replay_mode="batched", **kw)
    for out in (seq, bat):
        assert out.solver_traces is not None
        warm = [tr for traces in out.solver_traces for tr in traces]
        assert warm, "no warm-tick solver traces captured"
        assert all(isinstance(tr, ADMMTrace) for tr in warm), (
            "replay ran a different engine than solver_config asked for")
        # every captured trace certifies a converging solve
        for tr in warm:
            primal, dual = admm_residual_history(tr)
            assert primal.shape[0] >= 1
            assert primal[-1] <= primal[0] + 1e-6
    for rs, rb in zip(seq.tenants, bat.tenants):
        for ss, sb in zip(rs.steps, rb.steps):
            np.testing.assert_array_equal(ss.counts, sb.counts)

"""Time-expanded program invariants.

Property-style tests run through the deterministic ``repro.testing`` shim
when the image lacks hypothesis."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.objective as obj
from repro.horizon import (churn_bound_grad, churn_bound_penalty,
                           coupling_grad, coupling_penalty, expand_problems,
                           horizon_objective, horizon_objective_terms,
                           tick_problem)
from repro.testing import make_toy_problem


def _window(seed, H, n=10, m=3):
    """H same-shape per-tick problems with different demands (what a real
    lookahead window looks like: one catalog, drifting demand)."""
    return [make_toy_problem(seed=seed + h, n=n, m=m) for h in range(H)]


@settings(max_examples=8)
@given(seed=st.integers(0, 10_000), H=st.integers(1, 6))
def test_zero_coupling_decouples_into_per_tick_objectives(seed, H):
    """Satellite acceptance: with coupling_w == 0 the time-expanded
    objective equals the SUM of per-tick core.objective.objective values —
    the program decouples exactly."""
    probs = _window(seed, H)
    hp = expand_problems(probs, coupling_w=0.0)
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(0.0, 5.0, size=(H, probs[0].n)), jnp.float32)
    total = float(horizon_objective(hp, X))
    per_tick = sum(float(obj.objective(pb, X[h]))
                   for h, pb in enumerate(probs))
    np.testing.assert_allclose(total, per_tick, rtol=1e-6)


@settings(max_examples=6)
@given(seed=st.integers(0, 10_000), H=st.integers(2, 5))
def test_coupling_grad_matches_autodiff(seed, H):
    """The hand-written smoothed-|.| coupling gradient must agree with
    jax.grad of the penalty."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(H, 7)), jnp.float32)
    w, eps = jnp.asarray(0.3, jnp.float32), jnp.asarray(1e-4, jnp.float32)
    g_auto = jax.grad(lambda x: coupling_penalty(x, w, eps))(X)
    np.testing.assert_allclose(np.asarray(coupling_grad(X, w, eps)),
                               np.asarray(g_auto), rtol=1e-4, atol=1e-6)


@settings(max_examples=6)
@given(seed=st.integers(0, 10_000), H=st.integers(2, 5))
def test_churn_bound_grad_matches_autodiff(seed, H):
    """The soft churn-bound hinge gradient must agree with jax.grad."""
    rng = np.random.default_rng(seed)
    # large moves so some transitions exceed the bound and some don't
    X = jnp.asarray(rng.normal(scale=3.0, size=(H, 7)), jnp.float32)
    delta, w, eps = (jnp.asarray(4.0, jnp.float32),
                     jnp.asarray(5.0, jnp.float32),
                     jnp.asarray(1e-4, jnp.float32))
    g_auto = jax.grad(lambda x: churn_bound_penalty(x, delta, w, eps))(X)
    np.testing.assert_allclose(np.asarray(churn_bound_grad(X, delta, w, eps)),
                               np.asarray(g_auto), rtol=1e-3, atol=1e-4)


def test_churn_bound_inactive_within_budget():
    """Transitions within delta_max contribute nothing (hinge inactive)."""
    X = jnp.asarray([[0.0] * 5, [0.5] * 5], jnp.float32)   # churn 2.5 < 4
    assert float(churn_bound_penalty(X, 4.0, 10.0, 1e-6)) < 1e-4
    assert float(jnp.abs(churn_bound_grad(X, 4.0, 10.0, 1e-6)).max()) == 0.0


def test_coupling_vanishes_on_constant_plan():
    # s(0) = 0 exactly (the smoothing floor is subtracted)
    X = jnp.ones((4, 6)) * 3.0
    assert float(coupling_penalty(X, 1.0, 1e-6)) == 0.0


def test_expand_problems_padding_is_exact():
    """Padding a window up to bucket dims (as the batched fleet replay does)
    must not change the objective of an embedded plan."""
    probs = _window(7, 3, n=10, m=3)
    hp = expand_problems(probs, coupling_w=0.2)
    hp_pad = expand_problems(probs, coupling_w=0.2, n_max=16, m_max=4,
                             p_max=4)
    rng = np.random.default_rng(0)
    X = rng.uniform(0.0, 4.0, size=(3, 10)).astype(np.float32)
    X_pad = np.zeros((3, 16), np.float32)
    X_pad[:, :10] = X
    np.testing.assert_allclose(
        float(horizon_objective(hp, jnp.asarray(X))),
        float(horizon_objective(hp_pad, jnp.asarray(X_pad))), rtol=1e-6)


def test_tick_problem_round_trip():
    probs = _window(3, 4)
    hp = expand_problems(probs)
    for h, pb in enumerate(probs):
        back = tick_problem(hp, h)
        np.testing.assert_array_equal(np.asarray(back.K), np.asarray(pb.K))
        np.testing.assert_array_equal(np.asarray(back.d), np.asarray(pb.d))


def test_objective_terms_split():
    probs = _window(11, 3)
    hp = expand_problems(probs, coupling_w=0.5)
    X = jnp.ones((3, probs[0].n))
    terms = horizon_objective_terms(hp, X)
    assert terms["per_tick"].shape == (3,)
    np.testing.assert_allclose(
        float(jnp.sum(terms["per_tick"]) + terms["coupling"]),
        float(horizon_objective(hp, X)), rtol=1e-6)


@settings(max_examples=6)
@given(seed=st.integers(0, 10_000), H=st.integers(2, 5))
def test_commit_coupling_grad_matches_autodiff(seed, H):
    """The committed transition's churn-price gradient (only row 0 moves;
    x_current is a constant) must agree with jax.grad."""
    from repro.horizon import commit_coupling_grad, commit_coupling_penalty
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(H, 7)), jnp.float32)
    xc = jnp.asarray(rng.normal(size=(7,)), jnp.float32)
    w, eps = jnp.asarray(0.3, jnp.float32), jnp.asarray(1e-4, jnp.float32)
    g_auto = jax.grad(lambda x: commit_coupling_penalty(x, xc, w, eps))(X)
    np.testing.assert_allclose(
        np.asarray(commit_coupling_grad(X, xc, w, eps)),
        np.asarray(g_auto), rtol=1e-4, atol=1e-6)


def test_commit_coupling_vanishes_when_committed_row_holds():
    """No committed movement -> no price (s(0) = 0 exactly), regardless of
    what the planned rows do."""
    from repro.horizon import commit_coupling_grad, commit_coupling_penalty
    xc = jnp.asarray([2.0, 3.0, 1.0])
    X = jnp.stack([xc, xc * 4.0, xc * 0.5])
    assert float(commit_coupling_penalty(X, xc, 1.0, 1e-6)) == 0.0
    g = commit_coupling_grad(X, xc, 1.0, 1e-6)
    assert float(jnp.abs(g[0]).max()) == 0.0
    assert float(jnp.abs(g[1:]).max()) == 0.0      # planned rows untouched

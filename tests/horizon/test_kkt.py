"""KKT optimality of horizon solutions (committed tick).

Until now the horizon solver was tested only through EQUIVALENCES (H=1 ≡
myopic, batched ≡ sequential) — nothing certified that the committed tick
is actually near-optimal. These tests reuse ``repro.core.kkt`` residual
recovery on tick 0 of ``solve_horizon`` output:

* H=1 with a slack churn bound — the committed tick solves the plain
  per-tick problem over its box, so the recovered multipliers must drive
  all four KKT residual groups to ~solver tolerance (the same certificate
  ``tests/core/test_solver.py`` demands of ``solve_relaxation``).
* H=4 — the committed tick trades the per-tick gradient against the
  coupling/churn-bound forces of the lookahead, so exact tick-0
  stationarity is NOT expected; the residual must stay bounded by those
  forces' scale, while primal feasibility stays tight (lookahead never
  buys the right to violate today's constraints).
"""
import jax.numpy as jnp

from repro.core import kkt_report, objective_value, solve_incremental
from repro.horizon import HorizonSolverConfig, expand_problems, solve_horizon
from repro.testing import make_toy_problem

# churn bound slack enough to never bind on these toy scales: tick 0 is
# then the unconstrained-in-churn per-tick problem (box only)
SLACK_DELTA = 1e3
CFG = HorizonSolverConfig(steps=1200, tol=1e-7)


def _window(seed: int, H: int):
    return [make_toy_problem(seed=seed + 3 * h,
                             demand_scale=1.0 + 0.05 * h) for h in range(H)]


def _committed_report(seed: int, H: int, coupling_w: float):
    probs = _window(seed, H)
    hp = expand_problems(probs, coupling_w=coupling_w)
    x_cur = jnp.full(probs[0].n, 1.0, jnp.float32)
    X = solve_horizon(hp, x_cur, SLACK_DELTA, cfg=CFG)
    return probs[0], kkt_report(probs[0], X[0])


def test_h1_committed_tick_is_kkt_stationary():
    """H=1, slack churn ball: the committed tick must carry a near-exact
    KKT certificate for its own per-tick problem."""
    for seed in (0, 1, 5):
        p0, rep = _committed_report(seed, H=1, coupling_w=0.05)
        scale = float(jnp.max(jnp.abs(p0.c))) + 1.0
        assert float(rep.stationarity) <= 0.25 * scale, (seed, rep)
        # band violations stay at rounding-acceptance scale, boxes exact
        assert float(rep.primal_lo) <= 0.05
        assert float(rep.primal_hi) <= 0.05
        assert float(rep.primal_box) <= 1e-5
        assert float(rep.dual) <= 1e-6
        assert float(rep.comp_slack) <= 0.05


def test_h4_committed_tick_stationarity_bounded_by_lookahead_forces():
    """H=4: the committed tick balances its own gradient against the
    coupling pull of the plan, so its single-tick stationarity residual is
    nonzero but must stay bounded by the lookahead forces' scale — and
    primal feasibility must stay as tight as at H=1."""
    for seed in (0, 1, 5):
        p0, rep = _committed_report(seed, H=4, coupling_w=0.05)
        scale = float(jnp.max(jnp.abs(p0.c))) + 1.0
        assert float(rep.stationarity) <= 0.6 * scale, (seed, rep)
        assert float(rep.primal_lo) <= 0.05
        assert float(rep.primal_hi) <= 0.05
        assert float(rep.primal_box) <= 1e-5
        assert float(rep.dual) <= 1e-6
        assert float(rep.comp_slack) <= 0.05


def test_h4_zero_coupling_recovers_h1_certificate():
    """With every lookahead force switched off — coupling, soft churn
    bound AND planned band penalty — the H=4 committed tick is the H=1
    problem again: its KKT certificate must tighten back to the H=1 bound
    (the decoupling property, seen through optimality instead of objective
    values). The band penalty must be off too: its 1e3-stiff curvature on
    planned rows would otherwise dominate the SHARED BB step and starve
    tick 0 of step size."""
    for seed in (0, 5):
        probs = _window(seed, 4)
        hp = expand_problems(probs, coupling_w=0.0)
        x_cur = jnp.full(probs[0].n, 1.0, jnp.float32)
        X = solve_horizon(hp, x_cur, SLACK_DELTA,
                          cfg=CFG._replace(delta_penalty_w=0.0,
                                           penalty_w=0.0))
        rep = kkt_report(probs[0], X[0])
        scale = float(jnp.max(jnp.abs(probs[0].c))) + 1.0
        assert float(rep.stationarity) <= 0.3 * scale, (seed, rep)
        assert float(rep.primal_lo) <= 0.05
        assert float(rep.primal_hi) <= 0.05


# ---------------------------------------------------------------------------
# ADMM engine: the same certificates through the operator-splitting path
# ---------------------------------------------------------------------------

# equal per-tick compute to CFG's 1200-step budget: 60 outer sweeps of
# 20-iteration prox blocks
ADMM_CFG = HorizonSolverConfig(solver="admm", admm_iters=60, inner_steps=20)


def test_admm_h4_committed_tick_stationarity_bounded():
    """The ADMM committed tick must carry the SAME certificate the adaptive
    H=4 test demands: stationarity bounded by the lookahead forces' scale,
    primal feasibility tight. The committed block is an exact
    ``project_incremental`` prox, so feasibility comes out at least as
    tight as the monolithic engine's (measured: stationarity ~5x inside
    the bound)."""
    for seed in (0, 1, 5):
        probs = _window(seed, 4)
        hp = expand_problems(probs, coupling_w=0.05)
        x_cur = jnp.full(probs[0].n, 1.0, jnp.float32)
        X = solve_horizon(hp, x_cur, SLACK_DELTA, cfg=ADMM_CFG)
        rep = kkt_report(probs[0], X[0])
        scale = float(jnp.max(jnp.abs(probs[0].c))) + 1.0
        assert float(rep.stationarity) <= 0.6 * scale, (seed, rep)
        assert float(rep.primal_lo) <= 0.05
        assert float(rep.primal_hi) <= 0.05
        assert float(rep.primal_box) <= 1e-5
        assert float(rep.dual) <= 1e-6
        assert float(rep.comp_slack) <= 0.05


def test_admm_zero_coupling_converges_to_per_tick_optima():
    """With the coupling switched off the splitting is degenerate: g == 0,
    consensus is trivially satisfied (the z-update is a single exact step),
    and each outer iteration is a proximal-point step on its own tick. ADMM
    must then land on the per-tick optima — checked against independent
    myopic ``solve_incremental`` solves of each tick.

    The per-outer movement of a proximal-point step is ~|grad f|/rho, so
    exactness needs a small rho and ticks whose solo problems PGD actually
    closes (seeds chosen so the myopic reference converges in < 50
    iterations; stiff seeds crawl for thousands in EVERY engine and certify
    nothing). Band penalty off as in the adaptive zero-coupling test."""
    seeds = [1, 3, 18, 27]
    hp = expand_problems([make_toy_problem(seed=s) for s in seeds],
                         coupling_w=0.0)
    x_cur = jnp.zeros(hp.problem.c.shape[1], jnp.float32)
    cfg = ADMM_CFG._replace(rho=0.02, admm_iters=40, inner_steps=25,
                            penalty_w=0.0, delta_penalty_w=0.0, admm_tol=0.0)
    X = solve_horizon(hp, x_cur, SLACK_DELTA, cfg=cfg)
    for h, s in enumerate(seeds):
        prob = make_toy_problem(seed=s)
        x_ref = solve_incremental(prob, x_cur, SLACK_DELTA)
        J_admm = float(objective_value(prob, X[h]))
        J_ref = float(objective_value(prob, x_ref))
        # measured: gap <= 1e-4, allocations within 3e-3
        assert J_admm <= J_ref + 1e-3, (h, s, J_admm, J_ref)
        assert float(jnp.max(jnp.abs(X[h] - x_ref))) <= 0.05, (h, s)

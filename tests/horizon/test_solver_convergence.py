"""Adaptive (BB/Armijo) vs fixed-step horizon solver: convergence contract.

The tentpole's speedup claim, pinned as tests so regressions fail loudly:

* at the SAME iteration budget the adaptive engine's horizon merit is never
  worse than the fixed-step engine's (property-swept across random catalogs
  and H ∈ {4, 8, 16} through the ``repro.testing`` shim's ``sampled_from``);
* on at least the median draw the adaptive engine reaches the fixed-step
  engine's FINAL merit in at most HALF the iterations;
* iterations-to-tolerance are recorded and bounded: a warm-started re-solve
  (the MPC steady state — the plan barely moves tick to tick) must
  early-stop far under the budget instead of burning all of it.

Merit here is the full relaxed time-expanded objective the solver actually
minimizes (per-tick eq.(1) + coupling + churn bound + planned band
penalty), evaluated by the SAME ``_horizon_merit_fns`` triple both engines
share — so the comparison cannot drift from the implementation.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import pytest
import jax.numpy as jnp
import numpy as np

from repro.horizon import HorizonSolverConfig, expand_problems, solve_horizon_info
from repro.horizon.solver import _horizon_merit_fns
from repro.testing import make_toy_problem

BUDGET = 300           # fixed-step budget per draw (and the adaptive cap)
DELTA = 6.0


def _window(seed: int, H: int):
    """An H-tick lookahead of same-shape toy problems with drifting demand
    (what a real forecaster window looks like: one catalog, demand moving)."""
    return [make_toy_problem(seed=seed + 3 * h,
                             demand_scale=1.0 + 0.08 * h) for h in range(H)]


def _merit(hp, x_cur, X) -> float:
    value, _, _ = _horizon_merit_fns(hp, x_cur, jnp.asarray(DELTA, jnp.float32),
                                     HorizonSolverConfig().penalty_w,
                                     HorizonSolverConfig().delta_penalty_w)
    return float(value(X))


def _solve(hp, x_cur, **cfg_kw):
    return solve_horizon_info(hp, x_cur, DELTA,
                              cfg=HorizonSolverConfig(**cfg_kw))


@pytest.mark.slow
@settings(max_examples=6)
@given(seed=st.integers(0, 10_000), H=st.sampled_from((4, 8, 16)))
def test_adaptive_no_worse_than_fixed_at_same_budget(seed, H):
    """Same budget, same merit function, same warm start: the adaptive
    engine must end at a merit <= the fixed-step engine's."""
    probs = _window(seed, H)
    hp = expand_problems(probs)
    x_cur = jnp.full(probs[0].n, 1.0, jnp.float32)
    ra = _solve(hp, x_cur, solver="adaptive", steps=BUDGET)
    rf = _solve(hp, x_cur, solver="fixed", steps=BUDGET)
    ma, mf = _merit(hp, x_cur, ra.plan), _merit(hp, x_cur, rf.plan)
    assert ma <= mf * 1.001 + 1e-4, (ma, mf)
    assert int(ra.iters) <= BUDGET


@pytest.mark.slow
def test_adaptive_half_budget_beats_fixed_final_on_median_draw():
    """ISSUE acceptance: the adaptive engine reaches the fixed-step
    engine's FINAL merit in <= half the iterations on at least the median
    draw, for every H in the sweep. (The Armijo ladder makes each accepted
    adaptive iterate monotone in merit, so comparing the half-budget
    iterate against the fixed final merit IS the iterations-to-merit
    question.)"""
    for H in (4, 8, 16):
        wins = []
        records = []
        for seed in (0, 11, 23, 37, 41):
            probs = _window(seed, H)
            hp = expand_problems(probs)
            x_cur = jnp.full(probs[0].n, 1.0, jnp.float32)
            rf = _solve(hp, x_cur, solver="fixed", steps=BUDGET)
            ra = _solve(hp, x_cur, solver="adaptive", steps=BUDGET // 2)
            ma, mf = _merit(hp, x_cur, ra.plan), _merit(hp, x_cur, rf.plan)
            wins.append(ma <= mf * 1.001 + 1e-4)
            records.append((seed, int(ra.iters), round(ma, 3), round(mf, 3)))
        # median draw or better: at least half the draws must win
        assert sum(wins) * 2 >= len(wins), (H, records)


def test_warm_started_resolve_early_stops():
    """Iterations-to-tolerance, recorded: repeatedly re-solving from the
    previous solution (the MPC steady state — each restart is a tick whose
    plan barely moves) must reach a fixpoint where the engine early-stops
    far under the budget, instead of burning the full budget every tick the
    way the fixed engine does. (The first restarts may still find real
    progress — a fresh BB step escapes plateaus — so the bound is on the
    settled state, monotonicity on every restart.)"""
    for H in (4, 8):
        probs = _window(5, H)
        hp = expand_problems(probs)
        x_cur = jnp.full(probs[0].n, 1.0, jnp.float32)
        res = _solve(hp, x_cur, solver="adaptive", steps=600)
        merit_prev = _merit(hp, x_cur, res.plan)
        for _ in range(3):
            res = solve_horizon_info(hp, x_cur, DELTA, x_init=res.plan,
                                     cfg=HorizonSolverConfig(steps=600))
            merit = _merit(hp, x_cur, res.plan)
            assert merit <= merit_prev * 1.001 + 1e-4   # never spoils
            merit_prev = merit
        assert int(res.iters) <= 150, (H, int(res.iters))


def test_iters_reporting_contract():
    """The reported iteration count is the engine's actual effort: the
    fixed engine always bills its full budget, the adaptive engine never
    exceeds it, and a zero-budget adaptive solve reports zero."""
    probs = _window(2, 4)
    hp = expand_problems(probs)
    x_cur = jnp.full(probs[0].n, 1.0, jnp.float32)
    rf = _solve(hp, x_cur, solver="fixed", steps=40)
    assert int(rf.iters) == 40
    ra = _solve(hp, x_cur, solver="adaptive", steps=40)
    assert 0 < int(ra.iters) <= 40
    r0 = _solve(hp, x_cur, solver="adaptive", steps=0)
    assert int(r0.iters) == 0

"""Receding-horizon controller + fleet integration.

The anchor (ISSUE acceptance): MPC with H=1 and the last_value forecaster
must reproduce the myopic controller's per-tick INTEGER allocations exactly
— every lookahead behavior is then an explicit deviation from that anchored
baseline, not an artifact of a different solver.

Property-style tests run through the deterministic ``repro.testing`` shim
when the image lacks hypothesis."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog
from repro.core.controller import InfrastructureOptimizationController
from repro.fleet import TenantSpec, replay_fleet
from repro.fleet.traces import (constant_trace, diurnal_trace,
                                flash_crowd_trace, ramp_trace)
from repro.horizon import ModelPredictiveController, make_forecaster

BASE = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[::40])


def test_h1_last_value_mpc_reproduces_myopic(tiny_catalog):
    """Tentpole acceptance: H=1 + last_value ≡ the myopic controller,
    per-tick integer allocations compared EXACTLY through replay_fleet."""
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 4, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.5, 3, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   delta_max=4.0),
    ]
    myo = replay_fleet(tiny_catalog, specs, run_ca_baseline=False)
    mpc = replay_fleet(tiny_catalog, specs, run_ca_baseline=False,
                       controller="mpc", horizon=1, forecaster="last_value")
    assert mpc.metrics.controller == "mpc"
    for rm, rp in zip(myo.tenants, mpc.tenants):
        for sm, sp in zip(rm.steps, rp.steps):
            np.testing.assert_array_equal(sm.counts, sp.counts)
            assert sm.churn == sp.churn
            assert sm.replanned == sp.replanned
        assert rm.metrics.cost_integral == rp.metrics.cost_integral


@pytest.mark.slow
@settings(max_examples=3)
@given(cat_pick=st.integers(0, 2), trace_seed=st.integers(0, 50))
def test_h1_equivalence_across_random_catalogs(cat_pick, trace_seed):
    """Satellite property test: the H=1 ≡ myopic equivalence is structural,
    not tuned to one catalog — it holds across random catalog slices and
    random traces (strides drawn from a fixed set so compile shapes repeat
    across examples)."""
    stride = (38, 40, 44)[cat_pick]
    cat = Catalog(make_cloud_catalog().instances[::stride])
    trace = diurnal_trace(BASE * (0.6 + 0.1 * (trace_seed % 4)), 3,
                          amplitude=0.35, seed=trace_seed)
    myo = InfrastructureOptimizationController(catalog=cat, n_starts=2)
    mpc = ModelPredictiveController(catalog=cat, n_starts=2, horizon=1,
                                    forecaster=make_forecaster("last_value"))
    for d in trace:
        np.testing.assert_array_equal(myo.step(d).counts, mpc.step(d).counts)


@pytest.mark.slow
def test_batched_mpc_matches_sequential(tiny_catalog):
    """Tentpole acceptance: the batched MPC engine (one vmapped
    solve_horizon_fleet_step per shape bucket per tick) must yield per-tenant
    integer allocations identical to the sequential MPC loop on CPU —
    ragged horizons and a per-tenant catalog included."""
    cat_other = Catalog(make_cloud_catalog().instances[::50])
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 4, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.5, 2, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   catalog=cat_other, delta_max=4.0),
        TenantSpec(name="c", trace=constant_trace(BASE, 3), n_starts=2),
    ]
    kw = dict(run_ca_baseline=False, controller="mpc", horizon=3,
              forecaster="holt_winters", forecaster_kwargs=dict(period=24))
    seq = replay_fleet(tiny_catalog, specs, replay_mode="sequential", **kw)
    bat = replay_fleet(tiny_catalog, specs, replay_mode="batched", **kw)
    for rs, rb in zip(seq.tenants, bat.tenants):
        assert len(rs.steps) == len(rb.steps) == rs.spec.trace.shape[0]
        for ss, sb in zip(rs.steps, rb.steps):
            np.testing.assert_array_equal(ss.counts, sb.counts)
            assert ss.churn == sb.churn
            assert ss.replanned == sb.replanned
        assert rs.metrics == rb.metrics
    assert (seq.metrics.total_cost_integral == bat.metrics.total_cost_integral)


def test_mpc_lookahead_serves_demand(tiny_catalog):
    """An H>1 oracle-driven MPC replay on a flash crowd must keep serving
    demand every tick (the hard tick-0 problem is unchanged; lookahead only
    reshapes WHERE the plan is headed)."""
    spec = TenantSpec(name="fc", trace=flash_crowd_trace(BASE, 5,
                                                         burst_scale=2.5,
                                                         noise=0.0, seed=3),
                      n_starts=2, delta_max=16.0)
    out = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                       controller="mpc", horizon=4, forecaster="oracle")
    assert all(s.metrics.satisfied for s in out.tenants[0].steps)
    assert out.tenants[0].metrics.slo_violation_ticks == 0


def test_oracle_regret_plumbing(tiny_catalog):
    """run_oracle_baseline attaches the oracle twin: oracle-vs-oracle regret
    is exactly zero, the summary renders it, and the flag is rejected for
    the myopic controller (regret is an MPC notion)."""
    spec = TenantSpec(name="t", trace=diurnal_trace(BASE, 3, amplitude=0.2,
                                                    noise=0.0), n_starts=2)
    out = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                       controller="mpc", horizon=2, forecaster="oracle",
                       run_oracle_baseline=True)
    assert out.metrics.oracle is not None
    assert out.metrics.regret_vs_oracle == 0.0
    assert "regret vs oracle" in out.metrics.summary()
    with pytest.raises(ValueError):
        replay_fleet(tiny_catalog, [spec], controller="myopic",
                     run_oracle_baseline=True)


def test_mpc_plan_state(tiny_catalog):
    """The controller keeps its (H, n) relaxed plan as rolling state."""
    ctl = ModelPredictiveController(catalog=tiny_catalog, n_starts=2,
                                    horizon=3,
                                    forecaster=make_forecaster("ewma"))
    trace = diurnal_trace(BASE, 3, amplitude=0.2, noise=0.0)
    for d in trace:
        ctl.step(d)
    assert ctl.plan.shape == (3, tiny_catalog.n)
    assert len(ctl.history) == 3
    # the committed tick is always within the hard churn bound + rounding
    shifted = ctl.shifted_plan()
    np.testing.assert_array_equal(shifted[0], ctl.x_current)


@pytest.mark.slow
def test_solver_config_plumbs_through_replay(tiny_catalog):
    """Satellite acceptance: ``replay_fleet(controller="mpc",
    solver_config=...)`` must reach every warm tick's solve in BOTH engines
    — the recorded per-tick ``solver_iters`` respects the configured budget
    (adaptive) and equals it exactly (fixed), which a module-constant
    600-step solver could not produce. The PR 3 ``solver_steps``-unreachable
    bug class, pinned for the horizon path."""
    from repro.horizon import HorizonSolverConfig

    spec = TenantSpec(name="t", trace=diurnal_trace(BASE, 4, amplitude=0.3,
                                                    noise=0.0), n_starts=2)
    for mode in ("sequential", "batched"):
        cfg = HorizonSolverConfig(steps=7)
        out = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                           replay_mode=mode, controller="mpc", horizon=3,
                           solver_config=cfg)
        warm = out.tenants[0].steps[1:]
        assert all(0 < s.solver_iters <= 7 for s in warm), \
            [(mode, s.solver_iters) for s in warm]
        assert out.tenants[0].steps[0].solver_iters == 0     # cold tick
        fixed = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                             replay_mode=mode, controller="mpc", horizon=3,
                             solver_config=HorizonSolverConfig(
                                 solver="fixed", steps=11))
        assert all(s.solver_iters == 11 for s in fixed.tenants[0].steps[1:])


@pytest.mark.slow
def test_solver_iters_match_across_engines(tiny_catalog):
    """Iteration-count contract across engines: the FIRST warm tick's
    inputs (integer cold counts, tiled warm start) are bit-identical in
    both engines, so its adaptive trajectory — and hence its recorded
    ``solver_iters`` — must match exactly. Later ticks warm-start from the
    previous RELAXED plan, which the two engines carry with last-ulp
    differences (vmap batches the matmuls differently), so their
    early-stopping points may drift a little while the committed integer
    allocations stay identical (asserted elsewhere) — bound the drift, and
    require every tick to respect the budget."""
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 4, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.5, 3, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   delta_max=4.0),
    ]
    kw = dict(run_ca_baseline=False, controller="mpc", horizon=3,
              forecaster="last_value")
    seq = replay_fleet(tiny_catalog, specs, replay_mode="sequential", **kw)
    bat = replay_fleet(tiny_catalog, specs, replay_mode="batched", **kw)
    for rs, rb in zip(seq.tenants, bat.tenants):
        it_s = [s.solver_iters for s in rs.steps]
        it_b = [s.solver_iters for s in rb.steps]
        assert it_s[0] == it_b[0] == 0                # cold tick records 0
        assert it_s[1] == it_b[1] > 0                 # identical inputs
        for a, b in zip(it_s[1:], it_b[1:]):          # bounded ulp drift
            assert 0 < a <= 600 and 0 < b <= 600
            assert abs(a - b) <= max(10, 0.5 * max(a, b)), (it_s, it_b)


@pytest.mark.slow
def test_window_cold_start_batched_matches_sequential(tiny_catalog):
    """cold_start="window" must preserve the engine equivalence: the
    batched replay re-ranks the SAME multistart candidates by the same
    whole-window scores, so per-tenant integer allocations stay identical
    to the sequential loop."""
    specs = [
        TenantSpec(name="a", trace=flash_crowd_trace(BASE, 4, burst_scale=2.0,
                                                     noise=0.0, seed=1),
                   n_starts=3),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.6, 3, end_scale=1.8,
                                              noise=0.0), n_starts=3),
    ]
    kw = dict(run_ca_baseline=False, controller="mpc", horizon=3,
              forecaster="oracle", cold_start="window")
    seq = replay_fleet(tiny_catalog, specs, replay_mode="sequential", **kw)
    bat = replay_fleet(tiny_catalog, specs, replay_mode="batched", **kw)
    for rs, rb in zip(seq.tenants, bat.tenants):
        for ss, sb in zip(rs.steps, rb.steps):
            np.testing.assert_array_equal(ss.counts, sb.counts)
        assert rs.metrics == rb.metrics


def test_window_cold_start_h1_is_myopic(tiny_catalog):
    """At H=1 the whole-window score IS the tick-0 merit, so
    cold_start="window" must not perturb the H=1 ≡ myopic anchor."""
    spec = TenantSpec(name="t", trace=diurnal_trace(BASE, 3, amplitude=0.3,
                                                    noise=0.0), n_starts=2)
    myo = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False)
    mpc = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                       controller="mpc", horizon=1, cold_start="window")
    for sm, sp in zip(myo.tenants[0].steps, mpc.tenants[0].steps):
        np.testing.assert_array_equal(sm.counts, sp.counts)


def test_window_cold_start_scores_whole_window(tiny_catalog):
    """The window selection must actually consult the future: scoring is
    Σ_h f_h(candidate), so a candidate that is cheapest for tick 0 only
    loses to one that serves the whole ramp (verified on the controller's
    own multistart candidates via the public scoring helpers)."""
    from repro.horizon import (ModelPredictiveController, make_forecaster,
                               select_window_candidate,
                               window_candidate_scores)
    from repro.core.multistart import multistart_solve
    import repro.core.objective as obj

    trace = ramp_trace(BASE * 0.6, 6, end_scale=2.5, noise=0.0)
    ctl = ModelPredictiveController(
        catalog=tiny_catalog, n_starts=4, horizon=4, cold_start="window",
        forecaster=make_forecaster("oracle", trace=trace))
    demands = ctl.window_demands(trace[0])
    probs = ctl.window_problems(demands)
    ms = multistart_solve(probs[0], n_starts=4)
    cands = np.asarray(ms.x_int_all, np.float64)
    scores = window_candidate_scores(probs, cands)
    j = select_window_candidate(scores, np.asarray(ms.feas_int_all))
    # the helper's scores really are the sum of per-tick objectives
    for s, cand in zip(scores, cands):
        manual = sum(float(obj.objective(pb, np.asarray(cand, np.float32)))
                     for pb in probs)
        np.testing.assert_allclose(s, manual, rtol=1e-5)
    # and the controller's cold tick commits exactly that winner
    step = ctl.step(trace[0])
    np.testing.assert_array_equal(step.counts, cands[j])

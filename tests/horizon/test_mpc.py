"""Receding-horizon controller + fleet integration.

The anchor (ISSUE acceptance): MPC with H=1 and the last_value forecaster
must reproduce the myopic controller's per-tick INTEGER allocations exactly
— every lookahead behavior is then an explicit deviation from that anchored
baseline, not an artifact of a different solver.

Property-style tests run through the deterministic ``repro.testing`` shim
when the image lacks hypothesis."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog
from repro.core.controller import InfrastructureOptimizationController
from repro.fleet import TenantSpec, replay_fleet
from repro.fleet.traces import (constant_trace, diurnal_trace,
                                flash_crowd_trace, ramp_trace)
from repro.horizon import ModelPredictiveController, make_forecaster

BASE = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[::40])


def test_h1_last_value_mpc_reproduces_myopic(tiny_catalog):
    """Tentpole acceptance: H=1 + last_value ≡ the myopic controller,
    per-tick integer allocations compared EXACTLY through replay_fleet."""
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 4, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.5, 3, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   delta_max=4.0),
    ]
    myo = replay_fleet(tiny_catalog, specs, run_ca_baseline=False)
    mpc = replay_fleet(tiny_catalog, specs, run_ca_baseline=False,
                       controller="mpc", horizon=1, forecaster="last_value")
    assert mpc.metrics.controller == "mpc"
    for rm, rp in zip(myo.tenants, mpc.tenants):
        for sm, sp in zip(rm.steps, rp.steps):
            np.testing.assert_array_equal(sm.counts, sp.counts)
            assert sm.churn == sp.churn
            assert sm.replanned == sp.replanned
        assert rm.metrics.cost_integral == rp.metrics.cost_integral


@settings(max_examples=3)
@given(cat_pick=st.integers(0, 2), trace_seed=st.integers(0, 50))
def test_h1_equivalence_across_random_catalogs(cat_pick, trace_seed):
    """Satellite property test: the H=1 ≡ myopic equivalence is structural,
    not tuned to one catalog — it holds across random catalog slices and
    random traces (strides drawn from a fixed set so compile shapes repeat
    across examples)."""
    stride = (38, 40, 44)[cat_pick]
    cat = Catalog(make_cloud_catalog().instances[::stride])
    trace = diurnal_trace(BASE * (0.6 + 0.1 * (trace_seed % 4)), 3,
                          amplitude=0.35, seed=trace_seed)
    myo = InfrastructureOptimizationController(catalog=cat, n_starts=2)
    mpc = ModelPredictiveController(catalog=cat, n_starts=2, horizon=1,
                                    forecaster=make_forecaster("last_value"))
    for d in trace:
        np.testing.assert_array_equal(myo.step(d).counts, mpc.step(d).counts)


def test_batched_mpc_matches_sequential(tiny_catalog):
    """Tentpole acceptance: the batched MPC engine (one vmapped
    solve_horizon_fleet_step per shape bucket per tick) must yield per-tenant
    integer allocations identical to the sequential MPC loop on CPU —
    ragged horizons and a per-tenant catalog included."""
    cat_other = Catalog(make_cloud_catalog().instances[::50])
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 4, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.5, 2, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   catalog=cat_other, delta_max=4.0),
        TenantSpec(name="c", trace=constant_trace(BASE, 3), n_starts=2),
    ]
    kw = dict(run_ca_baseline=False, controller="mpc", horizon=3,
              forecaster="holt_winters", forecaster_kwargs=dict(period=24))
    seq = replay_fleet(tiny_catalog, specs, replay_mode="sequential", **kw)
    bat = replay_fleet(tiny_catalog, specs, replay_mode="batched", **kw)
    for rs, rb in zip(seq.tenants, bat.tenants):
        assert len(rs.steps) == len(rb.steps) == rs.spec.trace.shape[0]
        for ss, sb in zip(rs.steps, rb.steps):
            np.testing.assert_array_equal(ss.counts, sb.counts)
            assert ss.churn == sb.churn
            assert ss.replanned == sb.replanned
        assert rs.metrics == rb.metrics
    assert (seq.metrics.total_cost_integral == bat.metrics.total_cost_integral)


def test_mpc_lookahead_serves_demand(tiny_catalog):
    """An H>1 oracle-driven MPC replay on a flash crowd must keep serving
    demand every tick (the hard tick-0 problem is unchanged; lookahead only
    reshapes WHERE the plan is headed)."""
    spec = TenantSpec(name="fc", trace=flash_crowd_trace(BASE, 5,
                                                         burst_scale=2.5,
                                                         noise=0.0, seed=3),
                      n_starts=2, delta_max=16.0)
    out = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                       controller="mpc", horizon=4, forecaster="oracle")
    assert all(s.metrics.satisfied for s in out.tenants[0].steps)
    assert out.tenants[0].metrics.slo_violation_ticks == 0


def test_oracle_regret_plumbing(tiny_catalog):
    """run_oracle_baseline attaches the oracle twin: oracle-vs-oracle regret
    is exactly zero, the summary renders it, and the flag is rejected for
    the myopic controller (regret is an MPC notion)."""
    spec = TenantSpec(name="t", trace=diurnal_trace(BASE, 3, amplitude=0.2,
                                                    noise=0.0), n_starts=2)
    out = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                       controller="mpc", horizon=2, forecaster="oracle",
                       run_oracle_baseline=True)
    assert out.metrics.oracle is not None
    assert out.metrics.regret_vs_oracle == 0.0
    assert "regret vs oracle" in out.metrics.summary()
    with pytest.raises(ValueError):
        replay_fleet(tiny_catalog, [spec], controller="myopic",
                     run_oracle_baseline=True)


def test_mpc_plan_state(tiny_catalog):
    """The controller keeps its (H, n) relaxed plan as rolling state."""
    ctl = ModelPredictiveController(catalog=tiny_catalog, n_starts=2,
                                    horizon=3,
                                    forecaster=make_forecaster("ewma"))
    trace = diurnal_trace(BASE, 3, amplitude=0.2, noise=0.0)
    for d in trace:
        ctl.step(d)
    assert ctl.plan.shape == (3, tiny_catalog.n)
    assert len(ctl.history) == 3
    # the committed tick is always within the hard churn bound + rounding
    shifted = ctl.shifted_plan()
    np.testing.assert_array_equal(shifted[0], ctl.x_current)

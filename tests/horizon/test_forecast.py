"""Forecaster contracts: shapes, positivity, determinism, registry, and the
kind-specific behaviors (persistence, smoothing, seasonality, ground truth).
See docs/horizon.md for the observe/predict contract being enforced."""
import numpy as np
import pytest

from repro.fleet.traces import diurnal_trace
from repro.horizon import (FORECASTER_KINDS, HoltWintersForecaster,
                           LastValueForecaster, make_forecaster)

BASE = np.array([8.0, 16.0, 4.0, 100.0])


def _feed(fc, trace, upto):
    for d in trace[:upto]:
        fc.observe(d)
    return fc


@pytest.mark.parametrize("kind", sorted(FORECASTER_KINDS))
def test_contract_shape_positive_deterministic(kind):
    """Every kind: (k, m) forecasts, strictly positive, deterministic given
    the observation stream, and predict() does not mutate state."""
    trace = diurnal_trace(BASE, 30, seed=2)
    a = _feed(make_forecaster(kind, trace=trace), trace, 10)
    b = _feed(make_forecaster(kind, trace=trace), trace, 10)
    pa, pb = a.predict(6), b.predict(6)
    assert pa.shape == (6, 4)
    assert np.all(pa > 0)
    np.testing.assert_array_equal(pa, pb)
    # predict is read-only: asking twice gives the same answer
    np.testing.assert_array_equal(pa, a.predict(6))


def test_last_value_is_persistence():
    fc = LastValueForecaster()
    fc.observe(np.array([1.0, 2.0, 3.0, 4.0]))
    fc.observe(np.array([5.0, 6.0, 7.0, 8.0]))
    np.testing.assert_array_equal(fc.predict(3),
                                  np.tile([5.0, 6.0, 7.0, 8.0], (3, 1)))


def test_ewma_smooths_toward_recent():
    fc = make_forecaster("ewma", alpha=0.5)
    fc.observe(np.full(4, 10.0))
    fc.observe(np.full(4, 20.0))
    np.testing.assert_allclose(fc.predict(2), np.full((2, 4), 15.0))


def test_holt_winters_learns_seasonality():
    """After two clean cycles, the seasonal forecaster should track the next
    cycle far better than persistence does."""
    P = 8
    t = np.arange(4 * P)
    wave = 10.0 + 4.0 * np.sin(2 * np.pi * t / P)
    trace = np.tile(wave[:, None], (1, 4))
    hw = HoltWintersForecaster(period=P, alpha=0.4, gamma=0.5)
    lv = LastValueForecaster()
    upto = 3 * P
    _feed(hw, trace, upto)
    _feed(lv, trace, upto)
    future = trace[upto: upto + P]
    err_hw = np.abs(hw.predict(P) - future).mean()
    err_lv = np.abs(lv.predict(P) - future).mean()
    assert err_hw < 0.5 * err_lv, (err_hw, err_lv)


def test_oracle_reads_ground_truth_and_clamps_at_end():
    trace = diurnal_trace(BASE, 10, seed=1)
    fc = make_forecaster("oracle", trace=trace)
    _feed(fc, trace, 4)
    np.testing.assert_array_equal(fc.predict(3), trace[4:7])
    _feed(fc, trace[4:], 6)          # now all 10 observed
    # beyond the end: the final row repeats
    np.testing.assert_array_equal(fc.predict(2), np.tile(trace[-1], (2, 1)))


def test_registry_errors():
    with pytest.raises(ValueError):
        make_forecaster("nope")
    with pytest.raises(ValueError):
        make_forecaster("oracle")           # oracle needs trace=
    # non-oracle kinds ignore trace=, so replay code can pass it blindly
    fc = make_forecaster("last_value", trace=diurnal_trace(BASE, 5))
    fc.observe(BASE)
    assert fc.predict(1).shape == (1, 4)

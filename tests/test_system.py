"""End-to-end behaviour tests: the full paper pipeline on a reduced catalog —
scenario -> CA baseline -> convex optimization -> controller loop with a
failure event. Model-framework system tests live in tests/models and
tests/distributed."""
import numpy as np
import pytest


@pytest.mark.slow
def test_paper_pipeline_end_to_end(small_catalog):
    from repro.core import (InfrastructureOptimizationController, Scenario,
                            default_pools_for, evaluate, optimize,
                            simulate_cluster_autoscaler)

    demand = np.array([16, 32, 8, 200], np.float64)
    pools = default_pools_for(small_catalog,
                              small_catalog.select(lambda t: 2 <= t.cpu <= 8)[:6])
    scen = Scenario(name="sys", title="system test", demand=demand,
                    allowed_idx=None, pools=pools,
                    existing=np.zeros(small_catalog.n))

    ca = simulate_cluster_autoscaler(small_catalog, pools, demand)
    assert ca.satisfied
    ca_metrics = evaluate(small_catalog, ca.counts, demand)

    res = optimize(small_catalog, scen, n_starts=4)
    assert res.metrics.satisfied
    # headline claim: optimization matches or beats CA
    assert res.metrics.total_cost <= ca_metrics.total_cost * 1.05

    # controller keeps satisfying under drift + failure
    ctl = InfrastructureOptimizationController(catalog=small_catalog,
                                               delta_max=6.0, n_starts=2)
    for f in (1.0, 1.3, 1.6):
        st = ctl.step(demand * f)
        assert st.metrics.satisfied
    st = ctl.replan_on_failure(np.ceil(ctl.x_current * 0.3), demand * 1.6)
    assert st.metrics.satisfied

"""Benchmark-driver smoke tests.

The bench scripts are the repo's evidence layer, but they are NOT imported
by the library or the unit tests — a solver refactor can silently break
them and nobody notices until the next `make bench-*` run fails mid-sweep.
These tests import each driver by file path (benchmarks/ is not a package)
and run its entry functions at the tiniest configuration that still
exercises the real code path. They assert on structure, not numbers: the
point is "still runs and emits the schema", not performance.

All four driver smokes are marked `slow` (each runs a multi-second sweep
even at its tiniest configuration) so `make test-fast` stays within its
budget; `make test` — the tier-1 gate — always runs them.
"""
import importlib.util
import os

import pytest

BENCH_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks")


def _load(name: str):
    spec = importlib.util.spec_from_file_location(
        f"bench_smoke_{name}", os.path.join(BENCH_DIR, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def horizon_bench():
    return _load("horizon_bench")


@pytest.fixture(scope="module")
def fleet_bench():
    return _load("fleet_bench")


@pytest.mark.slow
@pytest.mark.parametrize("solvers", [("adaptive",), ("admm",)])
def test_horizon_bench_run_tiny(horizon_bench, solvers):
    """The MPC sweep runs end to end at a tiny grid under both the
    monolithic and the ADMM engine, and emits the cell schema downstream
    tooling reads (beats_myopic, regret, solver_iters, timing split)."""
    out = horizon_bench.run(B=2, T=6, horizons=(1, 2),
                            forecasters=("last_value",),
                            trace_kinds=("diurnal",), solvers=solvers)
    assert out["cells"], out
    for cell in out["cells"]:
        assert cell["solver"] == solvers[0]
        for key in ("objective", "beats_myopic", "regret_vs_oracle",
                    "solver_iters", "t_compile", "t_execute"):
            assert key in cell, (key, cell)
    assert "diurnal" in out["myopic"]
    assert out["telemetry"]["n_steady_ticks"] > 0


@pytest.mark.slow
def test_horizon_bench_solver_scaling_tiny(horizon_bench):
    """The admm-vs-adaptive-vs-fixed scaling section emits per-engine merit
    + wall time and the adaptive time-to-quality escalation record."""
    rows = horizon_bench.solver_scaling(B=2, horizons=(4,), repeats=1)
    assert len(rows) == 1
    row = rows[0]
    assert set(row["engines"]) == {"admm", "adaptive", "fixed"}
    for eng in row["engines"].values():
        assert eng["steady_ms"] > 0
        assert "J" in eng
    assert row["adaptive_to_match"] is not None
    assert "matched" in row["adaptive_to_match"]


@pytest.mark.slow
def test_fleet_bench_entries_tiny(fleet_bench):
    """Every fleet_bench entry function still runs: the batched-vs-naive
    comparison, the bucketing sweep, and both replay benches."""
    out = fleet_bench.run(B=4, n_starts=2)
    assert out["ragged_cold"]["speedup"] > 0
    assert out["ragged_warm"]["t_fleet"] > 0
    assert out["scaling"]
    out_b = fleet_bench.run_bucketing(B=4, n_starts=2)
    assert out_b["n_buckets"] >= 1
    out_r = fleet_bench.run_replay(B=4, T=2)
    assert out_r["tenant_ticks"] > 0
    assert out_r["cost_rel_drift"] <= 1e-6
    out_ca = fleet_bench.run_ca_replay(B=4, T=3)
    assert out_ca["tenant_ticks"] == 12
    assert out_ca["counts_identical"]


@pytest.mark.slow
def test_scenario_bench_run_tiny():
    """The scenario sweep (priced-term IR consumers vs CA) runs end to end
    at a tiny grid and emits the frontier schema: per-knob cells with
    cost/SLO/churn + CA comparison for all three scenarios on every trace
    kind, plus the acceptance checks block."""
    sb = _load("scenario_bench")
    out = sb.run(B=2, T=4, trace_kinds=("diurnal",), slo_prices=(0.0, 2.0),
                 eviction_prices=(0.0, 0.6), spot_rates=(0.2,))
    cells = out["scenarios"]["diurnal"]
    assert [c["price"] for c in cells["slo"]] == [0.0, 2.0]
    assert [c["eviction_price"] for c in cells["priority"]] == [0.0, 0.6]
    assert [c["interruption_rate"] for c in cells["spot"]] == [0.2]
    for scenario in ("slo", "priority", "spot"):
        for cell in cells[scenario]:
            for key in ("cost", "slo_ticks", "churn", "ca_cost",
                        "ca_slo_ticks", "savings_vs_ca_pct", "t_replay"):
                assert key in cell, (scenario, key, cell)
    assert cells["spot_on_demand_ref"]["interruption_rate"] is None
    assert out["checks"]["diurnal"].keys() == {
        "all_scenarios_save_vs_ca", "slo_pricing_not_worse"}


@pytest.mark.slow
def test_solver_bench_runs(capsys):
    """benchmarks/solver_bench.py (the paper §III table) survived the PGD
    extraction: it still produces a row per scenario with a KKT report and
    a rounding-vs-BnB comparison."""
    sb = _load("solver_bench")
    out = sb.run(n_starts=2)
    assert out["approaches"], out
    for row in out["approaches"]:
        assert row["bnb_fun"] <= row["round_fun"] + 1e-6
        assert "kkt_stationarity" in row
    assert out["kernel"]["grad_err"] <= 1e-3
    assert out["pareto_frontier_size"] >= 1


@pytest.mark.slow
def test_serve_bench_cell_and_degradation_tiny(monkeypatch):
    """benchmarks/serve_bench.py still runs: one tiny latency cell emits
    the ServeSummary schema, and the degradation sweep's contract checks
    hold (tighter budget -> equal-or-worse objective, always feasible)."""
    sb = _load("serve_bench")
    monkeypatch.setitem(sb.CONFIG, "ticks", 4)
    monkeypatch.setitem(sb.CONFIG, "degradation_budgets_ms", [1.0, 8.0])
    catalog = sb._make_catalog()
    cell = sb._latency_cell(catalog, 2, None, seed=0)
    assert cell["decisions"] > 0
    for key in ("p50_latency_ms", "p99_latency_ms", "truncated_rate",
                "miss_rate", "mean_staleness"):
        assert key in cell, (key, cell)
    assert cell["truncated_rate"] == 0.0      # no deadline in this cell
    deg = sb._degradation_sweep()
    assert len(deg["rows"]) == 2
    assert deg["checks"]["monotone_objective"]
    assert deg["checks"]["all_feasible"]
    assert deg["checks"]["tight_budget_truncates"]


@pytest.mark.slow
def test_check_bench_emits_comparable_sentinel_doc(tmp_path):
    """benchmarks/check_bench.py (the `make bench-check` canary) runs end
    to end and its fresh doc compares cleanly against the committed golden
    on the objective metrics — the exact comparison CI gates on (timings
    compared under the loose local tolerance there; skipped entirely here
    since this runner may not match the golden's platform)."""
    import json

    from repro.obs import compare_bench, validate_bench

    cb = _load("check_bench")
    out = os.path.join(tmp_path, "BENCH_check.json")
    assert cb.main(["--json", out]) == 0
    doc = json.load(open(out))
    assert validate_bench(doc) == []
    assert doc["provenance"]["config_digest"]
    assert doc["health"]["nonfinite_events"] == 0
    assert doc["health"]["kkt_ticks_certified"] > 0
    golden = json.load(open(os.path.join(BENCH_DIR, "golden",
                                         "BENCH_check.json")))
    cmp = compare_bench(golden, doc, allow_cross_platform=True)
    assert not cmp.refusals, cmp.summary()
    obj = [d for d in cmp.deltas if d.kind in ("objective", "quality")]
    assert obj and all(d.ok for d in obj), cmp.summary()

"""Stacking/padding correctness: the padded batch must be EXACTLY equivalent
to the original ragged problems — objective, gradient, constraints."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.objective as obj
from repro.fleet.batching import embed_solutions, stack_problems, unstack_solution
from repro.kernels.alloc_objective.ops import fleet_value_and_grad
from repro.testing import make_toy_problem

RAGGED = [dict(seed=0, m=3, n=8, p=2), dict(seed=1, m=4, n=14, p=3),
          dict(seed=2, m=2, n=11, p=2), dict(seed=3, m=4, n=8, p=2)]


@pytest.fixture(scope="module")
def ragged_fleet():
    probs = [make_toy_problem(**kw) for kw in RAGGED]
    return probs, stack_problems(probs)


def test_stack_shapes_and_roundtrip(ragged_fleet):
    probs, batch = ragged_fleet
    assert batch.B == len(probs)
    assert batch.n_max == max(p.n for p in probs)
    assert batch.problem.K.shape == (batch.B, max(p.m for p in probs),
                                     batch.n_max)
    xs = [np.arange(p.n, dtype=np.float32) for p in probs]
    X = embed_solutions(batch, xs)
    back = unstack_solution(batch, X)
    for a, b in zip(xs, back):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_padded_objective_matches_core(ragged_fleet, use_kernel):
    """f and grad on the padded batch == core objective on each original
    problem (both the Pallas kernel and the einsum oracle)."""
    probs, batch = ragged_fleet
    rng = np.random.default_rng(0)
    xs = [rng.uniform(0, 5, p.n).astype(np.float32) for p in probs]
    X = jnp.asarray(embed_solutions(batch, xs))[:, None, :]
    f, g = fleet_value_and_grad(batch.problem, X, use_kernel=use_kernel)
    for b, (p, x) in enumerate(zip(probs, xs)):
        fr = float(obj.objective(p, jnp.asarray(x)))
        gr = np.asarray(obj.grad_objective(p, jnp.asarray(x)))
        np.testing.assert_allclose(float(f[b, 0]), fr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(g[b, 0, :p.n]), gr,
                                   rtol=1e-3, atol=1e-3)
        # padded gradient columns are irrelevant but must be finite
        assert np.all(np.isfinite(np.asarray(g[b, 0])))


def test_padded_rows_strictly_interior(ragged_fleet):
    """Padded constraint rows must never violate nor block the barrier."""
    probs, batch = ragged_fleet
    pb = batch.problem
    for b, p in enumerate(probs):
        # real rows copied; padded rows have d=0, mu=g=1
        np.testing.assert_array_equal(np.asarray(pb.d[b, :p.m]),
                                      np.asarray(p.d))
        assert np.all(np.asarray(pb.mu[b, p.m:]) == 1.0)
        assert np.all(np.asarray(pb.g[b, p.m:]) == 1.0)
        # padded variables are pinned to zero
        assert np.all(np.asarray(pb.ub[b, p.n:]) == 0.0)
        assert np.all(np.asarray(pb.mask[b, p.n:]) == 0.0)


def test_barrier_unaffected_by_padding(ragged_fleet):
    probs, batch = ragged_fleet
    rng = np.random.default_rng(1)
    for b, p in enumerate(probs):
        x = rng.uniform(0.5, 2.0, p.n).astype(np.float32)
        pad = jnp.zeros(batch.n_max, jnp.float32).at[: p.n].set(jnp.asarray(x))
        slice_b = lambda a: a[b]
        import jax
        pb_b = jax.tree_util.tree_map(slice_b, batch.problem)
        t = jnp.asarray(10.0)
        orig = float(obj.barrier(p, jnp.asarray(x), t))
        padded = float(obj.barrier(pb_b, pad, t))
        if np.isfinite(orig):
            np.testing.assert_allclose(padded, orig, rtol=1e-5, atol=1e-5)
        else:
            assert not np.isfinite(padded)


def test_stack_problems_active_mask_roundtrip():
    """The optional per-tenant liveness mask rides along with n_true and
    never alters stacking itself (ragged-horizon replay plumbing)."""
    probs = [make_toy_problem(seed=s, n=12 + s) for s in range(3)]
    plain = stack_problems(probs)
    assert plain.active is None
    np.testing.assert_array_equal(plain.active_mask, np.ones(3, bool))
    masked = stack_problems(probs, active=np.array([True, False, True]))
    np.testing.assert_array_equal(masked.active_mask,
                                  np.array([True, False, True]))
    for a, b in [(plain.problem.K, masked.problem.K),
                 (plain.problem.c, masked.problem.c)]:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

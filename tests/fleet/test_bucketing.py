"""Shape-bucketed stacking: bucketing + scatter-back must be a
permutation-exact round trip, and bucketed solves must reproduce the
unbucketed (globally padded) solve_fleet results.

Property-style tests run through the deterministic ``repro.testing`` shim
when the image lacks hypothesis.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import pytest
import numpy as np

from repro.core import SolverConfig
from repro.fleet import (bucket_dims, bucket_problems, ceil_pow2,
                         padding_stats, scatter_from_buckets, solve_fleet,
                         solve_fleet_bucketed, stack_problems, tenant_problem)
from repro.fleet.batching import unstack_solution
from repro.testing import make_toy_problem

CFG = SolverConfig(max_iters=100, barrier_rounds=2)


def _ragged(B, seed0=0):
    return [make_toy_problem(seed=seed0 + s, n=6 + 7 * (s % 4),
                             m=2 + s % 3, p=2 + s % 2) for s in range(B)]


# ---------------------------------------------------------------------------
# bucket geometry
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(v=st.integers(1, 5000), floor=st.integers(1, 16))
def test_ceil_pow2_properties(v, floor):
    r = ceil_pow2(v, floor)
    assert r >= v and r >= floor
    # r is floor * 2^k and halving it (when possible) drops below v
    assert r == floor or r // 2 < max(v, floor)


@settings(max_examples=10)
@given(n=st.integers(1, 300), m=st.integers(1, 12), p=st.integers(1, 12))
def test_bucket_dims_dominate_true_dims(n, m, p):
    bn, bm, bp = bucket_dims(n, m, p)
    assert bn >= n and bm >= m and bp >= p
    # padding per axis is bounded: less than 2x above the floor
    assert bn < 2 * max(n, 8) and bm < 2 * max(m, 2) and bp < 2 * max(p, 2)


# ---------------------------------------------------------------------------
# permutation-exact round trip
# ---------------------------------------------------------------------------

@settings(max_examples=6)
@given(B=st.integers(2, 12), seed0=st.integers(0, 100))
def test_bucket_scatter_roundtrip_is_permutation_exact(B, seed0):
    probs = _ragged(B, seed0)
    bucketed = bucket_problems(probs)
    # tenant_idx is a permutation of range(B)
    flat = np.concatenate([np.asarray(i) for i in bucketed.tenant_idx])
    assert sorted(flat.tolist()) == list(range(B))
    # every bucket member slices back to its ORIGINAL problem bit-for-bit
    for batch, idx in zip(bucketed.batches, bucketed.tenant_idx):
        for i, b in enumerate(idx):
            orig = probs[int(b)]
            back = tenant_problem(batch, i)
            np.testing.assert_array_equal(np.asarray(back.K),
                                          np.asarray(orig.K))
            np.testing.assert_array_equal(np.asarray(back.c),
                                          np.asarray(orig.c))
            np.testing.assert_array_equal(np.asarray(back.d),
                                          np.asarray(orig.d))
    # scatter restores bucket-ordered payloads to original order exactly
    payload = [[f"tenant-{int(b)}" for b in idx]
               for idx in bucketed.tenant_idx]
    out = scatter_from_buckets(bucketed, payload)
    assert out == [f"tenant-{b}" for b in range(B)]
    # ... and per-tenant solution vectors survive embed -> unstack per bucket
    for batch, idx in zip(bucketed.batches, bucketed.tenant_idx):
        xs = [np.arange(probs[int(b)].n, dtype=np.float32) for b in idx]
        from repro.fleet import embed_solutions
        back = unstack_solution(batch, embed_solutions(batch, xs))
        for a, c in zip(xs, back):
            np.testing.assert_array_equal(a, c)


@settings(max_examples=6)
@given(B=st.integers(3, 16), seed0=st.integers(0, 50))
def test_padding_stats_accounting(B, seed0):
    probs = _ragged(B, seed0)
    g = padding_stats(probs)
    bk = padding_stats(probs, bucket_problems(probs))
    assert g["true_cells"] == bk["true_cells"] > 0
    assert 0.0 <= g["waste_frac"] < 1.0 and 0.0 <= bk["waste_frac"] < 1.0
    assert g["padded_cells"] >= g["true_cells"]
    assert bk["padded_cells"] >= bk["true_cells"]


def test_bucketing_cuts_padding_on_skewed_fleet():
    """The motivating case: one big tenant + many small ones. Global padding
    inflates every small tenant to the big tenant's shape; bucketing keeps
    the small tenants in their own small bucket."""
    probs = [make_toy_problem(seed=0, n=96, m=4)] + [
        make_toy_problem(seed=s, n=10, m=3) for s in range(1, 9)]
    g = padding_stats(probs)
    bk = padding_stats(probs, bucket_problems(probs))
    assert bk["padded_cells"] < 0.5 * g["padded_cells"]
    assert bk["waste_frac"] < g["waste_frac"]


# ---------------------------------------------------------------------------
# solve equivalence: bucketed == unbucketed
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_bucketed_solve_matches_unbucketed():
    """Bucketed stacking must not change WHAT is solved: per-tenant integer
    solutions/objectives identical to the single globally-padded batch
    (start points are drawn per tenant at true shape, so both layouts see
    the same subproblems)."""
    probs = _ragged(7)
    flat = solve_fleet(stack_problems(probs), n_starts=2, cfg=CFG,
                       hot_loop="vmap")
    buck = solve_fleet_bucketed(probs, n_starts=2, cfg=CFG, hot_loop="vmap")
    np.testing.assert_array_equal(np.asarray(buck.fun_int),
                                  np.asarray(flat.fun_int))
    np.testing.assert_array_equal(np.asarray(buck.x_int),
                                  np.asarray(flat.x_int))
    # relaxed trajectories may part ways in the last ulps under different
    # padded reduction shapes; the BB/Armijo engine's accept/reject line
    # search amplifies those ulps more than the old fixed ladder did, so
    # the relaxed values get solver tolerance while the INTEGER results
    # above stay the exact-equality gate
    np.testing.assert_allclose(np.asarray(buck.fun), np.asarray(flat.fun),
                               rtol=5e-3)
    assert bool(np.all(np.asarray(buck.feasible)))


@pytest.mark.slow
@settings(max_examples=3)
@given(seed0=st.integers(0, 30))
def test_bucketed_solve_property_sweep(seed0):
    """Property sweep over random ragged fleets: bucketed integer objectives
    match unbucketed stacking, and every tenant ends feasible."""
    probs = _ragged(5, seed0)
    flat = solve_fleet(stack_problems(probs), n_starts=2, cfg=CFG,
                       hot_loop="vmap")
    buck = solve_fleet_bucketed(probs, n_starts=2, cfg=CFG, hot_loop="vmap")
    np.testing.assert_array_equal(np.asarray(buck.fun_int),
                                  np.asarray(flat.fun_int))
    assert bool(np.all(np.asarray(buck.feasible)))

"""Trace generators + replay engine. A constant trace must reproduce the
single-shot api.optimize result tick after tick."""
import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog, optimize
from repro.core.scenarios import Scenario
from repro.fleet import TenantSpec, make_trace, replay_fleet
from repro.fleet.traces import (constant_trace, diurnal_trace,
                                flash_crowd_trace, ramp_trace, weekly_trace)

BASE = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[::40])


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["diurnal", "flash_crowd", "ramp", "weekly",
                                  "constant"])
def test_trace_shapes_positive_deterministic(kind):
    a = make_trace(kind, BASE, 48, seed=3)
    b = make_trace(kind, BASE, 48, seed=3)
    assert a.shape == (48, 4)
    assert np.all(a > 0)
    np.testing.assert_array_equal(a, b)
    if kind != "constant":
        c = make_trace(kind, BASE, 48, seed=4)
        assert not np.array_equal(a, c)


def test_trace_characteristics():
    d = diurnal_trace(BASE, 96, amplitude=0.5, noise=0.0)
    assert d.max() > 1.3 * d.min()            # real day/night swing
    f = flash_crowd_trace(BASE, 96, burst_scale=4.0, noise=0.0, seed=1)
    assert f.max() > 2.0 * np.median(f)       # a spike exists
    r = ramp_trace(BASE, 96, end_scale=3.0, noise=0.0)
    assert r[-1, 0] > 2.5 * r[0, 0]           # ramp grew
    w = weekly_trace(BASE, 24 * 14, noise=0.0)
    weekday = w[24 * 1 + 12]                  # Tue noon vs Sat noon
    weekend = w[24 * 5 + 12]
    assert weekday[0] > weekend[0]


def test_make_trace_unknown_kind():
    with pytest.raises(ValueError):
        make_trace("nope", BASE, 8)


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

def test_constant_trace_reproduces_single_shot(tiny_catalog):
    """Satellite acceptance: replay on a constant trace == api.optimize."""
    cat = tiny_catalog
    scen = Scenario(name="const", title="constant", demand=BASE.copy(),
                    allowed_idx=None, pools=[],
                    existing=np.zeros(cat.n))
    ref = optimize(cat, scen, n_starts=2, seed=0)

    spec = TenantSpec(name="t0", trace=constant_trace(BASE, 3), n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False)
    steps = out.tenants[0].steps
    # tick 0 is the same cold-start multistart solve as optimize()
    np.testing.assert_allclose(steps[0].counts, ref.counts, atol=1e-6)
    np.testing.assert_allclose(steps[0].metrics.total_cost,
                               ref.metrics.total_cost, rtol=1e-6)
    # steady state: no demand change -> no SLO violations, tiny churn,
    # cost stays at the one-shot optimum
    for s in steps[1:]:
        assert s.metrics.satisfied
        np.testing.assert_allclose(s.metrics.total_cost,
                                   ref.metrics.total_cost, rtol=0.02)
    assert out.tenants[0].metrics.slo_violation_ticks == 0


def test_replay_with_ca_baseline_and_aggregates(tiny_catalog):
    cat = tiny_catalog
    trace = diurnal_trace(BASE, 4, amplitude=0.3, noise=0.0)
    specs = [TenantSpec(name="a", trace=trace, n_starts=2),
             TenantSpec(name="b", trace=ramp_trace(BASE, 4, end_scale=1.5,
                                                   noise=0.0), n_starts=2)]
    out = replay_fleet(cat, specs, run_ca_baseline=True)
    m = out.metrics
    assert len(m.tenants) == 2 and len(m.baseline) == 2
    assert m.total_cost_integral > 0
    # CA must satisfy demand too (it over-provisions instead of failing)
    for t in m.baseline:
        assert t.slo_violation_ticks == 0
    # aggregate == sum of parts
    np.testing.assert_allclose(
        m.total_cost_integral, sum(t.cost_integral for t in m.tenants))
    assert m.baseline_cost_integral is not None
    assert m.summary()  # renders without error


# ---------------------------------------------------------------------------
# batched replay engine
# ---------------------------------------------------------------------------

def test_batched_replay_matches_sequential_exactly(tiny_catalog):
    """Tentpole acceptance: the batched engine (one solve_fleet /
    solve_fleet_step call per shape bucket per tick) must produce per-tenant
    integer allocations — hence integer objectives, costs and churn —
    IDENTICAL to the sequential per-tenant controller loop on CPU, including
    on a ragged fleet where tenants are padded to different bucket shapes."""
    cat = tiny_catalog
    cat_other = Catalog(make_cloud_catalog().instances[::50])  # ragged shape
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 3, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.5, 3, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   catalog=cat_other, delta_max=4.0),
        TenantSpec(name="c", trace=constant_trace(BASE, 3), n_starts=2),
    ]
    seq = replay_fleet(cat, specs, run_ca_baseline=False,
                       replay_mode="sequential")
    bat = replay_fleet(cat, specs, run_ca_baseline=False,
                       replay_mode="batched")
    assert bat.metrics.replay_mode == "batched"
    for rs, rb in zip(seq.tenants, bat.tenants):
        for ss, sb in zip(rs.steps, rb.steps):
            np.testing.assert_array_equal(ss.counts, sb.counts)
            assert ss.metrics.total_cost == sb.metrics.total_cost
            assert ss.churn == sb.churn
            assert ss.replanned == sb.replanned
        assert rs.metrics.cost_integral == rb.metrics.cost_integral
        assert rs.metrics.slo_violation_ticks == rb.metrics.slo_violation_ticks
    assert (seq.metrics.total_cost_integral
            == bat.metrics.total_cost_integral)


def test_batched_cold_start_reproduces_single_shot(tiny_catalog):
    """Satellite regression: the batched engine's cold-start path must also
    reproduce the one-shot api.optimize result on a constant-demand trace
    (the same guarantee the sequential path has)."""
    cat = tiny_catalog
    scen = Scenario(name="const", title="constant", demand=BASE.copy(),
                    allowed_idx=None, pools=[], existing=np.zeros(cat.n))
    ref = optimize(cat, scen, n_starts=2, seed=0)

    spec = TenantSpec(name="t0", trace=constant_trace(BASE, 3), n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False,
                       replay_mode="batched")
    steps = out.tenants[0].steps
    np.testing.assert_allclose(steps[0].counts, ref.counts, atol=1e-6)
    np.testing.assert_allclose(steps[0].metrics.total_cost,
                               ref.metrics.total_cost, rtol=1e-6)
    for s in steps[1:]:
        assert s.metrics.satisfied
        np.testing.assert_allclose(s.metrics.total_cost,
                                   ref.metrics.total_cost, rtol=0.02)
    assert out.tenants[0].metrics.slo_violation_ticks == 0


def test_batched_replay_relaxed_warm_start_stays_feasible(tiny_catalog):
    """warm_start="relaxed" (previous tick's relaxed batched solution) is an
    optimization knob, not an equivalence mode — but it must stay feasible
    and keep serving demand on a smooth trace."""
    cat = tiny_catalog
    spec = TenantSpec(name="w", trace=diurnal_trace(BASE, 4, amplitude=0.2,
                                                    noise=0.0), n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False,
                       replay_mode="batched", warm_start="relaxed")
    for s in out.tenants[0].steps:
        assert s.metrics.satisfied


def test_replay_mode_validation(tiny_catalog):
    spec = TenantSpec(name="x", trace=constant_trace(BASE, 2), n_starts=2)
    with pytest.raises(AssertionError):
        replay_fleet(tiny_catalog, [spec], replay_mode="nope")
    # batched mode requires equal-length traces
    specs = [TenantSpec(name="a", trace=constant_trace(BASE, 2), n_starts=2),
             TenantSpec(name="b", trace=constant_trace(BASE, 3), n_starts=2)]
    with pytest.raises(AssertionError):
        replay_fleet(tiny_catalog, specs, replay_mode="batched",
                     run_ca_baseline=False)


def test_replay_churn_is_bounded_on_smooth_trace(tiny_catalog):
    """On a gentle diurnal swing the warm-started controller should replan
    incrementally (bounded churn), never from scratch."""
    cat = tiny_catalog
    trace = diurnal_trace(BASE, 5, amplitude=0.15, noise=0.0)
    spec = TenantSpec(name="smooth", trace=trace, delta_max=4.0, n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False)
    steps = out.tenants[0].steps
    assert steps[0].replanned                 # cold start
    assert not any(s.replanned for s in steps[1:])
    for s in steps[1:]:
        assert s.metrics.satisfied
        assert s.churn <= 4.0 + 8.0           # delta + rounding slack

"""Trace generators + replay engine. A constant trace must reproduce the
single-shot api.optimize result tick after tick."""
import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog, optimize
from repro.core.scenarios import Scenario
from repro.fleet import TRACE_KINDS, TenantSpec, make_trace, replay_fleet
from repro.fleet.traces import (constant_trace, diurnal_trace,
                                flash_crowd_trace, ramp_trace, weekly_trace)

BASE = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[::40])


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

# satellite: enumerate the registry (exported from repro.fleet so sweeps —
# horizon_bench in particular — never hardcode the kind list) and check
# every registered kind is seed-deterministic
@pytest.mark.parametrize("kind", sorted(TRACE_KINDS))
def test_trace_shapes_positive_deterministic(kind):
    a = make_trace(kind, BASE, 48, seed=3)
    b = make_trace(kind, BASE, 48, seed=3)
    assert a.shape == (48, 4)
    if kind == "spot_interruption":
        # the one non-demand kind: an on/off availability overlay — {0, 1}
        # valued, all pools up at t=0, and some interruption must occur at
        # the default rate over 48 ticks with this seed
        assert set(np.unique(a)) <= {0.0, 1.0}
        assert np.all(a[0] == 1.0)
        assert np.any(a == 0.0)
    else:
        assert np.all(a > 0)
    np.testing.assert_array_equal(a, b)
    if kind != "constant":   # constant is seed-free by construction
        c = make_trace(kind, BASE, 48, seed=4)
        assert not np.array_equal(a, c)


def test_trace_characteristics():
    d = diurnal_trace(BASE, 96, amplitude=0.5, noise=0.0)
    assert d.max() > 1.3 * d.min()            # real day/night swing
    f = flash_crowd_trace(BASE, 96, burst_scale=4.0, noise=0.0, seed=1)
    assert f.max() > 2.0 * np.median(f)       # a spike exists
    r = ramp_trace(BASE, 96, end_scale=3.0, noise=0.0)
    assert r[-1, 0] > 2.5 * r[0, 0]           # ramp grew
    w = weekly_trace(BASE, 24 * 14, noise=0.0)
    weekday = w[24 * 1 + 12]                  # Tue noon vs Sat noon
    weekend = w[24 * 5 + 12]
    assert weekday[0] > weekend[0]


def test_make_trace_unknown_kind():
    with pytest.raises(ValueError):
        make_trace("nope", BASE, 8)


def test_make_trace_constant_rejects_unknown_kwargs():
    """Satellite regression: unknown kwargs for "constant" must raise, not be
    silently swallowed (a typo'd amplitude= used to yield a flat trace)."""
    with pytest.raises(TypeError):
        make_trace("constant", BASE, 8, amplitude=0.4)
    with pytest.raises(TypeError):
        constant_trace(BASE, 8, amplitude=0.4)
    # seed stays accepted at the registry level (universal knob, no-op here)
    np.testing.assert_array_equal(make_trace("constant", BASE, 8, seed=5),
                                  constant_trace(BASE, 8))


def test_tenant_spec_validates_trace_at_construction():
    """Satellite regression: malformed traces must fail AT CONSTRUCTION with
    a clear ValueError, not deep inside the solver with an opaque broadcast
    error."""
    with pytest.raises(ValueError, match="2-D"):
        TenantSpec(name="flat", trace=np.ones(8))
    with pytest.raises(ValueError, match="resource dim is 4"):
        TenantSpec(name="m3", trace=np.ones((6, 3)))
    with pytest.raises(ValueError, match="at least one tick"):
        TenantSpec(name="empty", trace=np.ones((0, 4)))
    # a per-tenant catalog decides the expected dim for that tenant
    cat = Catalog(make_cloud_catalog().instances[::200])
    with pytest.raises(ValueError):
        TenantSpec(name="c", trace=np.ones((6, 5)), catalog=cat)
    TenantSpec(name="ok", trace=np.ones((6, 4)), catalog=cat)  # no raise


def test_replay_fleet_rejects_empty_tenant_list(tiny_catalog):
    """Satellite regression: an empty fleet must raise a clear ValueError up
    front (both engines used to fail later with engine-specific errors)."""
    for mode in ("sequential", "batched"):
        with pytest.raises(ValueError, match="at least one TenantSpec"):
            replay_fleet(tiny_catalog, [], replay_mode=mode)


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

def test_constant_trace_reproduces_single_shot(tiny_catalog):
    """Satellite acceptance: replay on a constant trace == api.optimize."""
    cat = tiny_catalog
    scen = Scenario(name="const", title="constant", demand=BASE.copy(),
                    allowed_idx=None, pools=[],
                    existing=np.zeros(cat.n))
    ref = optimize(cat, scen, n_starts=2, seed=0)

    spec = TenantSpec(name="t0", trace=constant_trace(BASE, 3), n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False)
    steps = out.tenants[0].steps
    # tick 0 is the same cold-start multistart solve as optimize()
    np.testing.assert_allclose(steps[0].counts, ref.counts, atol=1e-6)
    np.testing.assert_allclose(steps[0].metrics.total_cost,
                               ref.metrics.total_cost, rtol=1e-6)
    # steady state: no demand change -> no SLO violations, tiny churn,
    # cost stays at the one-shot optimum
    for s in steps[1:]:
        assert s.metrics.satisfied
        np.testing.assert_allclose(s.metrics.total_cost,
                                   ref.metrics.total_cost, rtol=0.02)
    assert out.tenants[0].metrics.slo_violation_ticks == 0


def test_replay_with_ca_baseline_and_aggregates(tiny_catalog):
    cat = tiny_catalog
    trace = diurnal_trace(BASE, 4, amplitude=0.3, noise=0.0)
    specs = [TenantSpec(name="a", trace=trace, n_starts=2),
             TenantSpec(name="b", trace=ramp_trace(BASE, 4, end_scale=1.5,
                                                   noise=0.0), n_starts=2)]
    out = replay_fleet(cat, specs, run_ca_baseline=True)
    m = out.metrics
    assert len(m.tenants) == 2 and len(m.baseline) == 2
    assert m.total_cost_integral > 0
    # CA must satisfy demand too (it over-provisions instead of failing)
    for t in m.baseline:
        assert t.slo_violation_ticks == 0
    # aggregate == sum of parts
    np.testing.assert_allclose(
        m.total_cost_integral, sum(t.cost_integral for t in m.tenants))
    assert m.baseline_cost_integral is not None
    assert m.summary()  # renders without error


# ---------------------------------------------------------------------------
# batched replay engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_batched_replay_matches_sequential_exactly(tiny_catalog):
    """Tentpole acceptance: the batched engine (one solve_fleet /
    solve_fleet_step call per shape bucket per tick) must produce per-tenant
    integer allocations — hence integer objectives, costs and churn —
    IDENTICAL to the sequential per-tenant controller loop on CPU, including
    on a ragged fleet where tenants are padded to different bucket shapes."""
    cat = tiny_catalog
    cat_other = Catalog(make_cloud_catalog().instances[::50])  # ragged shape
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 3, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=ramp_trace(BASE * 0.5, 3, end_scale=1.5,
                                              noise=0.0), n_starts=2,
                   catalog=cat_other, delta_max=4.0),
        TenantSpec(name="c", trace=constant_trace(BASE, 3), n_starts=2),
    ]
    seq = replay_fleet(cat, specs, run_ca_baseline=False,
                       replay_mode="sequential")
    bat = replay_fleet(cat, specs, run_ca_baseline=False,
                       replay_mode="batched")
    assert bat.metrics.replay_mode == "batched"
    for rs, rb in zip(seq.tenants, bat.tenants):
        for ss, sb in zip(rs.steps, rb.steps):
            np.testing.assert_array_equal(ss.counts, sb.counts)
            assert ss.metrics.total_cost == sb.metrics.total_cost
            assert ss.churn == sb.churn
            assert ss.replanned == sb.replanned
        assert rs.metrics.cost_integral == rb.metrics.cost_integral
        assert rs.metrics.slo_violation_ticks == rb.metrics.slo_violation_ticks
    assert (seq.metrics.total_cost_integral
            == bat.metrics.total_cost_integral)


@pytest.mark.slow
def test_batched_cold_start_reproduces_single_shot(tiny_catalog):
    """Satellite regression: the batched engine's cold-start path must also
    reproduce the one-shot api.optimize result on a constant-demand trace
    (the same guarantee the sequential path has)."""
    cat = tiny_catalog
    scen = Scenario(name="const", title="constant", demand=BASE.copy(),
                    allowed_idx=None, pools=[], existing=np.zeros(cat.n))
    ref = optimize(cat, scen, n_starts=2, seed=0)

    spec = TenantSpec(name="t0", trace=constant_trace(BASE, 3), n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False,
                       replay_mode="batched")
    steps = out.tenants[0].steps
    np.testing.assert_allclose(steps[0].counts, ref.counts, atol=1e-6)
    np.testing.assert_allclose(steps[0].metrics.total_cost,
                               ref.metrics.total_cost, rtol=1e-6)
    for s in steps[1:]:
        assert s.metrics.satisfied
        np.testing.assert_allclose(s.metrics.total_cost,
                                   ref.metrics.total_cost, rtol=0.02)
    assert out.tenants[0].metrics.slo_violation_ticks == 0


@pytest.mark.slow
def test_batched_replay_relaxed_warm_start_stays_feasible(tiny_catalog):
    """warm_start="relaxed" (previous tick's relaxed batched solution) is an
    optimization knob, not an equivalence mode — but it must stay feasible
    and keep serving demand on a smooth trace."""
    cat = tiny_catalog
    spec = TenantSpec(name="w", trace=diurnal_trace(BASE, 4, amplitude=0.2,
                                                    noise=0.0), n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False,
                       replay_mode="batched", warm_start="relaxed")
    for s in out.tenants[0].steps:
        assert s.metrics.satisfied


def test_replay_mode_validation(tiny_catalog):
    spec = TenantSpec(name="x", trace=constant_trace(BASE, 2), n_starts=2)
    with pytest.raises(AssertionError):
        replay_fleet(tiny_catalog, [spec], replay_mode="nope")
    with pytest.raises(AssertionError):
        replay_fleet(tiny_catalog, [spec], ca_engine="nope")


@pytest.mark.slow
def test_batched_ragged_horizons_match_sequential(tiny_catalog):
    """Tentpole acceptance: tenants with trace lengths {T, T/2, 1} replayed
    batched vs sequential must yield identical per-tenant integer
    allocations, churn and TenantReplayMetrics — finished tenants freeze in
    their batch lane and contribute nothing after expiry."""
    cat = tiny_catalog
    cat_other = Catalog(make_cloud_catalog().instances[::50])
    T = 4
    specs = [
        TenantSpec(name="long", trace=diurnal_trace(BASE, T, amplitude=0.3,
                                                    noise=0.0), n_starts=2),
        TenantSpec(name="half", trace=ramp_trace(BASE * 0.5, T // 2,
                                                 end_scale=1.5, noise=0.0),
                   n_starts=2, catalog=cat_other, delta_max=4.0),
        TenantSpec(name="one", trace=constant_trace(BASE, 1), n_starts=2),
    ]
    seq = replay_fleet(cat, specs, run_ca_baseline=False,
                       replay_mode="sequential")
    bat = replay_fleet(cat, specs, run_ca_baseline=False,
                       replay_mode="batched")
    for rs, rb in zip(seq.tenants, bat.tenants):
        T_b = rs.spec.trace.shape[0]
        assert len(rs.steps) == len(rb.steps) == T_b   # history stops at T_b
        for ss, sb in zip(rs.steps, rb.steps):
            np.testing.assert_array_equal(ss.counts, sb.counts)
            assert ss.churn == sb.churn
            assert ss.replanned == sb.replanned
        assert rs.metrics == rb.metrics                # full TenantReplayMetrics
    assert (seq.metrics.total_cost_integral == bat.metrics.total_cost_integral)
    assert bat.metrics.total_tenant_ticks == T + T // 2 + 1
    assert "ragged" in bat.metrics.summary()


def test_vectorized_ca_engine_matches_sequential(tiny_catalog):
    """The vectorized CA replay (one batch-stepper call per tick) must agree
    tick-for-tick with the per-tenant sequential loop — ragged traces and a
    per-tenant catalog included."""
    cat = tiny_catalog
    cat_other = Catalog(make_cloud_catalog().instances[::50])
    specs = [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 5, amplitude=0.4,
                                                 noise=0.02, seed=1),
                   n_starts=2),
        TenantSpec(name="b", trace=flash_crowd_trace(BASE * 0.6, 3,
                                                     burst_scale=2.5, seed=2),
                   n_starts=2, catalog=cat_other),
        TenantSpec(name="c", trace=ramp_trace(BASE, 4, end_scale=2.0, seed=3),
                   n_starts=2),
    ]
    # ca_engine only varies the baseline; skip the optimizer cost by reusing
    # the cheap sequential replay for both
    vec = replay_fleet(cat, specs, run_ca_baseline=True,
                       ca_engine="vectorized")
    seq = replay_fleet(cat, specs, run_ca_baseline=True,
                       ca_engine="sequential")
    for rv, rs in zip(vec.tenants, seq.tenants):
        assert rv.ca_metrics == rs.ca_metrics
        np.testing.assert_array_equal(rv.ca_counts, rs.ca_counts)
    assert (vec.metrics.baseline_cost_integral
            == seq.metrics.baseline_cost_integral)


def _specialist_catalog():
    """Nine cheap general-purpose types with ZERO net capacity plus two
    pricier net-capable types — the shape that exposes tick-0 pool sizing:
    with no net demand at tick 0, every cheap type 'covers' the snapshot and
    fills all k pool slots, leaving the baseline structurally unable to
    schedule net demand that arrives later in the ramp."""
    from repro.core import InstanceType
    types = [InstanceType(name=f"gen{i}", provider="aws", family="gen",
                          cpu=2.0 + i, mem_gb=4.0 * (i + 1), net_units=0.0,
                          storage_gb=50.0 + 10 * i,
                          hourly_price=0.1 + 0.02 * i)
             for i in range(9)]
    types += [InstanceType(name=f"net{i}", provider="aws", family="net",
                           cpu=4.0, mem_gb=8.0, net_units=5.0 + 5 * i,
                           storage_gb=100.0, hourly_price=0.9 + 0.3 * i)
              for i in range(2)]
    return Catalog(types)


@pytest.mark.slow
def test_ca_pools_sized_from_peak_demand():
    """Bugfix regression (headline): `default_ca_pools` must size the
    baseline's node pools from the trace's per-resource PEAK demand
    (`trace.max(axis=0)`), not `trace[0]` — tick-0 sizing on a ramp fleet
    hands CA a pool set that cannot schedule peak demand, and the phantom
    unsatisfiable ticks inflate `cost_savings_vs_baseline_pct`."""
    from repro.fleet.replay import default_ca_pools
    cat = _specialist_catalog()
    specs = []
    for i in range(3):
        tr = ramp_trace(np.array([8.0, 16.0, 0.0, 100.0]) * (0.7 + 0.3 * i),
                        6, end_scale=4.0, noise=0.0, seed=i)
        tr[:, 2] = np.linspace(0.0, 10.0 + 2 * i, 6)   # net arrives mid-ramp
        specs.append(TenantSpec(name=f"ramp{i}", trace=tr, n_starts=2))

    # the bug in vitro: tick-0 pools are all zero-net types -> unschedulable
    tr0 = np.asarray(specs[0].trace)
    K, _, _ = cat.matrices()
    old_pools = default_ca_pools(cat, tr0[0])
    assert np.all(K[2, old_pools] == 0)
    # the fix: peak-sized pools cover every demanded resource
    new_pools = default_ca_pools(cat, tr0.max(axis=0))
    assert np.any(K[2, new_pools] > 0)

    out = replay_fleet(cat, specs, run_ca_baseline=True)
    for t in out.metrics.baseline:
        assert t.slo_violation_ticks == 0   # zero structurally-unsat ticks
    # savings are now measured against a schedulable baseline
    assert out.metrics.cost_savings_vs_baseline_pct is not None
    assert out.metrics.baseline_cost_integral > 0


def test_solver_steps_plumbed_to_batched_engine(tiny_catalog, monkeypatch):
    """Bugfix regression: replay_fleet must forward ``solver_steps`` to the
    batched engine's solve_fleet_step calls (it used to be dropped)."""
    import repro.fleet.replay as replay_mod
    seen = []
    real = replay_mod.solve_fleet_step

    def spy(*args, **kwargs):
        seen.append(kwargs.get("steps"))
        return real(*args, **kwargs)

    monkeypatch.setattr(replay_mod, "solve_fleet_step", spy)
    spec = TenantSpec(name="s", trace=constant_trace(BASE, 3), n_starts=2)
    replay_fleet(tiny_catalog, [spec], run_ca_baseline=False,
                 replay_mode="batched", solver_steps=123)
    assert seen == [123, 123]                   # one warm tick per t=1,2


def test_churn_violation_recorded_and_surfaced(tiny_catalog):
    """Satellite: ControllerStep.churn_violation must record the rounded
    allocation's excess over delta_max (previously only a code comment), and
    the fleet summary must surface the fleet max — honest churn comparisons
    between controllers need the overruns, not just the totals."""
    # a hard flash crowd under a tight churn bound forces rounding to break
    # the bound (feasibility-first: shortage beats churn); demand is scaled
    # so allocations span tens of nodes — at single-node scale the burst is
    # absorbed by integer over-capacity and nothing overruns
    trace = flash_crowd_trace(BASE * 25, 4, burst_scale=3.0, noise=0.0,
                              seed=1)
    spec = TenantSpec(name="tight", trace=trace, delta_max=1.0, n_starts=2)
    out = replay_fleet(tiny_catalog, [spec], run_ca_baseline=False)
    steps = out.tenants[0].steps
    assert steps[0].replanned and steps[0].churn_violation == 0.0
    for s in steps[1:]:
        assert s.churn_violation == pytest.approx(max(0.0, s.churn - 1.0))
    worst = max(s.churn_violation for s in steps)
    assert worst > 0.0                      # the scenario does overrun
    assert out.tenants[0].metrics.max_churn_violation == worst
    assert out.metrics.max_churn_violation == worst
    assert "churn overrun" in out.metrics.summary()


def test_replay_churn_is_bounded_on_smooth_trace(tiny_catalog):
    """On a gentle diurnal swing the warm-started controller should replan
    incrementally (bounded churn), never from scratch."""
    cat = tiny_catalog
    trace = diurnal_trace(BASE, 5, amplitude=0.15, noise=0.0)
    spec = TenantSpec(name="smooth", trace=trace, delta_max=4.0, n_starts=2)
    out = replay_fleet(cat, [spec], run_ca_baseline=False)
    steps = out.tenants[0].steps
    assert steps[0].replanned                 # cold start
    assert not any(s.replanned for s in steps[1:])
    for s in steps[1:]:
        assert s.metrics.satisfied
        assert s.churn <= 4.0 + 8.0           # delta + rounding slack

"""Anytime deadlines through the replay stack (ISSUE tentpole plumbing):
``replay_fleet(..., anytime=...)`` must actually truncate warm solves in
the sequential AND batched engine under the myopic AND MPC controller —
and ``deadline=None`` must replay bit-identically to no config at all.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog
from repro.core.pgd import AnytimeConfig
from repro.fleet import TenantSpec, make_trace, replay_fleet

BASE = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[::40])


def _fleet(T=3):
    return [TenantSpec(name="a", n_starts=2,
                       trace=make_trace("diurnal", BASE, T)),
            TenantSpec(name="b", n_starts=2,
                       trace=make_trace("ramp", BASE * 0.6, T))]


def _tight_anytime():
    """A deterministic config that must truncate every warm solve: the
    fake clock burns 5ms per reading against a 12ms budget, so at most a
    couple of 4-iteration chunks fit."""
    fake = SimpleNamespace(t=0.0)

    def clock():
        fake.t += 5e-3
        return fake.t

    return AnytimeConfig(deadline_ms=12.0, chunk_iters=4, clock=clock)


def _counts(res):
    return [[s.counts for s in t.steps] for t in res.tenants]


ENGINE_COMBOS = [("sequential", "myopic"), ("batched", "myopic"),
                 ("sequential", "mpc"), ("batched", "mpc")]


@pytest.mark.slow
@pytest.mark.parametrize("mode,controller", ENGINE_COMBOS)
def test_deadline_truncates_warm_solves_in_every_combo(tiny_catalog, mode,
                                                       controller):
    """Reachability (ISSUE satellite): the enforced deadline must reach
    the inner solve in all four engine×controller combos — every warm
    tick is flagged ``deadline_hit`` with an iteration count far below
    the untruncated budget, and cold ticks are never flagged."""
    res = replay_fleet(tiny_catalog, _fleet(), replay_mode=mode,
                       controller=controller, horizon=2,
                       run_ca_baseline=False, anytime=_tight_anytime())
    for tr in res.tenants:
        cold, warm = tr.steps[0], tr.steps[1:]
        assert not cold.deadline_hit
        assert warm, "fleet must have warm ticks to truncate"
        for s in warm:
            assert s.deadline_hit, (mode, controller, s)
            assert 0 < s.solver_iters <= 12, (mode, controller,
                                              s.solver_iters)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sequential", "batched"])
def test_disabled_deadline_replays_bit_identical(tiny_catalog, mode):
    """``AnytimeConfig(deadline_ms=None)`` must branch at Python level
    into the exact engines a no-config replay compiles — per-tenant
    integer allocations identical bit for bit."""
    off = replay_fleet(tiny_catalog, _fleet(), replay_mode=mode,
                       run_ca_baseline=False)
    disabled = replay_fleet(tiny_catalog, _fleet(), replay_mode=mode,
                            run_ca_baseline=False,
                            anytime=AnytimeConfig(deadline_ms=None))
    for c_off, c_dis in zip(_counts(off), _counts(disabled)):
        for a, b in zip(c_off, c_dis):
            np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_generous_deadline_matches_untruncated_replay(tiny_catalog):
    """A budget that never expires must not change a single allocation:
    the chunked engine walks the same iteration sequence."""
    off = replay_fleet(tiny_catalog, _fleet(), replay_mode="batched",
                       run_ca_baseline=False)
    on = replay_fleet(tiny_catalog, _fleet(), replay_mode="batched",
                      run_ca_baseline=False,
                      anytime=AnytimeConfig(deadline_ms=1e9))
    for c_off, c_on in zip(_counts(off), _counts(on)):
        for a, b in zip(c_off, c_on):
            np.testing.assert_array_equal(a, b)
    assert not any(s.deadline_hit for t in on.tenants for s in t.steps)


def test_anytime_rejects_capture_solver_trace(tiny_catalog):
    with pytest.raises(ValueError, match="mutually exclusive"):
        replay_fleet(tiny_catalog, _fleet(), capture_solver_trace=True,
                     anytime=AnytimeConfig(deadline_ms=5.0))


@pytest.mark.slow
def test_anytime_mpc_requires_adaptive_engine(tiny_catalog):
    from repro.horizon import HorizonSolverConfig

    with pytest.raises(ValueError, match="adaptive"):
        replay_fleet(tiny_catalog, _fleet(), controller="mpc", horizon=2,
                     solver_config=HorizonSolverConfig(solver="fixed"),
                     anytime=_tight_anytime())

"""solve_fleet vs the sequential per-problem solver.

The "vmap" hot loop must agree EXACTLY (XLA preserves per-lane op structure
under vmap); the hand-batched "ref"/"kernel" hot loops re-express the math
with batched einsums / the Pallas kernel, so their step acceptance is chaotic
in the last ulps — they must agree to solver tolerance and always end
feasible.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.objective as obj
from repro.core import SolverConfig, round_and_polish, solve_relaxation
from repro.core.multistart import make_starts
from repro.fleet import solve_fleet, solve_fleet_step, stack_problems
from repro.testing import make_toy_problem

CFG = SolverConfig(max_iters=150, barrier_rounds=2)
N_STARTS = 2


def _ragged_fleet(B):
    return [make_toy_problem(seed=s, m=3 + s % 2, n=9 + 2 * (s % 4),
                             p=2 + s % 2) for s in range(B)]


def _shared_starts(probs, batch):
    """Per-problem make_starts embedded into the padded batch, so both sides
    start from literally the same points."""
    from repro.fleet.batching import embed_solutions
    S = N_STARTS
    out = np.zeros((batch.B, S, batch.n_max), np.float32)
    for b, p in enumerate(probs):
        out[b, :, : p.n] = np.asarray(make_starts(p, S, seed=0))
    return jnp.asarray(out)


def _sequential_reference(probs, starts, cfg=None):
    """The naive loop: one multistart-style (start-vmapped, as
    core.multistart._solve_batch) solve per problem."""
    cfg = cfg or CFG

    def one_problem(p, xs):
        def one(x0):
            r = solve_relaxation(p, x0, cfg)
            xi = round_and_polish(p, r.x)
            return (r.fun, r.feasible, obj.objective(p, xi),
                    obj.is_feasible(p, xi, 1e-3))
        return jax.vmap(one)(xs)

    best_rel, best_int = [], []
    for b, p in enumerate(probs):
        fr, fe, fi, fie = one_problem(p, starts[b, :, : p.n])
        fr, fi = np.asarray(fr), np.asarray(fi)
        best_rel.append(np.min(np.where(np.asarray(fe), fr, fr + 1e12)))
        best_int.append(np.min(np.where(np.asarray(fie), fi, fi + 1e12)))
    return np.asarray(best_rel), np.asarray(best_int)


@pytest.mark.slow
def test_vmap_path_matches_sequential_exactly_uniform():
    """Uniform-shape fleet: no padding is added, vmap preserves per-lane op
    structure, so the batched solve is BIT-IDENTICAL to the loop."""
    probs = [make_toy_problem(seed=s) for s in range(8)]
    batch = stack_problems(probs)
    starts = _shared_starts(probs, batch)
    res = solve_fleet(batch, cfg=CFG, starts=starts, hot_loop="vmap")
    best_rel, best_int = _sequential_reference(probs, starts)
    np.testing.assert_allclose(np.asarray(res.fun), best_rel,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res.fun_int), best_int,
                               rtol=1e-6, atol=1e-6)
    assert bool(np.all(np.asarray(res.feasible)))


@pytest.mark.slow
def test_vmap_path_matches_sequential_ragged():
    """Tentpole acceptance: ragged fleet (padded reductions shift the last
    ulps, so trajectories can part ways) still agrees within 1e-3 rel."""
    probs = _ragged_fleet(8)
    batch = stack_problems(probs)
    starts = _shared_starts(probs, batch)
    res = solve_fleet(batch, cfg=CFG, starts=starts, hot_loop="vmap")
    best_rel, best_int = _sequential_reference(probs, starts)
    np.testing.assert_allclose(np.asarray(res.fun), best_rel, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(res.fun_int), best_int, rtol=1e-3)
    assert bool(np.all(np.asarray(res.feasible)))


@pytest.mark.slow
def test_integer_solutions_are_integral_and_feasible():
    probs = _ragged_fleet(6)
    batch = stack_problems(probs)
    res = solve_fleet(batch, n_starts=N_STARTS, cfg=CFG, hot_loop="vmap")
    X = np.asarray(res.x_int)
    np.testing.assert_allclose(X, np.round(X), atol=1e-5)
    for b, p in enumerate(probs):
        assert bool(obj.is_feasible(p, jnp.asarray(X[b, : p.n]), 1e-3)), b


@pytest.mark.slow
def test_ref_path_agrees_to_solver_tolerance():
    """The hand-batched PGD (einsum oracle) must stay within the stall
    band of the sequential solver and end feasible everywhere."""
    probs = _ragged_fleet(8)
    batch = stack_problems(probs)
    starts = _shared_starts(probs, batch)
    res = solve_fleet(batch, cfg=CFG, starts=starts, hot_loop="ref")
    best_rel, best_int = _sequential_reference(probs, starts)
    assert bool(np.all(np.asarray(res.feasible)))
    np.testing.assert_allclose(np.asarray(res.fun), best_rel, rtol=0.1)
    np.testing.assert_allclose(np.asarray(res.fun_int), best_int, rtol=0.05)
    # fleet-aggregate objective agrees much tighter than any single tenant
    agg_f = float(np.sum(np.asarray(res.fun_int)))
    assert abs(agg_f - best_int.sum()) / best_int.sum() < 2e-2


def test_kernel_path_matches_ref_path():
    """Pallas hot loop (interpret mode on CPU) vs the einsum oracle: same
    algorithm, same batching — only the objective evaluation differs."""
    probs = _ragged_fleet(3)
    batch = stack_problems(probs)
    cfg = SolverConfig(max_iters=40, barrier_rounds=1)
    starts = _shared_starts(probs, batch)
    r_ref = solve_fleet(batch, cfg=cfg, starts=starts, hot_loop="ref")
    r_ker = solve_fleet(batch, cfg=cfg, starts=starts, hot_loop="kernel",
                        interpret=True)
    assert bool(np.all(np.asarray(r_ker.feasible)))
    np.testing.assert_allclose(np.asarray(r_ker.fun_int),
                               np.asarray(r_ref.fun_int), rtol=0.05)


def test_heterogeneous_params_per_tenant():
    """Each tenant keeps its own penalty parameters through stacking."""
    probs = [make_toy_problem(seed=1, beta3=5.0),
             make_toy_problem(seed=1, beta3=50.0)]
    batch = stack_problems(probs)
    np.testing.assert_allclose(np.asarray(batch.problem.params.beta3),
                               [5.0, 50.0])
    res = solve_fleet(batch, n_starts=N_STARTS, cfg=CFG, hot_loop="vmap")
    # identical data, different shortage weight -> different solves allowed,
    # but both must be feasible
    assert bool(np.all(np.asarray(res.feasible)))


@pytest.mark.slow
def test_step_frozen_lanes_keep_warm_start():
    """Ragged-horizon contract: lanes with active=False are returned with
    x == x_int == x_current (the frozen tenant's last allocation), while
    live lanes are solved exactly as in an all-live batch."""
    probs = _ragged_fleet(3)
    batch = stack_problems(probs)
    res_all = solve_fleet(batch, n_starts=N_STARTS, cfg=CFG, hot_loop="vmap")
    X_cur = np.asarray(res_all.x_int, np.float64)
    active = np.array([True, False, True])
    frozen_batch = stack_problems(probs, active=active)
    live = solve_fleet_step(batch, X_cur, 4.0)
    part = solve_fleet_step(frozen_batch, X_cur, 4.0)    # mask via FleetBatch
    np.testing.assert_array_equal(np.asarray(part.x_int[1]), X_cur[1])
    np.testing.assert_array_equal(np.asarray(part.x[1]),
                                  np.asarray(X_cur[1], np.float32))
    for b in (0, 2):   # live lanes agree with the all-live batch exactly
        np.testing.assert_array_equal(np.asarray(part.x_int[b]),
                                      np.asarray(live.x_int[b]))

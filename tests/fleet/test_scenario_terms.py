"""Scenario terms through the replay engines (docs/scenarios.md).

The IR's whole-system contract: with scenario terms attached (and, for
spot, the availability overlay zeroing interrupted capacity), the batched
fleet engine must still produce per-tenant integer allocations identical
to the sequential reference — myopic and MPC alike — and the scenario
builders must validate their inputs.
"""
import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog
from repro.core.catalog import spot_catalog, spot_risk_prices
from repro.fleet import (PRIORITY_CLASSES, TenantSpec, make_spot_fleet,
                         make_trace, replay_fleet, with_priority_classes,
                         with_slo_pricing)

BASE = np.array([8.0, 16.0, 4.0, 100.0]) * 25


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[:24])


@pytest.fixture(scope="module")
def fleet_specs(tiny_catalog):
    return [TenantSpec(name=f"t{i}",
                       trace=make_trace("diurnal", BASE * (1 + 0.3 * i), 5,
                                        seed=i),
                       delta_max=6.0, n_starts=2)
            for i in range(3)]


def _assert_engines_agree(catalog, specs, **kw):
    seq = replay_fleet(catalog, specs, replay_mode="sequential",
                       run_ca_baseline=False, **kw)
    bat = replay_fleet(catalog, specs, replay_mode="batched",
                       run_ca_baseline=False, **kw)
    for a, b in zip(seq.tenants, bat.tenants):
        for sa, sb in zip(a.steps, b.steps):
            np.testing.assert_array_equal(sa.counts, sb.counts)
    return seq


def test_slo_pricing_batched_equals_sequential(tiny_catalog, fleet_specs):
    specs = with_slo_pricing(fleet_specs, price=0.8)
    assert all(len(s.terms) == 1 for s in specs)
    assert all(s.terms == () for s in fleet_specs)   # inputs untouched
    _assert_engines_agree(tiny_catalog, specs)


def test_priority_classes_batched_equals_sequential(tiny_catalog,
                                                    fleet_specs):
    """Mixed term signatures in ONE shape bucket: the critical tenant has
    no term, the others do — union stacking must keep engines bit-equal."""
    specs = with_priority_classes(fleet_specs,
                                  ["critical", "standard", "batch"],
                                  catalog=tiny_catalog)
    assert specs[0].terms == ()
    assert [t.kind for t in specs[1].terms] == ["priority_eviction"]
    # batch outranks standard in eviction exposure
    assert float(specs[2].terms[0].params["price"][0]) > \
        float(specs[1].terms[0].params["price"][0])
    _assert_engines_agree(tiny_catalog, specs)


def test_priority_classes_validation(tiny_catalog, fleet_specs):
    with pytest.raises(ValueError, match="unknown priority class"):
        with_priority_classes(fleet_specs, ["critical", "standard", "nope"],
                              catalog=tiny_catalog)
    with pytest.raises(ValueError, match="priorities"):
        with_priority_classes(fleet_specs, ["critical"],
                              catalog=tiny_catalog)
    assert set(PRIORITY_CLASSES) == {"critical", "standard", "batch"}


def test_spot_fleet_batched_equals_sequential_and_overlay(tiny_catalog,
                                                          fleet_specs):
    spot_cat, specs = make_spot_fleet(tiny_catalog, fleet_specs, seed=3)
    assert spot_cat.n == 2 * tiny_catalog.n
    seq = _assert_engines_agree(spot_cat, specs)
    # the overlay is enforced: interrupted pools hold zero allocation on
    # exactly the tick their availability row says they are down
    saw_interruption = False
    for spec, rep in zip(specs, seq.tenants):
        avail = spec.spot_availability
        for t, step in enumerate(rep.steps):
            down = spec.spot_idx[avail[min(t, len(avail) - 1)] <= 0.0]
            saw_interruption |= len(down) > 0
            assert np.all(step.counts[down] == 0.0)
    assert saw_interruption, "seed produced no interruptions — test is vacuous"


def test_spot_fleet_mpc_engines_agree(tiny_catalog, fleet_specs):
    """Terms + overlay through the MPC path: batched H-window stacking
    (bucket-union term signatures) matches the sequential controller."""
    spot_cat, specs = make_spot_fleet(tiny_catalog, fleet_specs, seed=3)
    _assert_engines_agree(spot_cat, specs, controller="mpc", horizon=3)


def test_mpc_h1_equals_myopic_with_terms(tiny_catalog, fleet_specs):
    """H=1 ≡ myopic survives attached terms (both flow through the same
    make_problem / objective registry)."""
    specs = with_slo_pricing(fleet_specs, price=1.2)
    myo = replay_fleet(tiny_catalog, specs, run_ca_baseline=False)
    mpc = replay_fleet(tiny_catalog, specs, run_ca_baseline=False,
                       controller="mpc", horizon=1)
    for a, b in zip(myo.tenants, mpc.tenants):
        for sa, sb in zip(a.steps, b.steps):
            np.testing.assert_array_equal(sa.counts, sb.counts)


def test_spot_fleet_rejects_tenant_catalog(tiny_catalog, fleet_specs):
    bad = [TenantSpec(name="own-cat", trace=fleet_specs[0].trace,
                      catalog=tiny_catalog)]
    with pytest.raises(ValueError, match="per-tenant catalog"):
        make_spot_fleet(tiny_catalog, bad)


def test_spot_catalog_and_risk_prices(tiny_catalog):
    spot_cat, spot_idx = spot_catalog(tiny_catalog, discount=0.7)
    assert len(spot_idx) == tiny_catalog.n
    for j, sj in enumerate(spot_idx):
        on, sp = tiny_catalog.instances[j], spot_cat.instances[int(sj)]
        assert sp.name == on.name + "#spot"
        assert sp.hourly_price == pytest.approx(0.3 * on.hourly_price,
                                                rel=1e-3)
        assert sp.cpu == on.cpu and sp.mem_gb == on.mem_gb
    risk = spot_risk_prices(spot_cat, spot_idx, rate=0.05, penalty_hours=2.0)
    assert risk.shape == (spot_cat.n,)
    assert np.all(risk[: tiny_catalog.n] == 0.0)     # on-demand: no risk
    j = int(spot_idx[0])
    assert risk[j] == pytest.approx(
        0.1 * spot_cat.instances[j].hourly_price, rel=1e-5)


def test_tenant_spec_spot_validation(fleet_specs):
    tr = fleet_specs[0].trace
    with pytest.raises(ValueError, match="together"):
        TenantSpec(name="half", trace=tr, spot_idx=np.arange(3))
    with pytest.raises(ValueError, match=r"\(T', S\)"):
        TenantSpec(name="shape", trace=tr, spot_idx=np.arange(3),
                   spot_availability=np.ones((4, 2)))

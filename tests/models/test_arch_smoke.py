"""Per-architecture smoke tests (deliverable f): REDUCED config of the same
family, one forward + one train step on CPU, asserting output shapes and
no-NaN. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import init_model, split, forward, loss_fn

ARCHS = list_archs()

# a forward+train smoke is 5-55s of CPU jit per arch and each param pays
# its own compile, so the whole sweep lives in the full tier (`make test`);
# the fast tier still covers model code via the cheap component tests
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) for a in ARCHS]


def _batch(cfg, rng, B=2, S=32):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_frontend_tokens, cfg.d_frontend)),
            jnp.float32)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_and_train_step(arch):
    rng = np.random.default_rng(0)
    cfg = get_config(arch).reduced()
    params, _ = split(init_model(cfg, jax.random.PRNGKey(0)))
    batch = _batch(cfg, rng)
    B, S = batch["tokens"].shape

    logits, aux = forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN/inf in logits"

    # one SGD train step: loss + grads finite, params actually move
    loss, metrics = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = loss_fn(cfg, new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dimensions(arch):
    """The FULL config matches the assignment table (no allocation)."""
    cfg = get_config(arch)
    table = {
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
    }
    L, D, H, G, F, V = table[arch]
    assert cfg.n_layers == L and cfg.d_model == D
    assert cfg.n_heads == H and cfg.n_kv_heads == G
    assert (cfg.moe_d_ff or cfg.d_ff) == F or cfg.d_ff == F
    assert cfg.vocab_size == V


def test_param_counts_match_tree():
    """param_counts() formula vs the real parameter tree (dense arch)."""
    cfg = get_config("musicgen-medium").reduced()
    params, _ = split(init_model(cfg, jax.random.PRNGKey(0)))
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    total, active = cfg.param_counts()
    assert total == active  # dense
    # formula covers >= 90% of real params (omits norms/small vectors)
    assert 0.9 * actual <= total <= 1.1 * actual


def test_moe_param_counts_active_less():
    cfg = get_config("mixtral-8x22b")
    total, active = cfg.param_counts()
    assert active < total
    # Mixtral-8x22B ~ 141B total / ~39B active (table bands)
    assert 1.0e11 < total < 1.8e11, total
    assert 3.0e10 < active < 5.0e10, active

"""Unit tests for model components: prefill/decode consistency, SWA ring
buffer, chunked-flash vs full SDPA, Mamba/RWKV chunk invariance, MoE
dispatch exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (decode_step, forward, init_model, prefill, split)
from repro.models.attention import _chunked_flash, _sdpa, causal_mask

CONSISTENCY_ARCHS = ["qwen1.5-4b", "mixtral-8x22b", "jamba-1.5-large-398b",
                     "rwkv6-7b", "granite-34b"]
# prefill+decode is 5-35s of CPU jit per reduced config (each pays its own
# compile): the whole consistency sweep runs in the full tier
CONSISTENCY_PARAMS = [pytest.param(a, marks=pytest.mark.slow)
                      for a in CONSISTENCY_ARCHS]


@pytest.mark.parametrize("arch", CONSISTENCY_PARAMS)
def test_prefill_then_decode_matches_teacher_forcing(arch):
    rng = np.random.default_rng(1)
    cfg = get_config(arch).reduced()
    params, _ = split(init_model(cfg, jax.random.PRNGKey(0)))
    B, S = 2, 48
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    logits_pre, caches = prefill(cfg, params, batch, s_max=S + 8)
    lg, _ = forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(lg[:, -1]),
                               rtol=2e-4, atol=2e-4)
    # two decode steps
    tok = jnp.argmax(logits_pre, -1)[:, None].astype(jnp.int32)
    full = batch["tokens"]
    for step in range(2):
        dl, caches = decode_step(cfg, params, caches, tok, jnp.asarray(S + step))
        full = jnp.concatenate([full, tok], axis=1)
        lg2, _ = forward(cfg, params, {"tokens": full})
        np.testing.assert_allclose(np.asarray(dl), np.asarray(lg2[:, -1]),
                                   rtol=2e-3, atol=2e-3)
        tok = jnp.argmax(dl, -1)[:, None].astype(jnp.int32)


@pytest.mark.slow
def test_sliding_window_decode_ring_buffer():
    """SWA decode with a window-sized ring buffer matches teacher forcing
    even past the window boundary."""
    rng = np.random.default_rng(2)
    cfg = get_config("mixtral-8x22b").reduced().scaled(window=16)
    params, _ = split(init_model(cfg, jax.random.PRNGKey(0)))
    B, S = 1, 24
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    logits_pre, caches = prefill(cfg, params, batch, s_max=cfg.window)
    tok = jnp.argmax(logits_pre, -1)[:, None].astype(jnp.int32)
    full = batch["tokens"]
    for step in range(4):   # crosses/stays past the ring boundary
        dl, caches = decode_step(cfg, params, caches, tok, jnp.asarray(S + step))
        full = jnp.concatenate([full, tok], axis=1)
        lg2, _ = forward(cfg, params, {"tokens": full})
        np.testing.assert_allclose(np.asarray(dl), np.asarray(lg2[:, -1]),
                                   rtol=3e-3, atol=3e-3)
        tok = jnp.argmax(dl, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("window", [0, 32])
def test_chunked_flash_matches_sdpa(window):
    rng = np.random.default_rng(3)
    B, S, H, G, dh = 2, 128, 8, 4, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), jnp.float32)
    ref = _sdpa(q, k, v, causal_mask(S, S, 0, window))
    out = _chunked_flash(q, k, v, window, q_chunk=32, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_mamba_chunk_invariance():
    from repro.models.mamba import init_mamba, mamba_block
    from repro.models.param import split as psplit
    cfg = get_config("jamba-1.5-large-398b").reduced()
    p, _ = psplit(init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 1, (2, 64, cfg.d_model)), jnp.float32)
    y1, _ = mamba_block(p, cfg.scaled(scan_chunk=8), x)
    y2, _ = mamba_block(p, cfg.scaled(scan_chunk=64), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_chunk_invariance_and_scan_equivalence():
    """Chunked WKV closed form == naive sequential recurrence."""
    from repro.models.rwkv import _wkv_chunked
    rng = np.random.default_rng(5)
    B, S, H, hs = 2, 40, 2, 8
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, S, H, hs)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.6, 0.999, (B, S, H, hs)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (H, hs)), jnp.float32)
    s0 = jnp.asarray(rng.normal(0, 1, (B, H, hs, hs)), jnp.float32)

    # naive recurrence
    def naive():
        S_t = np.asarray(s0).copy()
        ys = np.zeros((B, S, H, hs), np.float32)
        for t in range(S):
            rt, kt, vt, wt = (np.asarray(a[:, t]) for a in (r, k, v, w))
            bonus = np.einsum("bhc,bhc->bh", rt * np.asarray(u)[None], kt)
            ys[:, t] = (np.einsum("bhc,bhcd->bhd", rt, S_t)
                        + bonus[..., None] * vt)
            S_t = wt[..., None] * S_t + np.einsum("bhc,bhd->bhcd", kt, vt)
        return ys, S_t

    y_ref, s_ref = naive()
    for chunk in (8, 16, 40):
        y, s_end = _wkv_chunked(r, k, v, w, u, s0, chunk)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(s_end), s_ref, rtol=2e-3, atol=2e-3)


def test_moe_no_drop_exact():
    """With no_drop, MoE output == explicit per-token expert mixture."""
    from repro.models.moe import init_moe, moe_ffn
    from repro.models.param import split as psplit
    cfg = get_config("mixtral-8x22b").reduced()
    p, _ = psplit(init_moe(jax.random.PRNGKey(1), cfg, jnp.float32))
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(0, 1, (2, 8, cfg.d_model)), jnp.float32)
    out, aux = moe_ffn(p, cfg, x, no_drop=True)
    # reference: dense per-token computation
    xt = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xt @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    gv, ei = jax.lax.top_k(probs, cfg.top_k)
    gv = np.asarray(gv / gv.sum(-1, keepdims=True))
    ei = np.asarray(ei)
    wu, wg, wd = (np.asarray(p[k]) for k in ("w_up", "w_gate", "w_down"))
    ref = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(cfg.top_k):
            e = ei[t, j]
            up = xt[t] @ wu[e]
            gate = xt[t] @ wg[e]
            h = (gate * (1 / (1 + np.exp(-gate)))) * up   # silu(gate)*up
            ref[t] += gv[t, j] * (h @ wd[e])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model), ref,
                               rtol=2e-3, atol=2e-3)
    assert np.isfinite(float(aux))


@pytest.mark.slow
def test_perf_levers_numerically_close():
    """attn_probs_bf16 / ssm_scan_bf16 are perf levers — outputs must stay
    close to the f32 baseline."""
    from repro.models.attention import _chunked_flash
    rng = np.random.default_rng(21)
    B, S, H, G, dh = 1, 2048, 4, 2, 32
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), jnp.float32)
    ref = _chunked_flash(q, k, v, 0, q_chunk=512, kv_chunk=512)
    fast = _chunked_flash(q, k, v, 0, q_chunk=512, kv_chunk=512,
                          probs_bf16=True)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)

    from repro.models.mamba import init_mamba, mamba_block
    from repro.models.param import split as psplit
    cfg = get_config("jamba-1.5-large-398b").reduced()
    p, _ = psplit(init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jnp.asarray(rng.normal(0, 1, (2, 64, cfg.d_model)), jnp.float32)
    y_ref, _ = mamba_block(p, cfg, x)
    y_fast, _ = mamba_block(p, cfg.scaled(ssm_scan_bf16=True), x)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=5e-2, atol=5e-2)

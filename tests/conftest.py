"""Shared fixtures. NOTE: XLA_FLAGS device-count forcing is deliberately NOT
set here — tests run with the single real CPU device; only launch/dryrun.py
forces 512 placeholder devices (and a few subprocess-based tests set it in
their own child process environment)."""
import pytest

import jax

from repro.testing import make_toy_problem  # canonical home (rootdir-safe)

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def toy_problem():
    return make_toy_problem()


@pytest.fixture(scope="session")
def cloud_catalog():
    from repro.core import make_cloud_catalog
    return make_cloud_catalog()


@pytest.fixture(scope="session")
def small_catalog():
    """A trimmed catalog (every 20th instance) keeping both providers —
    scenario-scale tests stay fast on one CPU core."""
    from repro.core import Catalog, make_cloud_catalog
    cat = make_cloud_catalog()
    return Catalog(cat.instances[::20])

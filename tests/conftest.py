"""Shared fixtures. NOTE: XLA_FLAGS device-count forcing is deliberately NOT
set here — tests run with the single real CPU device; only launch/dryrun.py
forces 512 placeholder devices (and a few subprocess-based tests set it in
their own child process environment)."""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", False)


def make_toy_problem(seed=0, m=3, n=12, p=2, alpha=0.02, beta3=10.0,
                     demand_scale=1.0, gamma=0.005):
    """Small random-but-sane allocation problem for unit/property tests."""
    from repro.core import AllocationProblem, PenaltyParams

    rng = np.random.default_rng(seed)
    K = rng.uniform(0.2, 2.0, size=(m, n)).astype(np.float32)
    c = (K.sum(axis=0) * rng.uniform(0.05, 0.2, size=n)).astype(np.float32)
    E = np.zeros((p, n), np.float32)
    E[rng.integers(0, p, size=n), np.arange(n)] = 1.0
    d = (rng.uniform(1.0, 4.0, size=m) * demand_scale).astype(np.float32)
    params = PenaltyParams.create(alpha=alpha, beta1=1.0, beta2=0.1,
                                  beta3=beta3, gamma=gamma)
    return AllocationProblem.create(K, E, c, d, params=params, ub_default=100.0)


@pytest.fixture(scope="session")
def toy_problem():
    return make_toy_problem()


@pytest.fixture(scope="session")
def cloud_catalog():
    from repro.core import make_cloud_catalog
    return make_cloud_catalog()


@pytest.fixture(scope="session")
def small_catalog():
    """A trimmed catalog (every 20th instance) keeping both providers —
    scenario-scale tests stay fast on one CPU core."""
    from repro.core import Catalog, make_cloud_catalog
    cat = make_cloud_catalog()
    return Catalog(cat.instances[::20])

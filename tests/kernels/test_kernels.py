"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
ref.py pure-jnp oracles (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing import make_toy_problem

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# alloc_objective — the paper's solver hot loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,m,n,p,S", [
    (0, 4, 37, 2, 13), (1, 3, 128, 2, 64), (2, 4, 200, 3, 32),
    (3, 2, 16, 2, 1), (4, 4, 1880, 2, 8),
])
def test_alloc_objective_matches_ref(seed, m, n, p, S):
    from repro.kernels.alloc_objective.ops import batched_value_and_grad
    from repro.kernels.alloc_objective.ref import alloc_objective_ref
    prob = make_toy_problem(seed=seed, m=m, n=n, p=p)
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(0, 5, (S, n)), jnp.float32)
    f, g = batched_value_and_grad(prob, X)
    P = prob.params
    fr, gr = alloc_objective_ref(X, prob.K, prob.E, prob.c, prob.d,
                                 P.alpha, P.beta1, P.beta2, P.beta3, P.gamma)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-4)


def test_alloc_objective_matches_core_objective(toy_problem):
    """The kernel must agree with repro.core.objective exactly (same math)."""
    import repro.core.objective as obj
    from repro.kernels.alloc_objective.ops import batched_value_and_grad
    rng = np.random.default_rng(9)
    X = jnp.asarray(rng.uniform(0, 4, (5, toy_problem.n)), jnp.float32)
    f, g = batched_value_and_grad(toy_problem, X)
    for i in range(5):
        np.testing.assert_allclose(float(f[i]),
                                   float(obj.objective(toy_problem, X[i])),
                                   rtol=1e-4)
        np.testing.assert_allclose(np.asarray(g[i]),
                                   np.asarray(obj.grad_objective(toy_problem, X[i])),
                                   rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,G,dh,win,bq,bk", [
    (2, 128, 4, 2, 32, 0, 32, 32),
    (1, 256, 8, 8, 16, 0, 64, 64),
    (2, 128, 4, 1, 32, 48, 32, 32),     # MQA + sliding window
    (1, 64, 2, 2, 128, 0, 64, 32),
    (1, 128, 6, 3, 64, 0, 128, 128),    # single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, G, dh, win, bq, bk, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    rng = np.random.default_rng(B * S + H)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), dtype)
    out = flash_attention(q, k, v, window=win, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               **TOL[dtype])


def test_flash_attention_matches_model_path():
    """Kernel vs the model's _chunked_flash (the production jnp path)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.attention import _chunked_flash
    rng = np.random.default_rng(3)
    B, S, H, G, dh = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, G, dh)), jnp.float32)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = _chunked_flash(q, k, v, 0, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,G,S,dh,bk,nvalid", [
    (2, 4, 2, 256, 32, 64, 256),
    (1, 8, 1, 128, 64, 32, 100),
    (2, 2, 2, 512, 16, 128, 307),
    (1, 4, 4, 64, 128, 64, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, H, G, S, dh, bk, nvalid, dtype):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    rng = np.random.default_rng(S + nvalid)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, H, dh)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, G, S, dh)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, G, S, dh)), dtype)
    valid = jnp.arange(S) < nvalid
    out = decode_attention(q, k, v, valid, block_k=bk)
    ref = decode_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), valid)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               **TOL[dtype])


# ---------------------------------------------------------------------------
# rwkv6_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hs,chunk", [
    (2, 64, 2, 16, 16), (1, 128, 4, 32, 32), (2, 96, 1, 8, 48),
    (1, 64, 2, 64, 64),
])
def test_rwkv6_scan_matches_ref(B, S, H, hs, chunk):
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
    rng = np.random.default_rng(B * S + hs)
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, S, H, hs)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.7, 0.999, (B, S, H, hs)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (H, hs)), jnp.float32)
    s0 = jnp.asarray(rng.normal(0, 0.5, (B, H, hs, hs)), jnp.float32)
    y, sf = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk)
    yr, sr = rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), rtol=1e-3, atol=1e-3)


def test_rwkv6_scan_matches_model_chunked():
    """Kernel vs the model's _wkv_chunked (the production jnp path)."""
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan
    from repro.models.rwkv import _wkv_chunked
    rng = np.random.default_rng(11)
    B, S, H, hs = 1, 128, 2, 16
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, S, H, hs)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.8, 0.999, (B, S, H, hs)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (H, hs)), jnp.float32)
    s0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    y1, s1 = rwkv6_scan(r, k, v, w, u, s0, chunk=32)
    y2, s2 = _wkv_chunked(r, k, v, w, u, s0, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-3)

"""End-to-end scenario tests: the paper's comparison pipeline (§IV) on the
full catalog — optimizer vs CA, metric sanity, reproduction bands."""
import numpy as np
import pytest

from repro.core import (build_scenarios, evaluate, optimize,
                        simulate_cluster_autoscaler)


@pytest.fixture(scope="module")
def scenario_results(cloud_catalog):
    out = {}
    for s in build_scenarios(cloud_catalog):
        res = optimize(cloud_catalog, s, n_starts=6)
        ca_costs = [evaluate(cloud_catalog,
                             simulate_cluster_autoscaler(
                                 cloud_catalog, s.pools, s.demand, seed=sd).counts,
                             s.demand).total_cost for sd in range(3)]
        out[s.name] = (s, res, float(np.median(ca_costs)))
    return out


def test_all_scenarios_satisfied(scenario_results):
    for name, (s, res, _) in scenario_results.items():
        assert res.metrics.satisfied, f"{name} optimizer failed demand"


def test_allocations_are_integral(scenario_results):
    for name, (s, res, _) in scenario_results.items():
        assert np.allclose(res.counts, np.round(res.counts)), name


def test_optimizer_beats_or_matches_ca(scenario_results):
    """The paper's headline: optimization >= CA everywhere (S1 ~parity)."""
    for name, (s, res, ca_cost) in scenario_results.items():
        assert res.metrics.total_cost <= ca_cost * 1.05, (
            f"{name}: opt ${res.metrics.total_cost:.3f} vs CA ${ca_cost:.3f}")


def test_large_savings_in_constrained_scenarios(scenario_results):
    """Paper: scenarios 3-5 show the big savings (80.5/87.2/71.1%).
    We assert the direction with slack: >= 40% each."""
    for name in ("s3_enterprise", "s4_memory", "s5_constrained"):
        s, res, ca_cost = scenario_results[name]
        save = 100 * (ca_cost - res.metrics.total_cost) / ca_cost
        assert save >= 40.0, f"{name}: only {save:.1f}% savings"


def test_average_savings_band(scenario_results):
    """Paper avg 56.3% — accept the 30-85% band for synthetic catalogs."""
    saves = []
    for name, (s, res, ca_cost) in scenario_results.items():
        saves.append(100 * (ca_cost - res.metrics.total_cost) / ca_cost)
    assert 30.0 <= float(np.mean(saves)) <= 85.0


def test_restricted_scenarios_stay_in_allowed_set(scenario_results, cloud_catalog):
    for name in ("s3_enterprise", "s5_constrained"):
        s, res, _ = scenario_results[name]
        used = np.nonzero(res.counts)[0]
        allowed = set(np.asarray(s.allowed_idx).tolist())
        allowed |= set(np.nonzero(s.existing)[0].tolist())
        assert set(used.tolist()) <= allowed, name


def test_existing_allocation_respected(scenario_results):
    s, res, _ = scenario_results["s2_scaling"]
    assert np.all(res.counts >= s.existing - 1e-6)


def test_metrics_fields(scenario_results, cloud_catalog):
    s, res, _ = scenario_results["s1_greenfield"]
    m = res.metrics
    assert m.total_cost > 0
    assert 0 < m.utilization_pct <= 100
    assert m.instance_diversity >= 1
    assert m.provider_fragmentation in (1, 2)
    assert m.overprovision_pct >= 0

"""Unit + property tests for the eq.(1) objective and its analytic gradient."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import repro.core.objective as obj
from repro.core import PenaltyParams
from repro.testing import make_toy_problem


def _np_objective(prob, x):
    """Independent numpy re-implementation of eq. (1)."""
    P = prob.params
    K, E, c, d = map(np.asarray, (prob.K, prob.E, prob.c, prob.d))
    x = np.asarray(x)
    a, b1, b2, b3, g = (float(P.alpha), float(P.beta1), float(P.beta2),
                        float(P.beta3), float(P.gamma))
    Kx, Ex = K @ x, E @ x
    p = E.shape[0]
    val = c @ x
    val += a * p - a * np.sum(np.exp(-b1 * Ex))
    val += -g * np.sum(np.log1p(b2 * Ex))
    val += b3 * np.sum(np.maximum(d - Kx, 0.0) ** 2)
    return val


def test_objective_matches_numpy(toy_problem):
    rng = np.random.default_rng(1)
    for _ in range(5):
        x = jnp.asarray(rng.uniform(0, 5, toy_problem.n), jnp.float32)
        np.testing.assert_allclose(
            float(obj.objective(toy_problem, x)),
            _np_objective(toy_problem, x), rtol=1e-5)


def test_objective_terms_sum(toy_problem):
    x = jnp.ones(toy_problem.n)
    t = obj.objective_terms(toy_problem, x)
    total = sum(float(v) for v in t.values())
    np.testing.assert_allclose(total, float(obj.objective(toy_problem, x)),
                               rtol=1e-6)


def test_grad_matches_autodiff(toy_problem):
    """The hand-derived eq.(6) gradient must equal jax.grad of the objective
    (away from the max(0,.) kink)."""
    rng = np.random.default_rng(2)
    auto = jax.grad(lambda x: obj.objective(toy_problem, x))
    for _ in range(5):
        x = jnp.asarray(rng.uniform(0.5, 5, toy_problem.n), jnp.float32)
        np.testing.assert_allclose(np.asarray(obj.grad_objective(toy_problem, x)),
                                   np.asarray(auto(x)), rtol=2e-4, atol=2e-4)


def test_composite_grad_matches_autodiff(toy_problem):
    rng = np.random.default_rng(3)
    for use_barrier in (False, True):
        if use_barrier:
            # need a strictly feasible point for finite barrier
            from repro.core.solver import phase1_point
            x = phase1_point(toy_problem, jnp.full(toy_problem.n, 2.0))
            lo, hi = obj.constraint_residuals(toy_problem, x)
            if float(jnp.min(lo)) <= 1e-3 or float(jnp.min(hi)) <= 1e-3:
                pytest.skip("no strict interior found for barrier check")
        else:
            x = jnp.asarray(rng.uniform(0.5, 3, toy_problem.n), jnp.float32)
        t, w, ub = jnp.asarray(2.0), jnp.asarray(10.0), jnp.asarray(use_barrier)
        auto = jax.grad(lambda z: obj.composite(toy_problem, z, t, w, ub))(x)
        manual = obj.composite_grad(toy_problem, x, t, w, ub)
        np.testing.assert_allclose(np.asarray(manual), np.asarray(auto),
                                   rtol=5e-3, atol=5e-3)


def test_consolidation_term_bounds(toy_problem):
    """0 <= consolidation <= alpha * p, ->0 at x=0, -> alpha*p as x->inf."""
    P = toy_problem.params
    p = toy_problem.p
    t0 = obj.objective_terms(toy_problem, jnp.zeros(toy_problem.n))
    assert abs(float(t0["consolidation"])) < 1e-6
    tb = obj.objective_terms(toy_problem, jnp.full(toy_problem.n, 1e4))
    np.testing.assert_allclose(float(tb["consolidation"]),
                               float(P.alpha) * p, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(0.1, 10.0))
def test_objective_finite_and_grad_consistent(seed, scale):
    prob = make_toy_problem(seed=seed, demand_scale=scale)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.uniform(0, 10, prob.n), jnp.float32)
    f = float(obj.objective(prob, x))
    assert np.isfinite(f)
    g = np.asarray(obj.grad_objective(prob, x))
    assert np.all(np.isfinite(g))
    # descent along -g must reduce f locally (first-order sanity)
    eps = 1e-3 / (np.linalg.norm(g) + 1e-9)
    f2 = float(obj.objective(prob, x - eps * jnp.asarray(g)))
    assert f2 <= f + 1e-5


def test_convexity_on_convex_subset():
    """With alpha=0 the objective is convex: check midpoint inequality on
    random segments."""
    prob = make_toy_problem(alpha=0.0)
    rng = np.random.default_rng(7)
    for _ in range(20):
        x1 = jnp.asarray(rng.uniform(0, 8, prob.n), jnp.float32)
        x2 = jnp.asarray(rng.uniform(0, 8, prob.n), jnp.float32)
        fm = float(obj.objective(prob, 0.5 * (x1 + x2)))
        favg = 0.5 * (float(obj.objective(prob, x1)) +
                      float(obj.objective(prob, x2)))
        assert fm <= favg + 1e-4


def test_projection(toy_problem):
    x = jnp.asarray(np.linspace(-5, 150, toy_problem.n), jnp.float32)
    px = obj.project(toy_problem, x)
    assert float(jnp.min(px)) >= 0.0
    assert float(jnp.max(px)) <= float(jnp.max(toy_problem.ub))
    # idempotent
    np.testing.assert_allclose(np.asarray(obj.project(toy_problem, px)),
                               np.asarray(px))

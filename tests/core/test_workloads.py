"""Roofline -> allocator demand-vector integration tests."""
import numpy as np

from repro.core.workloads import (JobSpec, demand_from_job,
                                  demand_from_dryrun_record, fleet_demand)


def test_demand_from_job_units():
    job = JobSpec(name="j", hlo_flops=197e12 * 100, hlo_bytes=1e12,
                  collective_bytes=50e9, bytes_per_device=8e9, devices=256,
                  step_budget_s=1.0, host_ram_gb=64)
    d = demand_from_job(job)
    assert abs(d[0] - 100.0) < 1e-6          # chips for compute
    assert abs(d[1] - 8 * 256) < 1e-6        # HBM GB
    assert abs(d[2] - 50.0) < 1e-6           # ICI GB/s
    assert d[3] == 64


def test_demand_from_dryrun_record_and_fleet():
    rec = {"cell": "x__train_4k", "flops": 1e12, "bytes_accessed": 1e11,
           "collective_bytes": 1e10, "bytes_per_device": 4e9, "devices": 256}
    d = demand_from_dryrun_record(rec)
    assert d.shape == (4,) and np.all(d >= 0)
    total = fleet_demand([rec, rec])
    np.testing.assert_allclose(total, 2 * d)

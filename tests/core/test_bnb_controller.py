"""Branch-and-bound, controller, Pareto, and KKT-on-scenario tests."""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.objective as obj
from repro.core import (InfrastructureOptimizationController, branch_and_bound,
                        build_scenarios, grid_search, kkt_report, optimize,
                        pareto_mask, problem_from_scenario, sensitivity,
                        solve_relaxation, SolverConfig)

from repro.testing import make_toy_problem


def test_bnb_never_worse_than_rounding(toy_problem):
    cfg = SolverConfig(max_iters=200, barrier_rounds=2)
    res = solve_relaxation(toy_problem, jnp.zeros(toy_problem.n), cfg)
    from repro.core import round_and_polish
    f_round = float(obj.objective(toy_problem,
                                  round_and_polish(toy_problem, res.x)))
    bnb = branch_and_bound(toy_problem, np.asarray(res.x), max_nodes=16, cfg=cfg)
    assert bnb.fun <= f_round + 1e-5
    assert np.allclose(bnb.x, np.round(bnb.x))
    assert bool(obj.is_feasible(toy_problem, jnp.asarray(bnb.x, jnp.float32), 1e-3))


def test_bnb_explores_and_reports(toy_problem):
    bnb = branch_and_bound(toy_problem, max_nodes=8)
    assert bnb.nodes_explored >= 1
    assert bnb.gap >= 0.0


@pytest.mark.slow
def test_controller_churn_bounded():
    from repro.core import Catalog, make_cloud_catalog
    cat = Catalog(make_cloud_catalog().instances[::40])
    ctl = InfrastructureOptimizationController(catalog=cat, delta_max=5.0,
                                               n_starts=2)
    d = np.array([8, 16, 4, 100], np.float64)
    first = ctl.step(d)
    assert first.metrics.satisfied
    # small demand bump: churn stays ~bounded (rounding may add slack of a
    # few units to preserve feasibility, which dominates the bound check)
    second = ctl.step(d * 1.1)
    assert second.metrics.satisfied
    assert second.churn <= 5.0 + 8.0  # delta + rounding slack


@pytest.mark.slow
def test_controller_failure_replan():
    from repro.core import Catalog, make_cloud_catalog
    cat = Catalog(make_cloud_catalog().instances[::40])
    ctl = InfrastructureOptimizationController(catalog=cat, delta_max=4.0,
                                               n_starts=2)
    d = np.array([16, 32, 8, 200], np.float64)
    ctl.step(d)
    # half the fleet dies
    failed = np.ceil(ctl.x_current * 0.5)
    st = ctl.replan_on_failure(failed, d)
    assert st.metrics.satisfied


def test_pareto_mask_handcrafted():
    pts = np.array([[1.0, 5.0], [2.0, 2.0], [3.0, 3.0], [5.0, 1.0]])
    mask = pareto_mask(pts)
    assert mask.tolist() == [True, True, False, True]


def test_grid_search_and_sensitivity(toy_problem):
    pts = grid_search(toy_problem, alphas=(0.01, 0.1), gammas=(0.001, 0.01))
    assert len(pts) == 4
    assert any(p.on_frontier for p in pts)
    from repro.core import PenaltyParams
    sens = sensitivity(toy_problem, PenaltyParams.create())
    assert set(sens) == {"alpha", "beta1", "beta2", "beta3", "gamma"}
    assert all(np.isfinite(v) for v in sens.values())


def test_kkt_on_scenario(small_catalog):
    from repro.core import Scenario
    s = build_scenarios(small_catalog)[0] if False else None
    # build a scenario directly on the small catalog
    demand = np.array([8, 16, 4, 100], np.float64)
    scen = Scenario(name="t", title="t", demand=demand, allowed_idx=None,
                    pools=[], existing=np.zeros(small_catalog.n))
    prob = problem_from_scenario(small_catalog, scen)
    res = solve_relaxation(prob, jnp.zeros(prob.n),
                           SolverConfig(max_iters=300, barrier_rounds=3))
    rep = kkt_report(prob, res.x)
    assert float(rep.primal_lo) <= 1e-2
    assert float(rep.dual) <= 1e-6

"""Incremental adoption (paper III.E): L1-ball projection properties and the
bounded-churn solve."""
import pytest
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

from repro.core import project_l1_ball, project_incremental, solve_incremental
from repro.testing import make_toy_problem


@pytest.mark.slow
@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), radius=st.floats(0.1, 20.0), dim=st.integers(2, 40))
def test_l1_projection_properties(seed, radius, dim):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(0, 5, dim), jnp.float32)
    w = project_l1_ball(v, jnp.asarray(radius, jnp.float32))
    # inside the ball
    assert float(jnp.sum(jnp.abs(w))) <= radius * (1 + 1e-4) + 1e-5
    # idempotent
    w2 = project_l1_ball(w, jnp.asarray(radius, jnp.float32))
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=1e-5)
    # no-op when already inside
    if float(jnp.sum(jnp.abs(v))) <= radius:
        np.testing.assert_allclose(np.asarray(w), np.asarray(v), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_l1_projection_is_closest_point(seed):
    """Projection must beat random candidates inside the ball on distance."""
    rng = np.random.default_rng(seed)
    dim, radius = 10, 3.0
    v = jnp.asarray(rng.normal(0, 4, dim), jnp.float32)
    w = np.asarray(project_l1_ball(v, jnp.asarray(radius, jnp.float32)))
    dist_w = np.linalg.norm(np.asarray(v) - w)
    for _ in range(20):
        z = rng.normal(0, 2, dim)
        norm = np.abs(z).sum()
        if norm > radius:
            z = z * (radius / norm)
        assert dist_w <= np.linalg.norm(np.asarray(v) - z) + 1e-4


def test_project_incremental_respects_both_sets(toy_problem):
    x_cur = jnp.full(toy_problem.n, 2.0)
    x = jnp.asarray(np.linspace(-3, 9, toy_problem.n), jnp.float32)
    delta = jnp.asarray(4.0)
    z = project_incremental(toy_problem, x, x_cur, delta)
    assert float(jnp.min(z)) >= -1e-6                       # box
    assert float(jnp.sum(jnp.abs(z - x_cur))) <= 4.0 + 1e-3  # churn bound


def test_solve_incremental_bounded_churn():
    prob = make_toy_problem(seed=3)
    x_cur = jnp.full(prob.n, 1.0)
    for delta in (0.5, 2.0, 8.0):
        x = solve_incremental(prob, x_cur, delta)
        churn = float(jnp.sum(jnp.abs(x - x_cur)))
        assert churn <= delta + 1e-3

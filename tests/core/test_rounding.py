"""Greedy rounding (paper III.B) properties: integrality, feasibility,
monotone coverage; scale-down never breaks feasibility."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import repro.core.objective as obj
from repro.core import greedy_round, round_and_polish, scale_down, solve_relaxation, SolverConfig
from repro.testing import make_toy_problem


def _covers(prob, x):
    Kx = np.asarray(prob.K) @ np.asarray(x)
    return np.all(Kx >= np.asarray(prob.d - prob.mu) - 1e-5)


def test_rounding_integral_and_feasible(toy_problem):
    res = solve_relaxation(toy_problem, jnp.zeros(toy_problem.n),
                           SolverConfig(max_iters=200, barrier_rounds=2))
    x = np.asarray(greedy_round(toy_problem, res.x))
    assert np.allclose(x, np.round(x))
    assert _covers(toy_problem, x)


def test_round_and_polish_not_worse(toy_problem):
    res = solve_relaxation(toy_problem, jnp.zeros(toy_problem.n),
                           SolverConfig(max_iters=200, barrier_rounds=2))
    xa = greedy_round(toy_problem, res.x)
    xb = round_and_polish(toy_problem, res.x)
    fa = float(obj.objective(toy_problem, xa))
    fb = float(obj.objective(toy_problem, xb))
    assert fb <= fa + 1e-4
    assert _covers(toy_problem, np.asarray(xb))


def test_scale_down_keeps_feasibility(toy_problem):
    x = jnp.full(toy_problem.n, 6.0)  # heavily over-provisioned
    xd = scale_down(toy_problem, x)
    assert _covers(toy_problem, np.asarray(xd))
    assert float(jnp.sum(xd)) <= float(jnp.sum(x))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_rounding_properties(seed):
    prob = make_toy_problem(seed=seed)
    rng = np.random.default_rng(seed + 13)
    x_star = jnp.asarray(rng.uniform(0, 3, prob.n), jnp.float32)
    x = np.asarray(greedy_round(prob, x_star))
    # integral
    assert np.allclose(x, np.round(x))
    # never below floor of input (clipped)
    floor = np.floor(np.clip(np.asarray(x_star), np.asarray(prob.lb),
                             np.asarray(prob.ub))) * np.asarray(prob.mask)
    assert np.all(x >= floor - 1e-6)
    # covers demand (toy problems always have full coverage available)
    assert _covers(prob, x)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_scale_down_properties(seed):
    prob = make_toy_problem(seed=seed)
    x = jnp.asarray(np.full(prob.n, 5.0), jnp.float32)
    xd = np.asarray(scale_down(prob, x))
    assert _covers(prob, xd)
    assert np.allclose(xd, np.round(xd))
    # removal is monotone: no count increased
    assert np.all(xd <= 5.0 + 1e-6)

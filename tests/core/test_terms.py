"""The priced-term objective IR (repro.core.terms).

Three contracts, in rough order of importance:

1. jaxpr identity — with ``terms=()`` (the default), ``objective`` and
   ``grad_objective`` must trace to the BYTE-IDENTICAL jaxpr of the seed
   (pre-IR) implementation, replicated verbatim here. This is the static-
   omission guarantee every bit-exactness test in the repo leans on.
2. per-term autodiff — every registered term's analytic gradient matches
   ``jax.grad`` of its value function: unbatched, under vmap, and on
   zero-padded problems (the fleet-stacking regime).
3. attachment discipline — ``make_term`` / ``with_terms`` validation,
   zero-params exact no-op (padding exactness), fused ``value_and_grad``
   exact equality, and the fleet stack/slice round-trip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.objective as obj
from repro.core.problem import AllocationProblem
from repro.core.terms import (BASE_TERMS, SCENARIO_TERMS, TERM_DEFS,
                              PricedTerm, active_grad, active_value,
                              make_term, normalize_terms, register_term,
                              term_signature, with_terms)
from repro.fleet.batching import stack_problems, tenant_problem, union_term_kinds
from repro.testing import make_toy_problem


def _x(prob, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.0, 3.0, size=prob.n), jnp.float32)


def _scenario_params(prob, kind, seed=1, zero=False):
    """Random (or zero) params for an attachable kind, at problem shape."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, ax in TERM_DEFS[kind].param_axes.items():
        shape = {"": (), "n": (prob.n,), "m": (prob.m,)}[ax]
        out[k] = (np.zeros(shape, np.float32) if zero
                  else rng.uniform(0.05, 0.5, size=shape).astype(np.float32))
    return out


def _attach_all(prob, seed=1, zero=False):
    return with_terms(prob, [make_term(k, **_scenario_params(prob, k, seed,
                                                             zero=zero))
                             for k in SCENARIO_TERMS])


# ---------------------------------------------------------------------------
# 1. jaxpr identity with terms=()
# ---------------------------------------------------------------------------


def _seed_objective(prob, x):
    """The pre-IR eq. (1) objective, verbatim (git 73d97b2)."""
    P = prob.params
    Kx = prob.K @ x
    Ex = prob.E @ x
    base_cost = prob.c @ x
    consolidation = P.alpha * jnp.sum(1.0 - jnp.exp(-P.beta1 * Ex))
    volume_discount = -P.gamma * jnp.sum(jnp.log1p(P.beta2 * Ex))
    shortage = jnp.maximum(prob.d - Kx, 0.0)
    shortage_pen = P.beta3 * jnp.sum(shortage**2)
    return base_cost + consolidation + volume_discount + shortage_pen


def _seed_grad(prob, x):
    """The pre-IR analytic gradient, verbatim (git 73d97b2)."""
    P = prob.params
    Kx = prob.K @ x
    Ex = prob.E @ x
    g_consol = P.alpha * P.beta1 * (prob.E.T @ jnp.exp(-P.beta1 * Ex))
    g_volume = -P.gamma * P.beta2 * (prob.E.T @ (1.0 / (1.0 + P.beta2 * Ex)))
    shortage = jnp.maximum(prob.d - Kx, 0.0)
    g_short = -2.0 * P.beta3 * (prob.K.T @ shortage)
    return prob.c + g_consol + g_volume + g_short


def test_default_terms_jaxpr_identical_to_seed():
    """terms=() must be STATICALLY omitted: the registry-sum objective and
    gradient trace to the exact seed jaxpr — not numerically close, the
    same program."""
    prob = make_toy_problem(seed=0)
    assert prob.terms == ()
    x = _x(prob)
    assert str(jax.make_jaxpr(obj.objective)(prob, x)) == \
        str(jax.make_jaxpr(_seed_objective)(prob, x))
    assert str(jax.make_jaxpr(obj.grad_objective)(prob, x)) == \
        str(jax.make_jaxpr(_seed_grad)(prob, x))


def test_attached_terms_change_value_not_structure():
    prob = make_toy_problem(seed=0)
    probT = _attach_all(prob)
    x = _x(prob)
    assert term_signature(probT) == SCENARIO_TERMS
    assert float(obj.objective(probT, x)) > float(obj.objective(prob, x))
    # static structure: jit caches key on the kind tuple, so the same kinds
    # with different prices reuse one compiled program
    f = jax.jit(obj.objective)
    f(probT, x)
    probT2 = _attach_all(prob, seed=9)
    f(probT2, x)
    assert f._cache_size() == 1


# ---------------------------------------------------------------------------
# 2. per-term analytic gradient == jax.grad (property, full registry)
# ---------------------------------------------------------------------------


def _term_value_fn(prob, kind, params):
    td = TERM_DEFS[kind]

    def value(x):
        return td.value(prob, params, x, prob.K @ x, prob.E @ x)

    return value


@pytest.mark.parametrize("kind", sorted(TERM_DEFS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_term_grad_matches_autodiff(kind, seed):
    """Each registered term's hand-written gradient IS the derivative of
    its value function (away from hinge ties — the draw keeps d - Kx
    bounded away from 0 with probability 1)."""
    prob = make_toy_problem(seed=seed)
    params = (None if not TERM_DEFS[kind].param_axes
              else _scenario_params(prob, kind, seed + 10))
    x = _x(prob, seed)
    value = _term_value_fn(prob, kind, params)
    g_auto = jax.grad(value)(x)
    g_hand = TERM_DEFS[kind].grad(prob, params, x, prob.K @ x, prob.E @ x)
    g_hand = jnp.broadcast_to(g_hand, g_auto.shape)  # constant-grad terms
    np.testing.assert_allclose(np.asarray(g_hand), np.asarray(g_auto),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kind", sorted(TERM_DEFS))
def test_term_grad_matches_autodiff_vmapped(kind):
    """Same property under vmap over a batch of x — the fleet regime."""
    prob = make_toy_problem(seed=3)
    params = (None if not TERM_DEFS[kind].param_axes
              else _scenario_params(prob, kind, 13))
    X = jnp.stack([_x(prob, s) for s in range(4)])
    value = _term_value_fn(prob, kind, params)
    G_auto = jax.vmap(jax.grad(value))(X)
    G_hand = jax.vmap(lambda x: jnp.broadcast_to(
        TERM_DEFS[kind].grad(prob, params, x, prob.K @ x, prob.E @ x),
        x.shape))(X)
    np.testing.assert_allclose(np.asarray(G_hand), np.asarray(G_auto),
                               rtol=1e-5, atol=1e-6)


def test_full_objective_grad_matches_autodiff_with_terms():
    """The summed registry gradient equals jax.grad of the summed value,
    with every scenario term attached."""
    for seed in range(3):
        prob = _attach_all(make_toy_problem(seed=seed), seed=seed + 20)
        x = _x(prob, seed)
        g_auto = jax.grad(lambda x_: obj.objective(prob, x_))(x)
        g_hand = obj.grad_objective(prob, x)
        np.testing.assert_allclose(np.asarray(g_hand), np.asarray(g_auto),
                                   rtol=1e-5, atol=1e-6)


def test_padded_problem_terms_exact():
    """Padding exactness: a problem zero-padded to a larger bucket (extra
    types AND an absent term at zero params) yields the bit-identical
    objective/gradient on the true coordinates."""
    a = make_toy_problem(seed=0, n=10)
    b = make_toy_problem(seed=1, n=6)
    a = with_terms(a, [make_term("slo_penalty", price=0.3)])
    b = with_terms(b, [make_term("spot_risk",
                                 risk=_scenario_params(b, "spot_risk",
                                                       5)["risk"])])
    batch = stack_problems([a, b])
    xa = _x(a, 7)
    for i, orig in enumerate((a, b)):
        sub = tenant_problem(batch, i)
        x = _x(orig, 7)
        x_pad = jnp.zeros(batch.n_max).at[: orig.n].set(x)
        pb = jax.tree_util.tree_map(lambda l: l[i], batch.problem)
        # padded batch row vs the original unpadded problem: same bits
        assert float(obj.objective(pb, x_pad)) == float(
            obj.objective(orig, x))
        np.testing.assert_array_equal(
            np.asarray(obj.grad_objective(pb, x_pad))[: orig.n],
            np.asarray(obj.grad_objective(orig, x)))
        # and the round-trip slice reproduces the original terms exactly
        assert term_signature(sub) == union_term_kinds([a, b])


# ---------------------------------------------------------------------------
# 3. attachment discipline
# ---------------------------------------------------------------------------


def test_fused_value_and_grad_exact():
    """Satellite regression: the fused value_and_grad (one K@x/E@x pair)
    returns EXACTLY objective() and grad_objective() — same bits, with and
    without attached terms."""
    for prob in (make_toy_problem(seed=4),
                 _attach_all(make_toy_problem(seed=4))):
        x = _x(prob, 11)
        v, g = obj.value_and_grad(prob, x)
        assert float(v) == float(obj.objective(prob, x))
        np.testing.assert_array_equal(np.asarray(g),
                                      np.asarray(obj.grad_objective(prob, x)))


def test_zero_params_exact_noop():
    """A term at zero params contributes exactly 0.0 value and exactly zero
    gradient — the invariant zero-fill padding relies on."""
    prob = make_toy_problem(seed=2)
    probZ = _attach_all(prob, zero=True)
    x = _x(prob, 3)
    assert float(obj.objective(probZ, x)) == float(obj.objective(prob, x))
    np.testing.assert_array_equal(np.asarray(obj.grad_objective(probZ, x)),
                                  np.asarray(obj.grad_objective(prob, x)))
    assert float(active_value(probZ, x)) == 0.0
    np.testing.assert_array_equal(np.asarray(active_grad(probZ, x)),
                                  np.zeros(prob.n, np.float32))


def test_make_term_validation():
    with pytest.raises(ValueError, match="unknown term kind"):
        make_term("nope", price=1.0)
    with pytest.raises(ValueError, match="implicit"):
        make_term("base_cost")
    with pytest.raises(ValueError, match="expects params"):
        make_term("slo_penalty", prices=1.0)
    with pytest.raises(ValueError, match="expects params"):
        make_term("slo_penalty")
    t = make_term("slo_penalty", price=2)
    assert t.params["price"].dtype == jnp.float32


def test_with_terms_validation():
    prob = make_toy_problem(seed=0)
    with pytest.raises(ValueError, match="expected shape"):
        with_terms(prob, [make_term("spot_risk",
                                    risk=np.ones(prob.n + 1, np.float32))])
    with pytest.raises(ValueError, match="duplicate"):
        with_terms(prob, [make_term("slo_penalty", price=1.0),
                          ("slo_penalty", {"price": 2.0})])
    # (kind, params) pairs are accepted and normalized
    probT = with_terms(prob, [("slo_penalty", {"price": 1.5})])
    assert term_signature(probT) == ("slo_penalty",)
    assert normalize_terms(probT.terms) == probT.terms \
        or [t.kind for t in normalize_terms(probT.terms)] == ["slo_penalty"]


def test_register_term_validation():
    with pytest.raises(ValueError, match="already registered"):
        register_term("base_cost", _seed_objective, _seed_grad)
    with pytest.raises(ValueError, match="invalid param axes"):
        register_term("bad_axes", _seed_objective, _seed_grad,
                      {"w": "q"})


def test_priced_term_pytree_round_trip():
    t = make_term("slo_penalty", price=0.7)
    leaves, treedef = jax.tree_util.tree_flatten(t)
    assert len(leaves) == 1
    t2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(t2, PricedTerm) and t2.kind == "slo_penalty"
    # problems with terms flow through tree_map like any other field
    prob = with_terms(make_toy_problem(seed=0), [t])
    doubled = jax.tree_util.tree_map(lambda l: l * 2, prob)
    assert float(doubled.terms[0].params["price"]) == pytest.approx(1.4)


def test_base_terms_cover_seed_objective():
    """The base registry entries reproduce the seed term split exactly."""
    prob = make_toy_problem(seed=6)
    x = _x(prob, 6)
    terms = obj.objective_terms(prob, x)
    assert tuple(terms) == BASE_TERMS
    assert float(sum(terms.values())) == pytest.approx(
        float(_seed_objective(prob, x)), rel=1e-6)

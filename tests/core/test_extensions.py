"""Paper §VII future-work features (beyond-paper implementation): HA
constraints, zone spread, anti-affinity, reserved/spot pricing tiers."""
import jax.numpy as jnp
import numpy as np

import repro.core.objective as obj
from repro.core import (Catalog, Scenario, make_cloud_catalog, multistart_solve,
                        problem_from_scenario, round_and_polish)
from repro.core.extensions import (HAPolicy, PricingTiers, apply_ha,
                                   cap_reserved, enforce_anti_affinity,
                                   tiered_catalog, zone_replicated_catalog)


def _small():
    cat = Catalog(make_cloud_catalog().instances[::40])
    demand = np.array([16, 32, 8, 200], np.float64)
    scen = Scenario(name="x", title="x", demand=demand, allowed_idx=None,
                    pools=[], existing=np.zeros(cat.n))
    return cat, scen


def test_ha_min_replicas_enforced():
    cat, scen = _small()
    prob = problem_from_scenario(cat, scen)
    j = int(cat.select(lambda t: 2 <= t.cpu <= 4)[0])
    prob = apply_ha(prob, HAPolicy(min_replicas={j: 3}))
    ms = multistart_solve(prob, n_starts=2)
    x = np.asarray(ms.x_int)
    assert x[j] >= 3
    assert bool(obj.is_feasible(prob, jnp.asarray(x, jnp.float32), 1e-3))


def test_zone_spread():
    cat, scen = _small()
    zcat = zone_replicated_catalog(cat, zones=3)
    assert zcat.n == 3 * cat.n
    zscen = Scenario(name="z", title="z", demand=scen.demand, allowed_idx=None,
                     pools=[], existing=np.zeros(zcat.n))
    prob = problem_from_scenario(zcat, zscen)
    j = int(cat.select(lambda t: 2 <= t.cpu <= 4)[0])
    prob = apply_ha(prob, HAPolicy(min_replicas={j: 3}, zones=3),
                    n_base=cat.n)
    lb = np.asarray(prob.lb)
    for z in range(3):
        assert lb[z * cat.n + j] >= 1    # ceil(3/3) per zone


def test_anti_affinity_repair():
    cat, scen = _small()
    prob = problem_from_scenario(cat, scen)
    ms = multistart_solve(prob, n_starts=2)
    x = np.array(ms.x_int, np.float64)   # writable copy
    used = np.nonzero(x)[0]
    if len(used) < 2:   # force a conflict artificially
        x[used[0] + 1 if used[0] + 1 < cat.n else used[0] - 1] = 1
        used = np.nonzero(x)[0]
    group = used[:2].tolist()
    policy = HAPolicy(min_replicas={}, anti_affinity=[group])
    x2 = enforce_anti_affinity(x, prob, policy)
    assert (np.asarray(x2)[group] > 0.5).sum() <= 1
    K = np.asarray(prob.K)
    assert np.all(K @ np.asarray(x2) >= np.asarray(prob.d) - 1e-4)


def test_pricing_tiers_prefer_reserved_and_spot():
    cat, scen = _small()
    tiers = PricingTiers()
    tcat, res_mask, spot_mask = tiered_catalog(cat, tiers)
    assert tcat.n == 3 * cat.n
    # reserved twin strictly cheaper; spot effective cheaper still
    j = 0
    assert tcat.instances[cat.n + j].hourly_price < tcat.instances[j].hourly_price
    assert (tcat.instances[2 * cat.n + j].hourly_price
            < tcat.instances[cat.n + j].hourly_price)
    tscen = Scenario(name="t", title="t", demand=scen.demand, allowed_idx=None,
                     pools=[], existing=np.zeros(tcat.n))
    prob = problem_from_scenario(tcat, tscen)
    ms = multistart_solve(prob, n_starts=2)
    x = np.asarray(ms.x_int)
    used = np.nonzero(x)[0]
    # cost-optimal solution uses discounted tiers, not on-demand
    assert all(j >= cat.n for j in used), used
    # capping reserved: with cap 0, no reserved twin may be used
    cover = np.full(tcat.n, 10.0)
    prob2 = cap_reserved(prob, res_mask, cover * 0.0, tiers)
    ms2 = multistart_solve(prob2, n_starts=2)
    used2 = np.nonzero(np.asarray(ms2.x_int))[0]
    assert all(not res_mask[j] for j in used2)

"""Anytime-mode contract for the shared PGD engine (ISSUE tentpole).

Three guarantees, all test-enforced here:

1. **Off means off, bit-exactly** — ``anytime=None`` (or a config without
   a deadline) branches at Python level into the exact pre-anytime
   compiled program, and a chunked run whose budget never expires matches
   the monolithic solve bit-for-bit.
2. **Best-so-far is the merit-argmin prefix** — a truncated solve's
   returned iterate achieves exactly the minimum merit over the
   untruncated trajectory's first ``iters`` rows (plus the warm start):
   the driver returns the best thing it SAW, never a worse later iterate.
3. **Graceful floor** — an immediately-expired budget still returns the
   projected (feasible) warm start after one chunk, flagged
   ``deadline_hit``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnytimeConfig, objective_value, solve_incremental_info
from repro.core.pgd import run_anytime, PGDConfig
from repro.testing import make_toy_problem


def _warm_setup(seed=0):
    """A toy warm tick: problem, current allocation, a deliberately poor
    warm start (so the solve has real work to do)."""
    prob = make_toy_problem(seed=seed)
    n = prob.c.shape[0]
    x_cur = jnp.asarray(np.full(n, 2.0), jnp.float32)
    delta = jnp.asarray(50.0, jnp.float32)
    return prob, x_cur, delta


def _fake_clock(step_ms: float):
    state = {"t": 0.0}

    def clock():
        state["t"] += step_ms / 1e3
        return state["t"]

    return clock


def test_disabled_config_is_bit_identical_to_no_config():
    prob, x_cur, delta = _warm_setup()
    x_off, it_off = solve_incremental_info(prob, x_cur, delta)
    x_none, it_none = solve_incremental_info(
        prob, x_cur, delta, anytime=AnytimeConfig(deadline_ms=None))
    np.testing.assert_array_equal(np.asarray(x_off), np.asarray(x_none))
    assert int(it_off) == int(it_none)


def test_generous_deadline_matches_monolithic_solve_bit_exactly():
    """A chunked run that never expires walks the exact iteration sequence
    of the monolithic program (shared ``_pgd_iteration`` body), so its
    answer — and iteration count — are bit-identical."""
    prob, x_cur, delta = _warm_setup()
    x_off, it_off = solve_incremental_info(prob, x_cur, delta)
    x_any, it_any, report = solve_incremental_info(
        prob, x_cur, delta,
        anytime=AnytimeConfig(deadline_ms=1e9, chunk_iters=37))
    assert not report.deadline_hit
    assert int(it_any) == int(it_off)
    np.testing.assert_array_equal(np.asarray(x_off), np.asarray(x_any))


def test_truncated_best_so_far_is_merit_argmin_prefix():
    """Contract 2: truncate at several budgets with a deterministic clock
    and check the returned iterate's merit equals the min over the traced
    untruncated trajectory's first ``iters`` merits (including the warm
    start's own merit — a solve that never improved must return it)."""
    prob, x_cur, delta = _warm_setup()
    # untruncated traced run: merit[i] is the merit AFTER iteration i+1
    _, _, trace = solve_incremental_info(prob, x_cur, delta,
                                         capture_trace=True)
    merit = np.asarray(trace.merit, np.float64)
    # the warm start's merit: objective at the projected x_cur == the
    # chunk driver's f_best initialization (x_cur is already box-feasible
    # and inside its own churn ball, so projection is identity here)
    f0 = float(objective_value(prob, x_cur))
    for budget_ms, chunk in [(2.0, 4), (6.0, 8), (20.0, 16)]:
        x_best, iters, report = solve_incremental_info(
            prob, x_cur, delta,
            anytime=AnytimeConfig(deadline_ms=budget_ms, chunk_iters=chunk,
                                  clock=_fake_clock(1.0)))
        k = int(iters)
        assert report.deadline_hit
        assert 0 < k < 600       # actually truncated
        expect = min([f0] + list(merit[:k]))
        got = float(objective_value(prob, jnp.asarray(x_best)))
        np.testing.assert_allclose(got, expect, rtol=1e-6)


def test_zero_budget_returns_feasible_projected_warm_start():
    """Contract 3: a budget that expires on the first clock reading still
    runs one chunk and returns a best-so-far no worse than the projected
    warm start, flagged as a deadline hit."""
    from repro.core import is_feasible, round_and_polish

    prob, x_cur, delta = _warm_setup()
    x_best, iters, report = solve_incremental_info(
        prob, x_cur, delta,
        anytime=AnytimeConfig(deadline_ms=0.5, chunk_iters=4,
                              clock=_fake_clock(10.0)))
    assert report.deadline_hit
    assert int(iters) <= 4
    f0 = float(objective_value(prob, x_cur))
    assert float(objective_value(prob, jnp.asarray(x_best))) <= f0 + 1e-6
    x_int = round_and_polish(prob, jnp.asarray(x_best))
    assert bool(is_feasible(prob, x_int, 1e-3))


def test_tighter_budgets_never_return_better_merit():
    """Monotone degradation: with one deterministic clock, a larger budget
    sees a superset of the trajectory, so its best-so-far merit is <= any
    tighter budget's (the serve bench's graceful-degradation check)."""
    prob, x_cur, delta = _warm_setup()
    merits = []
    for budget_ms in (1.0, 4.0, 16.0, 64.0):
        x_best, _, _ = solve_incremental_info(
            prob, x_cur, delta,
            anytime=AnytimeConfig(deadline_ms=budget_ms, chunk_iters=8,
                                  clock=_fake_clock(0.5)))
        merits.append(float(objective_value(prob, jnp.asarray(x_best))))
    assert all(b <= a + 1e-6 for a, b in zip(merits, merits[1:]))


def test_anytime_and_capture_trace_are_mutually_exclusive():
    prob, x_cur, delta = _warm_setup()
    with pytest.raises(ValueError, match="mutually exclusive"):
        solve_incremental_info(
            prob, x_cur, delta, capture_trace=True,
            anytime=AnytimeConfig(deadline_ms=5.0))


def test_run_anytime_requires_a_deadline():
    with pytest.raises(ValueError):
        run_anytime(lambda: None, lambda s, e: s, PGDConfig(),
                    AnytimeConfig(deadline_ms=None))

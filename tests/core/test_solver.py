"""Solver tests: convergence, feasibility, KKT residuals, multistart."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis — deterministic shim
    from repro.testing import given, settings, strategies as st

import repro.core.objective as obj
from repro.core import (SolverConfig, kkt_report, multistart_solve,
                        solve_relaxation)
from repro.core.solver import phase1_point
from repro.testing import make_toy_problem

CFG = SolverConfig(max_iters=300, barrier_rounds=3)


def test_phase1_reaches_feasibility(toy_problem):
    x = phase1_point(toy_problem, jnp.zeros(toy_problem.n))
    lo, hi = obj.constraint_residuals(toy_problem, x)
    assert float(jnp.min(lo)) >= -1e-2
    assert float(jnp.min(hi)) >= -1e-2


def test_solution_feasible(toy_problem):
    res = solve_relaxation(toy_problem, jnp.zeros(toy_problem.n), CFG)
    assert bool(res.feasible)


def test_solver_descends(toy_problem):
    x0 = jnp.full(toy_problem.n, 3.0)
    x0p = phase1_point(toy_problem, x0)
    f0 = float(obj.objective(toy_problem, x0p))
    res = solve_relaxation(toy_problem, x0, CFG)
    assert float(res.fun) <= f0 + 1e-5


def test_convex_instance_start_independence():
    """alpha=0 (convex): different starts reach the same objective value."""
    prob = make_toy_problem(alpha=0.0, gamma=0.0)
    funs = []
    for s in [0.0, 1.0, 5.0]:
        res = solve_relaxation(prob, jnp.full(prob.n, s), CFG)
        funs.append(float(res.fun))
    assert max(funs) - min(funs) <= 5e-2 * max(abs(min(funs)), 1.0)


def test_kkt_residuals_small_on_convex():
    prob = make_toy_problem(alpha=0.0, gamma=0.0)
    res = solve_relaxation(prob, jnp.zeros(prob.n), CFG)
    # final barrier temperature of CFG: t0 * kappa^(rounds-1) = 100
    t_final = CFG.barrier_t0 * CFG.barrier_kappa ** (CFG.barrier_rounds - 1)
    rep = kkt_report(prob, res.x, barrier_t=jnp.asarray(t_final))
    scale = float(jnp.max(jnp.abs(prob.c))) + 1.0
    assert float(rep.primal_lo) <= 1e-2
    assert float(rep.primal_hi) <= 1e-2
    assert float(rep.dual) <= 1e-6            # nonneg by construction
    # interior-point duals make stationarity ~ solver tolerance
    assert float(rep.stationarity) <= 0.15 * scale
    # complementary slackness decays as 1/t
    assert float(rep.comp_slack) <= 10.0 / t_final + 0.1


def test_multistart_picks_best(toy_problem):
    ms = multistart_solve(toy_problem, n_starts=6, cfg=CFG)
    merit = np.where(np.asarray(ms.all_feasible), np.asarray(ms.all_fun), np.inf)
    assert float(ms.best.fun) <= np.min(merit) + 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_solver_feasible_property(seed):
    prob = make_toy_problem(seed=seed)
    res = solve_relaxation(prob, jnp.zeros(prob.n), CFG)
    # solver must end feasible (phase-1 + projections guarantee reachable)
    assert bool(res.feasible)
    assert np.all(np.isfinite(np.asarray(res.x)))

"""CA baseline simulator invariants."""
import numpy as np
import pytest

from repro.core import (default_pools_for, evaluate,
                        simulate_cluster_autoscaler)


def _pools(cat, k=6):
    idx = cat.select(lambda t: 2 <= t.cpu <= 8)[:k]
    return default_pools_for(cat, idx)


def test_ca_satisfies_when_possible(small_catalog):
    demand = np.array([8, 16, 4, 100], np.float64)
    res = simulate_cluster_autoscaler(small_catalog, _pools(small_catalog), demand)
    assert res.satisfied
    K, _, _ = small_catalog.matrices()
    assert np.all(K @ res.counts >= demand - 1e-9)


def test_ca_deterministic_per_seed(small_catalog):
    demand = np.array([8, 16, 4, 100], np.float64)
    a = simulate_cluster_autoscaler(small_catalog, _pools(small_catalog), demand, seed=3)
    b = simulate_cluster_autoscaler(small_catalog, _pools(small_catalog), demand, seed=3)
    np.testing.assert_array_equal(a.counts, b.counts)


def test_ca_only_uses_pool_types(small_catalog):
    demand = np.array([16, 32, 8, 200], np.float64)
    pools = _pools(small_catalog, k=4)
    res = simulate_cluster_autoscaler(small_catalog, pools, demand)
    allowed = {p.instance_idx for p in pools}
    used = set(np.nonzero(res.counts)[0].tolist())
    assert used <= allowed


def test_ca_wave_homogeneous(small_catalog):
    """In wave mode with a single pool, CA must scale that pool alone to
    cover everything (the homogeneous-scaling constraint)."""
    demand = np.array([16, 32, 8, 200], np.float64)
    idx = small_catalog.select(lambda t: t.cpu == 4)[:1]
    pools = default_pools_for(small_catalog, idx)
    res = simulate_cluster_autoscaler(small_catalog, pools, demand, mode="wave")
    used = np.nonzero(res.counts)[0]
    assert len(used) == 1 and used[0] == idx[0]


def test_ca_respects_pool_caps(small_catalog):
    demand = np.array([64, 128, 16, 500], np.float64)
    idx = small_catalog.select(lambda t: t.cpu == 2)[:2]
    pools = default_pools_for(small_catalog, idx, max_count=3)
    res = simulate_cluster_autoscaler(small_catalog, pools, demand)
    assert np.all(res.counts[idx] <= 3)
    # capped pools can't satisfy this demand
    assert not res.satisfied


def test_least_waste_not_worse_than_random_median(small_catalog):
    demand = np.array([24, 64, 12, 300], np.float64)
    pools = _pools(small_catalog, k=8)
    rnd = np.median([simulate_cluster_autoscaler(
        small_catalog, pools, demand, expander="random", seed=s).cost
        for s in range(5)])
    lw = simulate_cluster_autoscaler(small_catalog, pools, demand,
                                     expander="least-waste").cost
    assert lw <= rnd + 1e-6

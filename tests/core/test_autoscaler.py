"""CA baseline simulator invariants."""
import numpy as np
import pytest

from repro.core import (NodePool, default_pools_for, evaluate,
                        simulate_cluster_autoscaler,
                        simulate_cluster_autoscaler_batch)


def _pools(cat, k=6):
    idx = cat.select(lambda t: 2 <= t.cpu <= 8)[:k]
    return default_pools_for(cat, idx)


def test_ca_satisfies_when_possible(small_catalog):
    demand = np.array([8, 16, 4, 100], np.float64)
    res = simulate_cluster_autoscaler(small_catalog, _pools(small_catalog), demand)
    assert res.satisfied
    K, _, _ = small_catalog.matrices()
    assert np.all(K @ res.counts >= demand - 1e-9)


def test_ca_deterministic_per_seed(small_catalog):
    demand = np.array([8, 16, 4, 100], np.float64)
    a = simulate_cluster_autoscaler(small_catalog, _pools(small_catalog), demand, seed=3)
    b = simulate_cluster_autoscaler(small_catalog, _pools(small_catalog), demand, seed=3)
    np.testing.assert_array_equal(a.counts, b.counts)


def test_ca_only_uses_pool_types(small_catalog):
    demand = np.array([16, 32, 8, 200], np.float64)
    pools = _pools(small_catalog, k=4)
    res = simulate_cluster_autoscaler(small_catalog, pools, demand)
    allowed = {p.instance_idx for p in pools}
    used = set(np.nonzero(res.counts)[0].tolist())
    assert used <= allowed


def test_ca_wave_homogeneous(small_catalog):
    """In wave mode with a single pool, CA must scale that pool alone to
    cover everything (the homogeneous-scaling constraint)."""
    demand = np.array([16, 32, 8, 200], np.float64)
    idx = small_catalog.select(lambda t: t.cpu == 4)[:1]
    pools = default_pools_for(small_catalog, idx)
    res = simulate_cluster_autoscaler(small_catalog, pools, demand, mode="wave")
    used = np.nonzero(res.counts)[0]
    assert len(used) == 1 and used[0] == idx[0]


def test_ca_respects_pool_caps(small_catalog):
    demand = np.array([64, 128, 16, 500], np.float64)
    idx = small_catalog.select(lambda t: t.cpu == 2)[:2]
    pools = default_pools_for(small_catalog, idx, max_count=3)
    res = simulate_cluster_autoscaler(small_catalog, pools, demand)
    assert np.all(res.counts[idx] <= 3)
    # capped pools can't satisfy this demand
    assert not res.satisfied


def test_ca_duplicate_pools_aggregate_caps(small_catalog):
    """Two pools on the SAME instance type (e.g. per-zone pools of one
    machine family) must pool their headroom: caps are the SUM of
    max_counts, exactly like counts and min_counts already are."""
    demand = np.array([16, 32, 8, 200], np.float64)
    idx = small_catalog.select(lambda t: t.cpu == 4)[:1]
    j = int(idx[0])
    one = simulate_cluster_autoscaler(
        small_catalog, [NodePool(instance_idx=j, max_count=10_000)], demand)
    assert one.satisfied
    need = int(one.counts[j])
    assert need >= 2
    # split the needed capacity across two same-type pools, each too small
    # on its own: aggregation must still satisfy, capping at the sum
    half = (need + 1) // 2
    pools = [NodePool(instance_idx=j, max_count=half),
             NodePool(instance_idx=j, max_count=half)]
    res = simulate_cluster_autoscaler(small_catalog, pools, demand)
    assert res.satisfied
    assert res.counts[j] <= 2 * half
    assert res.counts[j] > half  # actually used the second pool's headroom


def test_ca_batch_matches_sequential_oracle(small_catalog):
    """Property-style sweep: the vectorized lockstep stepper must reproduce
    the sequential simulator's counts/cost/iterations/satisfied EXACTLY for
    every tenant, across expanders, modes, scale-down policies and seeds."""
    rng = np.random.default_rng(7)
    for expander in ("random", "first-fit", "least-waste"):
        for mode in ("wave", "incremental"):
            for sd in ("utilization", "greedy", "none"):
                B = 5
                demands = (rng.uniform(1, 40, size=(B, 4))
                           * np.array([1.0, 2.0, 0.5, 12.0]))
                pools = []
                for b in range(B):
                    k = int(rng.integers(2, 7))
                    idx = rng.choice(small_catalog.n, size=k, replace=False)
                    existing = {int(j): int(rng.integers(0, 4))
                                for j in idx[:2]}
                    pools.append(default_pools_for(
                        small_catalog, idx, existing=existing,
                        max_count=int(rng.integers(3, 30))))
                seq = [simulate_cluster_autoscaler(
                           small_catalog, pools[b], demands[b],
                           expander=expander, scale_down=sd, mode=mode,
                           seed=3)
                       for b in range(B)]
                bat = simulate_cluster_autoscaler_batch(
                    small_catalog, pools, demands, expander=expander,
                    scale_down=sd, mode=mode, seed=3)
                for b in range(B):
                    np.testing.assert_array_equal(
                        seq[b].counts, bat[b].counts,
                        err_msg=f"{expander}/{mode}/{sd} tenant {b}")
                    assert seq[b].iterations == bat[b].iterations
                    assert seq[b].satisfied == bat[b].satisfied
                    assert seq[b].cost == pytest.approx(bat[b].cost, abs=1e-9)


def test_ca_batch_shared_pools_and_capped_wave(small_catalog):
    """The batch stepper accepts one shared pool list, and reproduces the
    sequential wave cap-out (a pool scaled to its cap without satisfying)."""
    demand = np.array([64, 128, 16, 500], np.float64)
    idx = small_catalog.select(lambda t: t.cpu == 2)[:2]
    pools = default_pools_for(small_catalog, idx, max_count=3)
    seq = simulate_cluster_autoscaler(small_catalog, pools, demand)
    bat, = simulate_cluster_autoscaler_batch(small_catalog, pools,
                                             demand[None, :])
    np.testing.assert_array_equal(seq.counts, bat.counts)
    assert not bat.satisfied and seq.iterations == bat.iterations


def test_least_waste_not_worse_than_random_median(small_catalog):
    demand = np.array([24, 64, 12, 300], np.float64)
    pools = _pools(small_catalog, k=8)
    rnd = np.median([simulate_cluster_autoscaler(
        small_catalog, pools, demand, expander="random", seed=s).cost
        for s in range(5)])
    lw = simulate_cluster_autoscaler(small_catalog, pools, demand,
                                     expander="least-waste").cost
    assert lw <= rnd + 1e-6

"""CLI-level tests for the observability tools: ``trace_demo --validate``
must exit non-zero on corrupted artifacts (ISSUE satellite), the
``bench_compare`` CLI must map comparison outcomes to its documented exit
codes, and the committed ``benchmarks/golden/BENCH_check.json`` must stay
consistent with ``check_bench``'s CONFIG (a stale golden refuses instead
of producing nonsense deltas — catch it here, not in CI archaeology)."""
import importlib.util
import json
import os

import pytest

from repro.obs import (compare_bench, config_digest, telemetry,
                       validate_bench, write_chrome_trace, write_jsonl)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "benchmarks", "golden", "BENCH_check.json")


def _load(relpath: str):
    name = os.path.splitext(os.path.basename(relpath))[0]
    spec = importlib.util.spec_from_file_location(
        f"tools_obs_{name}", os.path.join(REPO, relpath))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def trace_demo():
    return _load("tools/trace_demo.py")


@pytest.fixture(scope="module")
def bench_compare():
    return _load("tools/bench_compare.py")


# ---------------------------------------------------------------------------
# trace_demo --validate
# ---------------------------------------------------------------------------

def _artifact_pair(tmp_path):
    """A real (trace.json, trace.jsonl) pair from a tiny recorder."""
    with telemetry() as rec:
        with rec.span("replay/tick", cat="replay", compile_key=("t", 0)):
            pass
    trace = write_chrome_trace(rec, tmp_path / "trace.json")
    write_jsonl(rec, tmp_path / "trace.jsonl")
    return trace


def test_trace_demo_validate_ok_on_valid_pair(trace_demo, tmp_path, capsys):
    trace = _artifact_pair(tmp_path)
    assert trace_demo.main(["--validate", str(trace)]) == 0
    assert "OK" in capsys.readouterr().out


def test_trace_demo_validate_nonzero_on_corrupt_jsonl(trace_demo, tmp_path,
                                                      capsys):
    trace = _artifact_pair(tmp_path)
    (tmp_path / "trace.jsonl").write_text('{"type": "mystery"}\n')
    assert trace_demo.main(["--validate", str(trace)]) == 1
    assert "jsonl schema" in capsys.readouterr().out


def test_trace_demo_validate_nonzero_on_corrupt_trace(trace_demo, tmp_path,
                                                      capsys):
    trace = _artifact_pair(tmp_path)
    trace.write_text(json.dumps({"traceEvents": [{"ph": "Z", "ts": -1}]}))
    assert trace_demo.main(["--validate", str(trace)]) == 1
    assert "trace schema" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# bench_compare CLI exit codes
# ---------------------------------------------------------------------------

def _doc(digest="d1", cost=100.0, p50=10.0):
    return {"provenance": {"platform": "linux", "backend": "cpu",
                           "config_digest": digest},
            "objective": {"cost_integral": cost},
            "steady_state": {"tick_ms": {"p50": p50}}}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_cli_exit_0_on_clean_pair(bench_compare, tmp_path, capsys):
    a = _write(tmp_path, "a.json", _doc())
    b = _write(tmp_path, "b.json", _doc(cost=100.2, p50=10.5))
    assert bench_compare.main([a, b]) == 0
    assert "OK" in capsys.readouterr().out


def test_cli_exit_1_on_regression(bench_compare, tmp_path, capsys):
    a = _write(tmp_path, "a.json", _doc())
    b = _write(tmp_path, "b.json", _doc(cost=105.0))   # +5% objective
    assert bench_compare.main([a, b]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    # a looser tolerance turns the same pair green
    assert bench_compare.main([a, b, "--objective-rtol", "0.10"]) == 0


def test_cli_exit_2_on_refusal(bench_compare, tmp_path, capsys):
    a = _write(tmp_path, "a.json", _doc())
    b = _write(tmp_path, "b.json", _doc(digest="d2"))
    assert bench_compare.main([a, b]) == 2
    assert "REFUSED" in capsys.readouterr().out


def test_cli_set_path_helper(bench_compare):
    doc = {"a": {"b": [1.0, {"c": 2.0}]}}
    bench_compare._set_path(doc, "a.b.1.c", 9.0)
    bench_compare._set_path(doc, "a.b.0", 7.0)
    assert doc == {"a": {"b": [7.0, {"c": 9.0}]}}


def test_cli_selftest_passes_on_golden(bench_compare, capsys):
    """The acceptance-criteria injection test: +25% timing and +2%
    objective perturbations of the committed golden must both be caught."""
    assert bench_compare.main(["--selftest", GOLDEN]) == 0
    assert "selftest OK" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# committed golden consistency
# ---------------------------------------------------------------------------

def test_golden_is_valid_and_matches_check_bench_config():
    golden = json.load(open(GOLDEN))
    assert validate_bench(golden) == []
    check_bench = _load("benchmarks/check_bench.py")
    assert (golden["provenance"]["config_digest"]
            == config_digest(check_bench.CONFIG)), (
        "benchmarks/golden/BENCH_check.json was produced by a different "
        "check_bench CONFIG — regenerate it with "
        "`python benchmarks/check_bench.py --golden`")
    assert golden["provenance"]["seeds"] == check_bench.SEEDS
    cmp = compare_bench(golden, golden)
    assert cmp.ok and not cmp.refusals

"""Solver convergence capture: the traced engine must agree with the
untraced one on ``(x, fx, iters)``, stay vmap-safe (fixed-size per-lane
rows), and raise for the fixed-step engine (no ladder to trace)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.incremental import solve_incremental_info
from repro.core.pgd import PGDConfig, pgd_minimize, pgd_minimize_traced
from repro.fleet import solve_fleet, solve_fleet_step, stack_problems
from repro.horizon import HorizonSolverConfig
from repro.horizon.problem import expand_problems
from repro.horizon.solver import solve_horizon_info
from repro.obs import lane_trace, trace_length, trace_summary, trim_trace
from repro.obs.solver_trace import traces_to_dict
from repro.testing import make_toy_problem

CFG = PGDConfig(max_iters=80)


def _quadratic(center):
    """A box-constrained quadratic: the simplest exercise of the ladder."""
    center = jnp.asarray(center, jnp.float32)
    value = lambda x: jnp.sum((x - center) ** 2)
    grad = jax.grad(value)
    project = lambda x: jnp.clip(x, 0.0, 10.0)
    return value, grad, project


def test_traced_matches_untraced_bit_exact():
    """Same compiled math, extra logging: (x, fx, iters) must agree
    EXACTLY, and the trace's last valid merit row IS the reported fx."""
    value, grad, project = _quadratic([3.0, 7.0, 1.5])
    x0 = jnp.zeros(3, jnp.float32)
    x, fx, iters = pgd_minimize(value, grad, project, x0, CFG)
    xt, fxt, itt, tr = pgd_minimize_traced(value, grad, project, x0, CFG)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(xt))
    assert float(fx) == float(fxt)
    assert int(iters) == int(itt)
    assert trace_length(tr) == CFG.max_iters          # fixed-size rows
    t = trim_trace(tr)
    assert t.merit.shape[0] == int(iters)             # NaN sentinel tail
    assert float(t.merit[-1]) == float(fx)
    s = trace_summary(tr)
    assert s["iters"] == int(iters)
    assert s["merit_drop"] >= 0
    assert 0.0 < s["accept_rate"] <= 1.0
    (d,) = traces_to_dict([t])
    assert d["iters"] == int(iters) and len(d["merit"]) == int(iters)


def test_traced_engine_is_vmap_safe():
    """vmapping the traced engine yields (B, max_iters) rows per leaf, and
    every lane matches its own single-lane traced run exactly."""
    centers = jnp.asarray([[3.0, 7.0, 1.5], [9.0, 0.5, 4.0]], jnp.float32)
    x0 = jnp.zeros(3, jnp.float32)

    def solve(center):
        value = lambda x: jnp.sum((x - center) ** 2)
        return pgd_minimize_traced(value, jax.grad(value),
                                   lambda x: jnp.clip(x, 0.0, 10.0), x0, CFG)

    xs, fxs, its, tr = jax.vmap(solve)(centers)
    assert np.asarray(tr.merit).shape == (2, CFG.max_iters)
    for b in range(2):
        _, fx1, it1, tr1 = solve(centers[b])
        assert float(fxs[b]) == float(fx1)
        assert int(its[b]) == int(it1)
        lane = lane_trace(tr, b)
        np.testing.assert_array_equal(np.asarray(lane.merit),
                                      np.asarray(tr1.merit))
        assert trace_summary(lane)["iters"] == int(it1)
    with pytest.raises(ValueError, match="single-lane"):
        lane_trace(lane, 0)                           # (L,) is not batched
    with pytest.raises(ValueError, match="lane_trace first"):
        trim_trace(tr)                                # (B, L) needs a lane


def test_incremental_capture_matches_untraced():
    prob = make_toy_problem(seed=0, n=24)
    x_cur = jnp.zeros(24, jnp.float32)
    x, iters = solve_incremental_info(prob, x_cur, jnp.float32(8.0))
    xt, itt, tr = solve_incremental_info(prob, x_cur, jnp.float32(8.0),
                                         capture_trace=True)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(xt))
    assert int(iters) == int(itt)
    assert trace_summary(tr)["iters"] == int(iters)


@pytest.mark.slow
def test_fleet_step_capture_per_lane(tmp_path):
    """solve_fleet_step(capture_trace=True): identical integer allocations
    to the untraced step, plus one (max_iters,) trace row set per lane
    whose executed-iteration count matches the lane's reported iters."""
    probs = [make_toy_problem(seed=s, n=16 + 4 * (s % 2), m=3)
             for s in range(3)]
    batch = stack_problems(probs)
    cold = solve_fleet(batch, n_starts=2)
    x_cur = jnp.asarray(cold.x_int)
    plain = solve_fleet_step(batch, x_cur, 8.0)
    traced = solve_fleet_step(batch, x_cur, 8.0, capture_trace=True)
    np.testing.assert_array_equal(np.asarray(plain.x_int),
                                  np.asarray(traced.x_int))
    np.testing.assert_array_equal(np.asarray(plain.iters),
                                  np.asarray(traced.iters))
    assert plain.trace is None
    assert np.asarray(traced.trace.merit).shape[0] == len(probs)
    for b in range(len(probs)):
        s = trace_summary(lane_trace(traced.trace, b))
        assert s["iters"] == int(np.asarray(traced.iters)[b])


def test_fixed_engine_rejects_capture():
    """The fixed-step engine has no BB/Armijo ladder to trace — asking for
    a capture must fail loudly, not return garbage rows."""
    probs = [make_toy_problem(seed=0, n=16, m=3)] * 2
    hp = expand_problems(probs)
    with pytest.raises(ValueError, match="fixed"):
        solve_horizon_info(hp, jnp.zeros(16, jnp.float32), jnp.float32(8.0),
                           cfg=HorizonSolverConfig(solver="fixed"),
                           capture_trace=True)

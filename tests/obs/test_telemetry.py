"""Telemetry spans: zero-overhead no-op when disabled, compile/execute
tagging when enabled — and the contract that matters most: telemetry NEVER
changes allocations (bit-equality with telemetry on vs off, both engines)."""
import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog
from repro.fleet import TenantSpec, replay_fleet
from repro.fleet.traces import constant_trace, diurnal_trace
from repro.obs import (ReplayReport, Recorder, counter, current_recorder,
                       gauge, span, telemetry)
from repro.obs.telemetry import _NOOP_CM, _NOOP_SPAN

BASE = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[::40])


@pytest.fixture(scope="module")
def specs():
    return [
        TenantSpec(name="a", trace=diurnal_trace(BASE, 3, amplitude=0.3,
                                                 noise=0.0), n_starts=2),
        TenantSpec(name="b", trace=constant_trace(BASE * 0.6, 3), n_starts=2),
    ]


# ---------------------------------------------------------------------------
# disabled path
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    """With no recorder installed, span() must return THE shared no-op
    context manager (no per-call allocation) whose fence is identity."""
    assert current_recorder() is None
    cm = span("replay/tick", compile_key=("k",), tick=0)
    assert cm is _NOOP_CM
    with cm as sp:
        assert sp is _NOOP_SPAN
        obj = object()
        assert sp.fence(obj) is obj          # no block_until_ready, no copy
        assert sp.tag(a=1) is sp
    counter("x")                              # both must be silent no-ops
    gauge("y", 1.0)


def test_telemetry_scope_installs_and_restores():
    assert current_recorder() is None
    with telemetry() as rec:
        assert current_recorder() is rec
        with telemetry(enabled=False) as none_rec:
            assert none_rec is None           # explicit no-op scope
        with telemetry() as inner:            # nested scope shadows...
            assert current_recorder() is inner
        assert current_recorder() is rec      # ...and restores on exit
    assert current_recorder() is None


# ---------------------------------------------------------------------------
# enabled path: nesting, tagging, counters/gauges
# ---------------------------------------------------------------------------

def test_compile_execute_tagging_and_nesting():
    with telemetry() as rec:
        with span("outer", cat="t", compile_key=("prog", 32)):
            with span("inner", cat="t"):
                pass
        with span("outer", cat="t", compile_key=("prog", 32)) as sp:
            sp.tag(tick=1)
        counter("n_solves", 2)
        gauge("waste", 0.25)
    evs = {(e.name, e.phase, e.depth) for e in rec.events}
    assert ("inner", None, 1) in evs          # nested one level down
    assert ("outer", "compile", 0) in evs     # first key sighting
    assert ("outer", "execute", 0) in evs     # repeat is steady-state
    assert rec.spans("outer", phase="execute")[0].tags == {"tick": 1}
    assert rec.counters["n_solves"] == 2.0
    assert [v for _, v in rec.gauges["waste"]] == [0.25]
    assert rec.total_us("outer") > 0
    assert "outer" in rec.summary()


# ---------------------------------------------------------------------------
# the contract: telemetry never changes allocations (both engines)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [
    "sequential",
    # the batched replay pays a multi-second vmap compile: full tier
    pytest.param("batched", marks=pytest.mark.slow),
])
def test_replay_bit_identical_with_telemetry_on(tiny_catalog, specs, mode):
    """ISSUE acceptance: a fully instrumented replay (telemetry recorder
    installed AND per-lane solver-trace capture on) must produce per-tick
    integer allocations, churn and metrics BIT-IDENTICAL to the plain
    run — the recorder only fences completion, never recomputes."""
    plain = replay_fleet(tiny_catalog, specs, run_ca_baseline=False,
                         replay_mode=mode)
    with telemetry() as rec:
        instr = replay_fleet(tiny_catalog, specs, run_ca_baseline=False,
                             replay_mode=mode, capture_solver_trace=True)
    for rp, ri in zip(plain.tenants, instr.tenants):
        for sp_, si in zip(rp.steps, ri.steps):
            np.testing.assert_array_equal(sp_.counts, si.counts)
            assert sp_.churn == si.churn
            assert sp_.solver_iters == si.solver_iters
        assert rp.metrics == ri.metrics
    assert instr.metrics.summary() == plain.metrics.summary()
    # the instrumented run actually recorded the replay
    assert len(rec.spans("replay/tick")) > 0
    assert instr.solver_traces is not None
    assert plain.solver_traces is None


def test_instrumented_replay_produces_report(tiny_catalog, specs):
    """ReplayReport rolls the recorder up with a compile/execute split and
    per-tick latency percentiles (ISSUE acceptance criterion)."""
    with telemetry() as rec:
        replay_fleet(tiny_catalog, specs, run_ca_baseline=False,
                     replay_mode="batched")
    rep = ReplayReport.from_recorder(rec)
    assert rep.n_ticks == 3
    assert rep.compile_ms > 0                 # first tick compiled something
    assert rep.execute_ms > 0
    assert set(rep.tick_ms) == {"p50", "p95", "p99"}
    names = {p.name for p in rep.phases}
    assert {"replay/tick", "replay/stack", "replay/solve"} <= names
    assert rep.padding_waste                  # stack_problems gauged waste
    assert rep.solver_iters.get("total", 0) > 0
    assert "replay report" in rep.render()


def test_report_degrades_on_empty_recorder():
    rep = ReplayReport.from_recorder(Recorder())
    assert rep.n_ticks == 0 and rep.phases == [] and rep.tick_ms == {}
    assert "0 ticks" in rep.render()

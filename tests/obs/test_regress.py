"""Bench regression sentinel: metric classification, leaf flattening,
schema validation, provenance-aware refusal, and the tolerance directions
the ISSUE's acceptance criteria name (a >=20% timing or >=1% objective
regression must fail)."""
import copy

import pytest

from repro.obs import (classify_metric, compare_bench, numeric_leaves,
                       validate_bench)


def _bench(platform="Linux-x86_64", backend="cpu", digest="abc123", **over):
    doc = {
        "provenance": {"platform": platform, "backend": backend,
                       "config_digest": digest},
        "config": {"B": 3, "quick": True},
        "steady_state": {"tick_ms": {"p50": 10.0, "p95": 20.0},
                         "compile_ms": 900.0},
        "objective": {"cost_integral": 100.0, "total_churn": 8.0,
                      "slo_violation_ticks": 0,
                      "savings_vs_ca_pct": 60.0},
        "replay": {"speedup": 4.0},
        "misc": {"distinct_shapes": 2},
    }
    for path, v in over.items():
        node = doc
        parts = path.split(".")
        for p in parts[:-1]:
            node = node[p]
        node[parts[-1]] = v
    return doc


# ---------------------------------------------------------------------------
# classification / flattening / validation
# ---------------------------------------------------------------------------

def test_classify_metric_classes():
    assert classify_metric("steady_state.tick_ms.p95") == "timing"
    assert classify_metric("telemetry.compile_ms") == "timing"
    assert classify_metric("replay.t_sequential") is None   # bare t_ prefix
    assert classify_metric("replay.speedup") == "throughput"
    assert classify_metric("ca.ticks_per_s_vectorized") == "throughput"
    assert classify_metric("objective.cost_integral") == "objective"
    assert classify_metric("objective.total_churn") == "objective"
    assert classify_metric("health.slo_breach_ticks") == "objective"
    assert classify_metric("health.nonfinite_events") == "objective"
    assert classify_metric("health.stall_events") == "objective"
    assert classify_metric("objective.savings_vs_ca_pct") == "quality"
    assert classify_metric("misc.distinct_shapes") is None
    # path-level fallback: an unclassifiable leaf under a timing section
    assert classify_metric("tick_ms.p50") == "timing"


def test_numeric_leaves_skips_meta_and_bools():
    leaves = numeric_leaves(_bench())
    assert "steady_state.tick_ms.p50" in leaves
    assert "objective.slo_violation_ticks" in leaves
    assert not any(p.startswith(("provenance", "config")) for p in leaves)
    assert not any("quick" in p for p in leaves)   # bool excluded
    nested = numeric_leaves({"a": [{"b": 1.0}, 2.0]})
    assert nested == {"a.0.b": 1.0, "a.1": 2.0}


def test_validate_bench_problems():
    assert validate_bench(_bench()) == []
    assert validate_bench([1, 2]) == ["BENCH doc is not a JSON object"]
    assert "missing provenance block" in validate_bench({"x": 1.0})
    missing = _bench()
    del missing["provenance"]["backend"]
    assert any("backend" in p for p in validate_bench(missing))
    empty = {"provenance": {"platform": "p", "backend": "cpu"}}
    assert any("no numeric" in p for p in validate_bench(empty))


# ---------------------------------------------------------------------------
# provenance-aware refusal
# ---------------------------------------------------------------------------

def test_refuses_config_digest_mismatch_even_cross_platform_allowed():
    cmp = compare_bench(_bench(), _bench(digest="zzz999"),
                        allow_cross_platform=True)
    assert not cmp.ok and cmp.refusals
    assert "config_digest" in cmp.refusals[0]
    assert "REFUSED" in cmp.summary()


def test_refuses_platform_mismatch_unless_allowed():
    other = _bench(platform="Darwin-arm64")
    refused = compare_bench(_bench(), other)
    assert refused.refusals and "platform" in refused.refusals[0]
    allowed = compare_bench(_bench(), other, allow_cross_platform=True)
    assert allowed.ok and not allowed.refusals
    # timing skipped, objective still compared
    assert any("cross-platform" in s for s in allowed.skipped)
    kinds = {d.kind for d in allowed.deltas}
    assert "objective" in kinds and "timing" not in kinds


def test_invalid_doc_refuses_with_side_label():
    cmp = compare_bench({"nope": True}, _bench())
    assert cmp.refusals and cmp.refusals[0].startswith("baseline:")


# ---------------------------------------------------------------------------
# tolerance directions (the acceptance-criteria numbers)
# ---------------------------------------------------------------------------

def test_timing_regression_20pct_caught_25pct_slowdown():
    cand = _bench(**{"steady_state.tick_ms.p50": 12.5})   # +25%
    cmp = compare_bench(_bench(), cand, timing_rtol=0.2)
    assert not cmp.ok
    (bad,) = cmp.regressions
    assert bad.path == "steady_state.tick_ms.p50" and bad.kind == "timing"
    assert bad.rel_change == pytest.approx(0.25)
    assert "REGRESSION" in cmp.summary()


def test_timing_improvement_and_within_tolerance_pass():
    faster = _bench(**{"steady_state.tick_ms.p50": 5.0})   # -50%
    assert compare_bench(_bench(), faster).ok
    slight = _bench(**{"steady_state.tick_ms.p50": 11.0})  # +10% < 20%
    assert compare_bench(_bench(), slight, timing_rtol=0.2).ok


def test_throughput_drop_is_a_regression():
    slower = _bench(**{"replay.speedup": 2.0})   # higher-better halved
    cmp = compare_bench(_bench(), slower, timing_rtol=0.2)
    assert any(d.path == "replay.speedup" and not d.ok for d in cmp.deltas)


def test_objective_1pct_tolerance():
    worse = _bench(**{"objective.cost_integral": 102.0})   # +2%
    cmp = compare_bench(_bench(), worse, objective_rtol=0.01)
    assert not cmp.ok
    assert cmp.regressions[0].rel_change == pytest.approx(0.02)
    tiny = _bench(**{"objective.cost_integral": 100.5})    # +0.5%
    assert compare_bench(_bench(), tiny, objective_rtol=0.01).ok
    better = _bench(**{"objective.cost_integral": 90.0})
    assert compare_bench(_bench(), better).ok


def test_quality_drop_is_a_regression():
    worse = _bench(**{"objective.savings_vs_ca_pct": 58.0})  # higher-better
    cmp = compare_bench(_bench(), worse, objective_rtol=0.01)
    assert any(d.path.endswith("savings_vs_ca_pct") and not d.ok
               for d in cmp.deltas)


def test_zero_baseline_counter_regression_detected():
    """slo ticks going 0 -> 1 must fail, not vanish in a 0-division."""
    worse = _bench(**{"objective.slo_violation_ticks": 1})
    cmp = compare_bench(_bench(), worse)
    assert any(d.path.endswith("slo_violation_ticks") and not d.ok
               for d in cmp.deltas)


# ---------------------------------------------------------------------------
# skipped reporting
# ---------------------------------------------------------------------------

def test_unclassified_and_one_sided_leaves_reported_as_skipped():
    base = _bench()
    cand = copy.deepcopy(base)
    del cand["replay"]["speedup"]
    cand["new_section"] = {"novel_ms": 1.0}
    cmp = compare_bench(base, cand)
    assert cmp.ok   # skipped leaves never fail the comparison
    assert any("only in baseline" in s for s in cmp.skipped)
    assert any("only in candidate" in s for s in cmp.skipped)
    assert any("unclassified" in s for s in cmp.skipped)
    assert "skipped" in cmp.summary()


def test_identity_comparison_is_clean():
    cmp = compare_bench(_bench(), _bench())
    assert cmp.ok and not cmp.refusals and not cmp.regressions
    assert all(d.rel_change == 0.0 for d in cmp.deltas)

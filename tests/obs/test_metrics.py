"""Metric registry: log2 histograms vs numpy, jit/vmap-safe device path vs
host path, exporters, and the disabled no-op contract."""
import json
import math

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricRegistry,
                       bucket_counts, collect_metrics, current_metrics, inc,
                       observe, observe_counts, set_gauge)
from repro.obs.metrics import _n_buckets


# ---------------------------------------------------------------------------
# bucket_counts: the jit/vmap-safe device path
# ---------------------------------------------------------------------------

def test_bucket_counts_shapes_are_static():
    """Output shapes depend only on (lo_exp, hi_exp), never on the data —
    the property that makes the record a legal jit/vmap/scan carry."""
    for vals in ([1.0], [0.5, 2.0, 7.0], np.zeros((3, 4))):
        hc = bucket_counts(vals, lo_exp=-4, hi_exp=4)
        assert hc.counts.shape == (_n_buckets(-4, 4),)
        assert hc.total.shape == () and hc.n.shape == ()


def test_bucket_counts_under_jit_and_vmap():
    import jax
    import jax.numpy as jnp

    vals = jnp.asarray([0.3, 1.5, 6.0, 100.0], jnp.float32)
    eager = bucket_counts(vals, lo_exp=-4, hi_exp=8)
    jitted = jax.jit(lambda v: bucket_counts(v, lo_exp=-4, hi_exp=8))(vals)
    np.testing.assert_array_equal(np.asarray(eager.counts),
                                  np.asarray(jitted.counts))
    assert float(eager.total) == pytest.approx(float(jitted.total))
    batch = jnp.stack([vals, vals * 2])
    vm = jax.vmap(lambda v: bucket_counts(v, lo_exp=-4, hi_exp=8))(batch)
    assert np.asarray(vm.counts).shape == (2, _n_buckets(-4, 8))
    np.testing.assert_array_equal(np.asarray(vm.counts)[0],
                                  np.asarray(eager.counts))


def test_device_merge_matches_host_observe_exactly():
    """The ISSUE's two accumulation paths — jnp bucket_counts + merge vs
    plain host observe — must agree bucket-for-bucket on the same stream."""
    rng = np.random.default_rng(0)
    vals = np.concatenate([rng.lognormal(1.0, 2.0, 500),
                           [0.0, -3.0, 1e9, 1e-9]]).astype(np.float32)
    host = Histogram("h")
    host.observe(vals)
    dev = Histogram("d")
    dev.merge(bucket_counts(vals))
    np.testing.assert_array_equal(host.counts, dev.counts)
    assert host.count == dev.count == vals.size
    assert host.total == pytest.approx(dev.total, rel=1e-5)
    assert host.vmin == pytest.approx(dev.vmin)
    assert host.vmax == pytest.approx(dev.vmax)


def test_nonfinite_samples_are_tallied_not_bucketed():
    vals = np.array([1.0, np.nan, np.inf, -np.inf, 2.0], np.float32)
    h = Histogram("h")
    h.observe(vals)
    assert h.nonfinite == 3 and h.count == 2
    hc = bucket_counts(vals)
    assert int(hc.nonfinite) == 3 and int(hc.n) == 2
    assert float(hc.total) == pytest.approx(3.0)   # NaN excluded from sum


def test_underflow_and_overflow_buckets():
    h = Histogram("h", lo_exp=0, hi_exp=4)   # core covers [1, 16)
    h.observe([0.0, -5.0, 0.5])              # all underflow
    h.observe([1e6])                         # overflow
    assert h.counts[0] == 3 and h.counts[-1] == 1
    assert h.counts[1:-1].sum() == 0


# ---------------------------------------------------------------------------
# quantiles vs numpy (ISSUE satellite: histograms validated against numpy)
# ---------------------------------------------------------------------------

def test_quantiles_exact_on_constant_stream():
    h = Histogram("h")
    h.observe(np.full(100, 12.5))
    assert h.quantile(50) == pytest.approx(12.5)
    assert h.percentiles() == {"p50": pytest.approx(12.5),
                               "p95": pytest.approx(12.5),
                               "p99": pytest.approx(12.5)}


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_quantiles_within_one_log2_bucket_of_numpy(seed):
    """Docstring contract: linear interpolation inside a log2 bucket keeps
    every estimate within a factor of 2 of numpy's exact quantile."""
    rng = np.random.default_rng(seed)
    vals = rng.lognormal(mean=2.0, sigma=1.5, size=2000)
    h = Histogram("h")
    h.observe(vals)
    for q in (50, 95, 99):
        exact = float(np.percentile(vals, q))
        est = h.quantile(q)
        assert exact / 2 <= est <= exact * 2, (q, exact, est)
    assert h.quantile(0) == pytest.approx(vals.min())
    assert h.quantile(100) == pytest.approx(vals.max())


def test_quantile_empty_histogram_is_none():
    assert Histogram("h").quantile(50) is None


# ---------------------------------------------------------------------------
# counters / gauges / registry
# ---------------------------------------------------------------------------

def test_counter_rejects_negative_increment():
    c = Counter("c")
    c.inc(); c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1.0)


def test_gauge_tracks_last_min_max():
    g = Gauge("g")
    g.set(3.0); g.set(-1.0); g.set(2.0)
    assert g.value == 2.0 and g.vmin == -1.0 and g.vmax == 3.0 and g.n == 3


def test_registry_get_or_create_and_type_clash():
    reg = MetricRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")
    with pytest.raises(ValueError, match="hi_exp"):
        reg.histogram("bad", lo_exp=3, hi_exp=3)


def test_histogram_merge_bucket_mismatch_raises():
    h = Histogram("h", lo_exp=0, hi_exp=4)
    with pytest.raises(ValueError, match="buckets"):
        h.merge(bucket_counts([1.0]))   # default range, different layout


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _populated_registry():
    reg = MetricRegistry()
    reg.counter("replay/slo_breach_ticks", help="breaches").inc(3)
    reg.gauge("health/worst_kkt").set(0.25)
    reg.histogram("replay/tick_ms").observe([1.0, 1.0, 3.0, 900.0])
    return reg


def test_prometheus_text_format():
    text = _populated_registry().to_prometheus()
    assert "# TYPE repro_replay_slo_breach_ticks_total counter" in text
    assert "repro_replay_slo_breach_ticks_total 3" in text
    assert "# HELP repro_replay_slo_breach_ticks_total breaches" in text
    assert "repro_health_worst_kkt 0.25" in text
    assert 'repro_replay_tick_ms_bucket{le="+Inf"} 4' in text
    assert "repro_replay_tick_ms_count 4" in text
    assert "repro_replay_tick_ms_sum 905" in text
    # cumulative bucket rows must be non-decreasing and end at count
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if "_bucket{" in line]
    assert cums == sorted(cums) and cums[-1] == 4


def test_snapshot_is_json_ready_and_write_exporters(tmp_path):
    reg = _populated_registry()
    snap = json.loads(json.dumps(reg.snapshot()))   # round-trips
    assert snap["counters"]["replay/slo_breach_ticks"] == 3
    assert snap["gauges"]["health/worst_kkt"]["value"] == 0.25
    h = snap["histograms"]["replay/tick_ms"]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 900.0
    assert sum(h["counts"]) == 4 and h["p50"] is not None
    p1 = reg.write_snapshot(tmp_path / "m.json")
    assert json.loads(p1.read_text())["counters"]
    p2 = reg.write_prometheus(tmp_path / "m.prom")
    assert p2.read_text().startswith("# ")


# ---------------------------------------------------------------------------
# contextvar scoping: the no-op disabled path
# ---------------------------------------------------------------------------

def test_module_helpers_noop_when_disabled():
    assert current_metrics() is None
    # none of these may raise or create state with no registry installed
    inc("x"); set_gauge("g", 1.0); observe("h", [1.0])
    observe_counts("h", bucket_counts([1.0]))
    with collect_metrics(enabled=False) as reg:
        assert reg is None and current_metrics() is None


def test_collect_metrics_scoping_and_shared_registry():
    outer = MetricRegistry()
    with collect_metrics(registry=outer) as reg:
        assert reg is outer and current_metrics() is outer
        inc("n")
        with collect_metrics() as inner:    # nested scope shadows
            assert current_metrics() is inner is not outer
            inc("n")
        inc("n")
    assert current_metrics() is None
    assert outer.counter("n").value == 2.0

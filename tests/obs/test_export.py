"""Export round-trips: JSONL and Chrome trace-event (Perfetto) formats,
and the schema gate ``make trace-demo`` relies on."""
import json

import pytest

from repro.obs import (telemetry, events_to_dicts, validate_chrome_trace,
                       validate_jsonl, write_chrome_trace, write_jsonl)
from repro.obs.export import to_chrome_trace


@pytest.fixture()
def recorder():
    with telemetry() as rec:
        with rec.span("replay/tick", cat="replay", tick=0,
                      compile_key=("tick", 0)):
            with rec.span("replay/solve", cat="replay",
                          compile_key=("solve", 32)):
                pass
        with rec.span("replay/tick", cat="replay", tick=1,
                      compile_key=("tick", 0)):
            pass
        rec.counter("n_solves")
        rec.gauge("stack/padding_waste", 0.3)
        rec.gauge("stack/padding_waste", 0.1)
    return rec


def test_chrome_trace_round_trips_with_valid_fields(recorder, tmp_path):
    """ISSUE satellite: the emitted file must re-load through plain
    json.load with valid ph/ts/dur on every event."""
    path = write_chrome_trace(recorder, tmp_path / "trace.json")
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert len(evs) == 5                        # 3 spans + 2 gauge samples
    spans = [e for e in evs if e["ph"] == "X"]
    gauges = [e for e in evs if e["ph"] == "C"]
    assert len(spans) == 3 and len(gauges) == 2
    for e in evs:
        assert isinstance(e["name"], str) and e["name"]
        assert e["ph"] in ("X", "C")
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    for e in spans:
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
    assert evs == sorted(evs, key=lambda d: d["ts"])   # stable diffs
    # compile/execute tags survive into args (what Perfetto shows on click)
    phases = sorted(e["args"]["phase"] for e in spans)
    assert phases == ["compile", "compile", "execute"]
    assert validate_chrome_trace(path) == []


def test_jsonl_round_trip(recorder, tmp_path):
    path = write_jsonl(recorder, tmp_path / "events.jsonl")
    lines = [json.loads(line) for line in open(path)]
    assert lines == events_to_dicts(recorder)
    kinds = {d["type"] for d in lines}
    assert kinds == {"span", "counter", "gauge"}
    tick0 = next(d for d in lines
                 if d["type"] == "span" and d["tags"].get("tick") == 0)
    assert tick0["phase"] == "compile" and tick0["dur_us"] >= 0


def test_validator_flags_schema_violations(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [
        {"name": "ok", "ph": "X", "ts": 1.0, "dur": 2.0, "pid": 1, "tid": 1},
        {"name": "", "ph": "Z", "ts": -1.0, "pid": "x"},
    ]}))
    problems = validate_chrome_trace(bad)
    assert any("bad ph" in p for p in problems)
    assert any("bad ts" in p for p in problems)
    assert any("missing name" in p for p in problems)
    assert any("missing pid" in p for p in problems)
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert validate_chrome_trace(empty) == ["trace has zero events"]
    notjson = tmp_path / "nope.json"
    notjson.write_text("{")
    assert "unreadable" in validate_chrome_trace(notjson)[0]
    assert validate_chrome_trace(tmp_path / "missing.json")  # unreadable too


def test_to_chrome_trace_is_json_serializable(recorder):
    json.dumps(to_chrome_trace(recorder))       # no numpy/tuple leakage


def test_validate_jsonl_accepts_emitted_log(recorder, tmp_path):
    path = write_jsonl(recorder, tmp_path / "events.jsonl")
    assert validate_jsonl(path) == []


def test_validate_jsonl_flags_corruption(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text("\n".join([
        "{not json",
        json.dumps([1, 2]),
        json.dumps({"type": "mystery"}),
        json.dumps({"type": "span", "name": 7, "cat": "c", "ts_us": -1.0,
                    "dur_us": 2.0, "depth": 0, "tags": {},
                    "phase": "weird"}),
        json.dumps({"type": "counter", "name": "n", "total": True}),
        json.dumps({"type": "gauge", "name": "g", "ts_us": 1.0,
                    "value": "x"}),
    ]) + "\n")
    problems = validate_jsonl(bad)
    assert any("not JSON" in p for p in problems)
    assert any("not an object" in p for p in problems)
    assert any("unknown type" in p for p in problems)
    assert any("bad name 7" in p for p in problems)
    assert any("negative ts_us" in p for p in problems)
    assert any("bad phase" in p for p in problems)
    assert any("bad total True" in p for p in problems)  # bool != numeric
    assert any("bad value 'x'" in p for p in problems)
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert validate_jsonl(empty) == ["event log has zero lines"]
    assert "unreadable" in validate_jsonl(tmp_path / "missing.jsonl")[0]

"""HealthMonitor: breach counters, NaN guards, stall detection, deadline
budget, KKT gauges — and the tentpole acceptance: health + metrics on vs
off leaves per-tenant integer allocations bit-identical in both replay
engines under both controllers."""
import json
import math
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import Catalog, make_cloud_catalog
from repro.fleet import TenantSpec, make_trace, replay_fleet
from repro.obs import (HealthEvent, HealthMonitor, MetricRegistry,
                       collect_metrics)
from repro.obs.health import (_flat_merit_streak, _nondecreasing_tail)
from repro.testing import make_toy_problem

BASE = np.array([8.0, 16.0, 4.0, 100.0])


@pytest.fixture(scope="module")
def tiny_catalog():
    return Catalog(make_cloud_catalog().instances[::40])


def _step(satisfied=True, churn_violation=0.0, counts=None, iters=5):
    """A minimal ControllerStep stand-in carrying the fields observe_step
    reads (duck typing keeps these unit tests solver-free)."""
    c = np.array([1.0, 0.0, 2.0]) if counts is None else np.asarray(counts)
    return SimpleNamespace(metrics=SimpleNamespace(satisfied=satisfied),
                           churn_violation=churn_violation, counts=c,
                           solver_iters=iters)


# ---------------------------------------------------------------------------
# breach counters / non-finite guards (unit level)
# ---------------------------------------------------------------------------

def test_breach_counters_and_registry_mirror():
    reg = MetricRegistry()
    mon = HealthMonitor(registry=reg)
    mon.observe_step(tenant="a", tick=0, step=_step(), solver="adaptive")
    mon.observe_step(tenant="a", tick=1, step=_step(satisfied=False),
                     solver="adaptive")
    mon.observe_step(tenant="a", tick=2, step=_step(churn_violation=1.5),
                     solver="adaptive", spot_unavailable=2)
    rep = mon.report()
    assert rep.slo_breach_ticks == 1
    assert rep.churn_violation_ticks == 1
    assert rep.spot_interruption_ticks == 1
    assert rep.nonfinite_events == 0
    assert reg.counter("health/slo_breach_ticks").value == 1
    assert reg.counter("health/churn_violation_ticks").value == 1
    assert reg.counter("health/spot_interruption_ticks").value == 1


def test_nonfinite_counts_and_relaxed_guards():
    mon = HealthMonitor()
    mon.observe_step(tenant="a", tick=3, step=_step(counts=[1.0, np.nan]),
                     solver="adaptive", lane=2)
    mon.observe_step(tenant="a", tick=4, step=_step(),
                     solver="adaptive", x_rel=np.array([np.inf, 0.0]))
    rep = mon.report()
    assert rep.nonfinite_events == 2
    ev = rep.events[0]
    assert (ev.kind, ev.severity, ev.tick, ev.lane) == ("non_finite",
                                                        "error", 3, 2)
    assert "counts" in ev.message
    assert "relaxed" in rep.events[1].message


def test_nonfinite_gradient_caught_via_kkt_residual():
    """A NaN in the objective (here: a NaN cost vector) leaves the iterate
    finite but poisons the gradient — the KKT stationarity residual is
    where it surfaces (module docstring's non-finite guard contract)."""
    prob = make_toy_problem(seed=0, n=8)
    bad = prob._replace(c=prob.c.at[0].set(np.nan))
    mon = HealthMonitor()
    x = np.ones(8)
    mon.observe_step(tenant="a", tick=0, step=_step(), solver="adaptive",
                     prob=bad, x_rel=x)
    rep = mon.report()
    assert rep.nonfinite_events == 1
    assert "gradient" in rep.events[0].message
    assert rep.worst_kkt_stationarity is None   # NaN never becomes "worst"
    # sanity: the same iterate on the healthy problem certifies finite
    mon2 = HealthMonitor()
    mon2.observe_step(tenant="a", tick=0, step=_step(), solver="adaptive",
                      prob=prob, x_rel=x)
    assert mon2.report().nonfinite_events == 0
    assert math.isfinite(mon2.report().worst_kkt_stationarity)


def test_kkt_worst_tracking_and_cadence():
    prob = make_toy_problem(seed=1, n=8)
    reg = MetricRegistry()
    mon = HealthMonitor(kkt_every=2, registry=reg)
    for t in range(4):   # ticks 0 and 2 certified, 1 and 3 skipped
        mon.observe_step(tenant="a", tick=t, step=_step(), solver="adaptive",
                         prob=prob, x_rel=np.full(8, 0.5 + t))
    rep = mon.report()
    assert rep.kkt_ticks_certified == 2
    assert rep.worst_kkt["tenant"] == "a" and rep.worst_kkt["tick"] in (0, 2)
    assert reg.histogram("health/kkt_stationarity").count == 2
    assert (reg.gauge("health/worst_kkt_stationarity").value
            == pytest.approx(rep.worst_kkt_stationarity))
    none = HealthMonitor(kkt_every=0)
    none.observe_step(tenant="a", tick=0, step=_step(), solver="adaptive",
                      prob=prob, x_rel=np.ones(8))
    assert none.report().kkt_ticks_certified == 0


def test_kkt_warn_threshold_emits_event():
    prob = make_toy_problem(seed=2, n=8)
    mon = HealthMonitor(kkt_warn=1e-12)   # any real residual exceeds this
    mon.observe_step(tenant="a", tick=0, step=_step(), solver="adaptive",
                     prob=prob, x_rel=np.ones(8))
    kinds = [e.kind for e in mon.report().events]
    assert "kkt_residual" in kinds


# ---------------------------------------------------------------------------
# stall detection
# ---------------------------------------------------------------------------

def test_flat_merit_streak_math():
    # improving run: no streak beyond the NaN sentinel tail
    improving = np.concatenate([np.linspace(10, 1, 30), [np.nan] * 10])
    assert _flat_merit_streak(improving) == 0
    # converged-then-flat: trailing 25 rows buy nothing
    flat = np.concatenate([np.linspace(10, 1, 10), np.full(25, 1.0)])
    assert _flat_merit_streak(flat) == 25
    assert _flat_merit_streak(np.array([5.0])) == 0


def test_nondecreasing_tail_math():
    contracting = np.array([8.0, 4.0, 2.0, 1.0, 0.5])
    assert _nondecreasing_tail(contracting) == 0
    stuck = np.array([8.0, 4.0, 4.0, 4.5, 5.0])
    assert _nondecreasing_tail(stuck) == 3
    assert _nondecreasing_tail(np.concatenate([stuck, [np.nan]])) == 3


def test_stall_events_pgd_and_admm():
    mon = HealthMonitor(stall_window=20)
    pgd_stuck = SimpleNamespace(
        merit=np.concatenate([np.linspace(10, 1, 5), np.full(30, 1.0)]))
    mon.observe_step(tenant="a", tick=1, step=_step(), solver="adaptive",
                     trace=pgd_stuck)
    admm_stuck = SimpleNamespace(
        primal=np.concatenate([[5.0], np.full(30, 2.0)]), dual=None)
    mon.observe_step(tenant="b", tick=2, step=_step(), solver="admm", lane=1,
                     trace=admm_stuck,
                     diag=SimpleNamespace(primal_res=np.float32(2.0)))
    rep = mon.report()
    assert rep.stall_events == 2
    by_solver = {e.solver: e for e in rep.events}
    assert "merit flat" in by_solver["adaptive"].message
    assert "ADMM" in by_solver["admm"].message
    assert "2.000e+00" in by_solver["admm"].message   # certificate residual
    # a healthy contracting solve emits nothing
    ok = HealthMonitor(stall_window=20)
    ok.observe_step(tenant="a", tick=1, step=_step(), solver="adaptive",
                    trace=SimpleNamespace(merit=np.linspace(10, 1, 40)))
    assert ok.report().stall_events == 0


# ---------------------------------------------------------------------------
# deadline budget (deterministic via the injectable clock)
# ---------------------------------------------------------------------------

def test_deadline_budget_observe_tick():
    reg = MetricRegistry()
    mon = HealthMonitor(deadline_ms=50.0, registry=reg)
    mon.observe_tick(0, 10.0)
    mon.observe_tick(1, 80.0)
    mon.observe_tick(2, 50.0)   # at budget = not over
    rep = mon.report()
    assert rep.ticks_observed == 3 and rep.deadline_miss_ticks == 1
    assert reg.counter("health/deadline_miss_ticks").value == 1
    assert reg.histogram("health/tick_ms").count == 3


@pytest.mark.slow
def test_deadline_miss_under_fake_clock(tiny_catalog):
    """The engines time ticks through monitor.clock, so a fake clock
    advancing 1s per reading makes every tick a deterministic 1000ms —
    over a 500ms budget every STEADY-STATE tick must miss, while the two
    compile ticks (the batched engine's cold t=0 and first-warm t=1
    programs, identified by their first-seen compile keys) are excluded
    from the miss counter and reported separately: before this split, the
    first warm tick after ANY jit cache miss was reported as a deadline
    miss even though its wall time was XLA compilation, not solving."""
    fake = SimpleNamespace(t=0.0)

    def clock():
        fake.t += 1.0
        return fake.t

    mon = HealthMonitor(deadline_ms=500.0, kkt_every=0, clock=clock)
    spec = TenantSpec(name="t0", n_starts=2,
                      trace=make_trace("constant", BASE, 4))
    replay_fleet(tiny_catalog, [spec], replay_mode="batched",
                 run_ca_baseline=False, health=mon)
    rep = mon.report()
    assert rep.ticks_observed == 4
    assert rep.compile_excluded_ticks == 2     # cold + first-warm programs
    assert rep.deadline_miss_ticks == 2        # only the steady-state ticks


def test_compile_key_first_sighting_excluded_from_deadline_budget():
    """Regression (ISSUE satellite, unit level): observe_tick with a
    compile_key excludes exactly the FIRST sighting of each key from the
    deadline budget — repeat sightings are normal budgeted ticks — and
    routes the excluded duration to its own histogram."""
    reg = MetricRegistry()
    mon = HealthMonitor(deadline_ms=50.0, registry=reg)
    mon.observe_tick(0, 900.0, compile_key=("tick", 0))   # compile: excluded
    mon.observe_tick(1, 700.0, compile_key=("tick", 1))   # new key: excluded
    mon.observe_tick(2, 80.0, compile_key=("tick", 1))    # seen: a real miss
    mon.observe_tick(3, 10.0, compile_key=("tick", 1))    # seen: within budget
    mon.observe_tick(4, 80.0)                             # keyless: a miss
    rep = mon.report()
    assert rep.ticks_observed == 5
    assert rep.compile_excluded_ticks == 2
    assert rep.deadline_miss_ticks == 2
    assert reg.counter("health/compile_excluded_ticks").value == 2
    assert reg.histogram("health/tick_compile_ms").count == 2
    assert reg.histogram("health/tick_ms").count == 3
    d = rep.to_dict()
    assert d["compile_excluded_ticks"] == 2
    assert d["deadline_truncated_ticks"] == 0


def test_deadline_truncated_steps_counted():
    """Steps committed with ``deadline_hit=True`` (an enforced anytime
    budget truncated their solve) are rolled up separately from wall-clock
    deadline misses."""
    reg = MetricRegistry()
    mon = HealthMonitor(registry=reg)
    step = _step()
    step.deadline_hit = True
    mon.observe_step(tenant="a", tick=0, step=step, solver="adaptive")
    mon.observe_step(tenant="a", tick=1, step=_step(), solver="adaptive")
    rep = mon.report()
    assert rep.deadline_truncated_ticks == 1
    assert reg.counter("health/deadline_truncated_ticks").value == 1
    assert "anytime trunc" in "\n".join(rep.summary_lines())


# ---------------------------------------------------------------------------
# event cap / serialization
# ---------------------------------------------------------------------------

def test_event_storage_cap_counters_keep_counting():
    mon = HealthMonitor(max_events=3)
    for t in range(10):
        mon.observe_step(tenant="a", tick=t, solver="adaptive",
                         step=_step(counts=[np.nan]))
    rep = mon.report()
    assert len(rep.events) == 3 and rep.nonfinite_events == 10


def test_report_and_events_are_json_ready():
    mon = HealthMonitor(deadline_ms=5.0)
    mon.observe_step(tenant="a", tick=0, solver="adaptive",
                     step=_step(counts=[np.nan]), lane=np.int64(3))
    mon.observe_tick(0, 10.0)
    doc = json.loads(json.dumps(mon.report().to_dict(), default=int))
    assert doc["nonfinite_events"] == 1 and doc["deadline_miss_ticks"] == 1
    assert doc["events"][0]["kind"] == "non_finite"
    assert HealthEvent(kind="x", severity="warn", tenant="t", tick=0,
                       solver="s").to_dict()["value"] is None


def test_constructor_validation():
    with pytest.raises(ValueError, match="kkt_every"):
        HealthMonitor(kkt_every=-1)
    with pytest.raises(ValueError, match="stall_window"):
        HealthMonitor(stall_window=1)


# ---------------------------------------------------------------------------
# tentpole acceptance: observe-only, both engines, both controllers
# ---------------------------------------------------------------------------

def _fleet(n_ticks=3):
    return [
        TenantSpec(name="a", n_starts=2,
                   trace=make_trace("diurnal", BASE, n_ticks, seed=0,
                                    amplitude=0.3)),
        TenantSpec(name="b", n_starts=2, delta_max=4.0,
                   trace=make_trace("ramp", BASE * 0.6, n_ticks, seed=1)),
    ]


def _counts(res):
    return [[s.counts for s in t.steps] for t in res.tenants]


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sequential", "batched"])
def test_myopic_allocations_bit_identical_with_obs_on_and_off(
        tiny_catalog, mode):
    """Acceptance criterion: metrics + health on vs off leaves per-tenant
    integer allocations bit-identical, per engine."""
    kw = dict(replay_mode=mode, run_ca_baseline=False,
              capture_solver_trace=True)
    off = replay_fleet(tiny_catalog, _fleet(), **kw)
    reg = MetricRegistry()
    mon = HealthMonitor(deadline_ms=1e9, registry=reg)
    with collect_metrics(registry=reg):
        on = replay_fleet(tiny_catalog, _fleet(), health=mon, **kw)
    for c_off, c_on in zip(_counts(off), _counts(on)):
        for a, b in zip(c_off, c_on):
            np.testing.assert_array_equal(a, b)
    # the monitored replay actually observed: every committed (tenant,
    # tick) certified, every tick timed, engine histograms filled
    rep = mon.report()
    assert rep.kkt_ticks_certified == 6      # 2 tenants x 3 ticks
    assert rep.ticks_observed == (6 if mode == "sequential" else 3)
    assert rep.worst_kkt_stationarity is not None
    assert reg.histogram("replay/tick_ms").count == rep.ticks_observed
    assert on.metrics.health is rep
    assert any("health:" in line for line in on.metrics.summary().split("\n"))
    assert off.metrics.health is None


@pytest.mark.slow
def test_mpc_allocations_bit_identical_with_obs_on_and_off(tiny_catalog):
    """Same acceptance for the MPC controller (batched engine — the
    sequential MPC path shares observe_step plumbing via _replay_sequential,
    covered by the cross-engine counter test below)."""
    kw = dict(replay_mode="batched", controller="mpc", horizon=2,
              run_ca_baseline=False)
    off = replay_fleet(tiny_catalog, _fleet(), **kw)
    mon = HealthMonitor()
    on = replay_fleet(tiny_catalog, _fleet(), health=mon, **kw)
    for c_off, c_on in zip(_counts(off), _counts(on)):
        for a, b in zip(c_off, c_on):
            np.testing.assert_array_equal(a, b)
    assert mon.report().kkt_ticks_certified == 6


@pytest.mark.slow
def test_health_counters_agree_across_engines(tiny_catalog):
    """The two engines feed the monitor through different code paths but
    observe the SAME committed steps — deterministic counters and the worst
    KKT residual must agree exactly."""
    reports = {}
    for mode in ("sequential", "batched"):
        mon = HealthMonitor()
        replay_fleet(tiny_catalog, _fleet(), replay_mode=mode,
                     run_ca_baseline=False, health=mon)
        reports[mode] = mon.report()
    seq, bat = reports["sequential"], reports["batched"]
    assert seq.slo_breach_ticks == bat.slo_breach_ticks
    assert seq.churn_violation_ticks == bat.churn_violation_ticks
    assert seq.kkt_ticks_certified == bat.kkt_ticks_certified
    assert seq.nonfinite_events == bat.nonfinite_events == 0
    assert seq.worst_kkt_stationarity == pytest.approx(
        bat.worst_kkt_stationarity, rel=1e-4)


@pytest.mark.slow
def test_spot_interruption_ticks_counted(tiny_catalog):
    """A tenant with an availability overlay that zeroes its spot twin on
    some tick must bump the spot-interruption counter."""
    avail = np.ones((3, 1))
    avail[1, 0] = 0.0   # interrupted on tick 1
    spec = TenantSpec(name="spot", n_starts=2,
                      trace=make_trace("constant", BASE, 3),
                      spot_idx=np.array([0]), spot_availability=avail)
    mon = HealthMonitor(kkt_every=0)
    replay_fleet(tiny_catalog, [spec], replay_mode="batched",
                 run_ca_baseline=False, health=mon)
    assert mon.report().spot_interruption_ticks == 1

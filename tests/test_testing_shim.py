"""Self-tests for the ``repro.testing`` property-test core.

The shim is the only property-testing machinery available when the image
lacks hypothesis, so its own contract needs tests: deterministic draws
(run-to-run reproducibility is what replaces shrinking), counterexample
reporting on failure, in-range strategies, combinator strategies, and
``settings`` stacking in both decorator orders."""
import numpy as np
import pytest

from repro.testing import _Strategy, composite, given, settings
from repro.testing import strategies as st


def _draws(strategy, n=200, seed=0):
    rng = np.random.default_rng(seed)
    return [strategy.sample(rng) for _ in range(n)]


# ---------------------------------------------------------------------------
# strategies draw in-range, with the right types
# ---------------------------------------------------------------------------


def test_integers_in_range_and_inclusive():
    vals = _draws(st.integers(-3, 5), n=500)
    assert all(isinstance(v, int) for v in vals)
    assert min(vals) == -3 and max(vals) == 5          # both ends reachable


def test_floats_in_range():
    vals = _draws(st.floats(0.5, 2.5), n=500)
    assert all(isinstance(v, float) for v in vals)
    assert all(0.5 <= v <= 2.5 for v in vals)


def test_booleans_hit_both_values():
    vals = _draws(st.booleans(), n=100)
    assert set(vals) == {True, False}
    assert all(isinstance(v, bool) for v in vals)


def test_sampled_from_membership_and_coverage():
    pool = ("diurnal", "flash_crowd", "ramp")
    vals = _draws(st.sampled_from(pool), n=200)
    assert set(vals) == set(pool)
    with pytest.raises(AssertionError):
        st.sampled_from([])


def test_tuples_draw_elementwise():
    vals = _draws(st.tuples(st.integers(0, 3), st.floats(0.0, 1.0),
                            st.booleans()), n=100)
    for a, b, c in vals:
        assert isinstance(a, int) and 0 <= a <= 3
        assert isinstance(b, float) and 0.0 <= b <= 1.0
        assert isinstance(c, bool)


def test_lists_respect_size_bounds():
    vals = _draws(st.lists(st.integers(0, 9), min_size=2, max_size=5), n=200)
    sizes = {len(v) for v in vals}
    assert sizes == {2, 3, 4, 5}                       # whole range reachable
    assert all(0 <= x <= 9 for v in vals for x in v)


def test_composite_builds_structured_values():
    @composite
    def demand_window(draw, m):
        h = draw(st.integers(1, 4))
        base = draw(st.floats(1.0, 8.0))
        return [[base * (1.0 + 0.1 * t)] * m for t in range(h)]

    vals = _draws(demand_window(3), n=50)
    for w in vals:
        assert 1 <= len(w) <= 4
        assert all(len(row) == 3 for row in w)
        # base <= 8.0, last tick scales by at most 1 + 0.1*3
        assert all(1.0 <= row[0] <= 8.0 * 1.3 + 1e-6 for row in w)


def test_st_composite_alias():
    """``st.composite`` must exist (hypothesis spells it both ways)."""
    assert st.composite is composite


# ---------------------------------------------------------------------------
# determinism: same test name -> same draw sequence, run after run
# ---------------------------------------------------------------------------


def test_given_draws_are_deterministic_across_runs():
    def run_once():
        seen = []

        def prop(a, b):
            seen.append((a, b))

        prop.__name__ = "prop_fixed_name"             # seed depends on name
        given(a=st.integers(0, 1000), b=st.floats(0.0, 1.0))(prop)()
        return seen

    first, second = run_once(), run_once()
    assert first == second
    assert len(first) == 10                            # default max_examples
    assert len(set(first)) > 1                         # actually sweeping


def test_different_test_names_get_different_streams():
    def collect(name):
        seen = []

        def prop(a):
            seen.append(a)

        prop.__name__ = name
        given(a=st.integers(0, 10**9))(prop)()
        return seen

    assert collect("prop_one") != collect("prop_two")


# ---------------------------------------------------------------------------
# settings stacking (either decorator order)
# ---------------------------------------------------------------------------


def test_settings_above_given_controls_examples():
    count = [0]

    @settings(max_examples=3)
    @given(a=st.integers(0, 5))
    def prop(a):
        count[0] += 1

    prop()
    assert count[0] == 3


def test_settings_below_given_controls_examples():
    count = [0]

    @given(a=st.integers(0, 5))
    @settings(max_examples=4)
    def prop(a):
        count[0] += 1

    prop()
    assert count[0] == 4


# ---------------------------------------------------------------------------
# counterexample reporting
# ---------------------------------------------------------------------------


def test_failure_surfaces_counterexample(capsys):
    """A failing draw must re-raise AND print the falsifying example —
    seed + kwargs — so the failure is reproducible by hand."""

    @given(a=st.integers(0, 100), b=st.booleans())
    def prop(a, b):
        assert a < 40, "drew a big one"

    with pytest.raises(AssertionError, match="drew a big one"):
        prop()
    out = capsys.readouterr().out
    assert "Falsifying example" in out
    assert "prop(" in out and "a=" in out and "b=" in out
    assert "seed=" in out
    # the printed draw is the real counterexample: parse `a=` back out and
    # check it actually violates the property
    a_val = int(out.split("a=")[1].split(",")[0].rstrip(")"))
    assert a_val >= 40


def test_failure_preserves_exception_type():
    @given(a=st.integers(0, 5))
    def prop(a):
        raise ValueError("not an assert")

    with pytest.raises(ValueError):
        prop()


def test_strategy_is_reusable_across_rngs():
    """One _Strategy object may be sampled with many rngs (the combinators
    rely on this) — it must hold no draw state of its own."""
    s = _Strategy(lambda rng: int(rng.integers(0, 100)))
    a = s.sample(np.random.default_rng(7))
    b = s.sample(np.random.default_rng(7))
    assert a == b

#!/usr/bin/env python
"""``make bench-check``'s gate: compare two BENCH_*.json files and fail CI
on a regression.

Thin CLI over :mod:`repro.obs.regress` — all comparison policy (metric
classification, per-class tolerances, provenance-aware refusal) lives
there and is unit-tested; this file only parses arguments, loads JSON and
maps outcomes to exit codes:

* **0** — comparable, no metric regressed past its class tolerance;
* **1** — comparable, and at least one metric regressed (the CI failure);
* **2** — NOT comparable: schema-invalid BENCH doc, config-digest
  mismatch, or platform/backend mismatch without
  ``--allow-cross-platform``. Distinct from 1 so a stale golden reads as
  "refresh the golden", not "you slowed the code down".

``--selftest BENCH.json`` proves the sentinel actually bites before CI
trusts it: the doc is compared against perturbed copies of itself — a
+25% inflation of every timing leaf must fail at the default 20% timing
tolerance, a +2% inflation of the objective leaves must fail at the 1%
objective tolerance, and the identity comparison must pass. Exit 0 only
when all three hold.

Usage:
  PYTHONPATH=src python tools/bench_compare.py BASE.json CAND.json \\
      [--timing-rtol 0.2] [--objective-rtol 0.01] [--allow-cross-platform]
  PYTHONPATH=src python tools/bench_compare.py --selftest BENCH.json
"""
from __future__ import annotations

import argparse
import copy
import json
import sys


def _load(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def _set_path(doc, path: str, value) -> None:
    """Set a dotted-path leaf (as produced by ``numeric_leaves``) in a
    nested dict/list document; list segments are integer indices."""
    node = doc
    parts = path.split(".")
    for part in parts[:-1]:
        node = node[int(part)] if isinstance(node, list) else node[part]
    last = parts[-1]
    if isinstance(node, list):
        node[int(last)] = value
    else:
        node[last] = value


def _perturbed(doc: dict, kinds: tuple, factor: float) -> dict:
    """Copy ``doc`` with every nonzero numeric leaf of the given metric
    classes scaled by ``factor`` (regression direction for lower-better
    classes when factor > 1). Returns the copy and leaves others intact."""
    from repro.obs.regress import classify_metric, numeric_leaves

    out = copy.deepcopy(doc)
    touched = 0
    for path, val in numeric_leaves(doc).items():
        if classify_metric(path) in kinds and abs(val) > 1e-9:
            _set_path(out, path, val * factor)
            touched += 1
    if touched == 0:
        raise SystemExit(f"selftest: doc has no nonzero {kinds} leaves "
                         f"to perturb")
    return out


def _selftest(path: str) -> int:
    from repro.obs.regress import compare_bench

    doc = _load(path)
    failures = []

    ident = compare_bench(doc, doc)
    if not ident.ok or ident.refusals:
        failures.append("identity comparison did not pass cleanly:\n"
                        + ident.summary())

    slow = compare_bench(doc, _perturbed(doc, ("timing",), 1.25),
                         timing_rtol=0.2)
    if slow.ok or not any(d.kind == "timing" for d in slow.regressions):
        failures.append("+25% timing perturbation was NOT caught at "
                        "timing_rtol=0.2:\n" + slow.summary())

    worse = compare_bench(doc, _perturbed(doc, ("objective",), 1.02),
                          objective_rtol=0.01)
    if worse.ok or not any(d.kind == "objective" for d in worse.regressions):
        failures.append("+2% objective perturbation was NOT caught at "
                        "objective_rtol=0.01:\n" + worse.summary())

    if failures:
        print(f"[bench-compare] SELFTEST FAILED ({len(failures)}):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("[bench-compare] selftest OK — identity passes; +25% timing and "
          "+2% objective regressions are both caught")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Compare two BENCH_*.json files; nonzero exit on "
                    "regression (1) or non-comparable pair (2).")
    ap.add_argument("base", nargs="?", help="baseline (golden) BENCH json")
    ap.add_argument("cand", nargs="?", help="candidate (fresh) BENCH json")
    ap.add_argument("--timing-rtol", type=float, default=0.2,
                    help="allowed relative regression for timing/throughput "
                         "metrics (default 0.2)")
    ap.add_argument("--objective-rtol", type=float, default=0.01,
                    help="allowed relative regression for objective/quality "
                         "metrics (default 0.01)")
    ap.add_argument("--allow-cross-platform", action="store_true",
                    help="on platform/backend mismatch, skip timing metrics "
                         "instead of refusing (objective still compared)")
    ap.add_argument("--selftest", metavar="BENCH.json",
                    help="prove the sentinel catches injected regressions "
                         "in this doc, then exit")
    args = ap.parse_args(argv)

    if args.selftest:
        return _selftest(args.selftest)
    if not args.base or not args.cand:
        ap.error("BASE and CAND are required (or use --selftest)")

    from repro.obs.regress import compare_bench

    cmp = compare_bench(
        _load(args.base), _load(args.cand),
        timing_rtol=args.timing_rtol, objective_rtol=args.objective_rtol,
        allow_cross_platform=args.allow_cross_platform)
    print(f"[bench-compare] {args.base} vs {args.cand}")
    print(cmp.summary())
    if cmp.refusals:
        return 2
    return 0 if cmp.ok else 1


if __name__ == "__main__":
    sys.exit(main())

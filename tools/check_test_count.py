#!/usr/bin/env python
"""Collected-test-count regression gate (CI).

Runs pytest collection and fails (exit 1) when the number of collected
tests drops below ``MIN_COLLECTED_TESTS`` (env var; default = the count
recorded when the gate was introduced). "All green" is meaningless if a
refactor silently stopped a test file from importing or collecting —
pytest reports collection ERRORS loudly, but a file dropped from testpaths
or skipped by a rename disappears without one. The floor only ratchets UP:
raise the default (and the pin in .github/workflows/ci.yml) when tests are
added; lowering it is a reviewed decision, not an accident.

Usage:  PYTHONPATH=src python tools/check_test_count.py
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

DEFAULT_MIN = 401  # ratcheted at ISSUE 10 (anytime/serve suites); 374 at ISSUE 9; 312 at ISSUE 8; 262 at introduction (ISSUE 7)


def main() -> int:
    floor = int(os.environ.get("MIN_COLLECTED_TESTS", DEFAULT_MIN))
    repo = Path(__file__).resolve().parent.parent
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q"],
        capture_output=True, text=True, cwd=repo)
    tail = proc.stdout.strip().splitlines()[-1] if proc.stdout.strip() else ""
    m = re.search(r"(\d+) tests? collected", tail)
    if proc.returncode != 0 or not m:
        print(f"[check_test_count] FAIL — collection errored "
              f"(rc={proc.returncode}): {tail}")
        sys.stderr.write(proc.stderr[-2000:])
        return 1
    count = int(m.group(1))
    if count < floor:
        print(f"[check_test_count] FAIL — {count} tests collected, floor "
              f"is {floor}: a test file stopped collecting, or the floor "
              f"needs a reviewed lowering")
        return 1
    print(f"[check_test_count] OK — {count} tests collected "
          f"(floor {floor})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""``make trace-demo`` — keep the trace export path from rotting silently.

Runs a SMALL fully-instrumented fleet replay (telemetry recorder + per-lane
solver-trace capture), writes the Perfetto-loadable Chrome trace to
``benchmarks/artifacts/trace.json`` (plus the JSONL event log next to it),
re-validates BOTH emitted files against their schemas
(``repro.obs.export.validate_chrome_trace`` / ``validate_jsonl``), and
prints the ``ReplayReport`` rollup. Exit 1 on any schema violation, on a
trace with no compile-tagged solve span, or on a replay that captured no
solver trace — the things the export pipeline exists to deliver.

``--validate TRACE.json`` skips the replay and only re-validates an
already-emitted artifact pair (the JSONL is looked up next to the trace),
exiting non-zero on problems — the mode the validator regression tests
drive with deliberately corrupted files.

Run:  PYTHONPATH=src python tools/trace_demo.py [--out PATH]
Open: https://ui.perfetto.dev  →  drag benchmarks/artifacts/trace.json in.
"""
from __future__ import annotations

import os
import sys
from typing import List

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO, "benchmarks", "artifacts", "trace.json")


def validate_artifacts(trace_path: str, jsonl_path: str) -> List[str]:
    """Validate an emitted (Chrome trace, JSONL event log) pair against
    both export schemas; returns all problems, each prefixed with the file
    it came from (empty list = both valid)."""
    from repro.obs import validate_chrome_trace, validate_jsonl

    problems = [f"trace schema: {p}" for p in validate_chrome_trace(trace_path)]
    problems += [f"jsonl schema: {p}" for p in validate_jsonl(jsonl_path)]
    return problems


def main(argv) -> int:
    out = DEFAULT_OUT
    if "--out" in argv:
        i = argv.index("--out")
        if i + 1 >= len(argv):
            raise SystemExit("--out requires a path argument")
        out = argv[i + 1]
    if "--validate" in argv:
        i = argv.index("--validate")
        if i + 1 >= len(argv):
            raise SystemExit("--validate requires a trace path argument")
        trace_path = argv[i + 1]
        jsonl_path = os.path.splitext(trace_path)[0] + ".jsonl"
        failures = validate_artifacts(trace_path, jsonl_path)
        if failures:
            print(f"[trace-demo] INVALID — {len(failures)} problem(s):")
            for f in failures:
                print(f"  {f}")
            return 1
        print(f"[trace-demo] OK — {trace_path} and {jsonl_path} validate")
        return 0

    from repro.core import Catalog, make_cloud_catalog
    from repro.fleet import TenantSpec, make_trace, replay_fleet
    from repro.obs import (ReplayReport, telemetry, write_chrome_trace,
                           write_jsonl)

    catalog = Catalog(make_cloud_catalog().instances[::40])
    base = np.array([8.0, 16.0, 4.0, 100.0])
    specs = [
        TenantSpec(name="diurnal", n_starts=2,
                   trace=make_trace("diurnal", base, 4, seed=0,
                                    amplitude=0.3)),
        TenantSpec(name="ramp", n_starts=2,
                   trace=make_trace("ramp", base * 0.6, 4, seed=1)),
    ]
    print(f"[trace-demo] instrumented batched replay: "
          f"{len(specs)} tenants x 4 ticks, catalog n={catalog.n}")
    with telemetry() as rec:
        res = replay_fleet(catalog, specs, run_ca_baseline=False,
                           replay_mode="batched", capture_solver_trace=True)

    path = write_chrome_trace(rec, out)
    jsonl = write_jsonl(rec, os.path.splitext(out)[0] + ".jsonl")
    failures = validate_artifacts(str(path), str(jsonl))
    if not rec.spans("replay/solve", phase="compile"):
        failures.append("no compile-tagged replay/solve span recorded")
    n_traces = sum(len(t) for t in (res.solver_traces or []))
    if n_traces == 0:
        failures.append("replay captured no per-lane solver traces")

    print(ReplayReport.from_recorder(rec).render())
    print(f"[trace-demo] wrote {path} ({len(rec.events)} spans) and {jsonl}")
    print(f"[trace-demo] {n_traces} per-lane solver traces captured")
    if failures:
        print(f"[trace-demo] FAILED — {len(failures)} problem(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("[trace-demo] OK — both artifacts validate; open the trace at "
          "https://ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

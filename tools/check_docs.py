#!/usr/bin/env python
"""Documentation gate (``make docs-check``).

Fails (exit 1) when:

* a public module under ``src/repro/fleet/``, ``src/repro/core/``,
  ``src/repro/horizon/``, ``src/repro/obs/`` or ``src/repro/serve/``
  lacks a module-level docstring,
* a public (non-underscore) top-level function or class in those packages
  lacks a docstring — NamedTuple/dataclass result containers included,
* a ``docs/*.md`` page referenced from README.md does not exist, or any of
  the canonical docs pages is missing entirely.

Pure stdlib (ast) — no imports of the package, so it runs anywhere.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CHECKED_PACKAGES = ("src/repro/fleet", "src/repro/core", "src/repro/horizon",
                    "src/repro/obs", "src/repro/serve")
# single modules gated outside the checked packages: the property-test core
# is public API for every test in the repo (note `src/repro/core/pgd.py`,
# the shared PGD engine, is already covered by the core package glob)
CHECKED_MODULES = ("src/repro/testing.py",)
REQUIRED_DOCS = ("docs/architecture.md", "docs/math.md", "docs/fleet.md",
                 "docs/horizon.md", "docs/observability.md",
                 "docs/scenarios.md", "docs/serving.md")


def iter_public_modules():
    for pkg in CHECKED_PACKAGES:
        for path in sorted((REPO / pkg).glob("*.py")):
            yield path
    for mod in CHECKED_MODULES:
        yield REPO / mod


def check_module(path: Path):
    """Return a list of problem strings for one module."""
    problems = []
    tree = ast.parse(path.read_text(), filename=str(path))
    rel = path.relative_to(REPO)
    if not ast.get_docstring(tree):
        problems.append(f"{rel}: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name.startswith("_"):
                continue
            if not ast.get_docstring(node):
                problems.append(
                    f"{rel}:{node.lineno}: public "
                    f"{'class' if isinstance(node, ast.ClassDef) else 'function'}"
                    f" `{node.name}` missing docstring")
    return problems


def check_docs_tree():
    problems = []
    for doc in REQUIRED_DOCS:
        if not (REPO / doc).is_file():
            problems.append(f"{doc}: required docs page missing")
    readme = (REPO / "README.md").read_text()
    for link in re.findall(r"\]\((docs/[^)#]+)", readme):
        if not (REPO / link).is_file():
            problems.append(f"README.md links to missing page {link}")
    if "docs/" not in readme:
        problems.append("README.md does not link to the docs/ tree")
    return problems


def main() -> int:
    problems = []
    n_modules = 0
    for path in iter_public_modules():
        n_modules += 1
        problems.extend(check_module(path))
    problems.extend(check_docs_tree())
    if problems:
        print(f"[docs-check] FAILED — {len(problems)} problem(s):")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"[docs-check] OK — {n_modules} modules documented, "
          f"{len(REQUIRED_DOCS)} docs pages present, README links valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())

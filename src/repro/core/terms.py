"""Composable priced-term objective IR.

Every term of the allocation objective — the four paper eq. (1) terms and
every scenario term (SLO pricing, priority eviction, spot risk) — is one
registered :class:`TermDef`: a ``(name, value_fn, grad_fn, param_axes)``
record whose value/grad functions share precomputed ``K@x`` / ``E@x``
matvecs, so ``value_and_grad`` does exactly one matvec pair no matter how
many terms are active.  Consumers (``core.objective``, ``core.kkt``,
``horizon``, ``fleet``) sum over the registry instead of hand-copying term
math; one definition per term is the contract the autodiff property suite
enforces.

Scenario terms are *attached* to a problem as :class:`PricedTerm` instances
in ``AllocationProblem.terms`` — a pytree extension alongside
``PenaltyParams``.  The tuple's *structure* (which kinds, which param keys)
is Python-time static: an empty ``terms=()`` contributes zero pytree leaves
and zero traced ops, so the default compiled graphs are byte-for-byte the
seed graphs (jaxpr-identity is test-pinned) and every bit-exactness
contract from PRs 5-7 — batched ≡ sequential, H=1 ≡ myopic, the Pallas
``alloc_objective`` oracle — survives unchanged.

Padding-exactness discipline: every attachable term must evaluate to
exactly ``0.0`` with exactly zero gradient when its params are zero, so
ragged fleet stacking can zero-fill absent tenants (``fleet.batching``)
without perturbing any trajectory bit.  All three scenario terms are linear
in their price params, which gives this for free; new terms must keep the
property (see docs/scenarios.md).

Param axes declare how each param pads and slices under fleet stacking:
``""`` = per-tenant scalar, ``"n"`` = per-instance-type vector, ``"m"`` =
per-resource vector.
"""
from __future__ import annotations

import operator
from functools import reduce
from typing import Callable, Dict, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .problem import AllocationProblem

# ---------------------------------------------------------------------------
# Term IR
# ---------------------------------------------------------------------------

# value/grad signature: (prob, params, x, Kx, Ex) -> scalar / (n,).
# ``params`` is the attached PricedTerm.params dict (None for the implicit
# base terms, which read ``prob.params`` / ``prob.c`` directly).
TermFn = Callable[[AllocationProblem, Optional[Dict[str, jnp.ndarray]],
                   jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


class TermDef(NamedTuple):
    """One registered objective term.

    ``param_axes`` maps each param name to its stacking axis ("" scalar,
    "n" per-type, "m" per-resource).  Base eq. (1) terms have no params
    (they read ``prob.params``) and are implicitly always active; only
    terms WITH declared params can be attached via :func:`make_term`.
    """

    name: str
    value: TermFn
    grad: TermFn
    param_axes: Mapping[str, str]


@jax.tree_util.register_pytree_node_class
class PricedTerm:
    """A scenario term attached to a problem: a registry kind plus its
    priced params.  Registered as a pytree whose leaves are the param
    arrays (sorted by key) and whose aux data ``(kind, keys)`` is static —
    jit caches key on the term *structure* while prices stay traced, and
    ``tree_map`` slicing/stacking (horizon ticks, fleet lanes) works on
    terms exactly as on every other problem field."""

    __slots__ = ("kind", "params")

    def __init__(self, kind: str, params: Dict[str, jnp.ndarray]):
        self.kind = str(kind)
        self.params = dict(params)

    def tree_flatten(self):
        keys = tuple(sorted(self.params))
        return tuple(self.params[k] for k in keys), (self.kind, keys)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, keys = aux
        return cls(kind, dict(zip(keys, children)))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        inner = ", ".join(f"{k}={v!r}" for k, v in sorted(self.params.items()))
        return f"PricedTerm({self.kind!r}, {inner})"


# ---------------------------------------------------------------------------
# Base terms (paper eq. 1) — implicit, always active
# ---------------------------------------------------------------------------


def _base_cost_value(prob, params, x, Kx, Ex):
    return prob.c @ x


def _base_cost_grad(prob, params, x, Kx, Ex):
    return prob.c


def _consolidation_value(prob, params, x, Kx, Ex):
    P = prob.params
    # alpha * p - alpha * 1^T e^{-b1 Ex}  ==  alpha * sum(1 - e^{-b1 Ex})
    return P.alpha * jnp.sum(1.0 - jnp.exp(-P.beta1 * Ex))


def _consolidation_grad(prob, params, x, Kx, Ex):
    P = prob.params
    return P.alpha * P.beta1 * (prob.E.T @ jnp.exp(-P.beta1 * Ex))


def _volume_discount_value(prob, params, x, Kx, Ex):
    P = prob.params
    return -P.gamma * jnp.sum(jnp.log1p(P.beta2 * Ex))


def _volume_discount_grad(prob, params, x, Kx, Ex):
    P = prob.params
    return -P.gamma * P.beta2 * (prob.E.T @ (1.0 / (1.0 + P.beta2 * Ex)))


def _shortage_value(prob, params, x, Kx, Ex):
    P = prob.params
    shortage = jnp.maximum(prob.d - Kx, 0.0)
    return P.beta3 * jnp.sum(shortage**2)


def _shortage_grad(prob, params, x, Kx, Ex):
    P = prob.params
    shortage = jnp.maximum(prob.d - Kx, 0.0)
    return -2.0 * P.beta3 * (prob.K.T @ shortage)


# ---------------------------------------------------------------------------
# Scenario terms — attachable, priced, zero-at-zero-params
# ---------------------------------------------------------------------------


def _slo_penalty_value(prob, params, x, Kx, Ex):
    # price * sum max(d - Kx, 0): an L1 shortage price in $/unit-shortage —
    # the *linear* SLO cost on top of the quadratic eq. (1) smoothing term,
    # so slo_violation_ticks becomes an objective cost, not a metric.
    return params["price"] * jnp.sum(jnp.maximum(prob.d - Kx, 0.0))


def _slo_penalty_grad(prob, params, x, Kx, Ex):
    # Subgradient with the 0 choice at the hinge — exact ties only occur on
    # zero-padded rows where the K column is zero too, so this matches
    # jax.grad everywhere it matters (property-tested).
    live = (prob.d - Kx > 0.0).astype(x.dtype)
    return -params["price"] * (prob.K.T @ live)


def _priority_eviction_value(prob, params, x, Kx, Ex):
    # price @ x: holding capacity costs eviction exposure. High-priority
    # tenants carry price 0; lower classes pay per node held, scaled by
    # fleet high-priority pressure (see fleet.scenarios).
    return params["price"] @ x


def _priority_eviction_grad(prob, params, x, Kx, Ex):
    return params["price"]


def _spot_risk_value(prob, params, x, Kx, Ex):
    # risk @ x: certainty-equivalent interruption surcharge on spot twins
    # (rate x penalty-hours x spot price), kept OUT of c so the catalog
    # lists the true spot price and the risk stays a visible priced term.
    return params["risk"] @ x


def _spot_risk_grad(prob, params, x, Kx, Ex):
    return params["risk"]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# Order is contractual: base terms trace in this order so the default
# objective/grad graphs are jaxpr-identical to the seed implementation.
BASE_TERMS: Tuple[str, ...] = (
    "base_cost", "consolidation", "volume_discount", "shortage")

TERM_DEFS: Dict[str, TermDef] = {}


def register_term(name: str, value: TermFn, grad: TermFn,
                  param_axes: Optional[Mapping[str, str]] = None) -> TermDef:
    """Register a term definition. Attachable terms must declare
    ``param_axes``; axis values must be one of "", "n", "m"."""
    axes = dict(param_axes or {})
    bad = {k: ax for k, ax in axes.items() if ax not in ("", "n", "m")}
    if bad:
        raise ValueError(f"invalid param axes for term {name!r}: {bad}")
    if name in TERM_DEFS:
        raise ValueError(f"term {name!r} already registered")
    td = TermDef(name, value, grad, axes)
    TERM_DEFS[name] = td
    return td


register_term("base_cost", _base_cost_value, _base_cost_grad)
register_term("consolidation", _consolidation_value, _consolidation_grad)
register_term("volume_discount", _volume_discount_value, _volume_discount_grad)
register_term("shortage", _shortage_value, _shortage_grad)

register_term("slo_penalty", _slo_penalty_value, _slo_penalty_grad,
              {"price": ""})
register_term("priority_eviction", _priority_eviction_value,
              _priority_eviction_grad, {"price": "n"})
register_term("spot_risk", _spot_risk_value, _spot_risk_grad,
              {"risk": "n"})

#: Attachable (scenario) kinds, in registration order.
SCENARIO_TERMS: Tuple[str, ...] = tuple(
    k for k in TERM_DEFS if TERM_DEFS[k].param_axes)


def make_term(kind: str, **params) -> PricedTerm:
    """Build a :class:`PricedTerm` for a registered attachable kind.

    Rejects unknown kinds, implicit (base) kinds, and unknown/missing
    params — mirroring the strict-kwarg discipline of ``make_trace``.
    """
    td = TERM_DEFS.get(kind)
    if td is None:
        raise ValueError(
            f"unknown term kind {kind!r}; known: {sorted(TERM_DEFS)}")
    if not td.param_axes:
        raise ValueError(
            f"term {kind!r} is implicit (always active via prob.params) "
            "and cannot be attached")
    expected, got = set(td.param_axes), set(params)
    if got != expected:
        raise ValueError(
            f"term {kind!r} expects params {sorted(expected)}, got "
            f"{sorted(got)}")
    return PricedTerm(
        kind, {k: jnp.asarray(v, jnp.float32) for k, v in params.items()})


def normalize_terms(terms) -> Tuple[PricedTerm, ...]:
    """Coerce a terms spec — PricedTerm instances and/or ``(kind, params)``
    pairs — into a validated tuple with unique kinds."""
    out = []
    for t in terms or ():
        if isinstance(t, PricedTerm):
            t = make_term(t.kind, **t.params)  # re-validate + cast
        else:
            kind, params = t
            t = make_term(kind, **dict(params))
        out.append(t)
    kinds = [t.kind for t in out]
    if len(set(kinds)) != len(kinds):
        raise ValueError(f"duplicate term kinds: {kinds}")
    return tuple(out)


def _axis_size(prob: AllocationProblem, axis: str) -> Tuple[int, ...]:
    return {"": (), "n": (prob.n,), "m": (prob.m,)}[axis]


def with_terms(prob: AllocationProblem, terms) -> AllocationProblem:
    """Attach a validated terms tuple to ``prob`` (shape-checked against
    the problem's n/m extents)."""
    tup = normalize_terms(terms)
    for t in tup:
        for k, ax in TERM_DEFS[t.kind].param_axes.items():
            want = _axis_size(prob, ax)
            got = tuple(t.params[k].shape)
            if got != want:
                raise ValueError(
                    f"term {t.kind!r} param {k!r}: expected shape {want} "
                    f"(axis {ax!r}), got {got}")
    return prob._replace(terms=tup)


def term_signature(prob: AllocationProblem) -> Tuple[str, ...]:
    """The static kind tuple of a problem's attached terms."""
    return tuple(t.kind for t in prob.terms)


# ---------------------------------------------------------------------------
# Registry sums — the one place term math is combined
# ---------------------------------------------------------------------------


def term_values(prob: AllocationProblem, x: jnp.ndarray,
                Kx: jnp.ndarray, Ex: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Every active term's value: base terms first (seed trace order), then
    attached scenario terms in attachment order."""
    out = {}
    for name in BASE_TERMS:
        out[name] = TERM_DEFS[name].value(prob, None, x, Kx, Ex)
    for t in prob.terms:
        out[t.kind] = TERM_DEFS[t.kind].value(prob, t.params, x, Kx, Ex)
    return out


def term_grads(prob: AllocationProblem, x: jnp.ndarray,
               Kx: jnp.ndarray, Ex: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Every active term's analytic gradient, same order as term_values."""
    out = {}
    for name in BASE_TERMS:
        out[name] = TERM_DEFS[name].grad(prob, None, x, Kx, Ex)
    for t in prob.terms:
        out[t.kind] = TERM_DEFS[t.kind].grad(prob, t.params, x, Kx, Ex)
    return out


def sum_terms(terms: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Left-associated sum in dict order — preserves the seed float
    association so default graphs stay jaxpr-identical."""
    return reduce(operator.add, terms.values())


def active_value(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Sum of the ATTACHED scenario terms only (excludes base terms) — the
    additive hook for hand-batched paths (fleet kernel hot loop) that keep
    their own base-term math.  Callers gate on ``if prob.terms:`` so the
    default graph is untouched."""
    Kx = prob.K @ x
    Ex = prob.E @ x
    vals = [TERM_DEFS[t.kind].value(prob, t.params, x, Kx, Ex)
            for t in prob.terms]
    return reduce(operator.add, vals) if vals else jnp.asarray(0.0, x.dtype)


def active_grad(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Gradient counterpart of :func:`active_value`."""
    Kx = prob.K @ x
    Ex = prob.E @ x
    grads = [TERM_DEFS[t.kind].grad(prob, t.params, x, Kx, Ex)
             for t in prob.terms]
    return (reduce(operator.add, grads) if grads
            else jnp.zeros_like(x))

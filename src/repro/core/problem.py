"""Problem container for the paper's allocation model (§II.A).

Everything is a pytree of jnp arrays so problems can be jit-ed, vmap-ed
(e.g. over parameter grids for Pareto sweeps) and donated.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np


class PenaltyParams(NamedTuple):
    """The five scalar knobs of eq. (1). Stored as 0-d arrays so that a
    vmap over a grid of parameter settings is a first-class operation."""

    alpha: jnp.ndarray   # provider-consolidation weight
    beta1: jnp.ndarray   # sharpness of the 1 - e^{-b1 z} indicator approx
    beta2: jnp.ndarray   # volume-discount curvature
    beta3: jnp.ndarray   # shortage-penalty weight
    gamma: jnp.ndarray   # volume-discount weight

    @classmethod
    def create(cls, alpha=0.02, beta1=1.0, beta2=0.1, beta3=10.0, gamma=0.005):
        # Defaults tuned via pareto.grid_search on the five paper scenarios:
        # penalties must live on the same scale as $/hr base costs (0.1-1.5),
        # otherwise consolidation (<= alpha*p) dominates the allocation.
        f = lambda v: jnp.asarray(v, dtype=jnp.float32)
        return cls(f(alpha), f(beta1), f(beta2), f(beta3), f(gamma))


class AllocationProblem(NamedTuple):
    """Paper §II.A: min f(x) s.t. d - mu <= Kx <= d + g, x >= 0 (int relaxed).

    Shapes: K (m, n), E (p, n), c (n,), d/mu/g (m,).
    ``lb``/``ub`` are per-variable box bounds — identity boxes for the root
    problem; branch-and-bound tightens them per node. ``mask`` zeroes out
    instance types that a scenario forbids (enterprise-approved lists etc.).

    ``terms`` extends the objective with attached scenario terms
    (``repro.core.terms.PricedTerm`` tuple — SLO pricing, priority
    eviction, spot risk, ...).  The tuple structure is Python-time static:
    the default ``()`` contributes zero pytree leaves, so problems without
    scenario terms compile to exactly the seed graphs.
    """

    K: jnp.ndarray
    E: jnp.ndarray
    c: jnp.ndarray
    d: jnp.ndarray
    mu: jnp.ndarray
    g: jnp.ndarray
    params: PenaltyParams
    lb: jnp.ndarray
    ub: jnp.ndarray
    mask: jnp.ndarray  # 1.0 = allowed, 0.0 = forbidden
    terms: tuple = ()  # attached PricedTerm scenario terms (may be empty)

    @property
    def n(self) -> int:
        return self.c.shape[-1]

    @property
    def m(self) -> int:
        return self.d.shape[-1]

    @property
    def p(self) -> int:
        return self.E.shape[-2]

    @classmethod
    def create(
        cls,
        K,
        E,
        c,
        d,
        mu: Optional[np.ndarray] = None,
        g: Optional[np.ndarray] = None,
        params: Optional[PenaltyParams] = None,
        lb=None,
        ub=None,
        mask=None,
        ub_default: float = 1e4,
        terms: tuple = (),
    ) -> "AllocationProblem":
        K = jnp.asarray(K, jnp.float32)
        E = jnp.asarray(E, jnp.float32)
        c = jnp.asarray(c, jnp.float32)
        d = jnp.asarray(d, jnp.float32)
        m, n = K.shape
        mu = jnp.zeros(m, jnp.float32) if mu is None else jnp.asarray(mu, jnp.float32)
        # Default waste cap: generous (20x demand) — the paper's scenarios
        # frequently *require* heavy over-provisioning (Fig. 2 bottom), so a
        # tight cap would make the integer problem infeasible.
        g = 19.0 * d if g is None else jnp.asarray(g, jnp.float32)
        params = params if params is not None else PenaltyParams.create()
        lb = jnp.zeros(n, jnp.float32) if lb is None else jnp.asarray(lb, jnp.float32)
        ub = (
            jnp.full((n,), ub_default, jnp.float32)
            if ub is None
            else jnp.asarray(ub, jnp.float32)
        )
        mask = jnp.ones(n, jnp.float32) if mask is None else jnp.asarray(mask, jnp.float32)
        return cls(K, E, c, d, mu, g, params, lb, ub, mask, tuple(terms))

    def restrict(self, allowed_idx) -> "AllocationProblem":
        """Return a problem where only ``allowed_idx`` instance types may be
        used (others get mask 0 and ub 0)."""
        mask = jnp.zeros(self.n, jnp.float32).at[jnp.asarray(allowed_idx)].set(1.0)
        return self._replace(mask=mask, ub=self.ub * mask)

    def with_existing(self, x_existing) -> "AllocationProblem":
        """Lower-bound the allocation by an existing deployment (nodes that
        are already running and must be kept — scenario 2/4 setups)."""
        x_existing = jnp.asarray(x_existing, jnp.float32)
        return self._replace(lb=jnp.maximum(self.lb, x_existing))

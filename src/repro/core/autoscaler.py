"""Kubernetes Cluster Autoscaler baseline simulator (paper §IV.A.2).

Reproduces the CA constraints the paper compares against:
  * scaling restricted to predefined node pools,
  * no dynamic instance-type selection outside pools,
  * homogeneous scaling within each pool,
  * scale-up driven by unschedulable demand, scale-down of underutilized
    nodes where removal keeps demand satisfied.

Pure numpy — the baseline does not need (and the paper's does not have)
accelerated math.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .catalog import Catalog, M


@dataclass
class NodePool:
    """One CA node pool: a single instance type with count bounds — the
    unit of homogeneous scaling the paper's baseline is restricted to."""

    instance_idx: int            # index into the catalog
    count: int = 0               # current nodes
    min_count: int = 0
    max_count: int = 10_000


@dataclass
class CAResult:
    """Cluster-Autoscaler simulation outcome for one demand snapshot."""

    counts: np.ndarray           # (n,) integer allocation over catalog types
    cost: float
    iterations: int
    satisfied: bool


def _provided(K: np.ndarray, counts: np.ndarray) -> np.ndarray:
    return K @ counts


def simulate_cluster_autoscaler(
    catalog: Catalog,
    pools: Sequence[NodePool],
    demand: np.ndarray,
    max_iters: int = 100_000,
    expander: str = "random",
    scale_down: str = "utilization",
    mode: str = "wave",
    seed: int = 0,
) -> CAResult:
    """Greedy CA loop: while some resource is unschedulable, scale up a pool
    that can schedule the bottleneck resource, then run the scale-down pass.

    ``expander`` mirrors the real Cluster Autoscaler's ``--expander`` flag:
      * "random"      — CA's DEFAULT: any pool that can schedule the pending
                        demand, chosen uniformly (paper-comparable baseline).
      * "least-waste" — CA's optional smarter expander (a strong baseline;
                        reported separately in benchmarks).
      * "first-fit"   — priority expander: first pool in listed order.

    ``scale_down``:
      * "utilization" — CA semantics: remove a node only if it is below the
                        50% utilization threshold w.r.t. residual demand and
                        removal keeps everything schedulable.
      * "greedy"      — remove most-expensive nodes while feasible (stronger
                        than real CA).
      * "none"

    ``mode``:
      * "wave"        — CA semantics (paper §IV.A.2): one scaling event picks
                        ONE pool and scales it homogeneously until the whole
                        pending demand fits (or the pool caps out). This is
                        the behavior that produces the paper's pathological
                        over-provisioning on asymmetric workloads.
      * "incremental" — re-pick the pool after every single node added (a
                        much stronger baseline than real CA; reported
                        separately in benchmarks).
    """
    K, _, c = catalog.matrices()
    n = catalog.n
    rng = np.random.default_rng(seed)
    counts = np.zeros(n, np.float64)
    for pool in pools:
        counts[pool.instance_idx] += pool.count

    # Aggregate caps per instance type: several pools may share a type (e.g.
    # per-zone pools of one machine family) and their counts/min_counts are
    # already summed, so the headroom must be the SUM of max_counts too.
    pool_caps: dict = {}
    for p in pools:
        pool_caps[p.instance_idx] = pool_caps.get(p.instance_idx, 0) + p.max_count
    it = 0
    while it < max_iters:
        it += 1
        deficit = demand - _provided(K, counts)
        if np.all(deficit <= 1e-9):
            break
        r_star = int(np.argmax(deficit / np.maximum(demand, 1e-9)))
        # candidate pools that provide r_star and have headroom
        cands = []
        for p in pools:
            j = p.instance_idx
            if K[r_star, j] <= 0 or counts[j] + 1 > pool_caps[j]:
                continue
            cands.append(j)
        if not cands:
            break  # nothing scalable — demand unsatisfiable in this pool set
        if expander == "random":
            best_j = int(rng.choice(cands))
        elif expander == "first-fit":
            best_j = cands[0]
        elif expander == "least-waste":
            best_j, best_waste = None, np.inf
            for j in cands:
                add = K[:, j]
                used = np.minimum(add, np.maximum(deficit, 0.0))
                waste = 1.0 - (used.sum() / max(add.sum(), 1e-9))
                if waste < best_waste - 1e-12:
                    best_waste, best_j = waste, j
        else:
            raise ValueError(f"unknown expander {expander!r}")
        if mode == "wave":
            # homogeneous scale-up of the chosen pool until the full pending
            # demand fits in it (or it caps out)
            while counts[best_j] + 1 <= pool_caps[best_j]:
                counts[best_j] += 1
                if np.all(demand - _provided(K, counts) <= 1e-9):
                    break
        else:
            counts[best_j] += 1

    if scale_down != "none":
        order = np.argsort(-c)
        changed = True
        while changed:
            changed = False
            for j in order:
                floor_j = sum(p.min_count for p in pools if p.instance_idx == j)
                while counts[j] > floor_j:
                    trial = counts.copy()
                    trial[j] -= 1
                    if not np.all(_provided(K, trial) >= demand - 1e-9):
                        break
                    if scale_down == "utilization":
                        # CA removes only under-utilized nodes: the node's
                        # contribution must be <50% needed given the rest.
                        surplus = _provided(K, counts) - demand
                        node_used = np.minimum(K[:, j], np.maximum(K[:, j] - surplus, 0.0))
                        util = node_used.sum() / max(K[:, j].sum(), 1e-9)
                        if util >= 0.5:
                            break
                    counts = trial
                    changed = True

    satisfied = bool(np.all(_provided(K, counts) >= demand - 1e-9))
    return CAResult(counts=counts, cost=float(c @ counts), iterations=it,
                    satisfied=satisfied)


def simulate_cluster_autoscaler_batch(
    catalog: Catalog,
    pools: Sequence,
    demands: np.ndarray,
    max_iters: int = 100_000,
    expander: str = "random",
    scale_down: str = "utilization",
    mode: str = "wave",
    seed: int = 0,
) -> List[CAResult]:
    """Vectorized CA: step B tenants' simulations in lockstep over one shared
    catalog, returning exactly what B :func:`simulate_cluster_autoscaler`
    calls would (the sequential simulator stays the test oracle —
    tests/core/test_autoscaler.py sweeps both and asserts equal counts).

    ``pools`` is either one pool list shared by every tenant or a sequence of
    B per-tenant pool lists; ``demands`` is (B, m). Each tenant draws from
    its own ``default_rng(seed)`` stream in the same order as its sequential
    run, so ``expander="random"`` matches too.

    The heavy inner work — deficit evaluation during scale-up and the
    feasibility/utilization checks during scale-down — runs as ONE numpy
    matmul over all still-active tenants per lockstep iteration, instead of a
    Python loop of per-tenant matvecs. Tenants that finish (satisfied, capped
    out, or converged scale-down) drop out of the active set; finished-tenant
    rows are never recomputed. Wave-mode scale-up uses a closed-form unit
    count verified against the sequential one-node-at-a-time predicate, so
    pathological cap-out waves cost O(1) matvecs instead of O(max_count)."""
    K, _, c = catalog.matrices()
    n = catalog.n
    demands = np.asarray(demands, np.float64)
    assert demands.ndim == 2, "demands must be (B, m)"
    B = demands.shape[0]
    if B > 0 and (len(pools) == 0 or isinstance(pools[0], NodePool)):
        pools = [pools] * B
    assert len(pools) == B, (len(pools), B)

    counts = np.zeros((B, n), np.float64)
    caps = np.zeros((B, n), np.float64)
    floors = np.zeros((B, n), np.float64)
    pool_js: List[List[int]] = []
    for b, ps in enumerate(pools):
        for p in ps:
            counts[b, p.instance_idx] += p.count
            caps[b, p.instance_idx] += p.max_count   # aggregated, as sequential
            floors[b, p.instance_idx] += p.min_count
        pool_js.append([int(p.instance_idx) for p in ps])
    rngs = [np.random.default_rng(seed) for _ in range(B)]

    def _fits(b: int, j: int, u: float) -> bool:
        """The sequential wave predicate, fresh matvec included."""
        trial = counts[b].copy()
        trial[j] += u
        return bool(np.all(demands[b] - _provided(K, trial) <= 1e-9))

    # ---- scale-up: lockstep over tenants still scaling ----------------------
    it = np.zeros(B, np.int64)
    done = np.zeros(B, bool)
    while True:
        act = np.nonzero(~done & (it < max_iters))[0]
        if act.size == 0:
            break
        it[act] += 1
        deficit = demands[act] - counts[act] @ K.T               # (A, m)
        sat = np.all(deficit <= 1e-9, axis=1)
        done[act[sat]] = True
        r_star = np.argmax(deficit / np.maximum(demands[act], 1e-9), axis=1)
        for a, b in enumerate(act):
            if sat[a]:
                continue
            r = int(r_star[a])
            cands = [j for j in pool_js[b]
                     if K[r, j] > 0 and counts[b, j] + 1 <= caps[b, j]]
            if not cands:
                done[b] = True       # nothing scalable — unsatisfiable
                continue
            if expander == "random":
                best_j = int(rngs[b].choice(cands))
            elif expander == "first-fit":
                best_j = cands[0]
            elif expander == "least-waste":
                best_j, best_waste = None, np.inf
                for j in cands:
                    add = K[:, j]
                    used = np.minimum(add, np.maximum(deficit[a], 0.0))
                    waste = 1.0 - (used.sum() / max(add.sum(), 1e-9))
                    if waste < best_waste - 1e-12:
                        best_waste, best_j = waste, j
            else:
                raise ValueError(f"unknown expander {expander!r}")
            if mode == "wave":
                # closed-form unit count for "add nodes until the pending
                # demand fits or the pool caps out", then verify/adjust with
                # the sequential predicate (guards the 1e-9 boundary ulps)
                head = int(caps[b, best_j] - counts[b, best_j])
                kj = K[:, best_j]
                if np.any((kj <= 0) & (deficit[a] > 1e-9)):
                    u = head                     # never fits: cap out
                else:
                    need = (deficit[a] - 1e-9) / np.where(kj > 0, kj, np.inf)
                    u = int(min(max(np.ceil(need.max()), 1.0), head))
                while u < head and not _fits(b, best_j, u):
                    u += 1
                while u > 1 and _fits(b, best_j, u - 1):
                    u -= 1
                counts[b, best_j] += u
            else:
                counts[b, best_j] += 1

    # ---- scale-down: lockstep sweeps until no tenant changes ----------------
    if scale_down != "none":
        order = np.argsort(-c)
        while True:
            changed = np.zeros(B, bool)
            for j in order:
                # only tenants actually holding removable nodes of type j
                # (as sequential's `counts[j] > floor_j` gate, hoisted so
                # unheld types cost no matmul at all)
                live = np.nonzero(counts[:, j] > floors[:, j])[0]
                if not live.size:
                    continue
                kj = K[:, j]
                kj_sum = max(kj.sum(), 1e-9)
                while live.size:
                    sub = counts[live]
                    provided = sub @ K.T
                    trial = sub.copy()
                    trial[:, j] -= 1.0
                    ok = ((sub[:, j] > floors[live, j])
                          & np.all(trial @ K.T >= demands[live] - 1e-9, axis=1))
                    if scale_down == "utilization":
                        surplus = provided - demands[live]
                        node_used = np.minimum(
                            kj[None, :], np.maximum(kj[None, :] - surplus, 0.0))
                        ok &= node_used.sum(axis=1) / kj_sum < 0.5
                    live = live[ok]
                    counts[live, j] -= 1.0
                    changed[live] = True
            if not changed.any():
                break

    provided = counts @ K.T
    satisfied = np.all(provided >= demands - 1e-9, axis=1)
    costs = counts @ c
    return [CAResult(counts=counts[b].copy(), cost=float(costs[b]),
                     iterations=int(it[b]), satisfied=bool(satisfied[b]))
            for b in range(B)]


def default_pools_for(catalog: Catalog, idxs: Sequence[int],
                      existing: Optional[dict] = None,
                      max_count: int = 10_000) -> List[NodePool]:
    """Wrap catalog indices as NodePools, seeding counts from an
    ``existing`` {index: count} deployment (replay carries these forward)."""
    existing = existing or {}
    return [NodePool(instance_idx=int(j), count=int(existing.get(int(j), 0)),
                     max_count=max_count) for j in idxs]

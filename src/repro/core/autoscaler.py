"""Kubernetes Cluster Autoscaler baseline simulator (paper §IV.A.2).

Reproduces the CA constraints the paper compares against:
  * scaling restricted to predefined node pools,
  * no dynamic instance-type selection outside pools,
  * homogeneous scaling within each pool,
  * scale-up driven by unschedulable demand, scale-down of underutilized
    nodes where removal keeps demand satisfied.

Pure numpy — the baseline does not need (and the paper's does not have)
accelerated math.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from .catalog import Catalog, M


@dataclass
class NodePool:
    """One CA node pool: a single instance type with count bounds — the
    unit of homogeneous scaling the paper's baseline is restricted to."""

    instance_idx: int            # index into the catalog
    count: int = 0               # current nodes
    min_count: int = 0
    max_count: int = 10_000


@dataclass
class CAResult:
    """Cluster-Autoscaler simulation outcome for one demand snapshot."""

    counts: np.ndarray           # (n,) integer allocation over catalog types
    cost: float
    iterations: int
    satisfied: bool


def _provided(K: np.ndarray, counts: np.ndarray) -> np.ndarray:
    return K @ counts


def simulate_cluster_autoscaler(
    catalog: Catalog,
    pools: Sequence[NodePool],
    demand: np.ndarray,
    max_iters: int = 100_000,
    expander: str = "random",
    scale_down: str = "utilization",
    mode: str = "wave",
    seed: int = 0,
) -> CAResult:
    """Greedy CA loop: while some resource is unschedulable, scale up a pool
    that can schedule the bottleneck resource, then run the scale-down pass.

    ``expander`` mirrors the real Cluster Autoscaler's ``--expander`` flag:
      * "random"      — CA's DEFAULT: any pool that can schedule the pending
                        demand, chosen uniformly (paper-comparable baseline).
      * "least-waste" — CA's optional smarter expander (a strong baseline;
                        reported separately in benchmarks).
      * "first-fit"   — priority expander: first pool in listed order.

    ``scale_down``:
      * "utilization" — CA semantics: remove a node only if it is below the
                        50% utilization threshold w.r.t. residual demand and
                        removal keeps everything schedulable.
      * "greedy"      — remove most-expensive nodes while feasible (stronger
                        than real CA).
      * "none"

    ``mode``:
      * "wave"        — CA semantics (paper §IV.A.2): one scaling event picks
                        ONE pool and scales it homogeneously until the whole
                        pending demand fits (or the pool caps out). This is
                        the behavior that produces the paper's pathological
                        over-provisioning on asymmetric workloads.
      * "incremental" — re-pick the pool after every single node added (a
                        much stronger baseline than real CA; reported
                        separately in benchmarks).
    """
    K, _, c = catalog.matrices()
    n = catalog.n
    rng = np.random.default_rng(seed)
    counts = np.zeros(n, np.float64)
    for pool in pools:
        counts[pool.instance_idx] += pool.count

    pool_caps = {p.instance_idx: p.max_count for p in pools}
    it = 0
    while it < max_iters:
        it += 1
        deficit = demand - _provided(K, counts)
        if np.all(deficit <= 1e-9):
            break
        r_star = int(np.argmax(deficit / np.maximum(demand, 1e-9)))
        # candidate pools that provide r_star and have headroom
        cands = []
        for p in pools:
            j = p.instance_idx
            if K[r_star, j] <= 0 or counts[j] + 1 > pool_caps[j]:
                continue
            cands.append(j)
        if not cands:
            break  # nothing scalable — demand unsatisfiable in this pool set
        if expander == "random":
            best_j = int(rng.choice(cands))
        elif expander == "first-fit":
            best_j = cands[0]
        elif expander == "least-waste":
            best_j, best_waste = None, np.inf
            for j in cands:
                add = K[:, j]
                used = np.minimum(add, np.maximum(deficit, 0.0))
                waste = 1.0 - (used.sum() / max(add.sum(), 1e-9))
                if waste < best_waste - 1e-12:
                    best_waste, best_j = waste, j
        else:
            raise ValueError(f"unknown expander {expander!r}")
        if mode == "wave":
            # homogeneous scale-up of the chosen pool until the full pending
            # demand fits in it (or it caps out)
            while counts[best_j] + 1 <= pool_caps[best_j]:
                counts[best_j] += 1
                if np.all(demand - _provided(K, counts) <= 1e-9):
                    break
        else:
            counts[best_j] += 1

    if scale_down != "none":
        order = np.argsort(-c)
        changed = True
        while changed:
            changed = False
            for j in order:
                floor_j = sum(p.min_count for p in pools if p.instance_idx == j)
                while counts[j] > floor_j:
                    trial = counts.copy()
                    trial[j] -= 1
                    if not np.all(_provided(K, trial) >= demand - 1e-9):
                        break
                    if scale_down == "utilization":
                        # CA removes only under-utilized nodes: the node's
                        # contribution must be <50% needed given the rest.
                        surplus = _provided(K, counts) - demand
                        node_used = np.minimum(K[:, j], np.maximum(K[:, j] - surplus, 0.0))
                        util = node_used.sum() / max(K[:, j].sum(), 1e-9)
                        if util >= 0.5:
                            break
                    counts = trial
                    changed = True

    satisfied = bool(np.all(_provided(K, counts) >= demand - 1e-9))
    return CAResult(counts=counts, cost=float(c @ counts), iterations=it,
                    satisfied=satisfied)


def default_pools_for(catalog: Catalog, idxs: Sequence[int],
                      existing: Optional[dict] = None,
                      max_count: int = 10_000) -> List[NodePool]:
    """Wrap catalog indices as NodePools, seeding counts from an
    ``existing`` {index: count} deployment (replay carries these forward)."""
    existing = existing or {}
    return [NodePool(instance_idx=int(j), count=int(existing.get(int(j), 0)),
                     max_count=max_count) for j in idxs]

"""Paper §VII future-work features, implemented (beyond-paper deliverables):

* §VII.A High availability: minimum-replica constraints (x_i >= k for chosen
  types), availability-zone spread (zone-replicated catalog + per-zone
  minimums), anti-affinity (mutually-exclusive type groups, enforced after
  rounding since it is combinatorial).
* §VII.B Reserved/spot pricing: a two-tier catalog transform — each type
  gains a "reserved" twin at a discount whose count is capped by the
  committed amount, and a "spot" twin at a deep discount with an
  interruption-risk surcharge folded into the effective price
  (risk-adjusted certainty-equivalent cost, the convexity-preserving
  stand-in for Chaisiri-style stochastic programming).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .catalog import Catalog, InstanceType
from .problem import AllocationProblem


# ---------------------------------------------------------------------------
# §VII.A — High availability
# ---------------------------------------------------------------------------

@dataclass
class HAPolicy:
    """High-availability add-ons (paper §VII.A): per-type minimum replicas,
    zone spread, and anti-affinity groups."""

    min_replicas: Dict[int, int]          # instance idx -> minimum count
    zones: int = 1                        # AZ spread factor
    anti_affinity: Sequence[Sequence[int]] = ()   # groups; use at most 1 of each


def zone_replicated_catalog(catalog: Catalog, zones: int) -> Catalog:
    """Replicate every instance type per availability zone (zone-suffixed
    names, identical specs). Spread constraints become per-zone minimums on
    the replicated types."""
    out: List[InstanceType] = []
    for z in range(zones):
        for it in catalog.instances:
            out.append(dataclasses.replace(it, name=f"{it.name}@z{z}"))
    return Catalog(out)


def apply_ha(prob: AllocationProblem, policy: HAPolicy,
             n_base: Optional[int] = None) -> AllocationProblem:
    """Lower-bound constraints for HA minimums; with ``zones`` > 1 the
    problem is assumed built on a zone-replicated catalog (n = zones *
    n_base) and each zone receives ceil(min/zones) replicas."""
    lb = np.asarray(prob.lb).copy()
    if policy.zones > 1:
        assert n_base is not None and prob.n == policy.zones * n_base
        per_zone = {j: int(np.ceil(k / policy.zones))
                    for j, k in policy.min_replicas.items()}
        for z in range(policy.zones):
            for j, k in per_zone.items():
                lb[z * n_base + j] = max(lb[z * n_base + j], k)
    else:
        for j, k in policy.min_replicas.items():
            lb[j] = max(lb[j], k)
    return prob._replace(lb=jnp.asarray(lb, jnp.float32))


def enforce_anti_affinity(x: np.ndarray, prob: AllocationProblem,
                          policy: HAPolicy) -> np.ndarray:
    """Post-rounding repair: within each anti-affinity group keep only the
    most cost-effective member, re-cover any deficit greedily (paper III.B
    scoring). Combinatorial constraints stay out of the convex core."""
    from .rounding import greedy_round
    x = np.asarray(x, np.float64).copy()
    c = np.asarray(prob.c)
    for group in policy.anti_affinity:
        active = [j for j in group if x[j] > 0.5]
        if len(active) <= 1:
            continue
        keep = min(active, key=lambda j: c[j] / max(
            float(np.asarray(prob.K)[:, j].sum()), 1e-9))
        for j in active:
            if j != keep:
                x[j] = np.asarray(prob.lb)[j]
    return np.asarray(greedy_round(prob, jnp.asarray(x, jnp.float32)))


# ---------------------------------------------------------------------------
# §VII.B — Reserved / spot pricing tiers
# ---------------------------------------------------------------------------

@dataclass
class PricingTiers:
    """Reserved/spot pricing knobs (paper §VII.B): discounts, the reserved
    capacity cap, and the spot interruption cost model."""

    reserved_discount: float = 0.4        # 40% off on committed capacity
    reserved_cap_fraction: float = 0.6    # at most this share may be reserved
    spot_discount: float = 0.7            # 70% off spot
    spot_interruption_rate: float = 0.05  # hourly interruption probability
    interruption_penalty_hours: float = 2.0   # lost work per interruption


def tiered_catalog(catalog: Catalog, tiers: PricingTiers
                   ) -> Tuple[Catalog, np.ndarray, np.ndarray]:
    """Returns (catalog with on-demand + reserved + spot twins,
    reserved_idx mask, spot_idx mask). Spot's effective price folds the
    interruption risk in as a certainty-equivalent surcharge:
        p_spot_eff = p_spot * (1 + rate * penalty_hours)
    keeping the objective linear (convexity preserved)."""
    out: List[InstanceType] = list(catalog.instances)
    n = len(out)
    reserved, spot = [], []
    for j, it in enumerate(catalog.instances):
        reserved.append(len(out))
        out.append(dataclasses.replace(
            it, name=it.name + "#res",
            hourly_price=round(it.hourly_price * (1 - tiers.reserved_discount), 6)))
    for j, it in enumerate(catalog.instances):
        spot.append(len(out))
        eff = (it.hourly_price * (1 - tiers.spot_discount)
               * (1 + tiers.spot_interruption_rate
                  * tiers.interruption_penalty_hours))
        out.append(dataclasses.replace(
            it, name=it.name + "#spot", hourly_price=round(eff, 6)))
    res_mask = np.zeros(len(out), bool)
    res_mask[np.asarray(reserved)] = True
    spot_mask = np.zeros(len(out), bool)
    spot_mask[np.asarray(spot)] = True
    return Catalog(out), res_mask, spot_mask


def cap_reserved(prob: AllocationProblem, res_mask: np.ndarray,
                 demand_cover_counts: np.ndarray,
                 tiers: PricingTiers) -> AllocationProblem:
    """Upper-bound reserved twins by the committed share of a reference
    cover (reservations are long-term commitments; the cap models the
    planner's commitment budget)."""
    ub = np.asarray(prob.ub).copy()
    cap = np.ceil(tiers.reserved_cap_fraction
                  * np.maximum(demand_cover_counts, 0.0))
    base_n = res_mask.sum()
    # reserved twins occupy [n_base, 2 n_base)
    ub[res_mask] = np.minimum(ub[res_mask], np.maximum(cap[:base_n], 0.0))
    return prob._replace(ub=jnp.asarray(ub, jnp.float32))

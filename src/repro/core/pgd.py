"""Shared projected-gradient engine: Barzilai-Borwein step + Armijo ladder.

Every inner solve loop in this codebase is the same algorithm — propose a
Barzilai-Borwein (BB1) step, safeguard it with an Armijo backtracking ladder
evaluated as one batch (vmap-friendly: no data-dependent trip counts inside
an iteration), accept the largest decreasing candidate, stop when the
projected move is tiny. This module is that loop, extracted once and
parameterized by ``(value_fn, grad_fn, project_fn, config)`` so the three
consumers share a single implementation:

* ``core.solver._pgd``            — the barrier/penalty relaxation solver
  (merit = eq.(1) objective + log-barrier or quadratic penalty).
* ``core.incremental.solve_incremental`` — the controller's warm tick
  (merit = eq.(1) objective; projection = box ∩ L1 churn ball), which the
  batched fleet engine ``solve_fleet_step`` vmaps across tenant lanes.
* ``repro.horizon.solver``        — the time-expanded MPC program (merit =
  per-tick objectives + churn coupling + soft churn bound + planned-tick
  band penalty; projection = exact ``project_incremental`` chaining on the
  committed tick, box on planned rows).

The engine is jit- and vmap-safe: the iterate may have ANY shape (``(n,)``
for a single tick, ``(H, n)`` for a plan), all inner products flatten over
every axis, and the loop is a ``lax.while_loop`` whose batching rule freezes
finished lanes in place — so a vmapped call's per-lane trajectory is
identical to a sequential call on the same data (the property every
batched ≡ sequential equivalence test in this repo leans on).
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PGDConfig(NamedTuple):
    """Hashable knobs of the shared BB/Armijo engine (static under jit).

    ``max_iters`` bounds the iteration count; the loop stops earlier when an
    accepted step moves no coordinate by more than ``tol`` (or when the
    ladder collapses without finding a decreasing candidate). ``step0`` is
    both the initial BB step and the reset value when the BB denominator
    degenerates; the ladder evaluates ``n_backtracks`` candidates at ratios
    ``backtrack ** (-1 .. n_backtracks-2)`` of the proposed step (one
    upscale, like ``core.solver``); ``armijo_c`` is the sufficient-decrease
    slope on the PROJECTED step."""

    max_iters: int = 600           # iteration budget (early-stops on tol)
    step0: float = 1.0             # initial / fallback BB step
    n_backtracks: int = 12         # Armijo ladder length
    backtrack: float = 0.5         # ladder ratio
    armijo_c: float = 1e-4         # sufficient-decrease constant
    tol: float = 1e-6              # stop when the accepted move is tiny
    ftol: float = 1e-4             # an accepted step whose RELATIVE merit
                                   # progress falls below this counts as
                                   # "flat" ...
    max_flat: int = 10             # ... and max_flat CONSECUTIVE flat steps
                                   # stop the loop (progress has stalled at
                                   # ~ftol/iter; one flat step alone is NOT
                                   # convergence — BB progress comes in
                                   # bursts separated by plateaus). The
                                   # default trades the merit's last ~0.1%
                                   # for a fraction of the iterations; pass
                                   # ftol=0.0 to only stop on true cycling
                                   # (the high-accuracy barrier-solver mode)


def _flat_dot(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """<a, b> over every axis (iterates may be (n,) or (H, n)).

    Elementwise multiply + reduce rather than ``jnp.vdot``: a vmapped dot
    lowers to a batched ``dot_general`` whose accumulation order differs
    from the unbatched kernel's in the last ulps, and the adaptive line
    search amplifies ulps into different accept/reject decisions — which
    would break the bit-exact batched ≡ sequential trajectory equivalence
    the fleet engines promise. A plain reduce batches order-preservingly."""
    return jnp.sum(a * b)


class PGDTrace(NamedTuple):
    """Per-iteration convergence capture of one :func:`pgd_minimize_traced`
    run — FIXED-SIZE ``(cfg.max_iters,)`` arrays (static shape), so the
    traced engine stays jit- and vmap-safe: a vmapped traced solve returns
    ``(B, max_iters)`` leaves, one full trace per lane. Rows at indices
    ``>= iters`` were never written: ``merit``/``step``/``move`` hold NaN,
    ``accepted`` False and ``rung`` -1 there (the validity sentinel —
    consumers slice ``[:iters]``).

    Fields, one row per iteration actually taken:

    * ``merit``    — merit value AFTER the iteration (the accepted
      candidate's value; unchanged from the previous iterate on a rejected
      ladder). ``merit[iters-1]`` equals the ``fx`` the engine returns.
    * ``step``     — the BB base step proposed at iteration start (the
      ladder evaluates ``step * backtrack**(-1..n_backtracks-2)``).
    * ``accepted`` — whether any ladder rung satisfied Armijo decrease.
    * ``rung``     — index of the accepted ladder rung (0 = the upscaled
      candidate, larger = more backtracking; -1 when the whole ladder was
      rejected).
    * ``move``     — max-abs coordinate move of the step (0 on rejection).
    """

    merit: jnp.ndarray      # (L,) float32 merit after each iteration
    step: jnp.ndarray       # (L,) float32 proposed BB base step
    accepted: jnp.ndarray   # (L,) bool   Armijo ladder found a candidate
    rung: jnp.ndarray       # (L,) int32  accepted ladder index (-1: none)
    move: jnp.ndarray       # (L,) float32 max|dx| of the accepted step


def _empty_trace(L: int) -> PGDTrace:
    return PGDTrace(merit=jnp.full((L,), jnp.nan, jnp.float32),
                    step=jnp.full((L,), jnp.nan, jnp.float32),
                    accepted=jnp.zeros((L,), bool),
                    rung=jnp.full((L,), -1, jnp.int32),
                    move=jnp.full((L,), jnp.nan, jnp.float32))


def _pgd_iteration(value_fn, grad_fn, project_fn, cfg, ratios,
                   x, fx, g, bb, it, flat):
    """One BB/Armijo iteration — the exact op sequence of the monolithic
    loop body, shared by :func:`_pgd_minimize_impl` and the chunked anytime
    loop so a chunked trajectory is bit-identical to the monolithic one.

    Returns ``(x_new, f_new, g_new, bb_new, it_new, flat_new, done,
    any_ok, idx, move)`` — the first seven are the loop-carried solver
    state, the last three feed the optional trace row."""
    steps = bb * ratios
    cands = jax.vmap(
        lambda s: project_fn(x - s * g))(steps)            # (L, *x.shape)
    fcands = jax.vmap(value_fn)(cands)                     # (L,)
    # Armijo on the projected step: F(x+) <= F(x) + c * <g, x+ - x>
    diff = cands - x[None]
    dec = fcands - (fx + cfg.armijo_c *
                    jnp.sum(diff * g[None],
                            axis=tuple(range(1, diff.ndim))))
    ok = (dec <= 0.0) & jnp.isfinite(fcands)
    idx = jnp.argmax(ok)          # first (largest) accepting step
    any_ok = jnp.any(ok)
    x_new = jnp.where(any_ok, cands[idx], x)
    f_new = jnp.where(any_ok, fcands[idx], fx)
    g_new = grad_fn(x_new)
    # BB1 step from the accepted move (safeguarded into [1e-8, 1e4])
    dx = x_new - x
    dg = g_new - g
    denom = _flat_dot(dx, dg)
    bb_new = jnp.where(jnp.abs(denom) > 1e-12,
                       jnp.abs(_flat_dot(dx, dx) / denom), cfg.step0)
    bb_new = jnp.clip(bb_new, 1e-8, 1e4)
    bb_new = jnp.where(any_ok, bb_new,
                       bb * cfg.backtrack ** cfg.n_backtracks)
    move = jnp.max(jnp.abs(dx))
    # converged when an ACCEPTED step barely moves, or when max_flat
    # CONSECUTIVE accepted steps barely improved the merit (boundary
    # cycling: the alternating projection keeps the iterate drifting
    # along a flat ridge). One flat step alone never stops the loop —
    # BB progress comes in bursts separated by plateaus.
    is_flat = any_ok & (f_new >= fx - cfg.ftol * (1.0 + jnp.abs(fx)))
    flat_new = jnp.where(is_flat, flat + 1, jnp.where(any_ok, 0, flat))
    done = ((~any_ok) & (bb < 1e-7)) | (any_ok & (move < cfg.tol)) \
        | (flat_new >= cfg.max_flat)
    return x_new, f_new, g_new, bb_new, it + 1, flat_new, done, \
        any_ok, idx, move


def _pgd_minimize_impl(
    value_fn: Callable[[jnp.ndarray], jnp.ndarray],
    grad_fn: Callable[[jnp.ndarray], jnp.ndarray],
    project_fn: Callable[[jnp.ndarray], jnp.ndarray],
    x0: jnp.ndarray,
    cfg: PGDConfig,
    trace: bool,
):
    """The one BB/Armijo loop, with optional per-iteration trace capture.

    ``trace`` is a PYTHON-level flag resolved at trace time: with
    ``trace=False`` the loop-carried state (hence the compiled program) is
    exactly the pre-trace engine's — the bit-exactness guarantees of every
    batched ≡ sequential test are untouched. With ``trace=True`` the state
    additionally carries a :class:`PGDTrace` written at index ``it`` each
    iteration; the iterate computation itself is THE SAME ops either way,
    so the traced run's ``(x, fx, iters)`` matches the untraced run's."""
    ratios = cfg.backtrack ** jnp.arange(-1, cfg.n_backtracks - 1)  # 1 upscale

    def cond(state):
        x, fx, g, bb, it, flat, done = state[:7]
        return (~done) & (it < cfg.max_iters)

    def body(state):
        x, fx, g, bb, it, flat = state[:6]
        (x_new, f_new, g_new, bb_new, it_new, flat_new, done,
         any_ok, idx, move) = _pgd_iteration(
            value_fn, grad_fn, project_fn, cfg, ratios,
            x, fx, g, bb, it, flat)
        out = (x_new, f_new, g_new, bb_new, it_new, flat_new, done)
        if trace:
            tr: PGDTrace = state[7]
            tr = PGDTrace(
                merit=tr.merit.at[it].set(f_new.astype(jnp.float32)),
                step=tr.step.at[it].set(bb.astype(jnp.float32)),
                accepted=tr.accepted.at[it].set(any_ok),
                rung=tr.rung.at[it].set(
                    jnp.where(any_ok, idx, -1).astype(jnp.int32)),
                move=tr.move.at[it].set(
                    jnp.where(any_ok, move, 0.0).astype(jnp.float32)))
            return out + (tr,)
        return out

    x0 = project_fn(x0)
    state = (x0, value_fn(x0), grad_fn(x0), jnp.asarray(cfg.step0),
             jnp.asarray(0), jnp.asarray(0), jnp.asarray(False))
    if trace:
        state = state + (_empty_trace(cfg.max_iters),)
    final = jax.lax.while_loop(cond, body, state)
    x, fx, it = final[0], final[1], final[4]
    return x, fx, it, (final[7] if trace else None)


def pgd_minimize(
    value_fn: Callable[[jnp.ndarray], jnp.ndarray],
    grad_fn: Callable[[jnp.ndarray], jnp.ndarray],
    project_fn: Callable[[jnp.ndarray], jnp.ndarray],
    x0: jnp.ndarray,
    cfg: PGDConfig = PGDConfig(),
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Minimize ``value_fn`` over the set ``project_fn`` projects onto.

    Per iteration: propose ``bb * ratios`` candidate steps, project each
    (``x - s * g``), evaluate all candidate VALUES as one vmapped batch,
    accept the first (largest) candidate satisfying Armijo sufficient
    decrease on the projected step, then refresh the BB1 step from the
    accepted move. No candidate accepted -> shrink the proposal and retry;
    converged (move < tol) or ladder exhausted -> stop.

    Returns ``(x, value, iters)`` where ``iters`` is the number of
    iterations actually taken (the early-stopping wins the benchmarks
    report). The iterate shape is whatever ``x0`` has; ``value_fn`` must map
    it to a scalar and ``grad_fn``/``project_fn`` to its own shape. Use
    :func:`pgd_minimize_traced` to also capture the per-iteration
    convergence trace."""
    x, fx, it, _ = _pgd_minimize_impl(value_fn, grad_fn, project_fn, x0, cfg,
                                      trace=False)
    return x, fx, it


def pgd_minimize_traced(
    value_fn: Callable[[jnp.ndarray], jnp.ndarray],
    grad_fn: Callable[[jnp.ndarray], jnp.ndarray],
    project_fn: Callable[[jnp.ndarray], jnp.ndarray],
    x0: jnp.ndarray,
    cfg: PGDConfig = PGDConfig(),
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, PGDTrace]:
    """:func:`pgd_minimize` with per-iteration convergence capture.

    Returns ``(x, value, iters, trace)`` where ``trace`` is a
    :class:`PGDTrace` of fixed-size ``(cfg.max_iters,)`` arrays — the
    fixed size keeps the capture jit/vmap-safe (vmapping this function
    yields ``(B, max_iters)`` per-lane traces). The iterate math is the
    SAME op sequence as the untraced engine (the trace arrays are extra
    loop state, not extra math), so ``(x, value, iters)`` match a plain
    ``pgd_minimize`` call; ``trace.merit[iters-1] == value`` whenever at
    least one iteration ran. See ``repro.obs.solver_trace`` for analysis
    helpers (validity slicing, per-lane extraction, summaries)."""
    x, fx, it, tr = _pgd_minimize_impl(value_fn, grad_fn, project_fn, x0, cfg,
                                       trace=True)
    return x, fx, it, tr


class AnytimeConfig(NamedTuple):
    """Host-side knobs of the chunked-budget *anytime* mode.

    The anytime driver runs the engine in ``chunk_iters``-iteration chunks
    (each chunk one jitted ``while_loop`` call with a TRACED iteration cap,
    so every chunk reuses one compiled program) and checks ``clock``
    between chunks: once ``deadline_ms`` wall milliseconds have elapsed it
    stops and the caller returns the best-so-far iterate *by merit*, not
    the last iterate. ``clock`` is injectable (seconds, monotonic;
    ``time.perf_counter`` by default) so tests and the degradation bench
    can drive deterministic fake time — it is only ever called host-side,
    never under jit.

    ``deadline_ms=None`` means "no budget": every consumer branches on it
    at PYTHON level and takes its pre-anytime untruncated path, so the
    compiled graph — and therefore the allocations, bit for bit — are
    exactly the non-anytime engine's (test-enforced)."""

    deadline_ms: Optional[float] = None   # wall budget; None = disabled
    chunk_iters: int = 32                 # iterations per clock check
    clock: Callable[[], float] = time.perf_counter   # injectable, host-only

    @property
    def enabled(self) -> bool:
        """Whether this config actually enforces a budget (``deadline_ms``
        is set). Consumers branch on this at Python level."""
        return self.deadline_ms is not None


class AnytimeReport(NamedTuple):
    """Host-side outcome of one :func:`run_anytime` drive.

    ``deadline_hit`` is True iff the clock expired while iterations
    remained (the returned iterate was truncated); a solve that converges
    or exhausts ``max_iters`` inside the budget reports False. ``chunks``
    counts chunk launches (0 when the budget was spent before the first
    chunk — the caller then holds the projected, feasible warm start)."""

    deadline_hit: bool
    elapsed_ms: float
    chunks: int


class PGDChunkState(NamedTuple):
    """Resumable loop-carried state of the chunked anytime engine.

    Fields 0–6 are EXACTLY the monolithic loop's state tuple (same dtypes,
    same update ops via :func:`_pgd_iteration`), plus the best-so-far pair
    ``(x_best, f_best)`` tracked across chunks. ``x_best`` is always a
    PROJECTED (feasible) point: it starts at the projected warm start and
    only ever moves to accepted (projected) iterates with strictly better
    merit. Works unbatched or vmapped (leaves gain a leading lane axis;
    ``done`` becomes a per-lane vector)."""

    x: jnp.ndarray        # current iterate
    fx: jnp.ndarray       # merit at x
    g: jnp.ndarray        # gradient at x
    bb: jnp.ndarray       # BB step
    it: jnp.ndarray       # iterations taken
    flat: jnp.ndarray     # consecutive flat-step counter
    done: jnp.ndarray     # converged / stalled flag
    x_best: jnp.ndarray   # best-merit iterate so far (feasible)
    f_best: jnp.ndarray   # merit at x_best


def pgd_chunk_init(
    value_fn: Callable[[jnp.ndarray], jnp.ndarray],
    grad_fn: Callable[[jnp.ndarray], jnp.ndarray],
    project_fn: Callable[[jnp.ndarray], jnp.ndarray],
    x0: jnp.ndarray,
    cfg: PGDConfig,
) -> PGDChunkState:
    """Build the iteration-0 :class:`PGDChunkState` (projects ``x0`` first,
    exactly like the monolithic loop — so the zero-budget answer is already
    feasible). Jit/vmap-safe; callers wrap it in their own jitted impl."""
    x0 = project_fn(x0)
    fx = value_fn(x0)
    return PGDChunkState(
        x=x0, fx=fx, g=grad_fn(x0), bb=jnp.asarray(cfg.step0),
        it=jnp.asarray(0), flat=jnp.asarray(0), done=jnp.asarray(False),
        x_best=x0, f_best=fx)


def pgd_chunk_run(
    value_fn: Callable[[jnp.ndarray], jnp.ndarray],
    grad_fn: Callable[[jnp.ndarray], jnp.ndarray],
    project_fn: Callable[[jnp.ndarray], jnp.ndarray],
    state: PGDChunkState,
    it_end: jnp.ndarray,
    cfg: PGDConfig,
) -> PGDChunkState:
    """Advance the chunked engine until ``it >= it_end`` (a TRACED scalar:
    one compiled program serves every chunk) or convergence. Each
    iteration is :func:`_pgd_iteration` — the monolithic loop's exact op
    sequence — plus the best-so-far merit tracking, so running chunks
    back-to-back reproduces the monolithic trajectory iterate for
    iterate."""
    ratios = cfg.backtrack ** jnp.arange(-1, cfg.n_backtracks - 1)  # 1 upscale
    it_cap = jnp.minimum(it_end, cfg.max_iters)

    def cond(s: PGDChunkState):
        return (~s.done) & (s.it < it_cap)

    def body(s: PGDChunkState):
        (x, fx, g, bb, it, flat, done, _any_ok, _idx, _move) = \
            _pgd_iteration(value_fn, grad_fn, project_fn, cfg, ratios,
                           s.x, s.fx, s.g, s.bb, s.it, s.flat)
        better = fx < s.f_best
        return PGDChunkState(
            x=x, fx=fx, g=g, bb=bb, it=it, flat=flat, done=done,
            x_best=jnp.where(better, x, s.x_best),
            f_best=jnp.where(better, fx, s.f_best))

    return jax.lax.while_loop(cond, body, state)


def run_anytime(init_fn, chunk_fn, cfg: PGDConfig,
                anytime: AnytimeConfig):
    """Drive a chunked solve against the wall clock; the generic host loop
    behind every anytime consumer (incremental, fleet, horizon).

    ``init_fn()`` returns the initial (possibly vmapped) state pytree with
    ``done``/``it``/``x_best``/``f_best`` leaves; ``chunk_fn(state,
    it_end)`` advances it to the traced iteration cap. Between chunks the
    driver syncs ``state.done`` to the host (which fences the previous
    chunk, so the clock reads true elapsed compute) and stops when all
    lanes converged, ``cfg.max_iters`` is reached, or ``deadline_ms``
    expires — whichever first. A non-positive ``deadline_ms`` returns the
    init state untouched: the projected warm start, always feasible.

    Returns ``(state, AnytimeReport)``."""
    if anytime.deadline_ms is None:
        raise ValueError("run_anytime requires AnytimeConfig.deadline_ms; "
                         "branch to the untruncated engine when it is None")
    clock = anytime.clock
    chunk = max(1, int(anytime.chunk_iters))
    deadline = float(anytime.deadline_ms)
    t0 = clock()
    state = init_fn()
    it_end = 0
    deadline_hit = False
    chunks = 0
    max_iters = int(cfg.max_iters)
    while it_end < max_iters and not bool(np.all(np.asarray(state.done))):
        if (clock() - t0) * 1e3 >= deadline:
            deadline_hit = True
            break
        it_end = min(it_end + chunk, max_iters)
        state = chunk_fn(state, jnp.asarray(it_end))
        chunks += 1
    elapsed_ms = (clock() - t0) * 1e3
    return state, AnytimeReport(deadline_hit=deadline_hit,
                                elapsed_ms=elapsed_ms, chunks=chunks)

"""repro.core — the paper's contribution: convex-optimization cloud resource
allocation (objective eq.1, KKT, solver, rounding, branch-and-bound,
multi-start, incremental adoption, CA baseline, controller)."""
from .problem import AllocationProblem, PenaltyParams
# NOTE: the bare function `objective` is NOT re-exported — it would shadow the
# `repro.core.objective` module attribute. Use `objective_value` or the module.
from .objective import objective as objective_value
from .objective import (objective_terms, grad_objective,
                        constraint_residuals, is_feasible)
from .pgd import (AnytimeConfig, AnytimeReport, PGDConfig, PGDTrace,
                  pgd_minimize, pgd_minimize_traced)
from .solver import SolverConfig, SolveResult, solve_relaxation
from .multistart import multistart_solve, make_starts
from .rounding import greedy_round, round_and_polish, scale_down
from .branch_bound import branch_and_bound, BnBResult
from .incremental import (project_l1_ball, project_incremental,
                          solve_incremental, solve_incremental_info)
from .kkt import kkt_report, KKTReport
from .terms import (BASE_TERMS, SCENARIO_TERMS, TERM_DEFS, PricedTerm,
                    TermDef, make_term, register_term, term_signature,
                    with_terms)
from .catalog import (Catalog, InstanceType, make_cloud_catalog,
                      make_tpu_catalog, spot_catalog, spot_risk_prices)
from .autoscaler import (NodePool, simulate_cluster_autoscaler,
                         simulate_cluster_autoscaler_batch, default_pools_for)
from .metrics import AllocationMetrics, evaluate, per_dim_utilization
from .scenarios import Scenario, build_scenarios, scaled_scenario
from .api import (optimize, problem_from_demand, problem_from_scenario,
                  OptimizeResult)
from .controller import InfrastructureOptimizationController, ControllerStep
from .pareto import grid_search, sensitivity, pareto_mask
from . import workloads

__all__ = [
    "AllocationProblem", "PenaltyParams", "objective_value", "objective_terms",
    "grad_objective", "constraint_residuals", "is_feasible", "PGDConfig",
    "AnytimeConfig", "AnytimeReport", "PGDTrace",
    "pgd_minimize", "pgd_minimize_traced", "SolverConfig",
    "SolveResult", "solve_relaxation", "multistart_solve", "make_starts",
    "greedy_round", "round_and_polish", "scale_down", "branch_and_bound",
    "BnBResult", "project_l1_ball", "project_incremental", "solve_incremental",
    "solve_incremental_info",
    "kkt_report", "KKTReport",
    "PricedTerm", "TermDef", "make_term", "register_term", "with_terms",
    "term_signature", "BASE_TERMS", "SCENARIO_TERMS", "TERM_DEFS",
    "Catalog", "InstanceType", "make_cloud_catalog",
    "make_tpu_catalog", "spot_catalog", "spot_risk_prices",
    "NodePool", "simulate_cluster_autoscaler",
    "simulate_cluster_autoscaler_batch", "default_pools_for", "AllocationMetrics", "evaluate", "per_dim_utilization",
    "Scenario", "build_scenarios", "scaled_scenario", "optimize",
    "problem_from_demand", "problem_from_scenario", "OptimizeResult",
    "InfrastructureOptimizationController", "ControllerStep", "grid_search",
    "sensitivity", "pareto_mask", "workloads",
]

"""Greedy rounding strategy — paper §III.B, implemented verbatim:

  1. x_hat = floor(x*)
  2. delta = d - K x_hat
  3. while delta has positive components:
       pick i maximizing  sum_{r: delta_r>0} K_ri * delta_r / c_i
       x_hat_i += 1; recompute delta

jit-able via ``lax.while_loop``; the iteration count is bounded by the number
of unit increments needed, capped at ``max_adds``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .problem import AllocationProblem


@partial(jax.jit, static_argnames=("max_adds",))
def greedy_round(prob: AllocationProblem, x_star: jnp.ndarray,
                 max_adds: int = 4096) -> jnp.ndarray:
    """Round a fractional solution to a feasible integer allocation."""
    x0 = jnp.floor(jnp.clip(x_star, prob.lb, prob.ub)) * prob.mask
    # deficits measured against the hard lower bound d - mu (primal feas.)
    target = prob.d - prob.mu

    def deficit(x):
        return target - prob.K @ x

    def cond(state):
        x, it = state
        return jnp.any(deficit(x) > 1e-6) & (it < max_adds)

    def body(state):
        x, it = state
        delta = deficit(x)
        pos = jnp.maximum(delta, 0.0)
        score = (prob.K.T @ pos) / jnp.maximum(prob.c, 1e-9)       # (n,)
        # never pick masked-out or at-upper-bound types
        ok = (prob.mask > 0) & (x < prob.ub)
        score = jnp.where(ok, score, -jnp.inf)
        i = jnp.argmax(score)
        return x.at[i].add(1.0), it + 1

    x, _ = jax.lax.while_loop(cond, body, (x0, jnp.asarray(0)))
    return x


def round_and_polish(prob: AllocationProblem, x_star: jnp.ndarray,
                     max_adds: int = 4096) -> jnp.ndarray:
    """Paper's greedy rounding plus two beyond-paper polish passes:
      * also try the ceil() candidate (keeps all fractional types instead of
        dropping them at floor()),
      * scale-down pass: drop units whose removal stays feasible,
        most-expensive first (mirrors CA's scale-down).
    Picks the feasible candidate with the lower objective."""
    import repro.core.objective as obj

    a = scale_down(prob, greedy_round(prob, x_star, max_adds=max_adds))
    ceil_start = jnp.ceil(jnp.clip(x_star, prob.lb, prob.ub)) * prob.mask
    # tiny fractions should not force a whole node: drop < 0.05 before ceil
    ceil_start = jnp.where(x_star - jnp.floor(x_star) < 0.05,
                           jnp.floor(x_star), ceil_start)
    b = scale_down(prob, greedy_round(prob, ceil_start, max_adds=max_adds))
    fa, fb = obj.objective(prob, a), obj.objective(prob, b)
    feas_a = obj.is_feasible(prob, a, 1e-3)
    feas_b = obj.is_feasible(prob, b, 1e-3)
    pick_a = jnp.where(feas_a == feas_b, fa <= fb, feas_a)
    return jnp.where(pick_a, a, b)


@partial(jax.jit, static_argnames=("max_removes",))
def scale_down(prob: AllocationProblem, x: jnp.ndarray,
               max_removes: int = 4096) -> jnp.ndarray:
    """Drop units whose removal keeps Kx >= d - mu, most-expensive first —
    the polish mirroring CA's utilization-gated scale-down."""
    target = prob.d - prob.mu

    def removable(x):
        """cost of each type whose decrement keeps K x >= target."""
        Kx = prob.K @ x
        slack_ok = jnp.all(Kx[:, None] - prob.K >= target[:, None] - 1e-6, axis=0)
        can = slack_ok & (x >= 1.0) & (x - 1.0 >= prob.lb)
        return jnp.where(can, prob.c, -jnp.inf)

    def cond(state):
        x, it = state
        return jnp.any(jnp.isfinite(removable(x)) & (removable(x) > 0)) & (it < max_removes)

    def body(state):
        x, it = state
        i = jnp.argmax(removable(x))
        return x.at[i].add(-1.0), it + 1

    x, _ = jax.lax.while_loop(cond, body, (x, jnp.asarray(0)))
    return x

"""KKT conditions (paper §II.C, eq. 8-11): residual computation and
multiplier recovery.

Given a primal candidate x, we recover (lambda, nu, omega) by non-negative
least squares on the stationarity equation restricted to the active sets,
then report the four KKT residual groups. The solver's output should drive
all four to ~0 on convex instances; tests assert this.

The stationarity gradient is ``core.objective.grad_objective`` — the
``repro.core.terms`` registry sum — so every attached scenario term's
gradient (SLO pricing, priority eviction, spot risk) enters the certificate
automatically; no term math is duplicated here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

import repro.core.objective as obj
from .problem import AllocationProblem


class KKTReport(NamedTuple):
    """KKT residual groups + recovered multipliers for a primal candidate."""

    stationarity: jnp.ndarray        # ||grad L||_inf after multiplier fit
    primal_lo: jnp.ndarray           # max violation of Kx >= d - mu
    primal_hi: jnp.ndarray           # max violation of Kx <= d + g
    primal_box: jnp.ndarray          # max violation of x >= lb (box)
    dual: jnp.ndarray                # max negative multiplier (>=0 by constr.)
    comp_slack: jnp.ndarray          # max |multiplier * slack|
    lam: jnp.ndarray                 # (m,)
    nu: jnp.ndarray                  # (m,)
    omega: jnp.ndarray               # (n,)


def _nnls_pgd(A: jnp.ndarray, b: jnp.ndarray, iters: int = 500) -> jnp.ndarray:
    """min ||A theta - b||^2 s.t. theta >= 0 via projected gradient."""
    AtA = A.T @ A
    Atb = A.T @ b
    L = jnp.linalg.norm(AtA, ord=2) + 1e-6

    def body(i, th):
        return jnp.maximum(th - (AtA @ th - Atb) / L, 0.0)

    return jax.lax.fori_loop(0, iters, body, jnp.zeros(A.shape[1]))


@jax.jit
def kkt_report(prob: AllocationProblem, x: jnp.ndarray,
               active_tol: float = 1e-2,
               barrier_t: jnp.ndarray | None = None) -> KKTReport:
    """Recover multipliers for a primal candidate ``x`` and report the four
    KKT residual groups (eq. 8-11) — the solver's optimality certificate."""
    # active_tol default 1e-2: interior-point solutions sit a barrier-width
    # (~ m / t_final) away from active constraints; 1e-2 covers t_final >= 1e2.
    #
    # If ``barrier_t`` is given, the classic interior-point dual estimates
    # lam_r = 1/(t*lo_r), nu_r = 1/(t*hi_r) are used instead of the NNLS fit —
    # exact at a barrier optimum of temperature t.
    m, n = prob.m, prob.n
    gf = obj.grad_objective(prob, x)
    lo, hi = obj.constraint_residuals(prob, x)

    act_lo = (lo <= active_tol).astype(jnp.float32)          # lambda support
    act_hi = (hi <= active_tol).astype(jnp.float32)          # nu support
    act_x = (x <= prob.lb + active_tol).astype(jnp.float32)  # omega support

    if barrier_t is not None:
        lam = 1.0 / (barrier_t * jnp.maximum(lo, 1e-9))
        nu = 1.0 / (barrier_t * jnp.maximum(hi, 1e-9))
        resid = gf - prob.K.T @ lam + prob.K.T @ nu
        omega = jnp.maximum(resid, 0.0) * act_x
    else:
        # stationarity: gf - K^T lam + K^T nu - omega = 0
        #   => [-K^T diag(act_lo) | K^T diag(act_hi) | -diag(act_x)] theta = -gf
        A = jnp.concatenate(
            [-prob.K.T * act_lo[None, :],
             prob.K.T * act_hi[None, :],
             -jnp.eye(n) * act_x[None, :]], axis=1)          # (n, 2m+n)
        theta = _nnls_pgd(A, -gf)
        lam, nu, omega = (theta[:m] * act_lo, theta[m:2 * m] * act_hi,
                          theta[2 * m:] * act_x)

    stat = jnp.max(jnp.abs(gf - prob.K.T @ lam + prob.K.T @ nu - omega))
    comp = jnp.maximum(jnp.max(jnp.abs(lam * lo)), jnp.max(jnp.abs(nu * hi)))
    comp = jnp.maximum(comp, jnp.max(jnp.abs(omega * (x - prob.lb))))
    return KKTReport(
        stationarity=stat,
        primal_lo=jnp.max(jnp.maximum(-lo, 0.0)),
        primal_hi=jnp.max(jnp.maximum(-hi, 0.0)),
        primal_box=jnp.max(jnp.maximum(prob.lb - x, 0.0)),
        dual=jnp.maximum(jnp.max(-lam), jnp.maximum(jnp.max(-nu), jnp.max(-omega))),
        comp_slack=comp,
        lam=lam, nu=nu, omega=omega,
    )

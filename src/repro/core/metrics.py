"""Evaluation metrics (paper §IV.B): cost, utilization, diversity,
fragmentation, over-provisioning."""
from __future__ import annotations

from dataclasses import dataclass, asdict

import numpy as np

from .catalog import Catalog


@dataclass
class AllocationMetrics:
    """Snapshot evaluation of one allocation against one demand vector, in
    raw catalog units (the paper's §IV.B comparison columns)."""

    total_cost: float            # $/hr
    utilization_pct: float       # mean_r demand/provided * 100
    instance_diversity: int      # distinct instance types deployed
    provider_fragmentation: int  # distinct providers used
    overprovision_pct: float     # mean_r (provided-demand)/demand * 100
    satisfied: bool

    def as_dict(self):
        return asdict(self)


def evaluate(catalog: Catalog, counts: np.ndarray, demand: np.ndarray) -> AllocationMetrics:
    """Score integer ``counts`` against ``demand`` in raw units — shared by
    the optimizer, the CA baseline, and both replay engines."""
    K, E, c = catalog.matrices()
    counts = np.asarray(counts, np.float64)
    provided = K @ counts
    nonzero = demand > 0
    util = np.mean(np.where(nonzero, demand / np.maximum(provided, 1e-9), 1.0)) * 100.0
    over = np.mean(np.where(nonzero,
                            (provided - demand) / np.maximum(demand, 1e-9), 0.0)) * 100.0
    used = counts > 0.5
    return AllocationMetrics(
        total_cost=float(c @ counts),
        utilization_pct=float(min(util, 100.0)),
        instance_diversity=int(used.sum()),
        provider_fragmentation=int((E @ used.astype(np.float64) > 0.5).sum()),
        overprovision_pct=float(over),
        satisfied=bool(np.all(provided >= demand - 1e-6)),
    )


def per_dim_utilization(catalog: Catalog, counts: np.ndarray,
                        demand: np.ndarray) -> np.ndarray:
    """Radar-graph data (paper Appendix A): demand/provided per resource."""
    K, _, _ = catalog.matrices()
    provided = K @ np.asarray(counts, np.float64)
    return np.clip(demand / np.maximum(provided, 1e-9), 0.0, 1.0)

"""Infrastructure Optimization Controller (paper §I.C bullet 3 + §III.E).

Maintains a cluster allocation against a time-varying demand stream, replanning
each tick under the incremental-adoption constraint ||x - x_cur||_1 <= delta.
This is the production control loop: bounded churn, warm-started solves,
failure-driven replans (used by repro.distributed.elastic for TPU fleets).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from .api import problem_from_demand
from .catalog import Catalog
from .incremental import solve_incremental_info
from .pgd import AnytimeConfig
from .metrics import AllocationMetrics, evaluate
from .multistart import multistart_solve
from .problem import AllocationProblem, PenaltyParams
from .rounding import round_and_polish


@dataclass
class ControllerStep:
    """One recorded tick: the demand seen, the allocation deployed, its
    snapshot metrics, the L1 churn paid, and whether it was a full replan.

    ``churn_violation`` is the excess of ``churn`` over the controller's
    ``delta_max`` on a warm (non-replanned) tick: rounding may exceed the
    relaxed solve's churn bound slightly when demand jumps — the
    feasibility-first tradeoff (shortage beats churn). Zero on replans,
    which deliberately ignore the bound. Surfaced fleet-wide by
    ``FleetReplayMetrics.summary()`` so churn comparisons between
    controllers are honest about bound overruns.

    ``solver_iters`` records the PGD iterations the solve behind this tick
    actually took (0 where the engine did not report one, e.g. cold-start
    multistart ticks) — the adaptive-vs-fixed speedup evidence
    ``benchmarks/horizon_bench.py`` aggregates per cell.

    ``deadline_hit`` marks ticks whose solve was truncated by an enforced
    anytime deadline (``core.pgd.AnytimeConfig``) — the allocation is the
    solve's best-so-far feasible iterate, not its converged answer. Always
    False without an anytime budget."""

    demand: np.ndarray
    counts: np.ndarray
    metrics: AllocationMetrics
    churn: float                 # ||x_t - x_{t-1}||_1
    replanned: bool
    churn_violation: float = 0.0  # max(0, churn - delta_max) on warm ticks
    solver_iters: int = 0         # inner PGD iterations spent on this tick
    deadline_hit: bool = False    # anytime budget truncated this tick's solve


@dataclass
class InfrastructureOptimizationController:
    """Stateful per-cluster control loop: cold multistart solve on the first
    tick, then warm-started incremental solves under the L1 churn bound
    ``delta_max``. The batched fleet replay drives the same state via
    :meth:`apply_counts` (see docs/fleet.md, replay modes)."""

    catalog: Catalog
    delta_max: float = 8.0                       # max L1 churn per tick
    params: Optional[PenaltyParams] = None
    n_starts: int = 4
    allowed_idx: Optional[np.ndarray] = None
    normalize: bool = True                       # demand-normalized solver units
    x_current: np.ndarray = None                 # set on first step
    history: List[ControllerStep] = field(default_factory=list)
    # scenario surface (repro.core.terms / docs/scenarios.md): ``terms`` is a
    # static tuple of scenario-term specs attached to EVERY tick's problem;
    # ``spot_idx``/``spot_availability`` drive the per-tick spot overlay —
    # availability row t (clamped to the last row) zeroes the interrupted
    # spot types' capacity for the tick the controller is about to solve.
    terms: tuple = ()
    spot_idx: Optional[np.ndarray] = None        # (S,) catalog spot-twin idx
    spot_availability: Optional[np.ndarray] = None   # (T', S) in {0, 1}
    # opt-in solver observability: when True, every warm solve also captures
    # the engine's per-iteration convergence rows (core.pgd.PGDTrace, one
    # entry per warm tick on ``solver_traces``). The traced program computes
    # the same solution — see repro.obs.solver_trace.
    capture_solver_trace: bool = False
    solver_traces: List = field(default_factory=list)
    # enforced anytime budget (core.pgd.AnytimeConfig): when set with a
    # deadline, every warm solve runs chunked against the injectable clock
    # and deploys its best-so-far feasible iterate at expiry. None (or a
    # config without a deadline) keeps the untruncated engine — the exact
    # pre-anytime compiled program.
    anytime: Optional[AnytimeConfig] = None

    # not a dataclass field: last warm solve's PGD iteration count, consumed
    # by step() when recording the tick (0 until a warm solve has run)
    _last_solver_iters = 0
    # not a dataclass field: whether the last warm solve's anytime budget
    # expired before convergence (False without an anytime deadline)
    _last_deadline_hit = False
    # not a dataclass field: the last solve's RELAXED solution (set by both
    # cold and warm solves, and by the batched fleet engine). Health
    # monitoring (repro.obs.health) certifies THIS point through kkt_report
    # — integer counts are a rounding of it, not a stationary point.
    last_x_rel: Optional[np.ndarray] = None

    def make_problem(self, demand: np.ndarray) -> AllocationProblem:
        """Build this tick's AllocationProblem — the same construction as the
        one-shot api.optimize pipeline, so a constant-demand replay reproduces
        the single-shot result. Also used by the batched fleet replay engine,
        which stacks these per-tenant problems into one padded batch.

        The current tick index is ``len(self.history)`` (the step being
        built has not been applied yet) — identical in the sequential and
        batched engines, so the spot overlay stays bit-exact across them.
        The MPC controller builds its whole lookahead window through this
        method before advancing history, so a tick's availability applies
        to all window rows: interruptions are observed, not forecast, and
        an observed outage is assumed to persist over the horizon."""
        unavailable = None
        if self.spot_idx is not None and self.spot_availability is not None:
            avail = np.asarray(self.spot_availability)
            t = min(len(self.history), len(avail) - 1)
            spot = np.asarray(self.spot_idx, np.int64)
            unavailable = spot[avail[t] <= 0.0]
        return problem_from_demand(self.catalog, demand, params=self.params,
                                   allowed_idx=self.allowed_idx,
                                   normalize=self.normalize,
                                   terms=self.terms,
                                   unavailable_idx=unavailable)

    # back-compat alias (pre-docs name)
    _problem = make_problem

    def cold_start_counts(self, prob: AllocationProblem) -> np.ndarray:
        """First-tick allocation: full multistart solve, no churn bound; take
        the best rounded start (matches api.optimize without BnB)."""
        ms = multistart_solve(prob, n_starts=self.n_starts)
        self.last_x_rel = np.asarray(ms.best.x, np.float64)
        return np.asarray(ms.x_int, np.float64)

    def incremental_counts(self, prob: AllocationProblem,
                           x_init: Optional[np.ndarray] = None) -> np.ndarray:
        """Warm-tick allocation: incremental solve from the current counts
        under the L1 churn bound, then greedy rounding. ``x_init`` optionally
        overrides the warm start (e.g. the previous tick's relaxed solution,
        plumbed through by the batched replay engine). The adaptive solve's
        iteration count is kept on ``_last_solver_iters`` for
        :meth:`apply_counts` bookkeeping; with ``capture_solver_trace`` the
        engine's convergence rows are appended to ``solver_traces``."""
        x_init = None if x_init is None else jnp.asarray(x_init, jnp.float32)
        self._last_deadline_hit = False
        if self.anytime is not None and self.anytime.enabled:
            if self.capture_solver_trace:
                raise ValueError("anytime deadlines and "
                                 "capture_solver_trace are mutually "
                                 "exclusive; drop one")
            x_rel, iters, report = solve_incremental_info(
                prob, jnp.asarray(self.x_current, jnp.float32),
                jnp.asarray(self.delta_max, jnp.float32), x_init=x_init,
                anytime=self.anytime)
            self._last_deadline_hit = bool(report.deadline_hit)
        elif self.capture_solver_trace:
            x_rel, iters, trace = solve_incremental_info(
                prob, jnp.asarray(self.x_current, jnp.float32),
                jnp.asarray(self.delta_max, jnp.float32),
                x_init=x_init, capture_trace=True)
            self.solver_traces.append(
                type(trace)(*(np.asarray(f) for f in trace)))
        else:
            x_rel, iters = solve_incremental_info(
                prob, jnp.asarray(self.x_current, jnp.float32),
                jnp.asarray(self.delta_max, jnp.float32), x_init=x_init)
        self._last_solver_iters = int(iters)
        self.last_x_rel = np.asarray(x_rel, np.float64)
        # rounding may exceed the churn bound slightly when demand jumps;
        # that's the feasibility-first tradeoff (shortage beats churn).
        return np.asarray(round_and_polish(prob, x_rel), np.float64)

    def apply_counts(self, demand: np.ndarray, counts: np.ndarray,
                     replanned: bool, solver_iters: int = 0,
                     deadline_hit: bool = False) -> ControllerStep:
        """Record an allocation computed for this tick (by :meth:`step`, or
        externally by the batched fleet engine): compute churn and metrics,
        advance ``x_current``, append to history. ``solver_iters`` optionally
        records the inner PGD iterations the solve took (see
        ``ControllerStep.solver_iters``); ``deadline_hit`` whether an
        anytime budget truncated it."""
        demand = np.asarray(demand, np.float64)
        x = np.asarray(counts, np.float64)
        churn = float(np.abs(x - (self.x_current if self.x_current is not None
                                  else np.zeros_like(x))).sum())
        # rounding may overshoot the relaxed solve's churn bound; record the
        # excess (replans ignore the bound by design, so they report 0)
        violation = 0.0 if replanned else max(0.0, churn - float(self.delta_max))
        self.x_current = x
        step = ControllerStep(demand=demand, counts=x,
                              metrics=evaluate(self.catalog, x, demand),
                              churn=churn, replanned=replanned,
                              churn_violation=violation,
                              solver_iters=int(solver_iters),
                              deadline_hit=bool(deadline_hit))
        self.history.append(step)
        return step

    def step(self, demand: np.ndarray,
             x_init: Optional[np.ndarray] = None) -> ControllerStep:
        """Advance one tick: solve for this demand (cold multistart on the
        first call, warm-started incremental solve after) and record it."""
        demand = np.asarray(demand, np.float64)
        prob = self.make_problem(demand)
        if self.x_current is None:
            x, replanned = self.cold_start_counts(prob), True
            self._last_solver_iters = 0
            self._last_deadline_hit = False
        else:
            x, replanned = self.incremental_counts(prob, x_init=x_init), False
        return self.apply_counts(demand, x, replanned,
                                 solver_iters=self._last_solver_iters,
                                 deadline_hit=self._last_deadline_hit)

    def replan_on_failure(self, failed_counts: np.ndarray,
                          demand: np.ndarray) -> ControllerStep:
        """Remove failed nodes from the current allocation, then replan with
        the churn bound relaxed by the failure size (we must at least replace
        what died)."""
        assert self.x_current is not None, "controller has no allocation yet"
        failed = np.minimum(np.asarray(failed_counts, np.float64), self.x_current)
        self.x_current = self.x_current - failed
        old_delta = self.delta_max
        self.delta_max = float(old_delta + failed.sum())
        try:
            out = self.step(demand)
        finally:
            self.delta_max = old_delta
        return out

    def total_cost(self) -> float:
        return sum(s.metrics.total_cost for s in self.history)

    def total_churn(self) -> float:
        return sum(s.churn for s in self.history)

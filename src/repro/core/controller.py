"""Infrastructure Optimization Controller (paper §I.C bullet 3 + §III.E).

Maintains a cluster allocation against a time-varying demand stream, replanning
each tick under the incremental-adoption constraint ||x - x_cur||_1 <= delta.
This is the production control loop: bounded churn, warm-started solves,
failure-driven replans (used by repro.distributed.elastic for TPU fleets).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from .api import problem_from_demand
from .catalog import Catalog
from .incremental import solve_incremental
from .metrics import AllocationMetrics, evaluate
from .multistart import multistart_solve
from .problem import AllocationProblem, PenaltyParams
from .rounding import round_and_polish


@dataclass
class ControllerStep:
    demand: np.ndarray
    counts: np.ndarray
    metrics: AllocationMetrics
    churn: float                 # ||x_t - x_{t-1}||_1
    replanned: bool


@dataclass
class InfrastructureOptimizationController:
    catalog: Catalog
    delta_max: float = 8.0                       # max L1 churn per tick
    params: Optional[PenaltyParams] = None
    n_starts: int = 4
    allowed_idx: Optional[np.ndarray] = None
    normalize: bool = True                       # demand-normalized solver units
    x_current: np.ndarray = None                 # set on first step
    history: List[ControllerStep] = field(default_factory=list)

    def _problem(self, demand: np.ndarray) -> AllocationProblem:
        # same construction as the one-shot api.optimize pipeline, so a
        # constant-demand replay reproduces the single-shot result
        return problem_from_demand(self.catalog, demand, params=self.params,
                                   allowed_idx=self.allowed_idx,
                                   normalize=self.normalize)

    def step(self, demand: np.ndarray) -> ControllerStep:
        demand = np.asarray(demand, np.float64)
        prob = self._problem(demand)
        if self.x_current is None:
            # cold start: full multistart solve, no churn bound; take the
            # best rounded start (matches api.optimize without BnB)
            ms = multistart_solve(prob, n_starts=self.n_starts)
            x = np.asarray(ms.x_int, np.float64)
            replanned = True
        else:
            x_rel = solve_incremental(
                prob, jnp.asarray(self.x_current, jnp.float32),
                jnp.asarray(self.delta_max, jnp.float32))
            x = np.asarray(round_and_polish(prob, x_rel), np.float64)
            # rounding may exceed the churn bound slightly when demand jumps;
            # that's the feasibility-first tradeoff (shortage beats churn).
            replanned = False
        churn = float(np.abs(x - (self.x_current if self.x_current is not None
                                  else np.zeros_like(x))).sum())
        self.x_current = x
        step = ControllerStep(demand=demand, counts=x,
                              metrics=evaluate(self.catalog, x, demand),
                              churn=churn, replanned=replanned)
        self.history.append(step)
        return step

    def replan_on_failure(self, failed_counts: np.ndarray,
                          demand: np.ndarray) -> ControllerStep:
        """Remove failed nodes from the current allocation, then replan with
        the churn bound relaxed by the failure size (we must at least replace
        what died)."""
        assert self.x_current is not None, "controller has no allocation yet"
        failed = np.minimum(np.asarray(failed_counts, np.float64), self.x_current)
        self.x_current = self.x_current - failed
        old_delta = self.delta_max
        self.delta_max = float(old_delta + failed.sum())
        try:
            out = self.step(demand)
        finally:
            self.delta_max = old_delta
        return out

    def total_cost(self) -> float:
        return sum(s.metrics.total_cost for s in self.history)

    def total_churn(self) -> float:
        return sum(s.churn for s in self.history)

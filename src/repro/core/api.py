"""High-level allocation pipeline: scenario -> problem -> multistart convex
solve -> greedy rounding (-> optional branch-and-bound) -> metrics.

This is the "optimization approach" column of the paper's comparison
methodology (§IV.B.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .branch_bound import branch_and_bound
from .catalog import Catalog
from .metrics import AllocationMetrics, evaluate
from .multistart import multistart_solve
from .problem import AllocationProblem, PenaltyParams
from .rounding import round_and_polish
from .scenarios import Scenario
from .solver import SolverConfig


@dataclass
class OptimizeResult:
    """One-shot pipeline output: the deployed allocation and its provenance.

    ``counts`` is the integer allocation (float array of whole numbers),
    ``relaxed`` the best continuous solution it was rounded from, ``fun``
    the eq.(1) objective at ``counts`` (solver units), ``metrics`` the
    raw-unit snapshot evaluation, and ``used_bnb`` whether branch-and-bound
    improved on greedy rounding."""

    counts: np.ndarray
    relaxed: np.ndarray
    metrics: AllocationMetrics
    fun: float
    used_bnb: bool


def problem_from_demand(catalog: Catalog, demand: np.ndarray,
                        params: Optional[PenaltyParams] = None,
                        allowed_idx: Optional[np.ndarray] = None,
                        existing: Optional[np.ndarray] = None,
                        normalize: bool = True,
                        terms=(),
                        unavailable_idx: Optional[np.ndarray] = None,
                        ) -> AllocationProblem:
    """Build the problem for a raw demand vector; with ``normalize`` (default)
    each resource row of K is divided by the demand d_r (so d == 1 in solver
    units). This conditions the problem — otherwise storage-GB (O(100))
    dominates both the shortage penalty and the greedy-rounding score over CPU
    cores (O(10)). Metrics are always computed in raw units against the
    catalog. Shared by the one-shot scenario pipeline and the controller /
    fleet-replay tick loop, so both sides solve the SAME problem.

    ``terms`` attaches scenario objective terms (``repro.core.terms`` specs:
    PricedTerm instances or ``(kind, params)`` pairs); their prices live in
    solver units like every other objective quantity. ``unavailable_idx``
    zeroes the listed instance types for this tick — mask, ub AND lb go to 0
    (an interrupted spot node is gone even if it was deployed) — the hook
    the ``spot_interruption`` availability overlay drives."""
    K, E, c = catalog.matrices()
    d = np.asarray(demand, np.float32)
    if normalize:
        scale = 1.0 / np.maximum(d, 1e-9)
        K = K * scale[:, None]
        d = np.ones_like(d)
    prob = AllocationProblem.create(K, E, c, d, params=params)
    if allowed_idx is not None:
        # existing nodes stay allowed even if outside the approved list
        allowed = np.asarray(allowed_idx)
        if existing is not None:
            existing_idx = np.nonzero(existing > 0)[0]
            allowed = np.unique(np.concatenate([allowed, existing_idx]))
        prob = prob.restrict(allowed)
    if existing is not None and np.asarray(existing).any():
        prob = prob.with_existing(np.asarray(existing, np.float32))
    if unavailable_idx is not None and len(np.asarray(unavailable_idx)):
        keep = np.ones(prob.n, np.float32)
        keep[np.asarray(unavailable_idx, np.int64)] = 0.0
        keep_j = jnp.asarray(keep)
        # lb too: availability overrides with_existing — interrupted
        # capacity cannot be "kept"
        prob = prob._replace(mask=prob.mask * keep_j, ub=prob.ub * keep_j,
                             lb=prob.lb * keep_j)
    if terms:
        from . import terms as _terms
        prob = _terms.with_terms(prob, terms)
    return prob


def problem_from_scenario(catalog: Catalog, scenario: Scenario,
                          params: Optional[PenaltyParams] = None,
                          normalize: bool = True,
                          ) -> AllocationProblem:
    """``problem_from_demand`` with the scenario's approved-type list and
    existing deployment applied (paper §IV.B scenario setups)."""
    return problem_from_demand(catalog, scenario.demand, params=params,
                               allowed_idx=scenario.allowed_idx,
                               existing=scenario.existing,
                               normalize=normalize)


def optimize(catalog: Catalog, scenario: Scenario,
             params: Optional[PenaltyParams] = None,
             n_starts: int = 8, seed: int = 0,
             use_bnb: bool = False, bnb_nodes: int = 24,
             cfg: Optional[SolverConfig] = None) -> OptimizeResult:
    """The paper's full "optimization approach" pipeline for one scenario:
    problem construction -> multistart relaxed solves -> greedy rounding
    (every start; best feasible integer merit wins) -> optional
    branch-and-bound refinement -> raw-unit metrics.

    This is the one-shot counterpart of the controller/replay tick loop —
    a constant-demand replay reproduces this result (see
    tests/fleet/test_replay.py)."""
    prob = problem_from_scenario(catalog, scenario, params)
    ms = multistart_solve(prob, n_starts=n_starts, seed=seed, cfg=cfg)
    x_rel = ms.best.x
    if use_bnb:
        bnb = branch_and_bound(prob, np.asarray(x_rel), max_nodes=bnb_nodes)
        x_int, used = bnb.x, True
        if float(ms.fun_int) < bnb.fun:   # keep the multistart incumbent
            x_int = np.asarray(ms.x_int)
    else:
        x_int, used = np.asarray(ms.x_int), False
    import repro.core.objective as obj
    fun = float(obj.objective(prob, jnp.asarray(x_int, jnp.float32)))
    return OptimizeResult(
        counts=np.asarray(x_int, np.float64),
        relaxed=np.asarray(x_rel, np.float64),
        metrics=evaluate(catalog, np.asarray(x_int), scenario.demand),
        fun=fun, used_bnb=used)

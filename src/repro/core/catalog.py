"""Synthetic instance catalogs — 940 Azure-like + 940 Linode-like types
(paper §IV.A collected these via live APIs; offline we generate catalogs with
the same scale and family/price structure, deterministically).

Resources (m=4, matching the paper's scenario dimensions):
  0: vCPU cores, 1: memory GB, 2: network units, 3: storage GB.

Also provides a TPU/accelerator-slice catalog used by the framework
integration (demands derived from dry-run rooflines → fleet planning).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

RESOURCES = ("cpu", "mem_gb", "net_units", "storage_gb")
M = len(RESOURCES)


@dataclass
class InstanceType:
    """One purchasable node type: per-node capacities and hourly price."""

    name: str
    provider: str
    family: str
    cpu: float
    mem_gb: float
    net_units: float
    storage_gb: float
    hourly_price: float


@dataclass
class Catalog:
    """An ordered list of instance types; ``matrices()`` lowers it to the
    paper's (K, E, c) model inputs (see docs/math.md)."""

    instances: List[InstanceType]

    @property
    def n(self) -> int:
        return len(self.instances)

    @property
    def providers(self) -> List[str]:
        seen: List[str] = []
        for it in self.instances:
            if it.provider not in seen:
                seen.append(it.provider)
        return seen

    def matrices(self):
        """Return (K (m,n), E (p,n), c (n,)) as float32 numpy arrays."""
        n = self.n
        K = np.zeros((M, n), np.float32)
        for j, it in enumerate(self.instances):
            K[:, j] = (it.cpu, it.mem_gb, it.net_units, it.storage_gb)
        provs = self.providers
        E = np.zeros((len(provs), n), np.float32)
        for j, it in enumerate(self.instances):
            E[provs.index(it.provider), j] = 1.0
        c = np.asarray([it.hourly_price for it in self.instances], np.float32)
        return K, E, c

    def select(self, pred) -> np.ndarray:
        """Indices of instances satisfying a predicate."""
        return np.asarray([j for j, it in enumerate(self.instances) if pred(it)],
                          np.int64)


# family spec: (name, ram_per_cpu, storage_per_cpu, net_per_cpu,
#               price_per_cpu_hr, storage_price_per_gb_hr)
_AZURE_FAMILIES = [
    ("B", 4.0, 8.0, 0.25, 0.0104, 0.00005),     # burstable
    ("D", 4.0, 16.0, 0.50, 0.0480, 0.00005),    # general purpose
    ("F", 2.0, 8.0, 0.50, 0.0425, 0.00005),     # compute optimized
    ("E", 8.0, 32.0, 0.50, 0.0630, 0.00005),    # memory optimized
    ("M", 16.0, 64.0, 0.75, 0.1070, 0.00005),   # large memory
    ("L", 8.0, 340.0, 0.75, 0.0860, 0.00002),   # storage optimized
    ("DC", 4.0, 16.0, 0.50, 0.0980, 0.00005),   # confidential
    ("NV", 8.0, 48.0, 1.00, 0.1900, 0.00005),   # accel-adjacent
]
_AZURE_SIZES = [1, 2, 4, 8, 12, 16, 20, 24, 32, 48, 64, 96]
_AZURE_GENS = [("v3", 1.00), ("v4", 0.97), ("v5", 0.94), ("sv5", 0.99),
               ("av4", 0.90), ("av5", 0.87), ("dv4", 1.02), ("dv5", 0.98),
               ("ev4", 1.05), ("ev5", 1.01)]

_LINODE_FAMILIES = [
    ("standard", 2.0, 26.0, 0.40, 0.0270, 0.0),
    ("dedicated", 2.0, 25.0, 0.55, 0.0540, 0.0),
    ("highmem", 8.0, 20.0, 0.40, 0.0600, 0.0),
    ("premium", 2.0, 32.0, 0.80, 0.0650, 0.0),
    ("gpu-host", 6.0, 80.0, 1.00, 0.1500, 0.0),
    ("nanode", 1.0, 25.0, 0.20, 0.0075, 0.0),
]
_LINODE_SIZES = [1, 2, 4, 6, 8, 12, 16, 20, 24, 32, 40, 48, 56, 64, 80, 96]


def _mk_instance(rng, provider, fam, size, gen_name, gen_factor,
                 ram_per_cpu, st_per_cpu, net_per_cpu, ppc, spg) -> InstanceType:
    jitter = float(1.0 + 0.03 * rng.standard_normal())
    cpu = float(size)
    mem = cpu * ram_per_cpu
    storage = cpu * st_per_cpu
    net = max(0.25, cpu * net_per_cpu)
    # mild sublinear size discount, matching public price sheets
    size_disc = size ** -0.03
    price = (ppc * cpu * gen_factor * size_disc + spg * storage) * jitter
    return InstanceType(
        name=f"{provider}-{fam}{size}{gen_name}",
        provider=provider, family=fam, cpu=cpu, mem_gb=mem,
        net_units=net, storage_gb=storage, hourly_price=round(max(price, 0.003), 5),
    )


def make_cloud_catalog(seed: int = 0, n_per_provider: int = 940) -> Catalog:
    """Deterministic synthetic two-provider catalog (940 Azure-like + 940
    Linode-like types) with the paper's family/size/price structure."""
    rng = np.random.default_rng(seed)
    out: List[InstanceType] = []

    azure: List[InstanceType] = []
    for fam, rpc, spc, npc, ppc, spg in _AZURE_FAMILIES:
        for size in _AZURE_SIZES:
            for gen, gf in _AZURE_GENS:
                azure.append(_mk_instance(rng, "azure", fam, size, gen, gf,
                                          rpc, spc, npc, ppc, spg))
    azure = azure[:n_per_provider]

    linode: List[InstanceType] = []
    for fam, rpc, spc, npc, ppc, spg in _LINODE_FAMILIES:
        for size in _LINODE_SIZES:
            for rep in range(10):  # region/variant replicas with price jitter
                linode.append(_mk_instance(rng, "linode", fam, size, f"r{rep}",
                                           1.0 + 0.01 * rep, rpc, spc, npc, ppc, spg))
    linode = linode[:n_per_provider]

    out = azure + linode
    return Catalog(out)


def spot_catalog(catalog: Catalog, discount: float = 0.7,
                 suffix: str = "#spot"):
    """Append a spot/preemptible twin of every instance type at
    ``(1 - discount)`` times the on-demand price.

    Returns ``(catalog, spot_idx)`` — the widened catalog and the (S,)
    indices of the spot twins.  Unlike ``extensions.tiered_catalog`` (which
    folds interruption risk into the price as a certainty equivalent), the
    spot price here is the TRUE discounted price: interruption risk is
    priced separately via the ``spot_risk`` objective term
    (:func:`spot_risk_prices`), and availability is driven per tick by the
    ``spot_interruption`` trace overlay (``repro.fleet.traces``) zeroing
    interrupted twins' capacity (mask/bounds) — so risk stays visible in
    the objective split instead of hiding in the catalog."""
    from dataclasses import replace

    assert 0.0 < discount < 1.0, discount
    out = list(catalog.instances)
    spot: List[int] = []
    for it in catalog.instances:
        spot.append(len(out))
        out.append(replace(
            it, name=it.name + suffix,
            hourly_price=round(it.hourly_price * (1.0 - discount), 6)))
    return Catalog(out), np.asarray(spot, np.int64)


def spot_risk_prices(catalog: Catalog, spot_idx: np.ndarray,
                     rate: float = 0.05,
                     penalty_hours: float = 2.0) -> np.ndarray:
    """Per-type ``spot_risk`` term prices: the certainty-equivalent
    interruption surcharge ``rate * penalty_hours * hourly_price`` on each
    spot twin, zero on on-demand types.  Attach with
    ``make_term("spot_risk", risk=...)`` so the surcharge shows up as its
    own objective term rather than a repriced catalog."""
    risk = np.zeros(catalog.n, np.float32)
    for j in np.asarray(spot_idx, np.int64):
        risk[j] = rate * penalty_hours * catalog.instances[int(j)].hourly_price
    return risk


def make_tpu_catalog(seed: int = 0) -> Catalog:
    """Accelerator-slice catalog for the framework integration. Resources map
    to: cpu -> chips, mem_gb -> HBM GB, net_units -> ICI GB/s (aggregate),
    storage_gb -> host RAM GB."""
    slices = []
    # (name, chips, $/chip-hr)
    for chips, price_per_chip in [(1, 1.2), (4, 1.2), (8, 1.18), (16, 1.15),
                                  (32, 1.12), (64, 1.10), (128, 1.08),
                                  (256, 1.05)]:
        slices.append(InstanceType(
            name=f"v5e-{chips}", provider="tpu-cloud", family="v5e",
            cpu=float(chips), mem_gb=16.0 * chips, net_units=50.0 * 4 * chips,
            storage_gb=64.0 * max(1, chips // 4),
            hourly_price=round(price_per_chip * chips, 3)))
    for chips, price_per_chip in [(4, 4.2), (8, 4.1), (16, 4.0), (32, 3.9),
                                  (64, 3.85), (128, 3.8)]:
        slices.append(InstanceType(
            name=f"v5p-{chips}", provider="tpu-cloud", family="v5p",
            cpu=float(chips) * 2.33, mem_gb=95.0 * chips, net_units=90.0 * 6 * chips,
            storage_gb=128.0 * max(1, chips // 4),
            hourly_price=round(price_per_chip * chips, 3)))
    for chips, price_per_chip in [(1, 0.9), (16, 0.88), (64, 0.85), (256, 0.82)]:
        slices.append(InstanceType(
            name=f"trn2-{chips}", provider="aws", family="trn2",
            cpu=float(chips) * 0.65, mem_gb=24.0 * chips, net_units=30.0 * 4 * chips,
            storage_gb=96.0 * max(1, chips // 8),
            hourly_price=round(price_per_chip * chips, 3)))
    return Catalog(slices)

"""Multi-start strategy (paper §III.C) — vmapped solves from diverse starts.

Start-point families:
  * zeros (let the shortage penalty pull allocation up),
  * single-type covers: for the k most cost-efficient types, the minimal
    count of that one type covering the demand,
  * random scaled uniforms around a least-squares coverage level.

All deterministic given ``seed``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

import repro.core.objective as obj
from .problem import AllocationProblem
from .solver import SolveResult, SolverConfig, solve_relaxation


class MultiStartResult(NamedTuple):
    """Winner (+ per-start diagnostics) of a multi-start solve; ``x_int`` is
    the best feasible ROUNDED solution across starts. The per-start rounded
    candidates (``x_int_all`` / ``fun_int_all`` / ``feas_int_all``) are kept
    so callers can re-score the candidate set against a DIFFERENT merit —
    the receding-horizon controller's ``cold_start="window"`` scores them
    against the whole lookahead window's objective instead of tick 0's."""

    best: SolveResult
    x_int: jnp.ndarray          # (n,) best ROUNDED integer solution
    fun_int: jnp.ndarray        # objective at x_int
    all_fun: jnp.ndarray        # (S,) relaxed objective per start
    all_feasible: jnp.ndarray   # (S,)
    x_all: jnp.ndarray          # (S, n)
    x_int_all: jnp.ndarray      # (S, n) rounded candidate per start
    fun_int_all: jnp.ndarray    # (S,) objective per rounded candidate
    feas_int_all: jnp.ndarray   # (S,) integer feasibility per candidate


def make_starts(prob: AllocationProblem, n_starts: int, seed: int = 0) -> jnp.ndarray:
    """(S, n) start matrix."""
    n = prob.n
    key = jax.random.PRNGKey(seed)

    # -- single-type covers for the most cost-efficient types ---------------
    # cover_i = max_r ceil(d_r / K_ri); efficiency = cost of that cover.
    K = prob.K
    safe_K = jnp.where(K > 0, K, 1e-9)
    per_type_cover = jnp.max(prob.d[:, None] / safe_K, axis=0)          # (n,)
    covered = jnp.all((K > 0) | (prob.d[:, None] == 0), axis=0)         # (n,)
    cover_cost = jnp.where(covered & (prob.mask > 0),
                           per_type_cover * prob.c, jnp.inf)
    n_single = min(n_starts // 2, 16)
    order = jnp.argsort(cover_cost)[:n_single]
    singles = jnp.zeros((n_single, n), jnp.float32)
    singles = singles.at[jnp.arange(n_single), order].set(
        jnp.clip(per_type_cover[order], 0.0, 1e4))

    # -- random scaled starts ------------------------------------------------
    n_rand = n_starts - n_single - 1
    u = jax.random.uniform(key, (max(n_rand, 1), n))
    # scale so that E[Kx] ~ d on average
    col_mean = jnp.maximum(jnp.mean(K, axis=1), 1e-9)                   # (m,)
    scale = jnp.max(prob.d / (col_mean * n))                            # scalar
    rand = 2.0 * scale * u * prob.mask

    zeros = jnp.zeros((1, n), jnp.float32)
    starts = jnp.concatenate([zeros, singles, rand[:n_rand]], axis=0)
    return starts[:n_starts]


@partial(jax.jit, static_argnames=("cfg",))
def _solve_batch(prob: AllocationProblem, starts: jnp.ndarray, cfg: SolverConfig):
    def one(x0):
        res = solve_relaxation(prob, x0, cfg)
        # round EVERY start: relaxed merit is a poor predictor of the integer
        # cost (two relaxations within 1% can round 3x apart).
        from .rounding import round_and_polish
        x_int = round_and_polish(prob, res.x)
        f_int = obj.objective(prob, x_int)
        feas_int = obj.is_feasible(prob, x_int, 1e-3)
        return res, x_int, f_int, feas_int

    return jax.vmap(one)(starts)


def multistart_solve(
    prob: AllocationProblem,
    n_starts: int = 8,
    seed: int = 0,
    cfg: Optional[SolverConfig] = None,
) -> MultiStartResult:
    """Solve the relaxation from ``n_starts`` diverse starts (one vmapped
    program), round every start, and pick the best feasible integer merit
    (paper §III.C)."""
    cfg = cfg or SolverConfig()
    starts = make_starts(prob, n_starts, seed)
    res, x_int, f_int, feas_int = _solve_batch(prob, starts, cfg)
    # winner = best feasible INTEGER solution (paper §III.C picks the best
    # converged result; selecting on the end-to-end merit is strictly better)
    merit_int = jnp.where(feas_int, f_int, f_int + 1e12)
    j = jnp.argmin(merit_int)
    # relaxed best kept for diagnostics / branch-and-bound warm start
    merit_rel = jnp.where(res.feasible, res.fun, res.fun + 1e12)
    i = jnp.argmin(merit_rel)
    best = jax.tree_util.tree_map(lambda a: a[i], res)
    return MultiStartResult(best=best, x_int=x_int[j], fun_int=f_int[j],
                            all_fun=res.fun, all_feasible=res.feasible,
                            x_all=res.x, x_int_all=x_int, fun_int_all=f_int,
                            feas_int_all=feas_int)

"""The eq. (1) objective, its analytic gradient (eq. 6), and the constraint
machinery (log-barrier / quadratic penalty) used by the solver.

Term math lives in the ``repro.core.terms`` registry: the four paper terms
plus any scenario terms attached on ``prob.terms`` (SLO pricing, priority
eviction, spot risk).  The functions here are registry sums — base terms in
the seed trace order, then attached terms — so a problem with ``terms=()``
compiles to exactly the seed graph (jaxpr-identity is test-pinned).

Pure jnp — every function here is jit- and vmap-safe. The fused Pallas kernel
in ``repro.kernels.alloc_objective`` implements the batched (multi-start)
objective+gradient and is validated against THESE functions, which act as the
oracle.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import terms as _terms
from .problem import AllocationProblem

# ---------------------------------------------------------------------------
# Objective terms (paper eq. 1 + attached scenario terms)
# ---------------------------------------------------------------------------


def objective_terms(prob: AllocationProblem, x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Return each named term of f(x) — eq. (1) terms plus every attached
    scenario term, one matvec pair shared across all of them. x: (n,)."""
    Kx = prob.K @ x                       # (m,)
    Ex = prob.E @ x                       # (p,)
    return _terms.term_values(prob, x, Kx, Ex)


def objective(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """f(x): the full objective (registry sum of objective_terms)."""
    return _terms.sum_terms(objective_terms(prob, x))


def grad_objective(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Analytic gradient: registry sum of per-term gradients.  For the base
    terms this is the stationarity expression (eq. 6/8):

      grad = c + a*b1*E^T e^{-b1 Ex} - g*b2*E^T 1/(1+b2 Ex)
               - 2*b3*K^T diag(s)(d - Kx)
    """
    Kx = prob.K @ x
    Ex = prob.E @ x
    return _terms.sum_terms(_terms.term_grads(prob, x, Kx, Ex))


def value_and_grad(prob: AllocationProblem, x: jnp.ndarray):
    """(f(x), ∇f(x)) — the oracle the Pallas kernel is validated against.
    Fused: ONE ``K@x``/``E@x`` pair feeds both the value and gradient
    registry sums (the seed version recomputed the matvecs per side)."""
    Kx = prob.K @ x
    Ex = prob.E @ x
    val = _terms.sum_terms(_terms.term_values(prob, x, Kx, Ex))
    grad = _terms.sum_terms(_terms.term_grads(prob, x, Kx, Ex))
    return val, grad


# ---------------------------------------------------------------------------
# Constraint handling (paper eq. 2): d - mu <= Kx <= d + g
# ---------------------------------------------------------------------------


def constraint_residuals(prob: AllocationProblem, x: jnp.ndarray):
    """Positive residual == satisfied. Returns (lower (m,), upper (m,))."""
    Kx = prob.K @ x
    return Kx - (prob.d - prob.mu), (prob.d + prob.g) - Kx


def constraint_violation(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Squared violation of the two-sided band (0 iff band-feasible)."""
    lo, hi = constraint_residuals(prob, x)
    return jnp.sum(jnp.maximum(-lo, 0.0) ** 2) + jnp.sum(jnp.maximum(-hi, 0.0) ** 2)


def is_feasible(prob: AllocationProblem, x: jnp.ndarray, tol: float = 1e-4):
    """Band + box feasibility within ``tol`` (the rounding acceptance test)."""
    lo, hi = constraint_residuals(prob, x)
    box = jnp.all(x >= prob.lb - tol) & jnp.all(x <= prob.ub + tol)
    return jnp.all(lo >= -tol) & jnp.all(hi >= -tol) & box


def barrier(prob: AllocationProblem, x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Log-barrier for the two-sided Kx constraint. Returns +inf outside the
    strict interior (handled by the line search rejecting such points)."""
    lo, hi = constraint_residuals(prob, x)
    safe = (lo > 0).all() & (hi > 0).all()
    val = -(1.0 / t) * (jnp.sum(jnp.log(jnp.where(lo > 0, lo, 1.0)))
                        + jnp.sum(jnp.log(jnp.where(hi > 0, hi, 1.0))))
    return jnp.where(safe, val, jnp.inf)


def barrier_grad(prob: AllocationProblem, x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """∇ of the log-barrier (residuals clamped away from 0 for safety)."""
    lo, hi = constraint_residuals(prob, x)
    lo = jnp.maximum(lo, 1e-9)
    hi = jnp.maximum(hi, 1e-9)
    return -(1.0 / t) * (prob.K.T @ (1.0 / lo)) + (1.0 / t) * (prob.K.T @ (1.0 / hi))


def penalty(prob: AllocationProblem, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Smooth quadratic exact-ish penalty used when no strict interior exists."""
    return w * constraint_violation(prob, x)


def penalty_grad(prob: AllocationProblem, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """∇ of the quadratic penalty (the barrier's fallback, paper impl. notes)."""
    lo, hi = constraint_residuals(prob, x)
    g_lo = prob.K.T @ jnp.maximum(-lo, 0.0)   # d(sum max(-lo,0)^2)/dx = 2 K^T max(-lo,0) * d(-lo)/dKx ...
    g_hi = prob.K.T @ jnp.maximum(-hi, 0.0)
    return w * (-2.0 * g_lo + 2.0 * g_hi)


# ---------------------------------------------------------------------------
# Composite objective used by the solver
# ---------------------------------------------------------------------------


def composite(
    prob: AllocationProblem,
    x: jnp.ndarray,
    barrier_t: jnp.ndarray,
    penalty_w: jnp.ndarray,
    use_barrier: jnp.ndarray,
) -> jnp.ndarray:
    """f(x) + (barrier | penalty). ``use_barrier`` is a traced bool."""
    f = objective(prob, x)
    b = barrier(prob, x, barrier_t)
    q = penalty(prob, x, penalty_w)
    return f + jnp.where(use_barrier, b, q)


def composite_grad(
    prob: AllocationProblem,
    x: jnp.ndarray,
    barrier_t: jnp.ndarray,
    penalty_w: jnp.ndarray,
    use_barrier: jnp.ndarray,
) -> jnp.ndarray:
    """∇ of :func:`composite` — the solver's per-iteration gradient."""
    gf = grad_objective(prob, x)
    gb = barrier_grad(prob, x, barrier_t)
    gq = penalty_grad(prob, x, penalty_w)
    return gf + jnp.where(use_barrier, gb, gq)


# ---------------------------------------------------------------------------
# Projection
# ---------------------------------------------------------------------------


def project(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Project onto the box [lb, ub] intersected with the mask support."""
    return jnp.clip(x, prob.lb, prob.ub) * prob.mask

"""The five-term objective of eq. (1), its analytic gradient (eq. 6), and the
constraint machinery (log-barrier / quadratic penalty) used by the solver.

Pure jnp — every function here is jit- and vmap-safe. The fused Pallas kernel
in ``repro.kernels.alloc_objective`` implements the batched (multi-start)
objective+gradient and is validated against THESE functions, which act as the
oracle.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .problem import AllocationProblem

# ---------------------------------------------------------------------------
# Objective terms (paper eq. 1)
# ---------------------------------------------------------------------------


def objective_terms(prob: AllocationProblem, x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Return each named term of f(x). x: (n,)."""
    P = prob.params
    Kx = prob.K @ x                       # (m,)
    Ex = prob.E @ x                       # (p,)
    base_cost = prob.c @ x
    # alpha * p - alpha * 1^T e^{-b1 Ex}  ==  alpha * sum(1 - e^{-b1 Ex})
    consolidation = P.alpha * jnp.sum(1.0 - jnp.exp(-P.beta1 * Ex))
    volume_discount = -P.gamma * jnp.sum(jnp.log1p(P.beta2 * Ex))
    shortage = jnp.maximum(prob.d - Kx, 0.0)
    shortage_pen = P.beta3 * jnp.sum(shortage**2)
    return {
        "base_cost": base_cost,
        "consolidation": consolidation,
        "volume_discount": volume_discount,
        "shortage": shortage_pen,
    }


def objective(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """f(x): the full eq. (1) objective (sum of objective_terms)."""
    t = objective_terms(prob, x)
    return t["base_cost"] + t["consolidation"] + t["volume_discount"] + t["shortage"]


def grad_objective(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Analytic gradient, mirroring the stationarity expression (eq. 6/8):

      grad = c + a*b1*E^T e^{-b1 Ex} - g*b2*E^T 1/(1+b2 Ex)
               - 2*b3*K^T diag(s)(d - Kx)
    """
    P = prob.params
    Kx = prob.K @ x
    Ex = prob.E @ x
    g_consol = P.alpha * P.beta1 * (prob.E.T @ jnp.exp(-P.beta1 * Ex))
    g_volume = -P.gamma * P.beta2 * (prob.E.T @ (1.0 / (1.0 + P.beta2 * Ex)))
    shortage = jnp.maximum(prob.d - Kx, 0.0)
    g_short = -2.0 * P.beta3 * (prob.K.T @ shortage)
    return prob.c + g_consol + g_volume + g_short


def value_and_grad(prob: AllocationProblem, x: jnp.ndarray):
    """(f(x), ∇f(x)) — the oracle the Pallas kernel is validated against."""
    return objective(prob, x), grad_objective(prob, x)


# ---------------------------------------------------------------------------
# Constraint handling (paper eq. 2): d - mu <= Kx <= d + g
# ---------------------------------------------------------------------------


def constraint_residuals(prob: AllocationProblem, x: jnp.ndarray):
    """Positive residual == satisfied. Returns (lower (m,), upper (m,))."""
    Kx = prob.K @ x
    return Kx - (prob.d - prob.mu), (prob.d + prob.g) - Kx


def constraint_violation(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Squared violation of the two-sided band (0 iff band-feasible)."""
    lo, hi = constraint_residuals(prob, x)
    return jnp.sum(jnp.maximum(-lo, 0.0) ** 2) + jnp.sum(jnp.maximum(-hi, 0.0) ** 2)


def is_feasible(prob: AllocationProblem, x: jnp.ndarray, tol: float = 1e-4):
    """Band + box feasibility within ``tol`` (the rounding acceptance test)."""
    lo, hi = constraint_residuals(prob, x)
    box = jnp.all(x >= prob.lb - tol) & jnp.all(x <= prob.ub + tol)
    return jnp.all(lo >= -tol) & jnp.all(hi >= -tol) & box


def barrier(prob: AllocationProblem, x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """Log-barrier for the two-sided Kx constraint. Returns +inf outside the
    strict interior (handled by the line search rejecting such points)."""
    lo, hi = constraint_residuals(prob, x)
    safe = (lo > 0).all() & (hi > 0).all()
    val = -(1.0 / t) * (jnp.sum(jnp.log(jnp.where(lo > 0, lo, 1.0)))
                        + jnp.sum(jnp.log(jnp.where(hi > 0, hi, 1.0))))
    return jnp.where(safe, val, jnp.inf)


def barrier_grad(prob: AllocationProblem, x: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """∇ of the log-barrier (residuals clamped away from 0 for safety)."""
    lo, hi = constraint_residuals(prob, x)
    lo = jnp.maximum(lo, 1e-9)
    hi = jnp.maximum(hi, 1e-9)
    return -(1.0 / t) * (prob.K.T @ (1.0 / lo)) + (1.0 / t) * (prob.K.T @ (1.0 / hi))


def penalty(prob: AllocationProblem, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Smooth quadratic exact-ish penalty used when no strict interior exists."""
    return w * constraint_violation(prob, x)


def penalty_grad(prob: AllocationProblem, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """∇ of the quadratic penalty (the barrier's fallback, paper impl. notes)."""
    lo, hi = constraint_residuals(prob, x)
    g_lo = prob.K.T @ jnp.maximum(-lo, 0.0)   # d(sum max(-lo,0)^2)/dx = 2 K^T max(-lo,0) * d(-lo)/dKx ...
    g_hi = prob.K.T @ jnp.maximum(-hi, 0.0)
    return w * (-2.0 * g_lo + 2.0 * g_hi)


# ---------------------------------------------------------------------------
# Composite objective used by the solver
# ---------------------------------------------------------------------------


def composite(
    prob: AllocationProblem,
    x: jnp.ndarray,
    barrier_t: jnp.ndarray,
    penalty_w: jnp.ndarray,
    use_barrier: jnp.ndarray,
) -> jnp.ndarray:
    """f(x) + (barrier | penalty). ``use_barrier`` is a traced bool."""
    f = objective(prob, x)
    b = barrier(prob, x, barrier_t)
    q = penalty(prob, x, penalty_w)
    return f + jnp.where(use_barrier, b, q)


def composite_grad(
    prob: AllocationProblem,
    x: jnp.ndarray,
    barrier_t: jnp.ndarray,
    penalty_w: jnp.ndarray,
    use_barrier: jnp.ndarray,
) -> jnp.ndarray:
    """∇ of :func:`composite` — the solver's per-iteration gradient."""
    gf = grad_objective(prob, x)
    gb = barrier_grad(prob, x, barrier_t)
    gq = penalty_grad(prob, x, penalty_w)
    return gf + jnp.where(use_barrier, gb, gq)


# ---------------------------------------------------------------------------
# Projection
# ---------------------------------------------------------------------------


def project(prob: AllocationProblem, x: jnp.ndarray) -> jnp.ndarray:
    """Project onto the box [lb, ub] intersected with the mask support."""
    return jnp.clip(x, prob.lb, prob.ub) * prob.mask

"""Framework integration: convert dry-run roofline artifacts into the paper's
demand vectors, so the Infrastructure Optimization Controller plans
accelerator fleets for training/serving jobs.

Demand dims reuse the catalog convention (see catalog.make_tpu_catalog):
  0: chips-equivalent of compute  (HLO_FLOPs / (peak_flops * step_budget_s))
  1: HBM GB                       (per-device bytes * devices / 1e9)
  2: ICI GB/s aggregate           (collective_bytes / step_budget_s / 1e9)
  3: host RAM GB                  (data pipeline + checkpoint staging)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

PEAK_FLOPS_BF16 = 197e12       # per chip (given)
HBM_BW = 819e9                 # bytes/s per chip
ICI_LINK_BW = 50e9             # bytes/s per link


@dataclass
class JobSpec:
    """A training/serving job's roofline numbers (FLOPs, bytes moved,
    collective traffic) plus its step-time budget."""

    name: str
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    bytes_per_device: float
    devices: int
    step_budget_s: float = 1.0   # target step time
    host_ram_gb: float = 64.0


def demand_from_job(job: JobSpec) -> np.ndarray:
    """Lower a JobSpec to an (m,) accelerator demand vector (chips, HBM GB,
    ICI Gb/s, host RAM) — the bridge from dry-run rooflines to the
    allocator."""
    compute_chips = job.hlo_flops / (PEAK_FLOPS_BF16 * job.step_budget_s)
    hbm_gb = job.bytes_per_device * job.devices / 1e9
    ici_gbps = job.collective_bytes / job.step_budget_s / 1e9
    return np.array([compute_chips, hbm_gb, ici_gbps, job.host_ram_gb], np.float64)


def demand_from_dryrun_record(rec: Dict, step_budget_s: float = 1.0) -> np.ndarray:
    """rec: one JSON record produced by repro.launch.dryrun."""
    job = JobSpec(
        name=rec.get("cell", "job"),
        hlo_flops=float(rec["flops"]),
        hlo_bytes=float(rec.get("bytes_accessed", 0.0)),
        collective_bytes=float(rec.get("collective_bytes", 0.0)),
        bytes_per_device=float(rec.get("bytes_per_device", 0.0)),
        devices=int(rec.get("devices", 256)),
        step_budget_s=step_budget_s,
    )
    return demand_from_job(job)


def fleet_demand(records, step_budget_s: float = 1.0) -> np.ndarray:
    """Aggregate demand across a fleet of concurrent jobs."""
    total = np.zeros(4, np.float64)
    for rec in records:
        total += demand_from_dryrun_record(rec, step_budget_s)
    return total

"""Projected-gradient / interior-point solver for the relaxed problem.

The paper solves the relaxation with interior-point methods (via CVXPY).
Here the solver is a first-class JAX citizen: fully ``jit``-able and
``vmap``-able (multi-start batches thousands of solves), built from
``lax.while_loop`` so it runs as a single compiled program on TPU.

Structure per solve:
  outer loop (barrier continuation, R rounds):  t <- kappa * t
    inner loop (projected gradient):            x <- P(x - eta * grad F_t(x))
      with Armijo backtracking over a fixed geometric step ladder (vmap-safe).

If the problem has no strictly feasible interior (common in the paper's own
scenarios where integral covers overshoot d+g), the barrier is replaced by a
smooth quadratic penalty — chosen automatically per-solve from the phase-1
point, exactly the fallback the paper's implementation notes describe
("basic rounding strategy when the solver produces ... infeasible solutions").
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

import repro.core.objective as obj
from .pgd import PGDConfig, pgd_minimize
from .problem import AllocationProblem


class SolverConfig(NamedTuple):
    """Hashable solver knobs (static under jit): barrier continuation
    schedule, PGD iteration budget, and the Armijo backtracking ladder."""

    max_iters: int = 400           # inner PGD iterations per barrier round
    barrier_rounds: int = 4        # outer continuation rounds
    barrier_t0: float = 1.0        # initial barrier temperature
    barrier_kappa: float = 10.0    # t multiplier per round
    penalty_w: float = 1e3         # quadratic penalty weight (fallback mode)
    step0: float = 1.0             # top of the step ladder
    n_backtracks: int = 12         # ladder length
    backtrack: float = 0.5         # ladder ratio
    armijo_c: float = 1e-4
    tol: float = 1e-6              # stop when projected-gradient step is tiny


class SolveResult(NamedTuple):
    """One relaxed solve: final iterate, objective, merit, effort, and
    whether the barrier (vs quadratic-penalty) path was taken."""

    x: jnp.ndarray
    fun: jnp.ndarray            # objective f(x) (WITHOUT barrier/penalty)
    composite: jnp.ndarray      # final merit value
    iters: jnp.ndarray
    feasible: jnp.ndarray
    used_barrier: jnp.ndarray


def phase1_point(prob: AllocationProblem, x0: jnp.ndarray, steps: int = 200,
                 margin_frac: float = 0.02) -> jnp.ndarray:
    """Drive constraint violation to ~0 by PGD on the violation alone.
    Targets a small margin INSIDE the [d-mu, d+g] band so the result is
    strictly interior (enabling barrier mode) whenever the band has width.
    Returns a feasible (or least-infeasible) point for warm starts."""
    band = prob.mu + prob.g
    margin = margin_frac * band      # zero-width band -> zero margin

    def body(i, x):
        Kx = prob.K @ x
        lo_v = jnp.maximum((prob.d - prob.mu + margin) - Kx, 0.0)
        hi_v = jnp.maximum(Kx - (prob.d + prob.g - margin), 0.0)
        grad = -2.0 * (prob.K.T @ lo_v) + 2.0 * (prob.K.T @ hi_v)
        # Lipschitz-ish step from row norms; cheap and robust.
        L = 2.0 * jnp.sum(prob.K * prob.K) + 1e-6
        return obj.project(prob, x - (1.0 / L) * grad)

    return jax.lax.fori_loop(0, steps, body, obj.project(prob, x0))


def _pgd(prob, x0, barrier_t, penalty_w, use_barrier, cfg: SolverConfig):
    """Inner projected-gradient loop, routed through the shared BB/Armijo
    engine (``core.pgd.pgd_minimize``): merit = eq.(1) objective + barrier
    or quadratic penalty, projection = box ∩ mask."""

    F = partial(obj.composite, prob, barrier_t=barrier_t, penalty_w=penalty_w,
                use_barrier=use_barrier)
    G = partial(obj.composite_grad, prob, barrier_t=barrier_t,
                penalty_w=penalty_w, use_barrier=use_barrier)
    # ftol=0.0: the barrier solver keeps its high-accuracy behavior — the
    # flat-streak stop only fires on literal zero-progress cycling (the
    # relaxation feeds KKT certificates and BnB bounds, so trading merit
    # digits for iterations is the warm-tick engines' business, not ours)
    pcfg = PGDConfig(max_iters=cfg.max_iters, step0=cfg.step0,
                     n_backtracks=cfg.n_backtracks, backtrack=cfg.backtrack,
                     armijo_c=cfg.armijo_c, tol=cfg.tol, ftol=0.0)
    return pgd_minimize(F, G, partial(obj.project, prob), x0, pcfg)


@partial(jax.jit, static_argnames=("cfg",))
def solve_relaxation(
    prob: AllocationProblem,
    x0: jnp.ndarray,
    cfg: SolverConfig = SolverConfig(),
) -> SolveResult:
    """Solve the continuous relaxation from a single start point."""
    x = phase1_point(prob, x0)
    lo, hi = obj.constraint_residuals(prob, x)
    strict = (jnp.min(lo) > 1e-3) & (jnp.min(hi) > 1e-3)

    def round_body(r, carry):
        x, total_it = carry
        t = cfg.barrier_t0 * (cfg.barrier_kappa ** r.astype(jnp.float32))
        x, _, it = _pgd(prob, x, jnp.asarray(t), jnp.asarray(cfg.penalty_w),
                        strict, cfg)
        return (x, total_it + it)

    x, iters = jax.lax.fori_loop(0, cfg.barrier_rounds, round_body,
                                 (x, jnp.asarray(0)))
    # feasibility restoration: a no-op when feasible (phase-1 gradient is 0
    # at margin 0), otherwise walks penalty-mode residual violation to ~0.
    x = phase1_point(prob, x, steps=100, margin_frac=0.0)
    fx = obj.objective(prob, x)
    comp = obj.composite(prob, x, jnp.asarray(cfg.barrier_t0),
                         jnp.asarray(cfg.penalty_w), strict)
    return SolveResult(
        x=x, fun=fx, composite=comp, iters=iters,
        feasible=obj.is_feasible(prob, x, tol=1e-3),
        used_barrier=strict,
    )

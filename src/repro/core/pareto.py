"""Parameter tuning (paper §III.D): grid search over (alpha, beta1, beta2,
beta3, gamma), Pareto-frontier extraction over (cost, fragmentation,
diversity), and sensitivity analysis. The grid is vmapped — one compiled
solve evaluates the whole parameter grid batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.objective as obj
from .problem import AllocationProblem, PenaltyParams
from .rounding import greedy_round
from .solver import SolverConfig, solve_relaxation


@dataclass
class GridPoint:
    """One penalty-parameter setting and its (cost, fragmentation,
    diversity) outcome; ``on_frontier`` marks Pareto-efficient points."""

    params: Dict[str, float]
    cost: float
    fragmentation: int
    diversity: int
    objective: float
    on_frontier: bool = False


def _eval_grid(prob: AllocationProblem, grid: PenaltyParams,
               cfg: SolverConfig, x0: jnp.ndarray):
    def one(params: PenaltyParams):
        p = prob._replace(params=params)
        res = solve_relaxation(p, x0, cfg)
        x_int = greedy_round(p, res.x)
        cost = p.c @ x_int
        used = (x_int > 0.5).astype(jnp.float32)
        frag = jnp.sum((p.E @ used) > 0.5)
        div = jnp.sum(used)
        return cost, frag, div, obj.objective(p, x_int)

    return jax.jit(jax.vmap(one))(grid)


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """points (N, k): smaller is better on every axis. Returns frontier mask."""
    N = points.shape[0]
    mask = np.ones(N, bool)
    for i in range(N):
        if not mask[i]:
            continue
        dominated = (np.all(points <= points[i], axis=1)
                     & np.any(points < points[i], axis=1))
        if dominated.any():
            mask[i] = False
    return mask


def grid_search(prob: AllocationProblem,
                alphas: Sequence[float] = (0.1, 1.0, 5.0),
                gammas: Sequence[float] = (0.05, 0.2, 1.0),
                beta1s: Sequence[float] = (0.5,),
                beta2s: Sequence[float] = (0.05,),
                beta3s: Sequence[float] = (50.0,),
                cfg: SolverConfig = SolverConfig(max_iters=200, barrier_rounds=2),
                ) -> List[GridPoint]:
    """Sweep the five penalty knobs over a grid (one vmapped solve), score
    each rounded outcome, and mark the cost/fragmentation/diversity Pareto
    frontier — how the default PenaltyParams were tuned."""
    combos = [(a, b1, b2, b3, g)
              for a in alphas for b1 in beta1s for b2 in beta2s
              for b3 in beta3s for g in gammas]
    grid = PenaltyParams(
        alpha=jnp.asarray([c[0] for c in combos], jnp.float32),
        beta1=jnp.asarray([c[1] for c in combos], jnp.float32),
        beta2=jnp.asarray([c[2] for c in combos], jnp.float32),
        beta3=jnp.asarray([c[3] for c in combos], jnp.float32),
        gamma=jnp.asarray([c[4] for c in combos], jnp.float32),
    )
    x0 = jnp.zeros(prob.n, jnp.float32)
    cost, frag, div, fval = _eval_grid(prob, grid, cfg, x0)
    pts = np.stack([np.asarray(cost), np.asarray(frag, np.float64)], axis=1)
    frontier = pareto_mask(pts)
    out = []
    for i, (a, b1, b2, b3, g) in enumerate(combos):
        out.append(GridPoint(
            params=dict(alpha=a, beta1=b1, beta2=b2, beta3=b3, gamma=g),
            cost=float(cost[i]), fragmentation=int(frag[i]),
            diversity=int(div[i]), objective=float(fval[i]),
            on_frontier=bool(frontier[i])))
    return out


def sensitivity(prob: AllocationProblem, base: PenaltyParams,
                rel_step: float = 0.1,
                cfg: SolverConfig = SolverConfig(max_iters=200, barrier_rounds=2),
                ) -> Dict[str, float]:
    """d(cost)/d(log param) central differences — which knob matters most."""
    names = ["alpha", "beta1", "beta2", "beta3", "gamma"]
    x0 = jnp.zeros(prob.n, jnp.float32)

    def cost_at(params: PenaltyParams) -> float:
        p = prob._replace(params=params)
        res = solve_relaxation(p, x0, cfg)
        x_int = greedy_round(p, res.x)
        return float(p.c @ x_int)

    out = {}
    for nm in names:
        v = float(getattr(base, nm))
        hi = base._replace(**{nm: jnp.asarray(v * (1 + rel_step), jnp.float32)})
        lo = base._replace(**{nm: jnp.asarray(v * (1 - rel_step), jnp.float32)})
        out[nm] = (cost_at(hi) - cost_at(lo)) / (2 * rel_step)
    return out

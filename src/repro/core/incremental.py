"""Incremental adoption (paper §III.E): ||x - x_current||_1 <= delta_max.

Implemented as an exact Euclidean projection onto the L1 ball centered at
``x_current`` (Duchi et al. 2008), composed with the box projection by a short
alternating (Dykstra-like) loop. Used by the controller to bound per-step
cluster churn — the paper's "bounded perturbation" methodology.

``solve_incremental`` (the warm tick of both the myopic controller and —
under vmap — the batched fleet engine ``solve_fleet_step``) runs the shared
Barzilai-Borwein + Armijo projected-gradient engine (``core.pgd``) on the
objective over this feasible set — the ``repro.core.terms`` registry sum,
so attached scenario terms (SLO pricing, priority eviction, spot risk)
price the warm tick automatically: ``steps`` is an iteration BUDGET,
not an exact count — the solve early-stops once an accepted step moves no
coordinate by more than the tolerance. The H=1 time-expanded program in
``repro.horizon.solver`` reduces op-for-op to this function (same engine,
same merit, same projection), which anchors the MPC ≡ myopic equivalence.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .pgd import (AnytimeConfig, PGDConfig, pgd_chunk_init, pgd_chunk_run,
                  pgd_minimize, pgd_minimize_traced, run_anytime)
from .problem import AllocationProblem
import repro.core.objective as obj


def project_l1_ball(v: jnp.ndarray, radius: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection of v onto {z : ||z||_1 <= radius} (Duchi 2008)."""
    abs_v = jnp.abs(v)
    inside = jnp.sum(abs_v) <= radius
    u = jnp.sort(abs_v)[::-1]
    css = jnp.cumsum(u)
    ks = jnp.arange(1, v.shape[0] + 1, dtype=v.dtype)
    cond = u * ks > (css - radius)
    rho = jnp.max(jnp.where(cond, ks, 0.0))
    rho = jnp.maximum(rho, 1.0)
    theta = (jnp.sum(jnp.where(ks <= rho, u, 0.0)) - radius) / rho
    w = jnp.sign(v) * jnp.maximum(abs_v - theta, 0.0)
    return jnp.where(inside, v, w)


def project_incremental(
    prob: AllocationProblem,
    x: jnp.ndarray,
    x_current: jnp.ndarray,
    delta_max: jnp.ndarray,
    n_alternations: int = 8,
) -> jnp.ndarray:
    """Project onto box ∩ {||x - x_current||_1 <= delta_max} by alternating
    exact projections. Both sets are convex; alternation converges to the
    intersection (we take the last box-feasible iterate)."""

    def body(i, z):
        z = x_current + project_l1_ball(z - x_current, delta_max)
        return obj.project(prob, z)

    return jax.lax.fori_loop(0, n_alternations, body, obj.project(prob, x))


def _incremental_merit_fns(prob, x_current, delta_max):
    """The warm tick's ``(value, grad, project)`` triple — the eq.(1)
    objective (terms-registry sum) over box ∩ L1 churn ball. One builder
    shared by the monolithic, traced, and chunked-anytime engines so all
    three run the exact same merit graph."""
    F = partial(obj.objective, prob)
    G = partial(obj.grad_objective, prob)

    def proj(x):
        return project_incremental(prob, x, x_current, delta_max)

    return F, G, proj


@partial(jax.jit, static_argnames=("cfg",))
def _solve_incremental_impl(prob, x_current, delta_max, x0, cfg: PGDConfig):
    F, G, proj = _incremental_merit_fns(prob, x_current, delta_max)
    return pgd_minimize(F, G, proj, x0, cfg)


def incremental_anytime_init(prob, x_current, delta_max, x0,
                             cfg: PGDConfig):
    """Unjitted chunk-state init for the warm tick's anytime mode (same
    merit triple as ``_solve_incremental_impl``). Exposed unjitted so the
    fleet engine can vmap it inside its own jitted impl."""
    F, G, proj = _incremental_merit_fns(prob, x_current, delta_max)
    return pgd_chunk_init(F, G, proj, x0, cfg)


def incremental_anytime_chunk(prob, x_current, delta_max, state, it_end,
                              cfg: PGDConfig):
    """Unjitted chunk advance for the warm tick's anytime mode — run the
    shared engine until the traced cap ``it_end`` or convergence."""
    F, G, proj = _incremental_merit_fns(prob, x_current, delta_max)
    return pgd_chunk_run(F, G, proj, state, it_end, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def _anytime_init_impl(prob, x_current, delta_max, x0, cfg: PGDConfig):
    return incremental_anytime_init(prob, x_current, delta_max, x0, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def _anytime_chunk_impl(prob, x_current, delta_max, state, it_end,
                        cfg: PGDConfig):
    return incremental_anytime_chunk(prob, x_current, delta_max, state,
                                     it_end, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def _solve_incremental_traced_impl(prob, x_current, delta_max, x0,
                                   cfg: PGDConfig):
    """The traced twin of ``_solve_incremental_impl``: same merit triple,
    same engine, plus the fixed-size per-iteration PGDTrace capture."""
    F, G, proj = _incremental_merit_fns(prob, x_current, delta_max)
    return pgd_minimize_traced(F, G, proj, x0, cfg)


def solve_incremental(
    prob: AllocationProblem,
    x_current: jnp.ndarray,
    delta_max,
    x_init=None,
    steps: int = 600,
    cfg: PGDConfig | None = None,
) -> jnp.ndarray:
    """Adaptive PGD on f with the incremental-adoption feasible set, warm-
    started from the current allocation (the natural production warm start).

    Runs the shared BB/Armijo engine (``core.pgd.pgd_minimize``): ``steps``
    is the iteration budget (``PGDConfig.max_iters``); pass ``cfg`` to
    control the full ladder/tolerance instead. Returns the relaxed solution
    only — use :func:`solve_incremental_info` when the caller also wants the
    iteration count (benchmark instrumentation)."""
    return solve_incremental_info(prob, x_current, delta_max, x_init=x_init,
                                  steps=steps, cfg=cfg)[0]


def solve_incremental_info(
    prob: AllocationProblem,
    x_current: jnp.ndarray,
    delta_max,
    x_init=None,
    steps: int = 600,
    cfg: PGDConfig | None = None,
    capture_trace: bool = False,
    anytime: AnytimeConfig | None = None,
):
    """:func:`solve_incremental` variant returning ``(x, iters)`` — the
    relaxed solution plus the PGD iterations actually taken (the early-
    stopping win the adaptive engine buys over the old fixed-step loop).

    With ``capture_trace=True`` it returns ``(x, iters, trace)`` instead,
    where ``trace`` is the engine's per-iteration ``core.pgd.PGDTrace``
    (fixed-size ``(steps,)`` arrays — vmap-safe, so the batched fleet tick
    can surface one trace per lane; see ``repro.obs.solver_trace``). The
    solution and iteration count match the untraced call: the trace is
    extra loop state, not extra math.

    With an *enabled* ``anytime`` config (``deadline_ms`` set) the solve
    runs chunked against ``anytime.clock`` and returns ``(x_best, iters,
    AnytimeReport)`` — the best-so-far feasible iterate by merit when the
    budget expires (see ``core.pgd.AnytimeConfig``). ``anytime=None`` or a
    disabled config takes the untruncated path above, byte-for-byte the
    same compiled program as before the anytime mode existed. Anytime and
    ``capture_trace`` are mutually exclusive."""
    delta_max = jnp.asarray(delta_max, jnp.float32)
    x0 = x_current if x_init is None else x_init
    if cfg is None:
        cfg = PGDConfig(max_iters=int(steps))
    if anytime is not None and anytime.enabled:
        if capture_trace:
            raise ValueError("anytime deadlines and capture_trace are "
                             "mutually exclusive (truncated traces would "
                             "be misleading); drop one")
        xc = jnp.asarray(x_current)
        x0j = jnp.asarray(x0)
        state, report = run_anytime(
            lambda: _anytime_init_impl(prob, xc, delta_max, x0j, cfg),
            lambda s, e: _anytime_chunk_impl(prob, xc, delta_max, s, e, cfg),
            cfg, anytime)
        return state.x_best, state.it, report
    if capture_trace:
        x, _, iters, tr = _solve_incremental_traced_impl(
            prob, jnp.asarray(x_current), delta_max, jnp.asarray(x0), cfg)
        return x, iters, tr
    x, _, iters = _solve_incremental_impl(prob, jnp.asarray(x_current),
                                          delta_max, jnp.asarray(x0), cfg)
    return x, iters

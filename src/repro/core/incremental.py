"""Incremental adoption (paper §III.E): ||x - x_current||_1 <= delta_max.

Implemented as an exact Euclidean projection onto the L1 ball centered at
``x_current`` (Duchi et al. 2008), composed with the box projection by a short
alternating (Dykstra-like) loop. Used by the controller to bound per-step
cluster churn — the paper's "bounded perturbation" methodology.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .problem import AllocationProblem
import repro.core.objective as obj


def project_l1_ball(v: jnp.ndarray, radius: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection of v onto {z : ||z||_1 <= radius} (Duchi 2008)."""
    abs_v = jnp.abs(v)
    inside = jnp.sum(abs_v) <= radius
    u = jnp.sort(abs_v)[::-1]
    css = jnp.cumsum(u)
    ks = jnp.arange(1, v.shape[0] + 1, dtype=v.dtype)
    cond = u * ks > (css - radius)
    rho = jnp.max(jnp.where(cond, ks, 0.0))
    rho = jnp.maximum(rho, 1.0)
    theta = (jnp.sum(jnp.where(ks <= rho, u, 0.0)) - radius) / rho
    w = jnp.sign(v) * jnp.maximum(abs_v - theta, 0.0)
    return jnp.where(inside, v, w)


def project_incremental(
    prob: AllocationProblem,
    x: jnp.ndarray,
    x_current: jnp.ndarray,
    delta_max: jnp.ndarray,
    n_alternations: int = 8,
) -> jnp.ndarray:
    """Project onto box ∩ {||x - x_current||_1 <= delta_max} by alternating
    exact projections. Both sets are convex; alternation converges to the
    intersection (we take the last box-feasible iterate)."""

    def body(i, z):
        z = x_current + project_l1_ball(z - x_current, delta_max)
        return obj.project(prob, z)

    return jax.lax.fori_loop(0, n_alternations, body, obj.project(prob, x))


@partial(jax.jit, static_argnames=("steps",))
def solve_incremental(
    prob: AllocationProblem,
    x_current: jnp.ndarray,
    delta_max,
    x_init=None,
    steps: int = 600,
    step_scale: float = 1.0,
) -> jnp.ndarray:
    """PGD on f with the incremental-adoption feasible set. Warm-started from
    the current allocation (the natural production warm start)."""
    delta_max = jnp.asarray(delta_max, jnp.float32)
    x0 = x_current if x_init is None else x_init

    L = (2.0 * prob.params.beta3 * jnp.sum(prob.K * prob.K)
         + jnp.linalg.norm(prob.c) + 1e-3)

    def body(i, x):
        g = obj.grad_objective(prob, x)
        x = x - step_scale * g / L
        return project_incremental(prob, x, x_current, delta_max)

    return jax.lax.fori_loop(0, steps, body,
                             project_incremental(prob, x0, x_current, delta_max))

"""The paper's five evaluation scenarios (§IV.D), built over the synthetic
catalogs with the exact demand vectors from the text.

Each scenario yields: the demand vector, the optimizer's allowed-type mask,
the CA node pools, and any pre-existing allocation (applied to both sides,
as in the paper's harness).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from .autoscaler import NodePool, default_pools_for
from .catalog import Catalog, make_cloud_catalog


@dataclass
class Scenario:
    """One paper evaluation setup (§IV.B): a demand vector, the optimizer's
    approved types, the CA's node pools, and any pre-existing deployment."""

    name: str
    title: str
    demand: np.ndarray                       # (4,) cpu, mem, net, storage
    allowed_idx: Optional[np.ndarray]        # optimizer's allowed types (None = all)
    pools: List[NodePool]                    # CA node pools
    existing: np.ndarray                     # (n,) counts pre-deployed


def _existing_vec(n: int, items: Dict[int, int]) -> np.ndarray:
    v = np.zeros(n, np.float64)
    for j, k in items.items():
        v[j] = k
    return v


def _pick(catalog: Catalog, pred: Callable, k: int, sort_key=None) -> np.ndarray:
    idx = catalog.select(pred)
    if sort_key is not None:
        idx = idx[np.argsort([sort_key(catalog.instances[j]) for j in idx],
                             kind="stable")]
    return idx[:k]


def build_scenarios(catalog: Optional[Catalog] = None) -> List[Scenario]:
    """The paper's five scenarios (basic web app, enterprise migration,
    high-performance batch, storage-heavy, mixed) over ``catalog``."""
    cat = catalog or make_cloud_catalog()
    n = cat.n
    inst = cat.instances

    scenarios: List[Scenario] = []

    # ---- 1. Basic web application (greenfield) ----------------------------
    d1 = np.array([8, 16, 4, 100], np.float64)
    # CA: standard general-purpose types available in a new cluster
    # (burstable + general families — the defaults a fresh cluster offers)
    gp = np.concatenate([
        _pick(cat, lambda t: t.provider == "azure" and t.family in ("B", "D")
              and t.cpu in (2, 4, 8), 8, sort_key=lambda t: t.hourly_price),
        _pick(cat, lambda t: t.provider == "linode"
              and t.family in ("nanode", "standard")
              and t.cpu in (2, 4, 8), 8, sort_key=lambda t: t.hourly_price),
    ])
    scenarios.append(Scenario(
        name="s1_greenfield", title="Basic Web Application (Greenfield)",
        demand=d1, allowed_idx=None,
        pools=default_pools_for(cat, gp), existing=_existing_vec(n, {})))

    # ---- 2. Scaling with existing infrastructure --------------------------
    d2 = np.array([16, 32, 8, 200], np.float64)
    small_az = _pick(cat, lambda t: t.provider == "azure" and 2 <= t.cpu <= 4
                     and t.family in ("B", "D"), 2, sort_key=lambda t: t.hourly_price)
    small_li = _pick(cat, lambda t: t.provider == "linode" and 2 <= t.cpu <= 4
                     and t.family == "standard", 2, sort_key=lambda t: t.hourly_price)
    existing2 = _existing_vec(n, {int(small_az[0]): 2, int(small_li[0]): 1})
    pools2 = default_pools_for(cat, np.concatenate([small_az, small_li]),
                               existing={int(small_az[0]): 2, int(small_li[0]): 1})
    scenarios.append(Scenario(
        name="s2_scaling", title="Scaling with Existing Infrastructure",
        demand=d2, allowed_idx=None, pools=pools2, existing=existing2))

    # ---- 3. Enterprise fixed node pools ------------------------------------
    # Approved lists in enterprises standardize on a SPREAD of families
    # (incl. premium/confidential SKUs), not the cheapest types — pick
    # min/median/max-price representatives per size category & provider.
    d3 = np.array([24, 64, 12, 300], np.float64)

    def _spread(pred, prov, k):
        idx = cat.select(lambda t, pred=pred, prov=prov: t.provider == prov and pred(t))
        idx = idx[np.argsort([inst[j].hourly_price for j in idx], kind="stable")]
        if len(idx) == 0:
            return idx
        picks = np.unique(np.linspace(0, len(idx) - 1, k).astype(int))
        return idx[picks]

    small = np.concatenate([_spread(lambda t: 2 <= t.cpu <= 4, "azure", 3),
                            _spread(lambda t: 2 <= t.cpu <= 4, "linode", 2)])
    medium = np.concatenate([_spread(lambda t: 4 < t.cpu <= 8, "azure", 3),
                             _spread(lambda t: 4 < t.cpu <= 8, "linode", 2)])
    large = np.concatenate([_spread(lambda t: t.cpu >= 8, "azure", 3),
                            _spread(lambda t: t.cpu >= 8, "linode", 2)])
    approved3 = np.concatenate([small, medium, large])
    scenarios.append(Scenario(
        name="s3_enterprise", title="Enterprise Environment (Fixed Node Pools)",
        demand=d3, allowed_idx=approved3,
        pools=default_pools_for(cat, approved3), existing=_existing_vec(n, {})))

    # ---- 4. Memory-intensive data processing -------------------------------
    d4 = np.array([32, 128, 12, 500], np.float64)
    himem = np.concatenate([
        _pick(cat, lambda t: t.provider == "azure" and t.family in ("E", "M")
              and t.mem_gb >= 16, 5, sort_key=lambda t: t.hourly_price),
        _pick(cat, lambda t: t.provider == "linode" and t.family == "highmem"
              and t.mem_gb >= 16, 4, sort_key=lambda t: t.hourly_price)])
    # paper: general pools also exist — CA must pick within memory-opt + GP
    # (dedicated general-purpose families; burstables are not production
    # options for memory-intensive workloads)
    gp_d = np.concatenate([
        _pick(cat, lambda t: t.provider == "azure" and t.family == "D"
              and t.cpu in (2, 4, 8), 6, sort_key=lambda t: t.hourly_price),
        _pick(cat, lambda t: t.provider == "linode" and t.family == "standard"
              and t.cpu in (2, 4, 8), 6, sort_key=lambda t: t.hourly_price)])
    pools4_idx = np.concatenate([himem, gp_d])
    existing4 = _existing_vec(n, {int(himem[0]): 1})
    scenarios.append(Scenario(
        name="s4_memory", title="Memory-Intensive Data Processing",
        demand=d4, allowed_idx=None,
        pools=default_pools_for(cat, pools4_idx, existing={int(himem[0]): 1}),
        existing=existing4))

    # ---- 5. Constrained: only small instances ------------------------------
    d5 = np.array([32, 64, 12, 300], np.float64)
    tiny = cat.select(lambda t: t.cpu <= 2)
    # CA pools: a manageable subset of those tiny types (one pool per family)
    seen, tiny_pools = set(), []
    for j in tiny:
        key = (inst[j].provider, inst[j].family)
        if key not in seen:
            seen.add(key)
            tiny_pools.append(j)
    scenarios.append(Scenario(
        name="s5_constrained", title="Resource Constraints (Small Instances Only)",
        demand=d5, allowed_idx=tiny,
        pools=default_pools_for(cat, np.asarray(tiny_pools)),
        existing=_existing_vec(n, {})))

    return scenarios


def scaled_scenario(base: Scenario, factor: float) -> Scenario:
    """Demand-scaled variant (paper Fig. 2 sweep)."""
    return Scenario(name=f"{base.name}_x{factor:g}", title=base.title,
                    demand=base.demand * factor, allowed_idx=base.allowed_idx,
                    pools=list(base.pools), existing=base.existing)

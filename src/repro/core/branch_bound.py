"""Branch-and-bound / branch-and-cut driver (paper §III.A).

Host-side best-first search; every node's continuous relaxation is solved by
the jit-compiled PGD solver with per-variable box bounds (the projection
handles boxes exactly, so a node solve costs the same compiled program).

Honesty note (also in DESIGN.md): with the concave consolidation term the
relaxation value is not a certified global lower bound; as in the paper we
treat it as the node bound (the term's magnitude is <= alpha * p, so we widen
bounds by that constant to keep pruning conservative on near-convex
instances). Bound-tightening "cuts": cost-based upper bounds from the
incumbent (if c_i * x_i > U then x_i <= floor(U / c_i)).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

import repro.core.objective as obj
from .problem import AllocationProblem
from .rounding import round_and_polish
from .solver import SolverConfig, solve_relaxation


@dataclass(order=True)
class _Node:
    bound: float
    tie: int = field(compare=True)
    lb: np.ndarray = field(compare=False, default=None)
    ub: np.ndarray = field(compare=False, default=None)


@dataclass
class BnBResult:
    """Best integer solution found, with search-effort provenance
    (``gap`` = relative distance between incumbent and best relaxed bound)."""

    x: np.ndarray
    fun: float
    nodes_explored: int
    incumbent_updates: int
    gap: float


def _solve_node(prob: AllocationProblem, lb, ub, x0, cfg) -> tuple[np.ndarray, float]:
    node_prob = prob._replace(lb=jnp.asarray(lb, jnp.float32),
                              ub=jnp.asarray(ub, jnp.float32))
    res = solve_relaxation(node_prob, jnp.asarray(x0, jnp.float32), cfg)
    return np.asarray(res.x), float(res.fun)


def _cost_cuts(prob: AllocationProblem, ub: np.ndarray, incumbent_val: float) -> np.ndarray:
    """Tighten per-variable upper bounds from the incumbent cost."""
    if not np.isfinite(incumbent_val):
        return ub
    c = np.asarray(prob.c)
    cap = np.floor(np.maximum(incumbent_val, 0.0) / np.maximum(c, 1e-9)) + 1.0
    return np.minimum(ub, cap)


def branch_and_bound(
    prob: AllocationProblem,
    x_relaxed: Optional[np.ndarray] = None,
    max_nodes: int = 48,
    int_tol: float = 1e-3,
    cfg: Optional[SolverConfig] = None,
) -> BnBResult:
    """Best-first branch-and-bound on fractional variables (paper §III.D):
    each node re-solves the relaxation under tightened box bounds, an
    incumbent prunes by cost cuts; bounded by ``max_nodes`` relaxed solves."""
    cfg = cfg or SolverConfig()
    n = prob.n
    lb0 = np.asarray(prob.lb, np.float64)
    ub0 = np.asarray(prob.ub, np.float64)

    if x_relaxed is None:
        res = solve_relaxation(prob, jnp.zeros(n, jnp.float32), cfg)
        x_relaxed = np.asarray(res.x)

    # incumbent from greedy rounding (paper's fallback)
    x_inc = np.asarray(round_and_polish(prob, jnp.asarray(x_relaxed, jnp.float32)))
    f_inc = float(obj.objective(prob, jnp.asarray(x_inc, jnp.float32)))
    updates = 0

    # slack added to node bounds: the concave term can lower f by at most
    # alpha * p below its convex-ignored counterpart.
    bound_slack = float(prob.params.alpha) * prob.p

    tie = itertools.count()
    heap: list[_Node] = []
    root_x, root_f = _solve_node(prob, lb0, ub0, x_relaxed, cfg)
    heapq.heappush(heap, _Node(root_f, next(tie), lb0, ub0))
    node_x_cache = {0: (root_x, root_f)}
    explored = 0

    while heap and explored < max_nodes:
        node = heapq.heappop(heap)
        explored += 1
        if node.bound - bound_slack >= f_inc:
            continue  # pruned
        ub_cut = _cost_cuts(prob, node.ub, f_inc)
        x_rel, f_rel = _solve_node(prob, node.lb, ub_cut, x_inc, cfg)
        if f_rel - bound_slack >= f_inc:
            continue
        frac = np.abs(x_rel - np.round(x_rel))
        if np.max(frac) <= int_tol:
            x_int = np.round(x_rel)
            if bool(obj.is_feasible(prob, jnp.asarray(x_int, jnp.float32), 1e-3)):
                f_int = float(obj.objective(prob, jnp.asarray(x_int, jnp.float32)))
                if f_int < f_inc:
                    f_inc, x_inc = f_int, x_int
                    updates += 1
            continue
        # also round this node's solution — cheap incumbent candidates
        x_rnd = np.asarray(round_and_polish(prob, jnp.asarray(x_rel, jnp.float32)))
        f_rnd = float(obj.objective(prob, jnp.asarray(x_rnd, jnp.float32)))
        if f_rnd < f_inc and bool(obj.is_feasible(prob, jnp.asarray(x_rnd, jnp.float32), 1e-3)):
            f_inc, x_inc = f_rnd, x_rnd
            updates += 1

        i = int(np.argmax(frac))
        v = x_rel[i]
        lo_child = node.lb.copy(); lo_child[i] = np.ceil(v)
        hi_child = node.ub.copy(); hi_child[i] = np.floor(v)
        if lo_child[i] <= node.ub[i]:
            heapq.heappush(heap, _Node(f_rel, next(tie), lo_child, node.ub.copy()))
        if hi_child[i] >= node.lb[i]:
            heapq.heappush(heap, _Node(f_rel, next(tie), node.lb.copy(), hi_child))

    best_bound = min([nd.bound for nd in heap], default=f_inc)
    gap = max(0.0, f_inc - (best_bound - bound_slack))
    return BnBResult(x=x_inc, fun=f_inc, nodes_explored=explored,
                     incumbent_updates=updates, gap=gap)

"""AdamW with cosine schedule, global-norm clipping, and optional gradient
accumulation — pure-pytree implementation (no optax dependency), sharded the
same way as params (m/v inherit the param logical axes => FSDP+TP ZeRO-3).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def abstract_state(params_sds) -> AdamWState:
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree_util.tree_map(z, params_sds),
                      v=jax.tree_util.tree_map(z, params_sds))


def state_axes(param_axes) -> AdamWState:
    """Logical axes for the optimizer state (mirror of the params)."""
    return AdamWState(step=None, m=param_axes, v=param_axes)


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}

"""Gradient compression for the DP all-reduce: int8 block-quantized
gradients with error feedback (the residual of quantization is carried to
the next step, keeping the method unbiased in the long run).

Used inside shard_map'd data-parallel reductions: quantize -> psum(int32) ->
dequantize; at 4x compression the DCN/pod-axis gradient all-reduce bytes
drop 4x (the multi-pod 'pod' axis is the slow DCN link — this is where the
paper-style cost/bandwidth tradeoff bites).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class CompressState(NamedTuple):
    error: Any   # pytree like grads — error-feedback residual


def init_state(grads_like) -> CompressState:
    return CompressState(error=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8. x flat (n,) f32 -> (q (n,) int8, scale)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, n: int) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Local quantize+dequantize with error feedback — models the lossy
    channel; composition with psum is done by the caller."""
    flat = (g.astype(jnp.float32) + err).reshape(-1)
    q, scale = _quantize(flat)
    deq = _dequantize(q, scale, flat.shape[0]).reshape(g.shape)
    new_err = (flat.reshape(g.shape) - deq)
    return deq.astype(g.dtype), new_err


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis_name: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: int8-quantize (with error feedback), all-reduce the
    int8 payload as int32 partial sums, dequantize with the max scale.
    4x wire bytes saved vs f32; bf16 grads get 2x."""
    flat = (g.astype(jnp.float32) + err).reshape(-1)
    q, scale = _quantize(flat)
    # shared scale: max over participants so the int8 grid is common
    scale_max = jax.lax.pmax(scale, axis_name)
    requant = jnp.clip(jnp.round((q.astype(jnp.float32) * scale)
                                 / scale_max), -127, 127)
    summed = jax.lax.psum(requant.astype(jnp.int32), axis_name)
    deq = (summed.astype(jnp.float32) * scale_max).reshape(-1)[:flat.shape[0]]
    deq = deq.reshape(g.shape)
    # local error: what this participant's lossy contribution missed
    local = (requant.astype(jnp.float32) * scale_max).reshape(-1)[:flat.shape[0]]
    new_err = flat.reshape(g.shape) - local.reshape(g.shape)
    return deq.astype(g.dtype), new_err


def tree_compressed_psum(grads, state: CompressState, axis_name: str):
    out = jax.tree_util.tree_map(
        lambda g, e: compressed_psum(g, e, axis_name), grads, state.error)
    new_grads = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, CompressState(error=new_err)

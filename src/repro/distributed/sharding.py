"""Logical-axis sharding rules (MaxText-style) and activation constraints.

Weights and activations are annotated with LOGICAL axis names; a rule set
maps them to mesh axes. Changing the parallelism layout (the hillclimbing
lever) means changing rules, not model code.

Default layout on mesh ("pod", "data", "model") / ("data", "model"):

  weights:  embed (d_model dim)  -> data      (FSDP / ZeRO-3)
            mlp / heads / vocab  -> model     (TP)
            expert               -> model     (EP)
  acts:     batch                -> pod+data  (DP)
            kv_seq (decode)      -> model     (decode attention splits KV)
            kv_seq (long ctx)    -> data+model (context/sequence parallel)
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Rules = Dict[str, Optional[Tuple[str, ...]]]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, across jax versions:
    the top-level alias + ``check_vma`` appeared after 0.4.x; the installed
    0.4.37 only has ``jax.experimental.shard_map`` with ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def base_rules(mesh: Mesh, cfg=None) -> Rules:
    """Training/prefill layout. The activation residual stream must be
    sharded over 'model' between blocks (otherwise a 64-group command-r
    scan carry needs 100+GB/device). Two variants:

      * attention archs: shard the SEQUENCE dim ("seq" -> model). FFN/qkv
        einsums contract d_model, so s-sharded activations need NO gather;
        attention gathers only K/V (small under GQA). Megatron-SP flavored.
      * ssm/hybrid archs (mamba/rwkv scans iterate the seq axis, which
        cannot be a sharded scan axis): shard d_model ("act_embed" -> model)
        and pay the per-block all-gather.
    """
    has_pod = "pod" in _mesh_axes(mesh)
    batch = ("pod", "data") if has_pod else ("data",)
    seq_shardable = cfg is None or all(
        b == "attn" for b in getattr(cfg, "block_pattern", ("attn",)))
    return {
        # weights
        "embed": ("data",),          # FSDP shard dim
        "mlp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "rwkv_heads": ("model",),
        "mamba_inner": ("model",),
        "layers": None,              # stacked scan dim — replicated
        # activations
        "batch": batch,
        "seq": ("model",) if seq_shardable else None,
        "act_embed": None if seq_shardable else ("model",),
        "act_heads": ("model",),
        "kv_seq": None,
        "frontend": None,
        None: None,
    }


def decode_rules(mesh: Mesh, cfg=None) -> Rules:
    r = base_rules(mesh, cfg)
    # decode: small per-step compute; shard the KV cache along sequence
    # (flash-decode style) because kv_heads may be < mesh model size.
    r["seq"] = None                  # decode S == 1
    r["act_embed"] = None
    r["kv_seq"] = ("model",)
    r["kv_heads"] = None
    r["act_heads"] = None
    return r


def long_context_rules(mesh: Mesh, cfg=None) -> Rules:
    r = decode_rules(mesh, cfg)
    has_pod = "pod" in _mesh_axes(mesh)
    # batch=1: give both axes to the sequence dim (context parallelism)
    r["batch"] = None
    r["kv_seq"] = ("pod", "data", "model") if has_pod else ("data", "model")
    return r


RULESETS = {
    "train": base_rules,
    "prefill": base_rules,
    "decode": decode_rules,
    "long": long_context_rules,
}

_state = threading.local()


@contextmanager
def use_rules(rules: Rules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def current_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


def spec_for(axes: Sequence[Optional[str]], rules: Optional[Rules] = None,
             mesh: Optional[Mesh] = None, shape=None) -> P:
    """Map logical axes -> PartitionSpec under the active rules. When
    ``shape`` is known, an assignment that does not divide evenly is SKIPPED
    rather than consumed — so e.g. an 8-expert dim on a 16-way model axis
    leaves the axis free for the mlp dim behind it (mixtral would otherwise
    end up with replicated expert weights)."""
    rules = rules or current_rules()
    if rules is None or axes is None:
        return P()
    out, used = [], set()
    for i, ax in enumerate(axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            out.append(None)
            continue
        mesh_ax = tuple(a for a in mesh_ax if a not in used)
        if not mesh_ax:
            out.append(None)
            continue
        if shape is not None and mesh is not None:
            size = _axis_size(mesh, mesh_ax)
            if size <= 0 or shape[i] % max(size, 1) != 0:
                out.append(None)      # leave the mesh axis available
                continue
        used.update(mesh_ax)
        out.append(mesh_ax if len(mesh_ax) > 1 else mesh_ax[0])
    return P(*out)


def constrain(x, *axes):
    """with_sharding_constraint via logical axes; no-op outside a rule set.
    Divisibility-aware: an indivisible dim skips its assignment, leaving the
    mesh axis for later dims."""
    rules = current_rules()
    if rules is None:
        return x
    mesh = _get_abstract_mesh()
    if mesh is None or not getattr(mesh, "axis_names", None):
        return x
    spec = spec_for(axes, rules, mesh, shape=tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, spec)


def _get_abstract_mesh():
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    # jax 0.4.x: the ambient mesh set by `with mesh:` lives in the
    # thread-local resource env (no AbstractMesh yet)
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def _axis_size(mesh, name) -> int:
    try:
        return int(np.prod([dict(zip(mesh.axis_names, mesh.axis_sizes))[n]
                            for n in ((name,) if isinstance(name, str) else name)]))
    except Exception:
        return 1


def _drop_indivisible(spec: P, shape, mesh) -> P:
    if mesh is None or not getattr(mesh, "axis_names", None):
        return spec
    out = []
    for dim, assignment in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if assignment is None:
            out.append(None)
            continue
        size = _axis_size(mesh, assignment)
        out.append(assignment if size > 0 and dim % size == 0 else None)
    return P(*out)


def is_axes_leaf(x) -> bool:
    """A logical-axes leaf is None or a PLAIN tuple of str/None. NamedTuples
    (KVCache etc.) fail the exact-type check and recurse as pytree nodes."""
    return x is None or (type(x) is tuple and all(
        isinstance(e, (str, type(None))) for e in x))


def make_shardings(axes_tree, mesh: Mesh, rules: Optional[Rules] = None,
                   shapes_tree=None):
    """NamedSharding tree from a logical-axes tree (for jit in_shardings).
    If ``shapes_tree`` is given, indivisible dims fall back to replication."""
    specs = make_specs(axes_tree, mesh, rules, shapes_tree)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def make_specs(axes_tree, mesh: Mesh, rules: Optional[Rules] = None,
               shapes_tree=None):
    """PartitionSpec tree; if ``shapes_tree`` is given, indivisible dims are
    dropped to replication per-leaf."""
    rules = rules or base_rules(mesh)
    if shapes_tree is None:
        return jax.tree_util.tree_map(
            lambda axes: spec_for(axes, rules, mesh), axes_tree,
            is_leaf=is_axes_leaf)

    def one(axes, shaped):
        if axes is None:
            return P()
        spec = spec_for(axes, rules, mesh, shape=tuple(shaped.shape))
        return _drop_indivisible(spec, shaped.shape, mesh)

    return jax.tree_util.tree_map(one, axes_tree, shapes_tree,
                                  is_leaf=is_axes_leaf)

"""Elastic scaling — where the PAPER'S ALLOCATOR becomes the framework's
brain: on failure (or load change) the Infrastructure Optimization
Controller replans the accelerator fleet under the incremental-adoption
churn bound (paper §III.E), and the runtime rebuilds the mesh and reshards
the checkpoint.

Flow:
  demand  = roofline-derived demand vector (repro.core.workloads) for the
            jobs that must keep running
  replan  = controller.replan_on_failure(failed, demand)  (convex solve)
  rebuild = next_mesh_shape() -> make_mesh -> reshard params from checkpoint
            (deterministic data pipeline re-shards itself by step index)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import (InfrastructureOptimizationController, make_tpu_catalog)
from repro.core.workloads import JobSpec, demand_from_job


@dataclass
class FleetPlan:
    counts: np.ndarray            # catalog counts (slice types)
    total_chips: int
    cost_per_hour: float
    mesh_shape: Tuple[int, ...]   # (data, model) for the training job


def _mesh_from_chips(chips: int, model_parallel: int = 16) -> Tuple[int, int]:
    data = max(1, chips // model_parallel)
    return (data, model_parallel)


class ElasticFleet:
    """Owns the controller + current plan for ONE training job (extend with
    a job list for fleet-level planning — see examples/autoscale_controller)."""

    def __init__(self, job: JobSpec, delta_max: float = 64.0,
                 model_parallel: int = 16):
        self.catalog = make_tpu_catalog()
        self.job = job
        self.model_parallel = model_parallel
        self.controller = InfrastructureOptimizationController(
            catalog=self.catalog, delta_max=delta_max, n_starts=4)

    def _to_plan(self, counts: np.ndarray) -> FleetPlan:
        K, _, c = self.catalog.matrices()
        chips = float(K[0] @ counts)   # resource 0 = chips-equivalent
        return FleetPlan(
            counts=counts, total_chips=int(chips),
            cost_per_hour=float(c @ counts),
            mesh_shape=_mesh_from_chips(int(chips), self.model_parallel))

    def initial_plan(self) -> FleetPlan:
        demand = demand_from_job(self.job)
        step = self.controller.step(demand)
        return self._to_plan(step.counts)

    def replan_after_failure(self, failed_counts: np.ndarray) -> FleetPlan:
        demand = demand_from_job(self.job)
        step = self.controller.replan_on_failure(failed_counts, demand)
        return self._to_plan(step.counts)

    def replan_for_demand(self, scale: float) -> FleetPlan:
        job = dataclasses.replace(self.job, hlo_flops=self.job.hlo_flops * scale)
        step = self.controller.step(demand_from_job(job))
        return self._to_plan(step.counts)


def reshard_params(params, old_mesh, new_mesh, axes_tree, rules):
    """Reshard a param tree onto a new mesh (post-failure rebuild). With the
    checkpoint path, this is load(step_dir) -> device_put with new shardings;
    live resharding (no checkpoint) is a device_put across meshes."""
    import jax
    from repro.distributed import sharding as shd
    shardings = shd.make_shardings(axes_tree, new_mesh, rules, params)
    return jax.device_put(params, shardings)

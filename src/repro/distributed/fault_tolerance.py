"""Fault tolerance & straggler mitigation for 1000+-node fleets.

On real multi-host TPU deployments a failed host kills the SPMD program; the
recovery loop is PROCESS-level: detect -> replan capacity (the paper's
allocator, see elastic.py) -> rebuild mesh -> restore checkpoint -> resume
from the deterministic data stream. This module implements that control loop
plus straggler policies, with simulated failure/timing sources so the logic
is testable on CPU.

Pieces:
  * TrainingSupervisor — restart-with-backoff loop around a train function;
    checkpoint/restore + deterministic data resharding on membership change.
  * StragglerMonitor — per-step worker timing watchdog; policies:
      "wait"      — synchronous (baseline),
      "deadline"  — drop contributions slower than k x median (gradient
                    renormalization by participation weight),
      "backup"    — duplicate the slowest shard's work next step (speculative
                    re-execution, MapReduce-style backup tasks).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclass
class FailureEvent:
    step: int
    kind: str              # "host_down" | "straggler" | "preemption"
    worker: int


@dataclass
class StragglerMonitor:
    n_workers: int
    policy: str = "deadline"
    deadline_factor: float = 3.0
    history: List[np.ndarray] = field(default_factory=list)
    backup_queue: List[int] = field(default_factory=list)

    def observe(self, step_times: np.ndarray):
        """step_times (n_workers,) seconds for this step."""
        self.history.append(step_times)

    def plan(self, step_times: np.ndarray) -> Dict:
        """Returns {included: bool mask, renorm: float, backups: [worker]}."""
        med = float(np.median(step_times))
        if self.policy == "wait":
            included = np.ones(self.n_workers, bool)
        elif self.policy == "deadline":
            included = step_times <= self.deadline_factor * med
            if not included.any():
                included = np.ones(self.n_workers, bool)
        elif self.policy == "backup":
            included = np.ones(self.n_workers, bool)
            worst = int(np.argmax(step_times))
            if step_times[worst] > self.deadline_factor * med:
                self.backup_queue.append(worst)
        else:
            raise ValueError(self.policy)
        renorm = self.n_workers / max(int(included.sum()), 1)
        return {"included": included, "renorm": renorm,
                "backups": list(self.backup_queue)}

    def effective_step_time(self, step_times: np.ndarray) -> float:
        plan = self.plan(step_times)
        inc = step_times[plan["included"]]
        return float(inc.max()) if len(inc) else float(step_times.max())


@dataclass
class SupervisorConfig:
    max_restarts: int = 10
    backoff_s: float = 0.0           # simulated
    checkpoint_every: int = 25


class TrainingSupervisor:
    """Restart loop: run train_fn until completion, restoring from the last
    committed checkpoint after each failure. train_fn receives
    (start_step, num_shards) and must raise on (injected) failure."""

    def __init__(self, cfg: SupervisorConfig, ckpt_dir: str):
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        self.restarts = 0
        self.events: List[FailureEvent] = []

    def run(self, train_fn: Callable[[int, int], int], total_steps: int,
            initial_shards: int, replan_shards: Optional[Callable[[int], int]] = None):
        """Returns the final step reached. ``replan_shards(old)`` is invoked
        after each failure — the elastic hook (paper's controller decides the
        new fleet size)."""
        from repro.checkpoint.checkpoint import latest_step_dir
        num_shards = initial_shards
        step = 0
        while step < total_steps:
            try:
                step = train_fn(step, num_shards)
            except RuntimeError as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                if replan_shards is not None:
                    num_shards = replan_shards(num_shards)
                # resume point = last committed checkpoint (the failing step
                # itself is unknowable after a real crash)
                d = latest_step_dir(self.ckpt_dir)
                step = (int(d.split("step_")[-1]) if d else 0)
                self.events.append(FailureEvent(step=step, kind=str(e),
                                                worker=-1))
        return step


def simulate_step_times(rng: np.random.Generator, n_workers: int,
                        base_s: float = 1.0, straggle_prob: float = 0.05,
                        straggle_factor: float = 8.0) -> np.ndarray:
    t = rng.normal(base_s, 0.03 * base_s, n_workers).clip(base_s * 0.8)
    mask = rng.random(n_workers) < straggle_prob
    return np.where(mask, t * straggle_factor, t)

from . import sharding

"""Pipeline parallelism (GPipe-style microbatching over a 'pipe' mesh axis)
via shard_map + ppermute.

The production mesh assignment for this paper's dry-run is DP x TP (x pod),
but 1000+-node deployments of the deepest assigned archs (granite-34b 88L)
would add a pipe axis; this module provides the schedule and is exercised by
tests on a host-device mesh.

Implementation: layers are split into n_stages contiguous chunks; shard_map
over the 'pipe' axis gives each stage its chunk; the classic GPipe loop runs
n_micro + n_stages - 1 ticks, shifting activations stage-to-stage with
lax.ppermute. Steady-state bubble fraction = (n_stages-1)/(n_micro+n_stages-1).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(fn_stage: Callable, params_stacked, x_micro, *,
                   mesh, n_stages: int, axis: str = "pipe"):
    """Run x through n_stages of fn_stage with GPipe microbatching.

    fn_stage: (stage_params, x) -> x          (one stage's computation)
    params_stacked: pytree with leading dim n_stages (stage-major)
    x_micro: (n_micro, micro_batch, ...) microbatched input
    Returns (n_micro, micro_batch, ...) output (from the LAST stage).
    """
    n_micro = x_micro.shape[0]

    def per_stage(stage_params, xs):
        stage_params = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs)                 # output slots
        carry = jnp.zeros_like(xs[0])            # activation in flight

        def tick(t, state):
            buf, carry = state
            # stage 0 ingests microbatch t (if any); others use carry
            mb = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0, xs[mb], carry)
            y = fn_stage(stage_params, x_in)
            # valid iff this stage is processing a real microbatch:
            # stage s processes microbatch (t - s) at tick t
            my_mb = t - stage
            valid = (my_mb >= 0) & (my_mb < n_micro)
            y = jnp.where(valid, y, jnp.zeros_like(y))
            # last stage records its outputs
            slot = jnp.clip(my_mb, 0, n_micro - 1)
            record = valid & (stage == n_stages - 1)
            buf = jnp.where(record,
                            buf.at[slot].set(y), buf)
            # shift activations to the next stage
            carry = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, carry)

        buf, _ = jax.lax.fori_loop(0, n_ticks, tick, (buf, carry))
        return buf

    from .sharding import shard_map_compat
    per = shard_map_compat(per_stage, mesh=mesh,
                           in_specs=(P(axis), P()),
                           out_specs=P(axis))
    # every stage gets the full microbatch stream; outputs valid on last stage
    out = per(params_stacked, x_micro)
    # out is stacked over stages along the leading dim; take the last stage
    return out.reshape((n_stages, n_micro) + x_micro.shape[1:])[-1]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)

"""Stack heterogeneous AllocationProblems into one padded, masked batch.

Tenant problems are ragged: different catalog sizes (n), resource counts (m)
and provider counts (p). ``stack_problems`` pads every leaf to the fleet
maximum and stacks, so the whole fleet is ONE AllocationProblem whose leaves
carry a leading (B,) axis — directly consumable by vmap'd core-solver
internals and by the batched Pallas objective kernel.

Padding is EXACT, not approximate:

  * padded variables get mask=0, lb=ub=0, c=0 and all-zero K/E columns, so
    projection pins them to 0 and they contribute nothing to any term;
  * padded constraint rows get d=0, mu=g=1 and an all-zero K row, so their
    residual band is -1 <= 0 <= 1: strictly interior (log-barrier term
    log(1)=0) and never violated;
  * padded provider rows are all-zero in E, so 1 - exp(-b1*(Ex=0)) = 0 — the
    consolidation and volume-discount sums are unchanged.

Hence objective(padded, embed(x)) == objective(original, x) exactly, and a
solve on the stacked batch is equivalent to B independent solves.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.problem import AllocationProblem, PenaltyParams


class FleetBatch(NamedTuple):
    """A stacked fleet. ``problem`` leaves have a leading (B,) axis."""

    problem: AllocationProblem
    n_true: np.ndarray          # (B,) original variable counts
    m_true: np.ndarray          # (B,) original resource counts
    p_true: np.ndarray          # (B,) original provider counts

    @property
    def B(self) -> int:
        return self.problem.c.shape[0]

    @property
    def n_max(self) -> int:
        return self.problem.c.shape[1]


def _pad2(a: np.ndarray, rows: int, cols: int) -> np.ndarray:
    out = np.zeros((rows, cols), np.float32)
    out[: a.shape[0], : a.shape[1]] = a
    return out


def _pad1(a: np.ndarray, size: int, fill: float = 0.0) -> np.ndarray:
    out = np.full((size,), fill, np.float32)
    out[: a.shape[0]] = a
    return out


def stack_problems(problems: Sequence[AllocationProblem],
                   n_max: Optional[int] = None,
                   m_max: Optional[int] = None,
                   p_max: Optional[int] = None) -> FleetBatch:
    """Stack ragged problems into one padded batch problem."""
    assert len(problems) > 0, "empty fleet"
    ns = [int(pb.n) for pb in problems]
    ms = [int(pb.m) for pb in problems]
    ps = [int(pb.p) for pb in problems]
    n_max = n_max or max(ns)
    m_max = m_max or max(ms)
    p_max = p_max or max(ps)
    assert n_max >= max(ns) and m_max >= max(ms) and p_max >= max(ps)

    K, E, c, d, mu, g, lb, ub, mask = ([] for _ in range(9))
    par: List[PenaltyParams] = []
    for pb in problems:
        K.append(_pad2(np.asarray(pb.K, np.float32), m_max, n_max))
        E.append(_pad2(np.asarray(pb.E, np.float32), p_max, n_max))
        c.append(_pad1(np.asarray(pb.c, np.float32), n_max))
        d.append(_pad1(np.asarray(pb.d, np.float32), m_max))
        # padded rows: band [-1, 1] around Kx = 0 — strictly interior
        mu.append(_pad1(np.asarray(pb.mu, np.float32), m_max, fill=1.0))
        g.append(_pad1(np.asarray(pb.g, np.float32), m_max, fill=1.0))
        lb.append(_pad1(np.asarray(pb.lb, np.float32), n_max))
        ub.append(_pad1(np.asarray(pb.ub, np.float32), n_max))
        mask.append(_pad1(np.asarray(pb.mask, np.float32), n_max))
        par.append(pb.params)

    params = PenaltyParams(*(jnp.stack([jnp.asarray(getattr(p, f), jnp.float32)
                                        for p in par])
                             for f in PenaltyParams._fields))
    stacked = AllocationProblem(
        K=jnp.asarray(np.stack(K)), E=jnp.asarray(np.stack(E)),
        c=jnp.asarray(np.stack(c)), d=jnp.asarray(np.stack(d)),
        mu=jnp.asarray(np.stack(mu)), g=jnp.asarray(np.stack(g)),
        params=params,
        lb=jnp.asarray(np.stack(lb)), ub=jnp.asarray(np.stack(ub)),
        mask=jnp.asarray(np.stack(mask)))
    return FleetBatch(problem=stacked,
                      n_true=np.asarray(ns, np.int64),
                      m_true=np.asarray(ms, np.int64),
                      p_true=np.asarray(ps, np.int64))


def unstack_solution(batch: FleetBatch, X) -> List[np.ndarray]:
    """Slice a padded (B, n_max) solution back into per-tenant vectors."""
    X = np.asarray(X)
    return [X[b, : batch.n_true[b]].copy() for b in range(batch.B)]


def embed_solutions(batch: FleetBatch, xs: Sequence[np.ndarray]) -> np.ndarray:
    """Inverse of unstack_solution: per-tenant vectors -> padded (B, n_max)."""
    out = np.zeros((batch.B, batch.n_max), np.float32)
    for b, x in enumerate(xs):
        out[b, : len(x)] = x
    return out

"""Stack heterogeneous AllocationProblems into one padded, masked batch.

Tenant problems are ragged: different catalog sizes (n), resource counts (m)
and provider counts (p). ``stack_problems`` pads every leaf to the fleet
maximum and stacks, so the whole fleet is ONE AllocationProblem whose leaves
carry a leading (B,) axis — directly consumable by vmap'd core-solver
internals and by the batched Pallas objective kernel.

Padding is EXACT, not approximate:

  * padded variables get mask=0, lb=ub=0, c=0 and all-zero K/E columns, so
    projection pins them to 0 and they contribute nothing to any term;
  * padded constraint rows get d=0, mu=g=1 and an all-zero K row, so their
    residual band is -1 <= 0 <= 1: strictly interior (log-barrier term
    log(1)=0) and never violated;
  * padded provider rows are all-zero in E, so 1 - exp(-b1*(Ex=0)) = 0 — the
    consolidation and volume-discount sums are unchanged;
  * attached scenario terms (``prob.terms``) stack on the UNION of the
    batch's term kinds: params pad along their declared axis ("" scalar /
    "n" / "m" — see ``repro.core.terms``), and tenants missing a kind get
    all-zero params. Every registered term is linear in its params and
    hinges at zero on padded rows, so a zero-priced term contributes
    exactly 0.0 value and zero gradient — stacking stays exact.

Hence objective(padded, embed(x)) == objective(original, x) exactly, and a
solve on the stacked batch is equivalent to B independent solves.

For very heterogeneous fleets a single global pad is wasteful: one tenant
with n=120 forces every tenant to n=120. ``bucket_problems`` instead groups
tenants into power-of-two shape buckets (8/16/32/... on n, similarly on m and
p), stacks one FleetBatch per bucket, and remembers the original tenant order
so per-bucket results can be scattered back losslessly. Bucket pad sizes are
rounded up to the bucket's power-of-two dims — stable across calls, so XLA
compiles at most one program per occupied bucket however the fleet changes.

See docs/fleet.md for the full set of stacking/padding invariants.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import AllocationProblem, PenaltyParams
from repro.core.terms import TERM_DEFS, PricedTerm
from repro.obs.telemetry import current_recorder


class FleetBatch(NamedTuple):
    """A stacked fleet. ``problem`` leaves have a leading (B,) axis.

    ``active`` is the per-tenant liveness mask for ragged-horizon replays:
    ``None`` (the default) means every row is live; a (B,) bool array marks
    rows whose trace has expired as frozen. Frozen rows still occupy their
    batch lane (shapes — hence compiled programs — never change), but
    :func:`repro.fleet.solver.solve_fleet_step` returns their warm start
    untouched instead of a new solution."""

    problem: AllocationProblem
    n_true: np.ndarray          # (B,) original variable counts
    m_true: np.ndarray          # (B,) original resource counts
    p_true: np.ndarray          # (B,) original provider counts
    active: Optional[np.ndarray] = None   # (B,) bool liveness mask (None: all)

    @property
    def B(self) -> int:
        return self.problem.c.shape[0]

    @property
    def n_max(self) -> int:
        return self.problem.c.shape[1]

    @property
    def active_mask(self) -> np.ndarray:
        """The (B,) liveness mask, materialized (all-true when unset)."""
        if self.active is None:
            return np.ones(self.B, bool)
        return np.asarray(self.active, bool)


def _pad2(a: np.ndarray, rows: int, cols: int) -> np.ndarray:
    out = np.zeros((rows, cols), np.float32)
    out[: a.shape[0], : a.shape[1]] = a
    return out


def _pad1(a: np.ndarray, size: int, fill: float = 0.0) -> np.ndarray:
    out = np.full((size,), fill, np.float32)
    out[: a.shape[0]] = a
    return out


def union_term_kinds(problems: Sequence[AllocationProblem]) -> Tuple[str, ...]:
    """The union of attached term kinds across ``problems``, in first-
    appearance order — the batch-level term signature stacking uses."""
    kinds: List[str] = []
    for pb in problems:
        for t in pb.terms:
            if t.kind not in kinds:
                kinds.append(t.kind)
    return tuple(kinds)


def _term_pad_shape(axis: str, n_max: int, m_max: int) -> Tuple[int, ...]:
    return {"": (), "n": (n_max,), "m": (m_max,)}[axis]


def _stack_terms(problems: Sequence[AllocationProblem],
                 kinds: Tuple[str, ...], n_max: int,
                 m_max: int) -> Tuple[PricedTerm, ...]:
    """Stack each union kind's params with a leading (B,) axis: params pad
    along their declared axis; tenants without the kind get zeros (an exact
    no-op — every term is zero-valued with zero grad at zero params)."""
    out = []
    for kind in kinds:
        axes = TERM_DEFS[kind].param_axes
        per_param: Dict[str, List[np.ndarray]] = {k: [] for k in axes}
        for pb in problems:
            present = {t.kind: t for t in pb.terms}
            for k, ax in axes.items():
                if kind in present:
                    a = np.asarray(present[kind].params[k], np.float32)
                    if ax != "":
                        a = _pad1(a, n_max if ax == "n" else m_max)
                else:
                    a = np.zeros(_term_pad_shape(ax, n_max, m_max),
                                 np.float32)
                per_param[k].append(a)
        out.append(PricedTerm(kind, {k: jnp.asarray(np.stack(v))
                                     for k, v in per_param.items()}))
    return tuple(out)


def stack_problems(problems: Sequence[AllocationProblem],
                   n_max: Optional[int] = None,
                   m_max: Optional[int] = None,
                   p_max: Optional[int] = None,
                   active: Optional[np.ndarray] = None,
                   term_kinds: Optional[Tuple[str, ...]] = None) -> FleetBatch:
    """Stack ragged problems into one padded batch problem.

    ``active`` optionally attaches a (B,) per-tenant liveness mask (see
    :class:`FleetBatch`); stacking itself treats live and frozen tenants
    identically.

    ``term_kinds`` forces the stacked term signature (default: the union of
    the problems' attached kinds, first-appearance order). The batched MPC
    replay uses it to stack every tenant's window with the BUCKET's union
    signature so the per-tenant stacks share one treedef; kinds a tenant
    lacks get zero params — an exact no-op by the registry's
    zero-at-zero-params contract.

    When a telemetry recorder is installed (``repro.obs``), each stacking
    samples the ``stack/padding_waste`` gauge — the fraction of K-matrix
    cells this batch spends on padding (the per-tick series behind the
    ``ReplayReport`` padding numbers). Pure measurement: the stacked batch
    is byte-identical with telemetry on or off."""
    assert len(problems) > 0, "empty fleet"
    if active is not None:
        active = np.asarray(active, bool)
        assert active.shape == (len(problems),), active.shape
    ns = [int(pb.n) for pb in problems]
    ms = [int(pb.m) for pb in problems]
    ps = [int(pb.p) for pb in problems]
    n_max = n_max or max(ns)
    m_max = m_max or max(ms)
    p_max = p_max or max(ps)
    assert n_max >= max(ns) and m_max >= max(ms) and p_max >= max(ps)

    K, E, c, d, mu, g, lb, ub, mask = ([] for _ in range(9))
    par: List[PenaltyParams] = []
    for pb in problems:
        K.append(_pad2(np.asarray(pb.K, np.float32), m_max, n_max))
        E.append(_pad2(np.asarray(pb.E, np.float32), p_max, n_max))
        c.append(_pad1(np.asarray(pb.c, np.float32), n_max))
        d.append(_pad1(np.asarray(pb.d, np.float32), m_max))
        # padded rows: band [-1, 1] around Kx = 0 — strictly interior
        mu.append(_pad1(np.asarray(pb.mu, np.float32), m_max, fill=1.0))
        g.append(_pad1(np.asarray(pb.g, np.float32), m_max, fill=1.0))
        lb.append(_pad1(np.asarray(pb.lb, np.float32), n_max))
        ub.append(_pad1(np.asarray(pb.ub, np.float32), n_max))
        mask.append(_pad1(np.asarray(pb.mask, np.float32), n_max))
        par.append(pb.params)

    params = PenaltyParams(*(jnp.stack([jnp.asarray(getattr(p, f), jnp.float32)
                                        for p in par])
                             for f in PenaltyParams._fields))
    kinds = (union_term_kinds(problems) if term_kinds is None
             else tuple(term_kinds))
    stacked = AllocationProblem(
        K=jnp.asarray(np.stack(K)), E=jnp.asarray(np.stack(E)),
        c=jnp.asarray(np.stack(c)), d=jnp.asarray(np.stack(d)),
        mu=jnp.asarray(np.stack(mu)), g=jnp.asarray(np.stack(g)),
        params=params,
        lb=jnp.asarray(np.stack(lb)), ub=jnp.asarray(np.stack(ub)),
        mask=jnp.asarray(np.stack(mask)),
        terms=_stack_terms(problems, kinds, n_max, m_max))
    rec = current_recorder()
    if rec is not None:
        true_cells = sum(n * m for n, m in zip(ns, ms))
        rec.gauge("stack/padding_waste",
                  1.0 - true_cells / (len(problems) * n_max * m_max))
    return FleetBatch(problem=stacked,
                      n_true=np.asarray(ns, np.int64),
                      m_true=np.asarray(ms, np.int64),
                      p_true=np.asarray(ps, np.int64),
                      active=active)


def unstack_solution(batch: FleetBatch, X) -> List[np.ndarray]:
    """Slice a padded (B, n_max) solution back into per-tenant vectors."""
    X = np.asarray(X)
    return [X[b, : batch.n_true[b]].copy() for b in range(batch.B)]


def embed_solutions(batch: FleetBatch, xs: Sequence[np.ndarray]) -> np.ndarray:
    """Inverse of unstack_solution: per-tenant vectors -> padded (B, n_max)."""
    out = np.zeros((batch.B, batch.n_max), np.float32)
    for b, x in enumerate(xs):
        out[b, : len(x)] = x
    return out


def tenant_problem(batch: FleetBatch, b: int) -> AllocationProblem:
    """Recover tenant ``b``'s ORIGINAL (unpadded) problem from the batch.

    Padding only appends rows/columns, so slicing the true leading extents
    back out reproduces the pre-stacking problem exactly (bit-for-bit).
    Terms carry the BATCH's union signature: a tenant that lacked one of
    the batch's kinds comes back with that kind at zero params — an exact
    objective/gradient no-op, not a numeric perturbation."""
    n = int(batch.n_true[b])
    m = int(batch.m_true[b])
    p = int(batch.p_true[b])
    pb = batch.problem

    def _slice_param(a, axis):
        if axis == "":
            return a[b]
        return a[b, :n] if axis == "n" else a[b, :m]

    terms = tuple(
        PricedTerm(t.kind,
                   {k: _slice_param(t.params[k], ax)
                    for k, ax in TERM_DEFS[t.kind].param_axes.items()})
        for t in pb.terms)
    return AllocationProblem(
        K=pb.K[b, :m, :n], E=pb.E[b, :p, :n], c=pb.c[b, :n], d=pb.d[b, :m],
        mu=pb.mu[b, :m], g=pb.g[b, :m],
        params=jax.tree_util.tree_map(lambda a: a[b], pb.params),
        lb=pb.lb[b, :n], ub=pb.ub[b, :n], mask=pb.mask[b, :n], terms=terms)


# ---------------------------------------------------------------------------
# shape-bucketed stacking
# ---------------------------------------------------------------------------


def ceil_pow2(v: int, floor: int = 1) -> int:
    """Smallest power-of-two multiple of ``floor`` that is >= v."""
    r = max(int(floor), 1)
    while r < v:
        r *= 2
    return r


def bucket_dims(n: int, m: int, p: int, *,
                n_floor: int = 8, m_floor: int = 2,
                p_floor: int = 2) -> Tuple[int, int, int]:
    """The padded (n, m, p) bucket a problem of true shape (n, m, p) lands in.

    Powers of two (with small floors so tiny problems share one bucket) bound
    the number of distinct compiled shapes at O(log(max_dim)^3) while keeping
    per-tenant padding waste below 2x per axis."""
    return (ceil_pow2(n, n_floor), ceil_pow2(m, m_floor), ceil_pow2(p, p_floor))


class BucketedFleet(NamedTuple):
    """A fleet split into shape buckets.

    ``batches[i]`` is the stacked FleetBatch of bucket ``i`` (padded to that
    bucket's power-of-two dims); ``tenant_idx[i]`` holds the ORIGINAL fleet
    indices of its tenants, in their original relative order. Concatenating
    ``tenant_idx`` is always a permutation of ``range(B)``."""

    batches: List[FleetBatch]
    tenant_idx: List[np.ndarray]

    @property
    def B(self) -> int:
        return sum(len(idx) for idx in self.tenant_idx)

    @property
    def n_buckets(self) -> int:
        return len(self.batches)


def bucket_problems(problems: Sequence[AllocationProblem], *,
                    n_floor: int = 8, m_floor: int = 2,
                    p_floor: int = 2) -> BucketedFleet:
    """Group ragged problems into power-of-two shape buckets and stack each.

    Returns a BucketedFleet; use :func:`scatter_from_buckets` to restore
    per-bucket results to the original tenant order. Buckets are emitted in
    ascending shape order so the mapping is deterministic."""
    assert len(problems) > 0, "empty fleet"
    groups: Dict[Tuple[int, int, int], List[int]] = {}
    for b, pb in enumerate(problems):
        key = bucket_dims(int(pb.n), int(pb.m), int(pb.p), n_floor=n_floor,
                          m_floor=m_floor, p_floor=p_floor)
        groups.setdefault(key, []).append(b)
    batches, idxs = [], []
    for key in sorted(groups):
        members = groups[key]
        n_pad, m_pad, p_pad = key
        batches.append(stack_problems([problems[b] for b in members],
                                      n_max=n_pad, m_max=m_pad, p_max=p_pad))
        idxs.append(np.asarray(members, np.int64))
    return BucketedFleet(batches=batches, tenant_idx=idxs)


def scatter_from_buckets(bucketed: BucketedFleet,
                         rows_per_bucket: Sequence[Sequence]) -> List:
    """Restore per-bucket, per-tenant rows to the original fleet order.

    ``rows_per_bucket[i]`` must hold one entry per tenant of bucket ``i`` (in
    the bucket's order). The inverse of the permutation ``bucket_problems``
    applied — a round trip is exact for any payload type."""
    out: List = [None] * bucketed.B
    for idx, rows in zip(bucketed.tenant_idx, rows_per_bucket):
        assert len(rows) == len(idx), (len(rows), len(idx))
        for i, b in enumerate(idx):
            out[int(b)] = rows[i]
    return out


def padding_stats(problems: Sequence[AllocationProblem],
                  bucketed: Optional[BucketedFleet] = None) -> Dict[str, float]:
    """Padding-waste accounting for a stacking strategy.

    Counts K-matrix cells (the dominating leaf, m*n per tenant): ``true``
    cells carry real data, ``padded`` is what gets allocated and computed on.
    With ``bucketed=None`` the global single-batch pad (stack_problems) is
    measured; otherwise the bucketed layout. ``waste_frac`` is the fraction
    of compute spent on padding."""
    true = float(sum(int(pb.m) * int(pb.n) for pb in problems))
    if bucketed is None:
        n_max = max(int(pb.n) for pb in problems)
        m_max = max(int(pb.m) for pb in problems)
        padded = float(len(problems) * m_max * n_max)
    else:
        padded = float(sum(
            len(idx) * batch.problem.K.shape[1] * batch.problem.K.shape[2]
            for idx, batch in zip(bucketed.tenant_idx, bucketed.batches)))
    return dict(true_cells=true, padded_cells=padded,
                waste_frac=1.0 - true / padded)

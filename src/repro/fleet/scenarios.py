"""Fleet scenario builders: the first three consumers of the priced-term
objective IR (``repro.core.terms`` — see docs/scenarios.md).

Each helper takes plain :class:`~repro.fleet.replay.TenantSpec` fleets and
returns NEW specs (``dataclasses.replace``; inputs are never mutated) with
the scenario's priced terms — and, for spot, the widened catalog plus the
seeded availability overlay — attached. The replay engines need no
scenario-specific code: terms ride on every tick's problem through
``InfrastructureOptimizationController.terms`` and the batched stacker,
and the spot overlay flows through ``TenantSpec.spot_idx`` /
``spot_availability``.

Price conventions: term prices live in SOLVER UNITS like every other
objective quantity. Demand normalization leaves catalog prices untouched,
so per-type prices (``priority_eviction``, ``spot_risk``) are in catalog
$/hr; the scalar ``slo_penalty`` price is $ per unit of NORMALIZED
shortage (demand is scaled to 1 per resource), i.e. roughly $ per
"fraction of a resource's demand left unserved, summed over resources".
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.catalog import Catalog, spot_catalog, spot_risk_prices
from repro.core.terms import make_term

from .replay import TenantSpec, default_ca_pools
from .traces import make_trace

# eviction-exposure weight per priority class: critical work is never
# evicted (no surcharge), batch work carries full expected-restart cost
PRIORITY_CLASSES: Dict[str, float] = {
    "critical": 0.0,
    "standard": 0.4,
    "batch": 1.0,
}


def with_slo_pricing(specs: Sequence[TenantSpec], price: float = 0.5,
                     ) -> List[TenantSpec]:
    """Attach a contractual SLO-credit price to every tenant: the
    ``slo_penalty`` term charges ``price`` per unit of unmet (normalized)
    demand, on top of the solver's soft shortage penalty — so the
    cost/SLO tradeoff is PRICED in $ instead of tuned via penalty weights.
    Raising ``price`` moves the replay along the cost/SLO frontier
    (``benchmarks/scenario_bench.py`` sweeps it)."""
    assert price >= 0.0, price
    term = make_term("slo_penalty", price=price)
    return [replace(s, terms=tuple(s.terms) + (term,)) for s in specs]


def _peak_total(spec: TenantSpec) -> float:
    """A tenant's peak total demand (per-resource peaks summed) — the same
    peak the CA baseline provisions for; used only as a relative
    contention weight, so mixed resource units are acceptable."""
    return float(np.asarray(spec.trace, np.float64).max(axis=0).sum())


def with_priority_classes(specs: Sequence[TenantSpec],
                          priorities: Sequence[str], *,
                          catalog: Catalog,
                          eviction_price: float = 0.15,
                          classes: Optional[Dict[str, float]] = None,
                          ) -> List[TenantSpec]:
    """Attach per-tenant ``priority_eviction`` terms from named priority
    classes (one class per spec, keys of ``classes`` /
    :data:`PRIORITY_CLASSES`).

    A tenant's surcharge prices its eviction exposure PER NODE: an
    eviction costs drain + reschedule + warm-up overhead roughly per node
    regardless of size, so the per-type price is the flat
    ``weight * eviction_price * pressure * median(c)`` on every type
    (``c`` the tenant's catalog hourly prices; a price ∝ c would be a
    uniform objective rescale that never moves the argmin). ``pressure``
    is the fleet's high-priority peak-demand share (fraction of the
    fleet's summed peak demand owned by weight-0 tenants) — low-priority
    capacity is only at risk to the extent protected work can claim it.
    Critical tenants get no term (weight 0 would be an exact no-op
    anyway); batch tenants feel consolidation pressure — fewer, larger
    nodes expose fewer eviction targets."""
    classes = PRIORITY_CLASSES if classes is None else classes
    if len(priorities) != len(specs):
        raise ValueError(f"got {len(priorities)} priorities for "
                         f"{len(specs)} tenant specs")
    weights = []
    for p in priorities:
        try:
            weights.append(float(classes[p]))
        except KeyError:
            raise ValueError(f"unknown priority class {p!r}; choose from "
                             f"{sorted(classes)}") from None
    peaks = np.asarray([_peak_total(s) for s in specs])
    protected = np.asarray([w == 0.0 for w in weights])
    pressure = float(peaks[protected].sum() / max(peaks.sum(), 1e-9))
    out: List[TenantSpec] = []
    for spec, w in zip(specs, weights):
        if w == 0.0 or pressure == 0.0:
            out.append(replace(spec))
            continue
        c = (spec.catalog or catalog).matrices()[2]
        per_node = w * eviction_price * pressure * float(np.median(c))
        term = make_term("priority_eviction",
                         price=np.full(len(c), per_node, np.float32))
        out.append(replace(spec, terms=tuple(spec.terms) + (term,)))
    return out


def make_spot_fleet(catalog: Catalog, specs: Sequence[TenantSpec], *,
                    discount: float = 0.7,
                    interruption_rate: float = 0.08,
                    mean_outage: float = 3.0,
                    penalty_hours: float = 2.0,
                    seed: int = 0,
                    ) -> Tuple[Catalog, List[TenantSpec]]:
    """Widen the fleet onto a spot market: returns ``(spot_cat, specs)``
    where ``spot_cat`` appends a spot twin of every type at the true
    discounted price (:func:`~repro.core.catalog.spot_catalog`) and every
    spec gets (1) a ``spot_risk`` term pricing the expected interruption
    cost on the twins (:func:`~repro.core.catalog.spot_risk_prices` at
    ``interruption_rate``/``penalty_hours``), and (2) its own seeded
    ``spot_interruption`` availability overlay (``seed + tenant index`` —
    pools fail independently per tenant) that the controller applies per
    tick by zeroing interrupted twins' capacity. Tenants keeping an
    ``allowed_idx`` also get their types' spot twins allowed. Tenants
    without an explicit ``ca_pool_idx`` get one pinned to the ON-DEMAND
    catalog's default pools (indices are unchanged by twin appending), so
    the CA baseline stays the spot-blind operator status quo instead of
    scheduling on interruption-free discounted twins.

    Per-tenant catalog overrides are not supported (the twins must index
    into the shared fleet catalog for the overlay to line up)."""
    for spec in specs:
        if spec.catalog is not None:
            raise ValueError(
                f"TenantSpec {spec.name!r} has a per-tenant catalog; "
                f"make_spot_fleet requires the shared fleet catalog so "
                f"spot-twin indices line up across the fleet")
    spot_cat, spot_idx = spot_catalog(catalog, discount=discount)
    risk = spot_risk_prices(spot_cat, spot_idx, rate=interruption_rate,
                            penalty_hours=penalty_hours)
    term = make_term("spot_risk", risk=risk)
    out: List[TenantSpec] = []
    for i, spec in enumerate(specs):
        T = int(np.asarray(spec.trace).shape[0])
        avail = make_trace("spot_interruption", np.ones(len(spot_idx)), T,
                           seed=seed + i, rate=interruption_rate,
                           mean_outage=mean_outage)
        allowed = spec.allowed_idx
        if allowed is not None:
            allowed = np.asarray(allowed, np.int64)
            allowed = np.unique(np.concatenate([allowed, spot_idx[allowed]]))
        ca_pools = spec.ca_pool_idx
        if ca_pools is None:
            ca_pools = default_ca_pools(
                catalog, np.asarray(spec.trace, np.float64).max(axis=0))
        out.append(replace(spec, allowed_idx=allowed, ca_pool_idx=ca_pools,
                           terms=tuple(spec.terms) + (term,),
                           spot_idx=spot_idx, spot_availability=avail))
    return spot_cat, out

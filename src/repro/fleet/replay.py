"""Trace-driven replay: step every tenant's controller through its demand
trace, and (optionally) run the Cluster-Autoscaler baseline on the SAME
traces — the SLO/cost evaluation loop the static paper scenarios lack.

The optimizer side uses the production control loop
(InfrastructureOptimizationController): warm-started incremental solves with
bounded churn. The CA side carries its node counts tick to tick exactly like
the real autoscaler (scale-up on unschedulable demand, utilization-gated
scale-down).

Two replay engines drive the optimizer side (``replay_mode``):

* ``"sequential"`` — the reference loop: one controller solve per tenant per
  tick. Pays one XLA program dispatch (and, for ragged fleets, one compile
  per distinct tenant shape) per tenant per tick.
* ``"batched"`` — the fleet engine: tenants are grouped into power-of-two
  shape buckets (``repro.fleet.batching.bucket_problems`` dims), and each
  tick runs ONE ``solve_fleet`` call per bucket for the cold start and ONE
  ``solve_fleet_step`` call per bucket for every warm tick, warm-started
  from the previous tick's batched solution. Per-tenant problems, starts,
  warm starts and churn bounds are identical to the sequential engine, so
  per-tenant integer allocations (hence objectives and metrics) match the
  sequential path on CPU — see tests/fleet/test_replay.py.

Traces may be RAGGED (different per-tenant lengths): the batched engine
keeps every tenant in its batch lane for the full fleet horizon but marks
expired tenants frozen via a per-tenant active mask (``FleetBatch.active``).
Frozen rows keep their last allocation as a fixed warm start, are returned
untouched by ``solve_fleet_step``, and contribute no further churn, cost or
SLO metrics — exactly like a sequential replay that simply stops stepping
that tenant at the end of its trace.

Controller state (counts, churn, history, metrics) lives in the SAME
per-tenant ``InfrastructureOptimizationController`` objects in both modes;
the batched engine just computes the counts centrally and feeds them back
via ``controller.apply_counts``. See docs/fleet.md for the full contract.

Both engines can also drive the receding-horizon controller
(``controller="mpc"``, ``repro.horizon``): each tick forecasts ``horizon``
ticks, solves one time-expanded convex program, and commits only tick 0.
The batched MPC engine issues one ``solve_horizon_fleet_step`` per shape
bucket per warm tick — the same grouping, cold start and ragged-horizon
freezing as the myopic batched engine. See docs/horizon.md.

The CA baseline sizes each tenant's node pools from the trace's PER-RESOURCE
PEAK demand (``trace.max(axis=0)``) — sizing from any single tick would hand
the baseline a pool set that cannot schedule the peak of a ramp or flash
crowd, producing structurally-unsatisfiable ticks that unfairly inflate
``cost_savings_vs_baseline_pct``. By default the whole baseline fleet is
replayed by the vectorized lockstep stepper
(``simulate_cluster_autoscaler_batch``, one tenant-batched numpy program per
tick per distinct catalog); ``ca_engine="sequential"`` keeps the per-tenant
oracle loop, and the two agree tick-for-tick.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autoscaler import (default_pools_for,
                                   simulate_cluster_autoscaler,
                                   simulate_cluster_autoscaler_batch)
from repro.core.catalog import Catalog
from repro.core.catalog import M as RESOURCE_DIM
from repro.core.controller import (ControllerStep,
                                   InfrastructureOptimizationController)
from repro.core.metrics import AllocationMetrics, evaluate
from repro.core.pgd import AnytimeConfig
from repro.core.problem import PenaltyParams
from repro.obs import metrics as obs_metrics
from repro.obs.health import HealthMonitor
from repro.obs.telemetry import gauge, span

from .batching import (bucket_dims, embed_solutions, stack_problems,
                       union_term_kinds)
from .metrics import FleetReplayMetrics, TenantReplayMetrics, tenant_metrics
from .solver import make_fleet_starts, solve_fleet, solve_fleet_step


@dataclass
class TenantSpec:
    """One tenant cluster: a demand trace plus its controller knobs.

    The trace is validated at construction (2-D, at least one tick, and its
    resource columns matching the catalog's resource dim) so a malformed
    spec fails HERE with a clear message instead of deep inside the solver
    with an opaque broadcast error."""

    name: str
    trace: np.ndarray                            # (T, m) demand per tick
    delta_max: float = 8.0                       # max L1 churn per tick
    n_starts: int = 4
    params: Optional[PenaltyParams] = None
    allowed_idx: Optional[np.ndarray] = None     # approved instance types
    catalog: Optional[Catalog] = None            # overrides the fleet catalog
    ca_pool_idx: Optional[np.ndarray] = None     # CA node pools (default: the
                                                 # cheapest covering types)
    # scenario surface (repro.core.terms / docs/scenarios.md): ``terms`` is a
    # static tuple of scenario-term specs (PricedTerm or (kind, params))
    # attached to every tick's problem; the spot pair drives the per-tick
    # availability overlay — ``spot_availability`` row t zeroes the
    # interrupted ``spot_idx`` types' capacity (mask/ub/lb) for that tick.
    terms: tuple = ()
    spot_idx: Optional[np.ndarray] = None        # (S,) catalog spot-twin idx
    spot_availability: Optional[np.ndarray] = None   # (T', S) in {0, 1}

    def __post_init__(self) -> None:
        """Fail fast on malformed traces (see class docstring)."""
        trace = np.asarray(self.trace)
        if trace.ndim != 2:
            raise ValueError(
                f"TenantSpec {self.name!r}: trace must be a 2-D (T, m) array "
                f"of per-tick demand, got shape {trace.shape}")
        if trace.shape[0] < 1:
            raise ValueError(
                f"TenantSpec {self.name!r}: trace must have at least one "
                f"tick, got shape {trace.shape}")
        # every Catalog lowers to K with one row per RESOURCES entry; a
        # tenant catalog (if any) decides, else the fleet catalog — both
        # share the global resource convention
        m = (self.catalog.matrices()[0].shape[0]
             if self.catalog is not None else RESOURCE_DIM)
        if trace.shape[1] != m:
            raise ValueError(
                f"TenantSpec {self.name!r}: trace has {trace.shape[1]} "
                f"resource columns but the catalog's resource dim is {m} "
                f"(demand rows must be ordered like "
                f"repro.core.catalog.RESOURCES)")
        if (self.spot_idx is None) != (self.spot_availability is None):
            raise ValueError(
                f"TenantSpec {self.name!r}: spot_idx and spot_availability "
                f"must be given together (the availability overlay needs "
                f"both the spot-twin indices and their on/off trace)")
        if self.spot_availability is not None:
            avail = np.asarray(self.spot_availability)
            n_spot = len(np.asarray(self.spot_idx))
            if avail.ndim != 2 or avail.shape[1] != n_spot:
                raise ValueError(
                    f"TenantSpec {self.name!r}: spot_availability must be a "
                    f"2-D (T', S) array with S == len(spot_idx) == {n_spot}, "
                    f"got shape {avail.shape} (make it with "
                    f"make_trace('spot_interruption', ...))")


@dataclass
class TenantReplay:
    """One tenant's replayed history plus its aggregated metrics."""

    spec: TenantSpec
    steps: List[ControllerStep]
    metrics: TenantReplayMetrics
    ca_metrics: Optional[TenantReplayMetrics] = None
    ca_counts: Optional[np.ndarray] = None       # final CA allocation


@dataclass
class FleetReplayResult:
    """Everything a replay produced: per-tenant histories + fleet rollup.

    ``solver_traces`` is None unless the replay ran with
    ``capture_solver_trace=True``: one list per tenant holding that
    tenant's per-WARM-tick PGD convergence rows (``core.pgd.PGDTrace``,
    numpy leaves; cold ticks run the multistart solver, which is not
    traced). Both engines and both controllers fill it the same way — see
    ``repro.obs.solver_trace`` for the schema and analysis helpers."""

    tenants: List[TenantReplay]
    metrics: FleetReplayMetrics
    solver_traces: Optional[List[List]] = None


def default_ca_pools(catalog: Catalog, demand: np.ndarray,
                     k: int = 8) -> np.ndarray:
    """The k most cost-efficient single-type covers of ``demand`` — the node
    pools an operator would plausibly configure for this workload.

    For trace replays, ``demand`` must be the trace's PER-RESOURCE PEAK
    (``trace.max(axis=0)``), not a single tick: an operator provisions pools
    for the load they expect, and sizing from e.g. the first tick of a ramp
    leaves the baseline structurally unable to schedule the peak — phantom
    SLO violations that would inflate the optimizer's reported savings."""
    K, _, c = catalog.matrices()
    d = np.asarray(demand, np.float64)
    safe_K = np.where(K > 0, K, 1e-9)
    cover = np.max(d[:, None] / safe_K, axis=0)          # units of each type
    covers_all = np.all((K > 0) | (d[:, None] == 0), axis=0)
    cost = np.where(covers_all, cover * c, np.inf)
    order = np.argsort(cost)
    return order[: min(k, int(np.isfinite(cost).sum()))]


def _replay_ca(catalog: Catalog, spec: TenantSpec, pool_idx: np.ndarray,
               expander: str, mode: str):
    """Carry the Cluster-Autoscaler baseline tick to tick over one trace."""
    K, _, _ = catalog.matrices()
    counts_prev = np.zeros(catalog.n, np.float64)
    tick_metrics: List[AllocationMetrics] = []
    churns: List[float] = []
    for demand in np.asarray(spec.trace, np.float64):
        existing = {int(j): int(counts_prev[j])
                    for j in np.nonzero(counts_prev)[0]}
        pools = default_pools_for(catalog, pool_idx, existing=existing)
        res = simulate_cluster_autoscaler(catalog, pools, demand,
                                          expander=expander, mode=mode)
        churns.append(float(np.abs(res.counts - counts_prev).sum()))
        counts_prev = res.counts
        tick_metrics.append(evaluate(catalog, res.counts, demand))
    return tick_metrics, churns, counts_prev


def _ca_pool_idx(cat: Catalog, spec: TenantSpec) -> np.ndarray:
    """The tenant's CA node-pool types: explicit ``ca_pool_idx``, else pools
    sized from the trace's per-resource peak demand (the bugfixed default —
    see :func:`default_ca_pools`)."""
    if spec.ca_pool_idx is not None:
        return spec.ca_pool_idx
    return default_ca_pools(cat, np.asarray(spec.trace, np.float64).max(axis=0))


def _ca_baseline(catalog: Catalog, spec: TenantSpec, ca_expander: str,
                 ca_mode: str):
    """Run the sequential-oracle CA baseline for one tenant."""
    cat = spec.catalog or catalog
    tick_metrics, churns, ca_counts = _replay_ca(
        cat, spec, _ca_pool_idx(cat, spec), ca_expander, ca_mode)
    return tenant_metrics(f"{spec.name}/ca", tick_metrics, churns), ca_counts


def _replay_ca_fleet(catalog: Catalog, tenants: Sequence[TenantSpec],
                     expander: str, mode: str):
    """Vectorized CA baseline replay: carry ALL tenants' pool counts tick to
    tick at once.

    Tenants are grouped by (shared) catalog; each group advances through one
    :func:`simulate_cluster_autoscaler_batch` call per tick — the per-tick
    deficit/feasibility linear algebra is one numpy matmul over the group
    instead of a Python loop of per-tenant matvecs. Ragged traces are
    supported: a tenant leaves its group's active set when its trace ends.
    Results are tick-for-tick identical to the sequential per-tenant loop
    (``ca_engine="sequential"``), which stays the test oracle.

    Returns one ``(TenantReplayMetrics, final_counts)`` pair per tenant."""
    cats = [spec.catalog or catalog for spec in tenants]
    groups: Dict[int, List[int]] = {}
    for i, cat in enumerate(cats):
        groups.setdefault(id(cat), []).append(i)
    out: List = [None] * len(tenants)
    for idx in groups.values():
        cat = cats[idx[0]]
        traces = [np.asarray(tenants[i].trace, np.float64) for i in idx]
        pool_idx = [_ca_pool_idx(cat, tenants[i]) for i in idx]
        counts = np.zeros((len(idx), cat.n), np.float64)
        tick_metrics: List[List[AllocationMetrics]] = [[] for _ in idx]
        churns: List[List[float]] = [[] for _ in idx]
        for t in range(max(tr.shape[0] for tr in traces)):
            act = [k for k, tr in enumerate(traces) if t < tr.shape[0]]
            demands = np.stack([traces[k][t] for k in act])
            pools_t = []
            for k in act:
                existing = {int(j): int(counts[k, j])
                            for j in np.nonzero(counts[k])[0]}
                pools_t.append(default_pools_for(cat, pool_idx[k],
                                                 existing=existing))
            res = simulate_cluster_autoscaler_batch(cat, pools_t, demands,
                                                    expander=expander,
                                                    mode=mode)
            for k, r in zip(act, res):
                churns[k].append(float(np.abs(r.counts - counts[k]).sum()))
                counts[k] = r.counts
                tick_metrics[k].append(evaluate(cat, r.counts, traces[k][t]))
        for pos, i in enumerate(idx):
            out[i] = (tenant_metrics(f"{tenants[i].name}/ca",
                                     tick_metrics[pos], churns[pos]),
                      counts[pos].copy())
    return out


def _make_controller(catalog: Catalog, spec: TenantSpec
                     ) -> InfrastructureOptimizationController:
    return InfrastructureOptimizationController(
        catalog=spec.catalog or catalog, delta_max=spec.delta_max,
        params=spec.params, n_starts=spec.n_starts,
        allowed_idx=spec.allowed_idx, terms=spec.terms,
        spot_idx=spec.spot_idx, spot_availability=spec.spot_availability)


def _make_mpc_controller(catalog: Catalog, spec: TenantSpec, *, horizon: int,
                         forecaster: str, forecaster_kwargs: Optional[dict],
                         coupling_w: float, coupling_eps: float,
                         solver_steps: int, solver_config=None,
                         cold_start: str = "myopic"):
    """Build one tenant's receding-horizon controller (the MPC counterpart
    of :func:`_make_controller`); the forecaster gets the tenant's own trace
    so ``forecaster="oracle"`` reads that tenant's future. ``solver_config``
    (a ``repro.horizon.HorizonSolverConfig``) configures the per-tick
    engine; when None the controller builds one from ``solver_steps``.

    repro.horizon is imported lazily: it reuses ``repro.fleet.batching`` for
    window stacking, so a module-level import here would be circular."""
    from repro.horizon import ModelPredictiveController, make_forecaster
    fc = make_forecaster(forecaster,
                         trace=np.asarray(spec.trace, np.float64),
                         **(forecaster_kwargs or {}))
    return ModelPredictiveController(
        catalog=spec.catalog or catalog, delta_max=spec.delta_max,
        params=spec.params, n_starts=spec.n_starts,
        allowed_idx=spec.allowed_idx, terms=spec.terms,
        spot_idx=spec.spot_idx, spot_availability=spec.spot_availability,
        horizon=horizon, forecaster=fc,
        coupling_w=coupling_w, coupling_eps=coupling_eps,
        solver_steps=solver_steps, solver_config=solver_config,
        cold_start=cold_start)


def _assemble_replay(spec: TenantSpec, steps: List[ControllerStep],
                     ca: Optional[Tuple]) -> TenantReplay:
    """Roll one tenant's step history (plus a precomputed CA baseline
    ``(metrics, counts)`` pair, or None) into a TenantReplay — shared by
    both replay engines."""
    met = tenant_metrics(spec.name, [s.metrics for s in steps],
                         [s.churn for s in steps],
                         churn_violations=[s.churn_violation for s in steps],
                         solver_iters=[s.solver_iters for s in steps])
    ca_met, ca_counts = ca if ca is not None else (None, None)
    return TenantReplay(spec=spec, steps=steps, metrics=met,
                        ca_metrics=ca_met, ca_counts=ca_counts)


def replay_tenant(catalog: Catalog, spec: TenantSpec, *,
                  run_ca_baseline: bool = True,
                  ca_expander: str = "random",
                  ca_mode: str = "wave") -> TenantReplay:
    """Sequential reference replay of ONE tenant: a controller solve per tick
    plus (optionally) the CA baseline on the same trace."""
    ctl = _make_controller(catalog, spec)
    steps = [ctl.step(demand) for demand in np.asarray(spec.trace, np.float64)]
    ca = (_ca_baseline(catalog, spec, ca_expander, ca_mode)
          if run_ca_baseline else None)
    return _assemble_replay(spec, steps, ca)


def _spot_unavailable(spec: TenantSpec, t: int) -> int:
    """Number of this tenant's spot twins interrupted at tick ``t`` (the
    same clamped-row convention the controller's spot overlay uses)."""
    if spec.spot_idx is None or spec.spot_availability is None:
        return 0
    avail = np.asarray(spec.spot_availability)
    return int((avail[min(t, len(avail) - 1)] <= 0.0).sum())


class _TickObserver:
    """Shared per-tick observation plumbing for the three replay loops:
    decides once whether anything is watching (a :class:`HealthMonitor`
    and/or an installed ``repro.obs.metrics`` registry), times ticks with
    the monitor's injectable clock, and fans each tick's duration and
    iteration count out to both sinks. When nothing is watching, every
    method is a cheap no-op and NO clock is ever read — the engines'
    production paths are unchanged (the bit-identical on/off contract)."""

    __slots__ = ("health", "reg", "clock", "active", "_t0")

    def __init__(self, health: Optional[HealthMonitor]):
        self.health = health
        self.reg = obs_metrics.current_metrics()
        self.clock = health.clock if health is not None else time.perf_counter
        self.active = health is not None or self.reg is not None
        self._t0 = 0.0

    def tick_start(self) -> None:
        """Stamp the tick's start time (no-op when nothing watches)."""
        if self.active:
            self._t0 = self.clock()

    def tick_end(self, t: int, solver_iters: int,
                 compile_key=None) -> None:
        """Close the tick: duration to the latency histogram + deadline
        budget, iteration count to the effort histogram. ``compile_key``
        (the engine's tick-span compile key) lets the health monitor split
        first-sighting compile time out of the deadline budget instead of
        flagging every first warm tick after a jit cache miss as a miss."""
        if not self.active:
            return
        dur_ms = (self.clock() - self._t0) * 1e3
        if self.reg is not None:
            self.reg.histogram("replay/tick_ms").observe(dur_ms)
            self.reg.histogram("replay/solver_iters").observe(solver_iters)
        if self.health is not None:
            self.health.observe_tick(t, dur_ms, compile_key=compile_key)

    def step(self, **kw) -> None:
        """Forward one committed (tenant, tick) to the health monitor."""
        if self.health is not None:
            self.health.observe_step(**kw)


def _replay_sequential(ctls, tenants: Sequence[TenantSpec], controller: str,
                       capture_solver_trace: bool,
                       health: Optional[HealthMonitor] = None,
                       anytime: Optional[AnytimeConfig] = None):
    """The instrumented sequential loop shared by both controllers: one
    ``replay/tick`` span per (tenant, tick), warm ticks optionally tracing
    the solver through the controller's ``capture_solver_trace`` flag.
    Returns ``(histories, solver_traces)`` like the batched engines.

    With a :class:`HealthMonitor` attached, each (tenant, tick) is timed
    (per-TENANT tick — the sequential engine has no fleet tick) and
    observed: the tick's problem is built up front (``make_problem`` is
    pure and history has not advanced yet, so it is THE problem ``step``
    solves) and the controller's ``last_x_rel`` feeds the KKT gauge."""
    histories, solver_traces = [], []
    obs = _TickObserver(health)
    for ctl, spec in zip(ctls, tenants):
        ctl.capture_solver_trace = capture_solver_trace
        ctl.anytime = anytime
        steps = []
        for t, demand in enumerate(np.asarray(spec.trace, np.float64)):
            prob = ctl.make_problem(demand) if health is not None else None
            n_tr = len(ctl.solver_traces)
            obs.tick_start()
            # compile key: the cold (t=0) and warm programs compile
            # separately, per problem shape and per traced/untraced variant
            # (and per anytime on/off — the chunked driver is its own program)
            tick_key = ("seq_tick", controller, ctl.catalog.n, t > 0,
                        capture_solver_trace,
                        anytime is not None and anytime.enabled)
            with span("replay/tick", cat="replay", tick=t,
                      engine="sequential", controller=controller,
                      tenant=spec.name, compile_key=tick_key):
                step = ctl.step(demand)
                steps.append(step)
            obs.tick_end(t, step.solver_iters, compile_key=tick_key)
            gauge("replay/solver_iters", step.solver_iters)
            solver = ("multistart" if step.replanned
                      else ctl.solver_config.solver if controller == "mpc"
                      else "adaptive")
            obs.step(tenant=spec.name, tick=t, step=step, solver=solver,
                     prob=prob, x_rel=ctl.last_x_rel,
                     trace=(ctl.solver_traces[-1]
                            if len(ctl.solver_traces) > n_tr else None),
                     spot_unavailable=_spot_unavailable(spec, t))
        histories.append(steps)
        solver_traces.append(list(ctl.solver_traces))
    return histories, solver_traces


# ---------------------------------------------------------------------------
# batched fleet engine
# ---------------------------------------------------------------------------


def _replay_batch_groups(ctls: Sequence[InfrastructureOptimizationController],
                         tenants: Sequence[TenantSpec]
                         ) -> Dict[Tuple, List[int]]:
    """Group tenant indices by (shape bucket, n_starts).

    Tenant shapes are tick-invariant (the catalog fixes (n, m, p); demand
    normalization rescales K but never reshapes it), so grouping happens once
    per replay. ``n_starts`` joins the key because cold-start stacking needs
    a uniform (B, S, n) start tensor per group."""
    groups: Dict[Tuple, List[int]] = {}
    for b, (ctl, spec) in enumerate(zip(ctls, tenants)):
        cat = ctl.catalog
        key = bucket_dims(cat.n, len(cat.matrices()[0]),
                          len(cat.providers)) + (spec.n_starts,)
        groups.setdefault(key, []).append(b)
    return groups


def _replay_fleet_batched(catalog: Catalog, tenants: Sequence[TenantSpec], *,
                          warm_start: str = "counts",
                          solver_steps: int = 600,
                          hot_loop: Optional[str] = None,
                          capture_solver_trace: bool = False,
                          health: Optional[HealthMonitor] = None,
                          anytime: Optional[AnytimeConfig] = None):
    """Step ALL tenants through their traces with one batched solve per shape
    bucket per tick. Returns ``(histories, solver_traces)``: per-tenant step
    histories (controller objects hold the same state the sequential engine
    would leave behind) and — with ``capture_solver_trace`` — each tenant's
    per-warm-tick PGD convergence rows (else empty lists).

    Horizons may be RAGGED: the fleet runs for ``max_b T_b`` ticks, and a
    tenant whose trace ends freezes in place. Its batch lane persists (so
    bucket shapes — and compiled programs — never change mid-replay), holding
    the last allocation as a fixed warm start; ``solve_fleet_step`` returns
    frozen rows untouched (``FleetBatch.active``), no ``apply_counts`` is
    recorded, and its history stops at exactly ``T_b`` steps — identical to
    a sequential replay of that tenant alone.

    Telemetry (``repro.obs``): each tick is a ``replay/tick`` span wrapping
    per-bucket ``replay/stack`` / ``replay/solve`` / ``replay/round`` spans;
    solve spans carry a compile key per (program, bucket shape) so first
    calls are tagged as compile time. A :class:`HealthMonitor` additionally
    observes every committed (tenant, tick) — counts, relaxed solution for
    the KKT gauge, trace for stall detection — and the FLEET tick's
    duration against the deadline budget. Spans, metrics and health only
    measure — allocations are bit-identical with observability on or off
    (test-enforced)."""
    assert warm_start in ("counts", "relaxed"), warm_start
    assert len(tenants) > 0, "empty fleet"
    traces = [np.asarray(spec.trace, np.float64) for spec in tenants]
    assert all(tr.shape[0] >= 1 for tr in traces), "empty trace"
    T_len = np.asarray([tr.shape[0] for tr in traces])

    ctls = [_make_controller(catalog, spec) for spec in tenants]
    groups = _replay_batch_groups(ctls, tenants)
    # previous tick's RELAXED batched solution per tenant (warm_start="relaxed")
    x_rel_prev: List[Optional[np.ndarray]] = [None] * len(tenants)
    # per-tenant problem of the CURRENT tick; frozen tenants keep their last
    # one so stacked shapes stay put (its solve result is discarded)
    probs: List = [None] * len(tenants)
    solver_traces: List[List] = [[] for _ in tenants]
    obs = _TickObserver(health)

    for t in range(int(T_len.max())):
        obs.tick_start()
        # ticks 0 (cold program) and 1 (warm program) each trigger an XLA
        # compile; min(t, 1) makes exactly those two first-seen (tagged
        # phase="compile"), so tick percentiles reflect steady state
        tick_key = ("tick", "batched", "myopic", min(t, 1))
        with span("replay/tick", cat="replay", tick=t, engine="batched",
                  controller="myopic", compile_key=tick_key):
            tick_iters = 0
            for b, ctl in enumerate(ctls):
                if t < T_len[b]:
                    probs[b] = ctl.make_problem(traces[b][t])
            for key, idx in sorted(groups.items()):
                n_pad, m_pad, p_pad, n_starts = key
                active = T_len[idx] > t                 # (Bk,) liveness
                if not active.any():
                    continue    # whole bucket expired: nothing left to solve
                with span("replay/stack", cat="replay", bucket=str(key)):
                    batch = stack_problems([probs[b] for b in idx],
                                           n_max=n_pad, m_max=m_pad,
                                           p_max=p_pad, active=active)
                if t == 0:
                    # cold start: one batched multistart solve for the
                    # bucket, per-tenant starts drawn at true shape (seed 0,
                    # as the sequential controller's multistart_solve does).
                    # Every tenant is live at t=0 (traces are non-empty).
                    with span("replay/solve", cat="replay", bucket=str(key),
                              compile_key=("solve_fleet", key, len(idx)),
                              cold=True) as sp:
                        starts = make_fleet_starts(batch, n_starts, seed=0)
                        res = solve_fleet(batch, starts=starts,
                                          hot_loop=hot_loop)
                        sp.fence(res.x_int)
                    X_int = np.asarray(res.x_int, np.float64)
                    lane_iters = np.zeros(len(idx), np.int64)
                    tick_iters += int(res.iters)
                    bucket_hit = False
                else:
                    X_cur = embed_solutions(
                        batch, [ctls[b].x_current for b in idx])
                    X_init = None
                    if (warm_start == "relaxed"
                            and x_rel_prev[idx[0]] is not None):
                        X_init = embed_solutions(
                            batch, [x_rel_prev[b] for b in idx])
                    delta = np.asarray([tenants[b].delta_max for b in idx],
                                       np.float32)
                    with span("replay/solve", cat="replay", bucket=str(key),
                              compile_key=("solve_fleet_step", key, len(idx),
                                           capture_solver_trace,
                                           anytime is not None
                                           and anytime.enabled)) as sp:
                        res = solve_fleet_step(
                            batch, X_cur, delta, x_init=X_init,
                            steps=solver_steps,
                            capture_trace=capture_solver_trace,
                            anytime=anytime)
                        sp.fence(res.x_int)
                    X_int = np.asarray(res.x_int, np.float64)
                    lane_iters = np.asarray(res.iters, np.int64)
                    tick_iters += int(lane_iters.sum())
                    bucket_hit = bool(res.deadline_hit or False)
                # only pay the relaxed-solution transfer when it will be
                # used (warm start or the health monitor's KKT gauge)
                X_rel = (np.asarray(res.x)
                         if warm_start == "relaxed" or health is not None
                         else None)
                # cold-start FleetSolveResult has no trace field; warm ticks
                # carry one only when capture_solver_trace asked for it
                batch_tr = getattr(res, "trace", None)
                lane_tr = (None if batch_tr is None
                           else [np.asarray(f) for f in batch_tr])
                with span("replay/round", cat="replay", bucket=str(key)):
                    for i, b in enumerate(idx):
                        if not active[i]:
                            continue  # frozen: no churn, no metrics, no state
                        n_true = int(batch.n_true[i])
                        step = ctls[b].apply_counts(
                            traces[b][t], X_int[i, :n_true],
                            replanned=(t == 0),
                            solver_iters=int(lane_iters[i]),
                            deadline_hit=bucket_hit)
                        tr_b = (None if lane_tr is None else
                                type(batch_tr)(*(f[i] for f in lane_tr)))
                        if tr_b is not None:
                            solver_traces[b].append(tr_b)
                        if X_rel is not None and warm_start == "relaxed":
                            x_rel_prev[b] = X_rel[i, :n_true]
                        obs.step(tenant=tenants[b].name, tick=t, step=step,
                                 solver=("multistart" if t == 0
                                         else "adaptive"),
                                 lane=i, prob=probs[b],
                                 x_rel=(None if X_rel is None
                                        else X_rel[i, :n_true]),
                                 trace=tr_b,
                                 spot_unavailable=_spot_unavailable(
                                     tenants[b], t))
            gauge("replay/solver_iters", tick_iters)
        obs.tick_end(t, tick_iters, compile_key=tick_key)
    return [ctl.history for ctl in ctls], solver_traces


def _replay_fleet_batched_mpc(catalog: Catalog, tenants: Sequence[TenantSpec],
                              *, horizon: int, forecaster: str,
                              forecaster_kwargs: Optional[dict],
                              coupling_w: float, coupling_eps: float,
                              solver_steps: int, solver_config=None,
                              cold_start: str = "myopic",
                              hot_loop: Optional[str] = None,
                              capture_solver_trace: bool = False,
                              health: Optional[HealthMonitor] = None,
                              anytime: Optional[AnytimeConfig] = None):
    """Batched receding-horizon replay: one ``solve_horizon_fleet_step``
    call per shape bucket per warm tick, the fleet analogue of
    ``ModelPredictiveController.step``. Returns ``(histories,
    solver_traces)`` exactly like :func:`_replay_fleet_batched`, and emits
    the same ``replay/*`` telemetry spans.

    Mirrors :func:`_replay_fleet_batched` exactly where the two overlap:
    the same (bucket, n_starts) grouping, the same ``solve_fleet`` cold
    start (the MPC cold tick IS the myopic cold tick — no allocation means
    no churn to plan around; with ``cold_start="window"`` the same solve's
    per-start rounded candidates are re-ranked by each tenant's whole
    window, exactly like the sequential controller), and the same
    ragged-horizon freezing. The warm tick stacks each live tenant's H-tick
    window (observed demand + forecasts) padded to its bucket's dims,
    solves all lanes in one jitted vmapped program (engine and budget from
    ``solver_config``), commits tick 0 via ``apply_counts`` with the lane's
    iteration count, and stores each lane's relaxed plan back on its
    controller for the next tick's shifted warm start. Per-tenant integer
    allocations match the sequential MPC engine on CPU (test-enforced),
    forecaster state included — forecasts depend only on the observed
    trace, never on solver output."""
    import jax
    import jax.numpy as jnp

    from repro.horizon import (HorizonProblem, select_window_candidate,
                               solve_horizon_fleet_step,
                               window_candidate_scores)

    assert len(tenants) > 0, "empty fleet"
    traces = [np.asarray(spec.trace, np.float64) for spec in tenants]
    T_len = np.asarray([tr.shape[0] for tr in traces])

    ctls = [_make_mpc_controller(catalog, spec, horizon=horizon,
                                 forecaster=forecaster,
                                 forecaster_kwargs=forecaster_kwargs,
                                 coupling_w=coupling_w,
                                 coupling_eps=coupling_eps,
                                 solver_steps=solver_steps,
                                 solver_config=solver_config,
                                 cold_start=cold_start)
            for spec in tenants]
    groups = _replay_batch_groups(ctls, tenants)
    # each live tenant's CURRENT window of per-tick problems; frozen tenants
    # keep their last one so stacked shapes stay put (results discarded)
    windows: List = [None] * len(tenants)
    solver_traces: List[List] = [[] for _ in tenants]
    obs = _TickObserver(health)
    solver_name = ctls[0].solver_config.solver

    for t in range(int(T_len.max())):
      obs.tick_start()
      # same compile-tick tagging rationale as the myopic engine above
      tick_key = ("tick", "batched", "mpc", min(t, 1))
      with span("replay/tick", cat="replay", tick=t, engine="batched",
                controller="mpc", compile_key=tick_key):
        tick_iters = 0
        for b, ctl in enumerate(ctls):
            if t < T_len[b]:
                windows[b] = ctl.window_problems(
                    ctl.window_demands(traces[b][t]))
        for key, idx in sorted(groups.items()):
            n_pad, m_pad, p_pad, n_starts = key
            active = T_len[idx] > t
            if not active.any():
                continue
            if t == 0:
                # cold start: identical to the myopic batched engine (and to
                # a sequential cold_start_counts call per tenant); with
                # cold_start="window" the SAME per-start rounded candidates
                # are re-ranked by each tenant's whole-window objective at
                # its true shape (matching the sequential controller's
                # cold_window_counts selection exactly)
                with span("replay/stack", cat="replay", bucket=str(key)):
                    batch = stack_problems([windows[b][0] for b in idx],
                                           n_max=n_pad, m_max=m_pad,
                                           p_max=p_pad, active=active)
                with span("replay/solve", cat="replay", bucket=str(key),
                          compile_key=("solve_fleet", key, len(idx)),
                          cold=True) as sp:
                    starts = make_fleet_starts(batch, n_starts, seed=0)
                    res = solve_fleet(batch, starts=starts, hot_loop=hot_loop)
                    sp.fence(res.x_int)
                tick_iters += int(res.iters)
                X_int = np.asarray(res.x_int, np.float64)
                X_rel = np.asarray(res.x) if health is not None else None
                cand_all = np.asarray(res.x_int_all, np.float64)
                feas_all = np.asarray(res.feas_int_all, bool)
                with span("replay/round", cat="replay", bucket=str(key)):
                    for i, b in enumerate(idx):
                        n_true = int(batch.n_true[i])
                        if cold_start == "window":
                            cands = cand_all[i, :, :n_true]
                            scores = window_candidate_scores(windows[b],
                                                             cands)
                            x = cands[select_window_candidate(scores,
                                                              feas_all[i])]
                        else:
                            x = X_int[i, :n_true]
                        step = ctls[b].apply_counts(traces[b][t], x,
                                                    replanned=True)
                        ctls[b].plan = np.tile(x, (horizon, 1))
                        obs.step(tenant=tenants[b].name, tick=t, step=step,
                                 solver="multistart", lane=i,
                                 prob=windows[b][0],
                                 x_rel=(None if X_rel is None
                                        else X_rel[i, :n_true]),
                                 spot_unavailable=_spot_unavailable(
                                     tenants[b], t))
                continue
            # warm tick: stack each tenant's H-tick window at the bucket's
            # pad dims, then one vmapped horizon solve for the whole bucket.
            # Every per-tenant stack is forced to the BUCKET's union term
            # signature (absent tenants get exact-no-op zero params) so the
            # window pytrees share one treedef and tree_map can batch them.
            with span("replay/stack", cat="replay", bucket=str(key)):
                kinds = union_term_kinds([windows[b][0] for b in idx])
                stacked = [stack_problems(windows[b], n_max=n_pad,
                                          m_max=m_pad, p_max=p_pad,
                                          term_kinds=kinds).problem
                           for b in idx]
                prob_bh = jax.tree_util.tree_map(
                    lambda *leaves: jnp.stack(leaves), *stacked)
                X_cur = np.zeros((len(idx), n_pad), np.float32)
                X_init = np.zeros((len(idx), horizon, n_pad), np.float32)
                for i, b in enumerate(idx):
                    n_true = ctls[b].catalog.n
                    X_cur[i, :n_true] = ctls[b].x_current
                    X_init[i, :, :n_true] = ctls[b].shifted_plan()
            delta = np.asarray([tenants[b].delta_max for b in idx],
                               np.float32)
            hp = HorizonProblem(
                problem=prob_bh,
                coupling_w=jnp.asarray(coupling_w, jnp.float32),
                coupling_eps=jnp.asarray(coupling_eps, jnp.float32))
            # every controller in the replay shares one resolved config
            # (built in __post_init__ when solver_config was None)
            with span("replay/solve", cat="replay", bucket=str(key),
                      compile_key=("solve_horizon_fleet_step", key, len(idx),
                                   horizon, capture_solver_trace,
                                   anytime is not None
                                   and anytime.enabled)) as sp:
                res = solve_horizon_fleet_step(
                    hp, X_cur, delta, x_init=X_init, active=active,
                    cfg=ctls[idx[0]].solver_config,
                    capture_trace=capture_solver_trace,
                    anytime=anytime)
                sp.fence(res.x_int)
            X_int = np.asarray(res.x_int, np.float64)
            plans = np.asarray(res.plan, np.float64)
            lane_iters = np.asarray(res.iters, np.int64)
            tick_iters += int(lane_iters.sum())
            bucket_hit = bool(res.deadline_hit or False)
            lane_tr = (None if res.trace is None
                       else [np.asarray(f) for f in res.trace])
            diag_np = (None if res.diag is None
                       else [np.asarray(f) for f in res.diag])
            with span("replay/round", cat="replay", bucket=str(key)):
                for i, b in enumerate(idx):
                    if not active[i]:
                        continue
                    n_true = ctls[b].catalog.n
                    step = ctls[b].apply_counts(
                        traces[b][t], X_int[i, :n_true], replanned=False,
                        solver_iters=int(lane_iters[i]),
                        deadline_hit=bucket_hit)
                    ctls[b].plan = plans[i, :, :n_true]
                    tr_b = (None if lane_tr is None else
                            type(res.trace)(*(f[i] for f in lane_tr)))
                    if tr_b is not None:
                        solver_traces[b].append(tr_b)
                    obs.step(tenant=tenants[b].name, tick=t, step=step,
                             solver=solver_name, lane=i,
                             prob=windows[b][0],
                             x_rel=plans[i, 0, :n_true], trace=tr_b,
                             diag=(None if diag_np is None else
                                   type(res.diag)(*(f[i]
                                                    for f in diag_np))),
                             spot_unavailable=_spot_unavailable(
                                 tenants[b], t))
        gauge("replay/solver_iters", tick_iters)
      obs.tick_end(t, tick_iters, compile_key=tick_key)
    return [ctl.history for ctl in ctls], solver_traces


def replay_fleet(catalog: Catalog, tenants: Sequence[TenantSpec], *,
                 replay_mode: str = "sequential",
                 controller: str = "myopic",
                 horizon: int = 8,
                 forecaster: str = "last_value",
                 forecaster_kwargs: Optional[dict] = None,
                 coupling_w: Optional[float] = None,
                 coupling_eps: Optional[float] = None,
                 solver_config=None,
                 cold_start: str = "myopic",
                 run_oracle_baseline: bool = False,
                 run_ca_baseline: bool = True,
                 ca_engine: str = "vectorized",
                 ca_expander: str = "random",
                 ca_mode: str = "wave",
                 warm_start: str = "counts",
                 solver_steps: int = 600,
                 hot_loop: Optional[str] = None,
                 capture_solver_trace: bool = False,
                 health: Optional[HealthMonitor] = None,
                 anytime: Optional[AnytimeConfig] = None) -> FleetReplayResult:
    """Replay every tenant; returns per-tenant histories + fleet aggregates.

    ``replay_mode`` selects the optimizer engine:

    * ``"sequential"`` (reference) — one controller solve per tenant per tick.
    * ``"batched"`` — one batched solve call per shape bucket per tick (see
      module docstring). Traces may have different per-tenant lengths:
      finished tenants freeze in their batch lane (``FleetBatch.active``)
      and stop accruing churn/metrics. Produces per-tenant integer
      allocations identical to the sequential engine on CPU, ragged
      horizons included.

    ``controller`` selects the control loop both engines drive:

    * ``"myopic"`` (reference) — the paper's §III.E loop: each tick solves
      for the CURRENT demand under the L1 churn bound.
    * ``"mpc"`` — the receding-horizon controller (``repro.horizon``):
      each tick forecasts ``horizon`` ticks with ``forecaster``
      (a ``repro.horizon.forecast`` registry kind; ``forecaster_kwargs``
      forwarded, the tenant's own trace supplied so ``"oracle"`` works),
      solves the time-expanded program with smoothed inter-tick churn
      coupling (``coupling_w`` / ``coupling_eps``, defaulting to
      ``repro.horizon.problem``'s tuned values), and commits tick 0.
      ``horizon=1`` with any forecaster reproduces the myopic controller's
      integer allocations exactly (test-enforced).

    ``solver_config`` (MPC only; a ``repro.horizon.HorizonSolverConfig``)
    configures every warm tick's horizon solve per replay — engine choice
    (``solver="adaptive"`` BB/Armijo ladder vs ``"fixed"`` step), iteration
    budget, tolerance, ladder parameters and penalty weights — instead of
    relying on module constants (when None, a default config is built from
    ``solver_steps``). ``cold_start`` (MPC only) selects the cold tick's
    candidate ranking: ``"myopic"`` (tick-0 merit, the default) or
    ``"window"`` (the same multistart candidates re-scored against each
    tenant's whole lookahead window — see ``repro.horizon.controller``).
    Both engines honor both knobs identically (equivalence holds).

    ``run_oracle_baseline`` (MPC only) additionally replays the SAME fleet
    and controller under the ground-truth oracle forecaster and attaches
    its metrics as ``FleetReplayMetrics.oracle`` — enabling
    ``regret_vs_oracle`` (what forecast error cost).

    ``warm_start`` (batched myopic mode only) picks the incremental solve's
    warm start: ``"counts"`` (the previous integer allocation — what the
    sequential controller uses) or ``"relaxed"`` (the previous tick's relaxed
    batched solution); the MPC controller always warm-starts from its
    shifted previous plan. ``solver_steps`` is the PGD iteration budget of
    each warm tick; the default 600 matches ``solve_incremental`` — required
    for engine equivalence. ``hot_loop`` forwards to :func:`solve_fleet`
    for the cold-start solve.

    ``ca_engine`` selects the baseline replay implementation (the baseline
    itself is always the same numpy CA simulation, pools sized from each
    trace's peak demand): ``"vectorized"`` (default) steps all tenants per
    tick through one :func:`simulate_cluster_autoscaler_batch` call per
    distinct catalog; ``"sequential"`` loops
    :func:`simulate_cluster_autoscaler` per tenant — the oracle the
    vectorized engine must match tick-for-tick.

    ``capture_solver_trace=True`` records every warm tick's PGD convergence
    rows and returns them as ``FleetReplayResult.solver_traces`` (both
    engines, both controllers; MPC requires the adaptive engine — the fixed
    loop has no ladder to trace). Traced solves compute bit-identical
    allocations; they are merely separately-compiled programs that also
    write the per-iteration log.

    Run a replay inside ``with repro.obs.telemetry() as rec:`` to collect
    per-tick/per-phase timing spans, then aggregate them with
    ``repro.obs.report.ReplayReport.from_recorder(rec)``. Without a
    recorder installed every instrumentation point is a no-op, and either
    way allocations, churn and metrics are bit-identical (test-enforced).

    ``health`` (a ``repro.obs.HealthMonitor``) attaches per-tick health
    monitoring to the optimizer replay: SLO-breach/churn-violation/spot-
    interruption counters, committed-tick KKT-residual gauges on the
    relaxed solutions, solver stall detection (from captured traces),
    non-finite guards and the observe-only per-tick deadline budget. The
    monitor's rolled-up ``HealthReport`` lands on
    ``FleetReplayMetrics.health`` (and in ``summary()``). Baselines are
    never monitored — the CA replay runs no solver and the oracle twin is
    a reference, not the system under observation. Run inside ``with
    repro.obs.collect_metrics() as reg:`` to additionally fill
    ``replay/tick_ms`` and ``replay/solver_iters`` histograms on ``reg``
    (Prometheus/JSON exportable). Health and metrics observe only:
    per-tenant integer allocations are bit-identical with them on or off
    (test-enforced).

    ``anytime`` (a ``repro.core.AnytimeConfig`` with a ``deadline_ms``)
    enforces a per-solve deadline on every WARM tick in both engines and
    both controllers: the solve runs in iteration chunks against the
    config's injectable clock and deploys its best-so-far feasible iterate
    when the budget expires, marking the tick's ``ControllerStep`` with
    ``deadline_hit`` (batched engines flag every lane of a truncated
    bucket solve — the bucket shares one chunked program). Cold multistart
    ticks are never truncated (there is no prior allocation to fall back
    on). ``None`` — or a config without a deadline — keeps the untruncated
    engines bit-exactly (Python-level branch, test-enforced). Mutually
    exclusive with ``capture_solver_trace`` (a truncated trace is not the
    convergence evidence the trace consumers expect), and MPC replays
    require the adaptive engine (the fixed and admm engines have no
    chunk-resumable state)."""
    if len(tenants) == 0:
        raise ValueError("replay_fleet needs at least one TenantSpec; got an "
                         "empty tenant list")
    assert replay_mode in ("sequential", "batched"), replay_mode
    assert controller in ("myopic", "mpc"), controller
    assert ca_engine in ("vectorized", "sequential"), ca_engine
    if (anytime is not None and anytime.enabled and capture_solver_trace):
        raise ValueError("anytime deadlines and capture_solver_trace are "
                         "mutually exclusive; drop one")
    if run_oracle_baseline and controller != "mpc":
        raise ValueError("run_oracle_baseline compares a forecast-driven MPC "
                         "replay against its oracle-forecast twin; it "
                         'requires controller="mpc"')
    if controller == "mpc":
        # defaults resolved HERE, not above: the myopic path must not import
        # repro.horizon at all (the fleet->horizon edge stays deferred)
        if coupling_w is None or coupling_eps is None:
            from repro.horizon import DEFAULT_COUPLING_EPS, DEFAULT_COUPLING_W
            coupling_w = (DEFAULT_COUPLING_W if coupling_w is None
                          else coupling_w)
            coupling_eps = (DEFAULT_COUPLING_EPS if coupling_eps is None
                            else coupling_eps)
        mpc_kwargs = dict(horizon=horizon, forecaster=forecaster,
                          forecaster_kwargs=forecaster_kwargs,
                          coupling_w=coupling_w, coupling_eps=coupling_eps,
                          solver_steps=solver_steps,
                          solver_config=solver_config, cold_start=cold_start)
        if replay_mode == "sequential":
            ctls = [_make_mpc_controller(catalog, spec, **mpc_kwargs)
                    for spec in tenants]
            histories, traces_out = _replay_sequential(
                ctls, tenants, "mpc", capture_solver_trace, health=health,
                anytime=anytime)
        else:
            histories, traces_out = _replay_fleet_batched_mpc(
                catalog, tenants, hot_loop=hot_loop,
                capture_solver_trace=capture_solver_trace, health=health,
                anytime=anytime, **mpc_kwargs)
    elif replay_mode == "sequential":
        ctls = [_make_controller(catalog, spec) for spec in tenants]
        histories, traces_out = _replay_sequential(
            ctls, tenants, "myopic", capture_solver_trace, health=health,
            anytime=anytime)
    else:
        histories, traces_out = _replay_fleet_batched(
            catalog, tenants, warm_start=warm_start,
            solver_steps=solver_steps, hot_loop=hot_loop,
            capture_solver_trace=capture_solver_trace, health=health,
            anytime=anytime)
    if not run_ca_baseline:
        cas = [None] * len(tenants)
    elif ca_engine == "vectorized":
        cas = _replay_ca_fleet(catalog, tenants, ca_expander, ca_mode)
    else:
        cas = [_ca_baseline(catalog, spec, ca_expander, ca_mode)
               for spec in tenants]
    oracle_metrics = None
    if run_oracle_baseline:  # the oracle twin is a baseline: never traced
        oracle = replay_fleet(catalog, tenants, replay_mode=replay_mode,
                              controller="mpc", horizon=horizon,
                              forecaster="oracle", coupling_w=coupling_w,
                              coupling_eps=coupling_eps,
                              solver_config=solver_config,
                              cold_start=cold_start,
                              run_ca_baseline=False, warm_start=warm_start,
                              solver_steps=solver_steps, hot_loop=hot_loop)
        oracle_metrics = [r.metrics for r in oracle.tenants]
    with span("replay/metrics", cat="replay"):
        replays = [_assemble_replay(spec, steps, ca)
                   for spec, steps, ca in zip(tenants, histories, cas)]
        metrics = FleetReplayMetrics(
            tenants=[r.metrics for r in replays],
            baseline=([r.ca_metrics for r in replays]
                      if run_ca_baseline else None),
            replay_mode=replay_mode, controller=controller,
            oracle=oracle_metrics,
            health=health.report() if health is not None else None)
    return FleetReplayResult(
        tenants=replays, metrics=metrics,
        solver_traces=traces_out if capture_solver_trace else None)

"""Trace-driven replay: step every tenant's controller through its demand
trace, and (optionally) run the Cluster-Autoscaler baseline on the SAME
traces — the SLO/cost evaluation loop the static paper scenarios lack.

The optimizer side uses the production control loop
(InfrastructureOptimizationController): warm-started incremental solves with
bounded churn. The CA side carries its node counts tick to tick exactly like
the real autoscaler (scale-up on unschedulable demand, utilization-gated
scale-down).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.autoscaler import default_pools_for, simulate_cluster_autoscaler
from repro.core.catalog import Catalog
from repro.core.controller import (ControllerStep,
                                   InfrastructureOptimizationController)
from repro.core.metrics import AllocationMetrics, evaluate
from repro.core.problem import PenaltyParams

from .metrics import FleetReplayMetrics, TenantReplayMetrics, tenant_metrics


@dataclass
class TenantSpec:
    """One tenant cluster: a demand trace plus its controller knobs."""

    name: str
    trace: np.ndarray                            # (T, m) demand per tick
    delta_max: float = 8.0                       # max L1 churn per tick
    n_starts: int = 4
    params: Optional[PenaltyParams] = None
    allowed_idx: Optional[np.ndarray] = None     # approved instance types
    catalog: Optional[Catalog] = None            # overrides the fleet catalog
    ca_pool_idx: Optional[np.ndarray] = None     # CA node pools (default: the
                                                 # cheapest covering types)


@dataclass
class TenantReplay:
    spec: TenantSpec
    steps: List[ControllerStep]
    metrics: TenantReplayMetrics
    ca_metrics: Optional[TenantReplayMetrics] = None
    ca_counts: Optional[np.ndarray] = None       # final CA allocation


@dataclass
class FleetReplayResult:
    tenants: List[TenantReplay]
    metrics: FleetReplayMetrics


def default_ca_pools(catalog: Catalog, demand: np.ndarray,
                     k: int = 8) -> np.ndarray:
    """The k most cost-efficient single-type covers of ``demand`` — the node
    pools an operator would plausibly configure for this workload."""
    K, _, c = catalog.matrices()
    d = np.asarray(demand, np.float64)
    safe_K = np.where(K > 0, K, 1e-9)
    cover = np.max(d[:, None] / safe_K, axis=0)          # units of each type
    covers_all = np.all((K > 0) | (d[:, None] == 0), axis=0)
    cost = np.where(covers_all, cover * c, np.inf)
    order = np.argsort(cost)
    return order[: min(k, int(np.isfinite(cost).sum()))]


def _replay_ca(catalog: Catalog, spec: TenantSpec, pool_idx: np.ndarray,
               expander: str, mode: str):
    K, _, _ = catalog.matrices()
    counts_prev = np.zeros(catalog.n, np.float64)
    tick_metrics: List[AllocationMetrics] = []
    churns: List[float] = []
    for demand in np.asarray(spec.trace, np.float64):
        existing = {int(j): int(counts_prev[j])
                    for j in np.nonzero(counts_prev)[0]}
        pools = default_pools_for(catalog, pool_idx, existing=existing)
        res = simulate_cluster_autoscaler(catalog, pools, demand,
                                          expander=expander, mode=mode)
        churns.append(float(np.abs(res.counts - counts_prev).sum()))
        counts_prev = res.counts
        tick_metrics.append(evaluate(catalog, res.counts, demand))
    return tick_metrics, churns, counts_prev


def replay_tenant(catalog: Catalog, spec: TenantSpec, *,
                  run_ca_baseline: bool = True,
                  ca_expander: str = "random",
                  ca_mode: str = "wave") -> TenantReplay:
    cat = spec.catalog or catalog
    ctl = InfrastructureOptimizationController(
        catalog=cat, delta_max=spec.delta_max, params=spec.params,
        n_starts=spec.n_starts, allowed_idx=spec.allowed_idx)
    steps = [ctl.step(demand) for demand in np.asarray(spec.trace, np.float64)]
    met = tenant_metrics(spec.name, [s.metrics for s in steps],
                         [s.churn for s in steps])

    ca_met, ca_counts = None, None
    if run_ca_baseline:
        pool_idx = (spec.ca_pool_idx if spec.ca_pool_idx is not None
                    else default_ca_pools(cat, np.asarray(spec.trace)[0]))
        tick_metrics, churns, ca_counts = _replay_ca(
            cat, spec, pool_idx, ca_expander, ca_mode)
        ca_met = tenant_metrics(f"{spec.name}/ca", tick_metrics, churns)
    return TenantReplay(spec=spec, steps=steps, metrics=met,
                        ca_metrics=ca_met, ca_counts=ca_counts)


def replay_fleet(catalog: Catalog, tenants: Sequence[TenantSpec], *,
                 run_ca_baseline: bool = True,
                 ca_expander: str = "random",
                 ca_mode: str = "wave") -> FleetReplayResult:
    """Replay every tenant; returns per-tenant histories + fleet aggregates."""
    replays = [replay_tenant(catalog, spec, run_ca_baseline=run_ca_baseline,
                             ca_expander=ca_expander, ca_mode=ca_mode)
               for spec in tenants]
    metrics = FleetReplayMetrics(
        tenants=[r.metrics for r in replays],
        baseline=([r.ca_metrics for r in replays] if run_ca_baseline else None))
    return FleetReplayResult(tenants=replays, metrics=metrics)

"""Trace-driven replay: step every tenant's controller through its demand
trace, and (optionally) run the Cluster-Autoscaler baseline on the SAME
traces — the SLO/cost evaluation loop the static paper scenarios lack.

The optimizer side uses the production control loop
(InfrastructureOptimizationController): warm-started incremental solves with
bounded churn. The CA side carries its node counts tick to tick exactly like
the real autoscaler (scale-up on unschedulable demand, utilization-gated
scale-down).

Two replay engines drive the optimizer side (``replay_mode``):

* ``"sequential"`` — the reference loop: one controller solve per tenant per
  tick. Pays one XLA program dispatch (and, for ragged fleets, one compile
  per distinct tenant shape) per tenant per tick.
* ``"batched"`` — the fleet engine: tenants are grouped into power-of-two
  shape buckets (``repro.fleet.batching.bucket_problems`` dims), and each
  tick runs ONE ``solve_fleet`` call per bucket for the cold start and ONE
  ``solve_fleet_step`` call per bucket for every warm tick, warm-started
  from the previous tick's batched solution. Per-tenant problems, starts,
  warm starts and churn bounds are identical to the sequential engine, so
  per-tenant integer allocations (hence objectives and metrics) match the
  sequential path on CPU — see tests/fleet/test_replay.py.

Controller state (counts, churn, history, metrics) lives in the SAME
per-tenant ``InfrastructureOptimizationController`` objects in both modes;
the batched engine just computes the counts centrally and feeds them back
via ``controller.apply_counts``. See docs/fleet.md for the full contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autoscaler import default_pools_for, simulate_cluster_autoscaler
from repro.core.catalog import Catalog
from repro.core.controller import (ControllerStep,
                                   InfrastructureOptimizationController)
from repro.core.metrics import AllocationMetrics, evaluate
from repro.core.problem import PenaltyParams

from .batching import bucket_dims, embed_solutions, stack_problems
from .metrics import FleetReplayMetrics, TenantReplayMetrics, tenant_metrics
from .solver import make_fleet_starts, solve_fleet, solve_fleet_step


@dataclass
class TenantSpec:
    """One tenant cluster: a demand trace plus its controller knobs."""

    name: str
    trace: np.ndarray                            # (T, m) demand per tick
    delta_max: float = 8.0                       # max L1 churn per tick
    n_starts: int = 4
    params: Optional[PenaltyParams] = None
    allowed_idx: Optional[np.ndarray] = None     # approved instance types
    catalog: Optional[Catalog] = None            # overrides the fleet catalog
    ca_pool_idx: Optional[np.ndarray] = None     # CA node pools (default: the
                                                 # cheapest covering types)


@dataclass
class TenantReplay:
    """One tenant's replayed history plus its aggregated metrics."""

    spec: TenantSpec
    steps: List[ControllerStep]
    metrics: TenantReplayMetrics
    ca_metrics: Optional[TenantReplayMetrics] = None
    ca_counts: Optional[np.ndarray] = None       # final CA allocation


@dataclass
class FleetReplayResult:
    """Everything a replay produced: per-tenant histories + fleet rollup."""

    tenants: List[TenantReplay]
    metrics: FleetReplayMetrics


def default_ca_pools(catalog: Catalog, demand: np.ndarray,
                     k: int = 8) -> np.ndarray:
    """The k most cost-efficient single-type covers of ``demand`` — the node
    pools an operator would plausibly configure for this workload."""
    K, _, c = catalog.matrices()
    d = np.asarray(demand, np.float64)
    safe_K = np.where(K > 0, K, 1e-9)
    cover = np.max(d[:, None] / safe_K, axis=0)          # units of each type
    covers_all = np.all((K > 0) | (d[:, None] == 0), axis=0)
    cost = np.where(covers_all, cover * c, np.inf)
    order = np.argsort(cost)
    return order[: min(k, int(np.isfinite(cost).sum()))]


def _replay_ca(catalog: Catalog, spec: TenantSpec, pool_idx: np.ndarray,
               expander: str, mode: str):
    """Carry the Cluster-Autoscaler baseline tick to tick over one trace."""
    K, _, _ = catalog.matrices()
    counts_prev = np.zeros(catalog.n, np.float64)
    tick_metrics: List[AllocationMetrics] = []
    churns: List[float] = []
    for demand in np.asarray(spec.trace, np.float64):
        existing = {int(j): int(counts_prev[j])
                    for j in np.nonzero(counts_prev)[0]}
        pools = default_pools_for(catalog, pool_idx, existing=existing)
        res = simulate_cluster_autoscaler(catalog, pools, demand,
                                          expander=expander, mode=mode)
        churns.append(float(np.abs(res.counts - counts_prev).sum()))
        counts_prev = res.counts
        tick_metrics.append(evaluate(catalog, res.counts, demand))
    return tick_metrics, churns, counts_prev


def _ca_baseline(catalog: Catalog, spec: TenantSpec, ca_expander: str,
                 ca_mode: str):
    """Run the CA baseline for one tenant (both replay modes share this)."""
    cat = spec.catalog or catalog
    pool_idx = (spec.ca_pool_idx if spec.ca_pool_idx is not None
                else default_ca_pools(cat, np.asarray(spec.trace)[0]))
    tick_metrics, churns, ca_counts = _replay_ca(
        cat, spec, pool_idx, ca_expander, ca_mode)
    return tenant_metrics(f"{spec.name}/ca", tick_metrics, churns), ca_counts


def _make_controller(catalog: Catalog, spec: TenantSpec
                     ) -> InfrastructureOptimizationController:
    return InfrastructureOptimizationController(
        catalog=spec.catalog or catalog, delta_max=spec.delta_max,
        params=spec.params, n_starts=spec.n_starts,
        allowed_idx=spec.allowed_idx)


def _assemble_replay(catalog: Catalog, spec: TenantSpec,
                     steps: List[ControllerStep], run_ca_baseline: bool,
                     ca_expander: str, ca_mode: str) -> TenantReplay:
    """Roll one tenant's step history into a TenantReplay (metrics + optional
    CA baseline) — shared by both replay engines."""
    met = tenant_metrics(spec.name, [s.metrics for s in steps],
                         [s.churn for s in steps])
    ca_met, ca_counts = None, None
    if run_ca_baseline:
        ca_met, ca_counts = _ca_baseline(catalog, spec, ca_expander, ca_mode)
    return TenantReplay(spec=spec, steps=steps, metrics=met,
                        ca_metrics=ca_met, ca_counts=ca_counts)


def replay_tenant(catalog: Catalog, spec: TenantSpec, *,
                  run_ca_baseline: bool = True,
                  ca_expander: str = "random",
                  ca_mode: str = "wave") -> TenantReplay:
    """Sequential reference replay of ONE tenant: a controller solve per tick
    plus (optionally) the CA baseline on the same trace."""
    ctl = _make_controller(catalog, spec)
    steps = [ctl.step(demand) for demand in np.asarray(spec.trace, np.float64)]
    return _assemble_replay(catalog, spec, steps, run_ca_baseline,
                            ca_expander, ca_mode)


# ---------------------------------------------------------------------------
# batched fleet engine
# ---------------------------------------------------------------------------


def _replay_batch_groups(ctls: Sequence[InfrastructureOptimizationController],
                         tenants: Sequence[TenantSpec]
                         ) -> Dict[Tuple, List[int]]:
    """Group tenant indices by (shape bucket, n_starts).

    Tenant shapes are tick-invariant (the catalog fixes (n, m, p); demand
    normalization rescales K but never reshapes it), so grouping happens once
    per replay. ``n_starts`` joins the key because cold-start stacking needs
    a uniform (B, S, n) start tensor per group."""
    groups: Dict[Tuple, List[int]] = {}
    for b, (ctl, spec) in enumerate(zip(ctls, tenants)):
        cat = ctl.catalog
        key = bucket_dims(cat.n, len(cat.matrices()[0]),
                          len(cat.providers)) + (spec.n_starts,)
        groups.setdefault(key, []).append(b)
    return groups


def _replay_fleet_batched(catalog: Catalog, tenants: Sequence[TenantSpec], *,
                          warm_start: str = "counts",
                          solver_steps: int = 600,
                          hot_loop: Optional[str] = None
                          ) -> List[List[ControllerStep]]:
    """Step ALL tenants through their traces with one batched solve per shape
    bucket per tick. Returns per-tenant step histories (controller objects
    hold the same state the sequential engine would leave behind)."""
    assert warm_start in ("counts", "relaxed"), warm_start
    assert len(tenants) > 0, "empty fleet"
    traces = [np.asarray(spec.trace, np.float64) for spec in tenants]
    T = traces[0].shape[0]
    assert all(tr.shape[0] == T for tr in traces), \
        "batched replay needs equal-length traces (pad or use sequential mode)"

    ctls = [_make_controller(catalog, spec) for spec in tenants]
    groups = _replay_batch_groups(ctls, tenants)
    # previous tick's RELAXED batched solution per tenant (warm_start="relaxed")
    x_rel_prev: List[Optional[np.ndarray]] = [None] * len(tenants)

    for t in range(T):
        probs = [ctl.make_problem(traces[b][t])
                 for b, ctl in enumerate(ctls)]
        for key, idx in sorted(groups.items()):
            n_pad, m_pad, p_pad, n_starts = key
            batch = stack_problems([probs[b] for b in idx],
                                   n_max=n_pad, m_max=m_pad, p_max=p_pad)
            if t == 0:
                # cold start: one batched multistart solve for the bucket,
                # per-tenant starts drawn at true shape (seed 0, as the
                # sequential controller's multistart_solve does)
                starts = make_fleet_starts(batch, n_starts, seed=0)
                res = solve_fleet(batch, starts=starts, hot_loop=hot_loop)
                X_int = np.asarray(res.x_int, np.float64)
            else:
                X_cur = embed_solutions(
                    batch, [ctls[b].x_current for b in idx])
                X_init = None
                if warm_start == "relaxed" and x_rel_prev[idx[0]] is not None:
                    X_init = embed_solutions(
                        batch, [x_rel_prev[b] for b in idx])
                delta = np.asarray([tenants[b].delta_max for b in idx],
                                   np.float32)
                res = solve_fleet_step(batch, X_cur, delta, x_init=X_init,
                                       steps=solver_steps)
                X_int = np.asarray(res.x_int, np.float64)
            # only pay the relaxed-solution transfer when it will be used
            X_rel = np.asarray(res.x) if warm_start == "relaxed" else None
            for i, b in enumerate(idx):
                n_true = int(batch.n_true[i])
                ctls[b].apply_counts(traces[b][t], X_int[i, :n_true],
                                     replanned=(t == 0))
                if X_rel is not None:
                    x_rel_prev[b] = X_rel[i, :n_true]
    return [ctl.history for ctl in ctls]


def replay_fleet(catalog: Catalog, tenants: Sequence[TenantSpec], *,
                 replay_mode: str = "sequential",
                 run_ca_baseline: bool = True,
                 ca_expander: str = "random",
                 ca_mode: str = "wave",
                 warm_start: str = "counts",
                 hot_loop: Optional[str] = None) -> FleetReplayResult:
    """Replay every tenant; returns per-tenant histories + fleet aggregates.

    ``replay_mode`` selects the optimizer engine:

    * ``"sequential"`` (reference) — one controller solve per tenant per tick.
    * ``"batched"`` — one ``solve_fleet`` / ``solve_fleet_step`` call per
      shape bucket per tick (see module docstring); requires equal-length
      traces. Produces per-tenant integer allocations identical to the
      sequential engine on CPU.

    ``warm_start`` (batched mode only) picks the incremental solve's warm
    start: ``"counts"`` (the previous integer allocation — what the
    sequential controller uses) or ``"relaxed"`` (the previous tick's relaxed
    batched solution). ``hot_loop`` forwards to :func:`solve_fleet` for the
    cold-start solve. The CA baseline always replays sequentially — it is a
    numpy simulation with no solver in the loop."""
    assert replay_mode in ("sequential", "batched"), replay_mode
    if replay_mode == "sequential":
        replays = [replay_tenant(catalog, spec,
                                 run_ca_baseline=run_ca_baseline,
                                 ca_expander=ca_expander, ca_mode=ca_mode)
                   for spec in tenants]
    else:
        histories = _replay_fleet_batched(catalog, tenants,
                                          warm_start=warm_start,
                                          hot_loop=hot_loop)
        replays = [_assemble_replay(catalog, spec, steps, run_ca_baseline,
                                    ca_expander, ca_mode)
                   for spec, steps in zip(tenants, histories)]
    metrics = FleetReplayMetrics(
        tenants=[r.metrics for r in replays],
        baseline=([r.ca_metrics for r in replays] if run_ca_baseline else None),
        replay_mode=replay_mode)
    return FleetReplayResult(tenants=replays, metrics=metrics)

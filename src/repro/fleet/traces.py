"""Seedable synthetic demand-trace generators.

The paper evaluates five STATIC scenarios; production allocators face
time-varying demand. Every generator returns a (T, m) float64 array of
per-tick resource demand (same resource convention as repro.core.catalog:
cpu, mem_gb, net_units, storage_gb for the cloud catalogs), is deterministic
given ``seed``, and keeps demand strictly positive.

Ticks are hours unless noted — diurnal period 24, weekly period 168.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np


def _noise(rng: np.random.Generator, T: int, m: int, level: float) -> np.ndarray:
    """Multiplicative lognormal-ish noise around 1."""
    return np.exp(level * rng.standard_normal((T, m)))


def _positive(trace: np.ndarray, base: np.ndarray) -> np.ndarray:
    return np.maximum(trace, 0.05 * base[None, :])


def diurnal_trace(base: np.ndarray, T: int, *, amplitude: float = 0.4,
                  period: float = 24.0, phase: float = 0.0,
                  noise: float = 0.03, seed: int = 0) -> np.ndarray:
    """Day/night sinusoid: base * (1 + amplitude * sin(2 pi t / period))."""
    base = np.asarray(base, np.float64)
    rng = np.random.default_rng(seed)
    t = np.arange(T, dtype=np.float64)
    wave = 1.0 + amplitude * np.sin(2 * np.pi * (t + phase) / period)
    return _positive(base[None, :] * wave[:, None] * _noise(rng, T, len(base), noise),
                     base)


def flash_crowd_trace(base: np.ndarray, T: int, *, n_bursts: int = 2,
                      burst_scale: float = 3.0, decay: float = 6.0,
                      noise: float = 0.03, seed: int = 0) -> np.ndarray:
    """Baseline demand with sudden spikes decaying exponentially (viral
    events, incident failover). Burst times are drawn from ``seed``."""
    base = np.asarray(base, np.float64)
    rng = np.random.default_rng(seed)
    t = np.arange(T, dtype=np.float64)
    mult = np.ones(T)
    for start in sorted(rng.uniform(0.1 * T, 0.9 * T, size=n_bursts)):
        scale = burst_scale * rng.uniform(0.6, 1.4)
        after = t >= start
        mult = mult + after * (scale - 1.0) * np.exp(-(t - start) / decay)
    return _positive(base[None, :] * mult[:, None] * _noise(rng, T, len(base), noise),
                     base)


def ramp_trace(base: np.ndarray, T: int, *, end_scale: float = 4.0,
               start_frac: float = 0.2, end_frac: float = 0.8,
               noise: float = 0.03, seed: int = 0) -> np.ndarray:
    """Linear growth from base to end_scale*base between the two fractions
    of the horizon (product launch / steady adoption)."""
    base = np.asarray(base, np.float64)
    rng = np.random.default_rng(seed)
    t = np.arange(T, dtype=np.float64) / max(T - 1, 1)
    frac = np.clip((t - start_frac) / max(end_frac - start_frac, 1e-9), 0.0, 1.0)
    mult = 1.0 + (end_scale - 1.0) * frac
    return _positive(base[None, :] * mult[:, None] * _noise(rng, T, len(base), noise),
                     base)


def weekly_trace(base: np.ndarray, T: int, *, daily_amplitude: float = 0.35,
                 weekend_dip: float = 0.45, noise: float = 0.05,
                 seed: int = 0) -> np.ndarray:
    """Diurnal cycle modulated by a weekday/weekend square-ish wave —
    the classic enterprise traffic shape."""
    base = np.asarray(base, np.float64)
    rng = np.random.default_rng(seed)
    t = np.arange(T, dtype=np.float64)
    daily = 1.0 + daily_amplitude * np.sin(2 * np.pi * t / 24.0)
    day_of_week = (t // 24.0) % 7
    weekday = np.where(day_of_week < 5, 1.0, 1.0 - weekend_dip)
    mult = daily * weekday
    return _positive(base[None, :] * mult[:, None] * _noise(rng, T, len(base), noise),
                     base)


def constant_trace(base: np.ndarray, T: int) -> np.ndarray:
    """Static demand — replaying it must reproduce the single-shot solve."""
    base = np.asarray(base, np.float64)
    return np.tile(base[None, :], (T, 1))


def spot_interruption_trace(base: np.ndarray, T: int, *, rate: float = 0.08,
                            mean_outage: float = 3.0,
                            seed: int = 0) -> np.ndarray:
    """Seeded spot AVAILABILITY overlay: (T, S) array in {0.0, 1.0}.

    The one registry kind that is not a demand trace: ``base``'s LENGTH
    sets the number of independent spot pools S (its values are unused) and
    each column is an on/off Markov chain — an available pool is
    interrupted with probability ``rate`` per tick and recovers with
    probability ``1/mean_outage`` (geometric outage lengths, mean
    ``mean_outage`` ticks). All pools start available. Consumers
    (``repro.fleet.replay`` via ``TenantSpec.spot_availability``) zero an
    interrupted pool's capacity for the tick: mask/ub/lb of its catalog
    spot twins go to 0, so the controller must rebuy on-demand or eat the
    shortage — the repricing the ``spot_risk`` term anticipates."""
    base = np.asarray(base, np.float64)
    assert base.ndim == 1 and len(base) >= 1, base.shape
    assert 0.0 <= rate <= 1.0 and mean_outage >= 1.0, (rate, mean_outage)
    S = len(base)
    rng = np.random.default_rng(seed)
    recover = 1.0 / mean_outage
    avail = np.ones(S, np.float64)
    out = np.empty((T, S), np.float64)
    for t in range(T):
        out[t] = avail
        u = rng.random(S)
        # up pools fail w.p. rate; down pools recover w.p. 1/mean_outage
        avail = np.where(avail > 0.0,
                         (u >= rate).astype(np.float64),
                         (u < recover).astype(np.float64))
    return out


TRACE_KINDS: Dict[str, Callable] = {
    "diurnal": diurnal_trace,
    "flash_crowd": flash_crowd_trace,
    "ramp": ramp_trace,
    "weekly": weekly_trace,
    "constant": constant_trace,
    "spot_interruption": spot_interruption_trace,
}


def make_trace(kind: str, base: np.ndarray, T: int, *, seed: int = 0,
               **kwargs) -> np.ndarray:
    """Registry entry point: make_trace("diurnal", base, 72, seed=3)."""
    try:
        fn = TRACE_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown trace kind {kind!r}; "
                         f"choose from {sorted(TRACE_KINDS)}") from None
    if kind == "constant":
        # no seed (deterministic by construction); unknown kwargs raise
        # instead of being silently swallowed (a typo'd amplitude= would
        # otherwise produce a flat trace without complaint)
        return fn(base, T, **kwargs)
    return fn(base, T, seed=seed, **kwargs)

"""solve_fleet — one compiled program solving the whole fleet.

Mirrors core.solver.solve_relaxation (phase-1 -> barrier/penalty PGD with a
Barzilai-Borwein step and an Armijo backtracking ladder -> feasibility
restoration -> rounding) but carries the full (B tenants, S starts) state
through every step. In the hand-batched hot loop ("kernel"/"ref" modes) each
iteration evaluates the Armijo ladder's B*S*L candidate VALUES in one batched
pass and the objective+gradient at the accepted iterate with a single call
into the batched Pallas alloc_objective kernel ("kernel"; grid over tenants x
point blocks) or its einsum oracle ("ref"). The "vmap" mode instead vmaps the
unmodified core solver — bit-identical per lane to sequential solves, and the
fastest dispatch on CPU where Pallas runs in interpret mode.

Phase-1, greedy rounding and start generation genuinely reuse the core
implementations under vmap — the stacked batch from repro.fleet.batching is
a valid AllocationProblem per vmap slice, padding included.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import terms as core_terms
from repro.core.incremental import (incremental_anytime_chunk,
                                    incremental_anytime_init,
                                    solve_incremental_info)
from repro.core.multistart import make_starts
from repro.core.pgd import AnytimeConfig, PGDConfig, PGDTrace, run_anytime
from repro.core.objective import is_feasible, objective
from repro.core.problem import AllocationProblem
from repro.core.rounding import round_and_polish
from repro.core.solver import SolverConfig, phase1_point, solve_relaxation
from repro.kernels.alloc_objective.ops import fleet_value_and_grad
from repro.kernels.alloc_objective.ref import alloc_objective_fleet_value

from .batching import (BucketedFleet, FleetBatch, bucket_problems,
                       scatter_from_buckets, stack_problems, tenant_problem)


class FleetSolveResult(NamedTuple):
    """Per-tenant outputs of a batched fleet solve (leading axis = tenant).

    The per-start rounded candidates (``x_int_all`` / ``fun_int_all`` /
    ``feas_int_all``) mirror ``core.multistart.MultiStartResult``: callers
    can re-score the whole candidate set against a different merit — the
    batched MPC replay's ``cold_start="window"`` scores them against each
    tenant's whole lookahead window instead of tick 0."""

    x: jnp.ndarray            # (B, n) best relaxed solution per tenant
    fun: jnp.ndarray          # (B,) objective at x
    x_int: jnp.ndarray        # (B, n) best rounded integer solution
    fun_int: jnp.ndarray      # (B,) objective at x_int
    feasible: jnp.ndarray     # (B,) integer-solution feasibility
    used_barrier: jnp.ndarray  # (B, S)
    all_fun: jnp.ndarray      # (B, S) relaxed objective per start
    iters: jnp.ndarray        # total PGD iterations (fleet-wide)
    x_int_all: jnp.ndarray    # (B, S, n) rounded candidate per start
    fun_int_all: jnp.ndarray  # (B, S) objective per rounded candidate
    feas_int_all: jnp.ndarray  # (B, S) integer feasibility per candidate


# ---------------------------------------------------------------------------
# batched constraint machinery (leaves carry a leading (B,) axis; points may
# be (B, T, n) for any T — starts or the flattened candidate ladder)
# ---------------------------------------------------------------------------


def _bcast(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Reshape a (B, k) problem leaf to broadcast against (B, ..., k) x."""
    return a.reshape(a.shape[0], *([1] * (x.ndim - 2)), a.shape[-1])


def _project(prob: AllocationProblem, X: jnp.ndarray) -> jnp.ndarray:
    return (jnp.clip(X, _bcast(prob.lb, X), _bcast(prob.ub, X))
            * _bcast(prob.mask, X))


def _residuals(prob: AllocationProblem, X: jnp.ndarray):
    KX = jnp.einsum("bmn,b...n->b...m", prob.K, X)
    lo = KX - _bcast(prob.d - prob.mu, X)
    hi = _bcast(prob.d + prob.g, X) - KX
    return lo, hi


def _terms_value(prob: AllocationProblem, X: jnp.ndarray) -> jnp.ndarray:
    """(B, T) sum of attached scenario-term values — the registry's additive
    hook for the hand-batched hot loop (the Pallas kernel computes only the
    four base terms; its oracle contract is untouched)."""
    return jax.vmap(lambda pb, Xt: jax.vmap(
        lambda x: core_terms.active_value(pb, x))(Xt))(prob, X)


def _terms_grad(prob: AllocationProblem, X: jnp.ndarray) -> jnp.ndarray:
    """(B, T, n) gradient counterpart of :func:`_terms_value`."""
    return jax.vmap(lambda pb, Xt: jax.vmap(
        lambda x: core_terms.active_grad(pb, x))(Xt))(prob, X)


def _objective_value(prob: AllocationProblem, X: jnp.ndarray) -> jnp.ndarray:
    """Objective values only for X (B, T, n) — the Armijo-ladder evaluation.
    The gradient (kernel path) is evaluated once per iteration at the
    ACCEPTED point, exactly like core.solver._pgd.  Attached scenario terms
    add on top of the kernel's base-term value; the ``if prob.terms:`` gate
    is Python-static, so the default (no-terms) compiled graph is the seed
    graph byte-for-byte."""
    P = prob.params
    val = alloc_objective_fleet_value(X, prob.K, prob.E, prob.c, prob.d,
                                      P.alpha, P.beta1, P.beta2, P.beta3,
                                      P.gamma)
    if prob.terms:
        val = val + _terms_value(prob, X)
    return val


def _constraint_values(prob: AllocationProblem, X: jnp.ndarray,
                       barrier_t, penalty_w):
    """Barrier and penalty VALUES for X (B, T, n)."""
    lo, hi = _residuals(prob, X)                       # (B, T, m) each
    safe = jnp.all(lo > 0, -1) & jnp.all(hi > 0, -1)   # (B, T)
    bval = -(1.0 / barrier_t) * (
        jnp.sum(jnp.log(jnp.where(lo > 0, lo, 1.0)), -1)
        + jnp.sum(jnp.log(jnp.where(hi > 0, hi, 1.0)), -1))
    bval = jnp.where(safe, bval, jnp.inf)
    vlo = jnp.maximum(-lo, 0.0)
    vhi = jnp.maximum(-hi, 0.0)
    qval = penalty_w * (jnp.sum(vlo**2, -1) + jnp.sum(vhi**2, -1))
    return bval, qval


def _constraint_grads(prob: AllocationProblem, X: jnp.ndarray,
                      barrier_t, penalty_w):
    """Barrier and penalty GRADIENTS for X (B, T, n)."""
    lo, hi = _residuals(prob, X)
    lo_c = jnp.maximum(lo, 1e-9)
    hi_c = jnp.maximum(hi, 1e-9)
    bgrad = (1.0 / barrier_t) * (
        jnp.einsum("bmn,btm->btn", prob.K, 1.0 / hi_c)
        - jnp.einsum("bmn,btm->btn", prob.K, 1.0 / lo_c))
    vlo = jnp.maximum(-lo, 0.0)
    vhi = jnp.maximum(-hi, 0.0)
    qgrad = penalty_w * 2.0 * (jnp.einsum("bmn,btm->btn", prob.K, vhi)
                               - jnp.einsum("bmn,btm->btn", prob.K, vlo))
    return bgrad, qgrad


def _is_feasible(prob: AllocationProblem, X: jnp.ndarray, tol: float):
    """(B, ...) feasibility for X (B, ..., n)."""
    lo, hi = _residuals(prob, X)
    box = (jnp.all(X >= _bcast(prob.lb, X) - tol, -1)
           & jnp.all(X <= _bcast(prob.ub, X) + tol, -1))
    return jnp.all(lo >= -tol, -1) & jnp.all(hi >= -tol, -1) & box


def _pgd_fleet(prob, X0, barrier_t, penalty_w, strict, cfg: SolverConfig,
               use_kernel: bool, interpret: bool):
    """Batched inner PGD over (B, S) simultaneous solves.

    Per-element state exactly mirrors core.solver._pgd; finished elements
    freeze in place while the rest keep iterating.
    """
    B, S, n = X0.shape

    def F_values(Xc, T):
        """Composite values for Xc (B, T, n); T is S or S*L."""
        f = _objective_value(prob, Xc)
        bval, qval = _constraint_values(prob, Xc, barrier_t, penalty_w)
        s = jnp.repeat(strict, T // S, axis=1) if T != S else strict
        return f + jnp.where(s, bval, qval)

    def G_at(Xc):
        """Composite gradient at the (B, S, n) iterate — the hot call routed
        through the batched Pallas kernel (or its einsum oracle); attached
        scenario terms add their registry gradients on top (statically
        absent when ``prob.terms`` is empty)."""
        _, g = fleet_value_and_grad(prob, Xc, interpret=interpret,
                                    use_kernel=use_kernel)
        if prob.terms:
            g = g + _terms_grad(prob, Xc)
        bgrad, qgrad = _constraint_grads(prob, Xc, barrier_t, penalty_w)
        return g + jnp.where(strict[..., None], bgrad, qgrad)

    L = cfg.n_backtracks
    ratios = cfg.backtrack ** jnp.arange(-1, L - 1)    # 1 upscale, as core

    def cond(state):
        x, fx, g, bb, it, done = state
        return jnp.any(~done) & (it < cfg.max_iters)

    def body(state):
        x, fx, g, bb, it, done = state
        steps = bb[..., None] * ratios                                # (B,S,L)
        cands = _project(prob, x[:, :, None, :]
                         - steps[..., None] * g[:, :, None, :])       # (B,S,L,n)
        Fc = F_values(cands.reshape(B, S * L, n), S * L).reshape(B, S, L)
        # Armijo on the projected step: F(x+) <= F(x) + c * g^T (x+ - x)
        dec = Fc - (fx[..., None] + cfg.armijo_c *
                    jnp.sum(g[:, :, None, :] * (cands - x[:, :, None, :]), -1))
        ok = (dec <= 0.0) & jnp.isfinite(Fc)
        idx = jnp.argmax(ok, axis=-1)                  # first (largest) step
        any_ok = jnp.any(ok, axis=-1)
        sel = lambda a, extra: jnp.take_along_axis(
            a, idx.reshape(B, S, 1, *([1] * extra)), axis=2).squeeze(2)
        x_new = jnp.where(any_ok[..., None], sel(cands, 1), x)
        f_new = jnp.where(any_ok, sel(Fc, 0), fx)
        g_new = G_at(x_new)
        # BB1 step from the accepted move (safeguarded into [1e-8, 1e4])
        dx = x_new - x
        dg = g_new - g
        denom = jnp.sum(dx * dg, -1)
        bb_new = jnp.where(jnp.abs(denom) > 1e-12,
                           jnp.abs(jnp.sum(dx * dx, -1) / denom), cfg.step0)
        bb_new = jnp.clip(bb_new, 1e-8, 1e4)
        bb_new = jnp.where(any_ok, bb_new, bb * cfg.backtrack ** L)
        move = jnp.max(jnp.abs(dx), -1)
        newly_done = ((~any_ok) & (bb < 1e-7)) | (any_ok & (move < cfg.tol))
        # freeze elements that were already done before this iteration
        x_new = jnp.where(done[..., None], x, x_new)
        f_new = jnp.where(done, fx, f_new)
        g_new = jnp.where(done[..., None], g, g_new)
        bb_new = jnp.where(done, bb, bb_new)
        return (x_new, f_new, g_new, bb_new, it + 1, done | newly_done)

    X0 = _project(prob, X0)
    state = (X0, F_values(X0, S), G_at(X0), jnp.full((B, S), cfg.step0),
             jnp.asarray(0), jnp.zeros((B, S), bool))
    x, fx, _, _, it, _ = jax.lax.while_loop(cond, body, state)
    return x, fx, it


def _relax_kernel_path(prob, starts, cfg, use_kernel, interpret):
    """Hand-batched phase-1 -> barrier PGD with the kernel-routed hot loop."""
    phase1 = jax.vmap(lambda pb, xs: jax.vmap(
        lambda x0: phase1_point(pb, x0))(xs))
    x = phase1(prob, starts)                                       # (B, S, n)
    lo, hi = _residuals(prob, x)
    strict = (jnp.min(lo, -1) > 1e-3) & (jnp.min(hi, -1) > 1e-3)   # (B, S)

    def round_body(r, carry):
        x, total_it = carry
        t = cfg.barrier_t0 * (cfg.barrier_kappa ** r.astype(jnp.float32))
        x, _, it = _pgd_fleet(prob, x, jnp.asarray(t),
                              jnp.asarray(cfg.penalty_w), strict, cfg,
                              use_kernel, interpret)
        return (x, total_it + it)

    x, iters = jax.lax.fori_loop(0, cfg.barrier_rounds, round_body,
                                 (x, jnp.asarray(0)))
    # feasibility restoration (no-op when already feasible)
    restore = jax.vmap(lambda pb, xs: jax.vmap(
        lambda x0: phase1_point(pb, x0, steps=100, margin_frac=0.0))(xs))
    x = restore(prob, x)
    fun = _objective_value(prob, x)                                 # (B, S)
    feas = _is_feasible(prob, x, 1e-3)
    return x, fun, feas, strict, iters


def _relax_vmap_path(prob, starts, cfg):
    """vmap of the UNMODIFIED core solver. XLA preserves the per-lane op
    structure under vmap, so each lane's trajectory is bit-identical to a
    standalone solve_relaxation call — the reference fleet path (and the
    fastest on CPU, where the Pallas kernel would run in interpret mode)."""
    res = jax.vmap(lambda pb, xs: jax.vmap(
        lambda x0: solve_relaxation(pb, x0, cfg))(xs))(prob, starts)
    return res.x, res.fun, res.feasible, res.used_barrier, jnp.sum(res.iters)


@partial(jax.jit, static_argnames=("cfg", "hot_loop", "interpret"))
def _solve_fleet_impl(prob: AllocationProblem, starts: jnp.ndarray,
                      cfg: SolverConfig, hot_loop: str, interpret: bool
                      ) -> FleetSolveResult:
    B, S, n = starts.shape
    if hot_loop == "vmap":
        x, fun, feas_rel, strict, iters = _relax_vmap_path(prob, starts, cfg)
    else:
        x, fun, feas_rel, strict, iters = _relax_kernel_path(
            prob, starts, cfg, use_kernel=(hot_loop == "kernel"),
            interpret=interpret)

    # round EVERY start (relaxed merit predicts integer cost poorly); the
    # vmapped greedy rounding + objective reuse the core implementations
    x_int = jax.vmap(lambda pb, xs: jax.vmap(
        lambda xr: round_and_polish(pb, xr))(xs))(prob, x)          # (B, S, n)
    f_int = jax.vmap(lambda pb, xs: jax.vmap(
        lambda xi: objective(pb, xi))(xs))(prob, x_int)
    feas_int = jax.vmap(lambda pb, xs: jax.vmap(
        lambda xi: is_feasible(pb, xi, 1e-3))(xs))(prob, x_int)

    take_b = lambda a, j, extra: jnp.take_along_axis(
        a, j.reshape(B, 1, *([1] * extra)), axis=1).squeeze(1)
    merit_int = jnp.where(feas_int, f_int, f_int + 1e12)
    j = jnp.argmin(merit_int, axis=1)                               # (B,)
    merit_rel = jnp.where(feas_rel, fun, fun + 1e12)
    i = jnp.argmin(merit_rel, axis=1)
    return FleetSolveResult(
        x=take_b(x, i, 1), fun=take_b(fun, i, 0),
        x_int=take_b(x_int, j, 1), fun_int=take_b(f_int, j, 0),
        feasible=take_b(feas_int, j, 0),
        used_barrier=strict, all_fun=fun, iters=iters,
        x_int_all=x_int, fun_int_all=f_int, feas_int_all=feas_int)


def solve_fleet(
    fleet: Union[FleetBatch, Sequence[AllocationProblem], AllocationProblem],
    n_starts: int = 4,
    seed: int = 0,
    cfg: Optional[SolverConfig] = None,
    starts: Optional[jnp.ndarray] = None,
    hot_loop: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> FleetSolveResult:
    """Solve every tenant problem in one compiled batched program.

    ``fleet`` may be a FleetBatch, a list of (ragged) AllocationProblems, or
    an already-stacked AllocationProblem with (B,) leading leaf axes.
    ``starts`` overrides the generated (B, S, n) start points.

    ``hot_loop`` picks the relaxation engine:
      * "vmap"   — vmap of the unmodified core solver; per-lane trajectories
                   are bit-identical to sequential solve_relaxation calls.
                   Default on CPU.
      * "kernel" — hand-batched PGD with objective+gradient routed through
                   the batched Pallas alloc_objective kernel (one pallas_call
                   per iteration for the whole fleet). Default on TPU;
                   ``interpret=True`` validates it on CPU.
      * "ref"    — the hand-batched PGD with the einsum oracle instead of
                   the Pallas kernel (kernel-path debugging).
    The PGD step acceptance is chaotic in the last ulps, so "kernel"/"ref"
    agree with sequential solves to solver tolerance (per-tenant ~1e-2,
    fleet aggregate ~1e-3), while "vmap" agrees exactly.
    """
    batch: Optional[FleetBatch] = None
    if isinstance(fleet, FleetBatch):
        batch, prob = fleet, fleet.problem
    elif isinstance(fleet, AllocationProblem):
        prob = fleet
    else:
        batch = stack_problems(list(fleet))
        prob = batch.problem
    cfg = cfg or SolverConfig()
    on_tpu = jax.default_backend() == "tpu"
    if hot_loop is None:
        hot_loop = "kernel" if on_tpu else "vmap"
    assert hot_loop in ("vmap", "kernel", "ref"), hot_loop
    if interpret is None:
        interpret = not on_tpu
    if starts is None:
        if batch is not None:
            # per-tenant starts at TRUE shapes: invariant to how the fleet
            # is padded/bucketed, and identical to the starts a sequential
            # multistart_solve on the original problem would draw
            starts = make_fleet_starts(batch, n_starts, seed)
        else:
            starts = jax.vmap(lambda pb: make_starts(pb, n_starts, seed))(prob)
    return _solve_fleet_impl(prob, jnp.asarray(starts), cfg, hot_loop,
                             bool(interpret))


def make_fleet_starts(batch: FleetBatch, n_starts: int,
                      seed: int = 0) -> jnp.ndarray:
    """(B, S, n_max) start points, drawn PER TENANT at its true shape.

    ``core.multistart.make_starts`` shapes its random-start scaling by the
    problem dimensions, so drawing on the padded batch would make start
    points (hence solve results) depend on the fleet's padding. Drawing each
    tenant at its true (n, m, p) and zero-embedding keeps solve_fleet results
    independent of batch composition — bucketed and globally-padded stacking
    see literally the same starts, as does a sequential per-tenant loop."""
    out = np.zeros((batch.B, n_starts, batch.n_max), np.float32)
    for b in range(batch.B):
        pb = tenant_problem(batch, b)
        out[b, :, : int(batch.n_true[b])] = np.asarray(
            make_starts(pb, n_starts, seed))
    return jnp.asarray(out)


def solve_fleet_bucketed(
    problems: Sequence[AllocationProblem],
    n_starts: int = 4,
    seed: int = 0,
    cfg: Optional[SolverConfig] = None,
    hot_loop: Optional[str] = None,
    interpret: Optional[bool] = None,
    bucketed: Optional[BucketedFleet] = None,
) -> FleetSolveResult:
    """solve_fleet with shape-bucketed stacking (padding-waste reduction).

    Groups the ragged fleet into power-of-two shape buckets
    (:func:`repro.fleet.batching.bucket_problems`), runs one batched solve
    per bucket, and scatters results back into the ORIGINAL tenant order.
    Returns a FleetSolveResult padded to the global n_max, so callers can
    treat it exactly like an unbucketed ``solve_fleet`` result.

    Because start points are drawn per tenant at true shape
    (:func:`make_fleet_starts`), per-tenant results match unbucketed
    stacking to solver tolerance — and the rounded integer objectives are
    identical in practice on CPU. ``bucketed`` lets callers reuse a
    precomputed bucket layout (the replay engine re-stacks every tick but
    buckets only once)."""
    problems = list(problems)
    if bucketed is None:
        bucketed = bucket_problems(problems)
    n_max = max(int(pb.n) for pb in problems)
    results = [solve_fleet(b, n_starts=n_starts, seed=seed, cfg=cfg,
                           hot_loop=hot_loop, interpret=interpret)
               for b in bucketed.batches]

    def to_n_max(a: np.ndarray, is_solution: bool) -> np.ndarray:
        """Align a bucket's last axis to the global true n_max. Bucket pads
        are powers of two, so they may exceed n_max (truncate: solution
        columns past every member's true n are pinned-zero padding) or fall
        short of it (zero-pad up)."""
        a = np.asarray(a)
        if not is_solution or a.shape[-1] == n_max:
            return a
        if a.shape[-1] > n_max:
            return a[..., :n_max]
        pad = [(0, 0)] * (a.ndim - 1) + [(0, n_max - a.shape[-1])]
        return np.pad(a, pad)

    def gather(field: str, is_solution: bool = False) -> jnp.ndarray:
        rows = [list(to_n_max(getattr(r, field), is_solution))
                for r in results]
        return jnp.asarray(np.stack(scatter_from_buckets(bucketed, rows)))

    return FleetSolveResult(
        x=gather("x", is_solution=True), fun=gather("fun"),
        x_int=gather("x_int", is_solution=True), fun_int=gather("fun_int"),
        feasible=gather("feasible"), used_barrier=gather("used_barrier"),
        all_fun=gather("all_fun"),
        iters=jnp.asarray(sum(int(r.iters) for r in results)),
        x_int_all=gather("x_int_all", is_solution=True),
        fun_int_all=gather("fun_int_all"),
        feas_int_all=gather("feas_int_all"))


# ---------------------------------------------------------------------------
# batched incremental tick (the replay engine's warm-started per-tick solve)
# ---------------------------------------------------------------------------


class FleetStepResult(NamedTuple):
    """One batched incremental tick over the whole fleet.

    ``trace`` is None unless the tick ran with ``capture_trace=True``, in
    which case it is a batched ``core.pgd.PGDTrace`` whose leaves carry a
    leading (B,) lane axis — per-lane convergence rows, fixed-size
    ``steps`` long (see ``repro.obs.solver_trace``)."""

    x: jnp.ndarray         # (B, n) relaxed incremental solution
    x_int: jnp.ndarray     # (B, n) rounded allocation actually deployed
    fun_int: jnp.ndarray   # (B,) objective at x_int
    feasible: jnp.ndarray  # (B,) integer-solution feasibility
    iters: jnp.ndarray     # (B,) adaptive-PGD iterations per lane
    trace: Optional[PGDTrace] = None  # (B, steps) per-lane convergence rows
    deadline_hit: Optional[bool] = None  # anytime tick truncated (None: n/a)


@partial(jax.jit, static_argnames=("steps",))
def _step_fleet_impl(prob: AllocationProblem, x_current: jnp.ndarray,
                     delta_max: jnp.ndarray, x_init: jnp.ndarray,
                     active: jnp.ndarray, steps: int) -> FleetStepResult:
    x_rel, iters = jax.vmap(
        lambda pb, xc, dm, xi: solve_incremental_info(pb, xc, dm, x_init=xi,
                                                      steps=steps)
    )(prob, x_current, delta_max, x_init)
    x_int = jax.vmap(round_and_polish)(prob, x_rel)
    # frozen lanes (active=False) keep their warm start as the answer; the
    # mask is a traced array, so ragged fleets reuse one compiled program
    x_rel = jnp.where(active[:, None], x_rel, x_current)
    x_int = jnp.where(active[:, None], x_int, x_current)
    f_int = jax.vmap(objective)(prob, x_int)
    feas = jax.vmap(lambda pb, xi: is_feasible(pb, xi, 1e-3))(prob, x_int)
    return FleetStepResult(x=x_rel, x_int=x_int, fun_int=f_int, feasible=feas,
                           iters=jnp.where(active, iters, 0))


@partial(jax.jit, static_argnames=("steps",))
def _step_fleet_traced_impl(prob: AllocationProblem, x_current: jnp.ndarray,
                            delta_max: jnp.ndarray, x_init: jnp.ndarray,
                            active: jnp.ndarray, steps: int
                            ) -> FleetStepResult:
    """Traced twin of ``_step_fleet_impl``: same solves, plus per-lane
    PGDTrace capture (the trace is extra while_loop state, not extra math,
    so ``(x, x_int, iters)`` match the untraced program)."""
    x_rel, iters, trace = jax.vmap(
        lambda pb, xc, dm, xi: solve_incremental_info(
            pb, xc, dm, x_init=xi, steps=steps, capture_trace=True)
    )(prob, x_current, delta_max, x_init)
    x_int = jax.vmap(round_and_polish)(prob, x_rel)
    x_rel = jnp.where(active[:, None], x_rel, x_current)
    x_int = jnp.where(active[:, None], x_int, x_current)
    f_int = jax.vmap(objective)(prob, x_int)
    feas = jax.vmap(lambda pb, xi: is_feasible(pb, xi, 1e-3))(prob, x_int)
    return FleetStepResult(x=x_rel, x_int=x_int, fun_int=f_int, feasible=feas,
                           iters=jnp.where(active, iters, 0), trace=trace)


@partial(jax.jit, static_argnames=("cfg",))
def _step_fleet_anytime_init_impl(prob, x_current, delta_max, x_init,
                                  cfg: PGDConfig):
    """Vmapped chunk-state init: every lane's projected warm start plus the
    best-so-far trackers, stacked on a leading (B,) axis."""
    return jax.vmap(
        lambda pb, xc, dm, xi: incremental_anytime_init(pb, xc, dm, xi, cfg)
    )(prob, x_current, delta_max, x_init)


@partial(jax.jit, static_argnames=("cfg",))
def _step_fleet_anytime_chunk_impl(prob, x_current, delta_max, state, it_end,
                                   cfg: PGDConfig):
    """Advance every lane to the traced iteration cap ``it_end`` (closed
    over, so it broadcasts across the vmap). Per-lane op structure is the
    sequential chunk's — converged lanes freeze in place."""
    return jax.vmap(
        lambda pb, xc, dm, s: incremental_anytime_chunk(pb, xc, dm, s,
                                                        it_end, cfg)
    )(prob, x_current, delta_max, state)


@jax.jit
def _step_fleet_anytime_finalize_impl(prob, x_rel, x_current, active, iters):
    """The untruncated tick's tail — rounding, frozen-lane masking,
    objective and feasibility — applied to the anytime best-so-far
    iterates."""
    x_int = jax.vmap(round_and_polish)(prob, x_rel)
    x_rel = jnp.where(active[:, None], x_rel, x_current)
    x_int = jnp.where(active[:, None], x_int, x_current)
    f_int = jax.vmap(objective)(prob, x_int)
    feas = jax.vmap(lambda pb, xi: is_feasible(pb, xi, 1e-3))(prob, x_int)
    return FleetStepResult(x=x_rel, x_int=x_int, fun_int=f_int, feasible=feas,
                           iters=jnp.where(active, iters, 0))


def solve_fleet_step(
    fleet: Union[FleetBatch, AllocationProblem],
    x_current: jnp.ndarray,
    delta_max: Union[float, jnp.ndarray],
    x_init: Optional[jnp.ndarray] = None,
    steps: int = 600,
    active: Optional[np.ndarray] = None,
    capture_trace: bool = False,
    anytime: Optional[AnytimeConfig] = None,
) -> FleetStepResult:
    """One incremental-adoption tick for EVERY tenant in one jitted program.

    The fleet analogue of ``InfrastructureOptimizationController``'s warm
    tick: per tenant, PGD on the objective constrained to the L1 churn ball
    ``||x - x_current||_1 <= delta_max`` (``core.incremental``), then greedy
    rounding — all under one vmap, so a T-tick replay issues T device
    programs instead of T*B.

    ``x_current`` is the (B, n) previous-tick allocation (also the warm
    start); ``x_init`` optionally overrides the warm start, e.g. with the
    previous tick's RELAXED batched solution. ``delta_max`` may be scalar or
    per-tenant (B,). vmap preserves per-lane op structure, so each lane
    matches a sequential ``solve_incremental`` + ``round_and_polish`` call
    on the same padded problem.

    ``active`` is the (B,) ragged-horizon liveness mask: frozen lanes
    (``active[b] == False`` — the tenant's trace has expired) are returned
    with ``x == x_int == x_current`` instead of a fresh solution, so their
    rows carry the last allocation forward unchanged. Defaults to the
    batch's own ``FleetBatch.active`` mask, else all-live. Live lanes are
    unaffected — vmap keeps lanes independent, so results on live tenants
    are identical whether or not frozen rows share the batch.

    ``capture_trace=True`` additionally returns per-lane PGD convergence
    rows in ``FleetStepResult.trace`` (a separately-compiled program whose
    solves agree with the untraced one — test-enforced).

    An *enabled* ``anytime`` config (``core.pgd.AnytimeConfig`` with
    ``deadline_ms`` set) runs the tick chunked against the injectable
    clock and returns each lane's best-so-far feasible iterate when the
    fleet-wide budget expires, with ``FleetStepResult.deadline_hit``
    reporting the truncation; a disabled/absent config takes the exact
    pre-anytime program (Python-level branch — bit-identical results)."""
    prob = fleet.problem if isinstance(fleet, FleetBatch) else fleet
    if active is None and isinstance(fleet, FleetBatch):
        active = fleet.active_mask
    B = prob.c.shape[0]
    x_current = jnp.asarray(x_current, jnp.float32)
    delta_max = jnp.broadcast_to(jnp.asarray(delta_max, jnp.float32), (B,))
    x_init = x_current if x_init is None else jnp.asarray(x_init, jnp.float32)
    active = (jnp.ones(B, bool) if active is None
              else jnp.asarray(np.asarray(active, bool)))
    if anytime is not None and anytime.enabled:
        if capture_trace:
            raise ValueError("anytime deadlines and capture_trace are "
                             "mutually exclusive; drop one")
        cfg = PGDConfig(max_iters=int(steps))
        state, report = run_anytime(
            lambda: _step_fleet_anytime_init_impl(prob, x_current, delta_max,
                                                  x_init, cfg),
            lambda s, e: _step_fleet_anytime_chunk_impl(prob, x_current,
                                                        delta_max, s, e, cfg),
            cfg, anytime)
        res = _step_fleet_anytime_finalize_impl(prob, state.x_best, x_current,
                                                active, state.it)
        return res._replace(deadline_hit=report.deadline_hit)
    impl = _step_fleet_traced_impl if capture_trace else _step_fleet_impl
    return impl(prob, x_current, delta_max, x_init, active, int(steps))

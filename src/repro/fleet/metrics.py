"""Fleet/time metric aggregation for trace replays.

Extends the paper's snapshot metrics (repro.core.metrics) along two axes:
over TIME (cost integral, SLO-violation ticks, churn) and over the FLEET
(tenant aggregates, optimizer-vs-CA deltas).

Metric definitions (see docs/fleet.md for the full glossary):

* cost integral — sum over ticks of the allocation's $/hr ($ for 1h ticks).
* SLO-violation ticks — ticks where provided capacity < demand on any
  resource (the snapshot metric's ``satisfied`` flag, counted over time).
* churn — L1 distance between consecutive allocations, summed over ticks:
  the number of node adds+removes the plan asked operations to execute.
* fragmentation — providers in use per tick (mean over the trace).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core.metrics import AllocationMetrics
from repro.obs.health import HealthReport


@dataclass
class TenantReplayMetrics:
    """One tenant's trace replay, integrated over ticks."""

    name: str
    ticks: int
    cost_integral: float          # sum over ticks of $/hr (== $ for 1h ticks)
    slo_violation_ticks: int      # ticks where provided < demand
    total_churn: float            # sum ||x_t - x_{t-1}||_1
    mean_utilization_pct: float
    mean_fragmentation: float     # mean providers used per tick
    mean_diversity: float         # mean distinct instance types per tick
    peak_cost: float
    max_churn_violation: float = 0.0  # worst per-tick excess over delta_max
    # per-tick PGD iteration counts (ControllerStep.solver_iters; 0 on cold
    # multistart ticks). None for baselines that run no solver (the CA
    # replay). compare=False: solver EFFORT is diagnostics, not part of the
    # engine-equivalence contract — padded-reduction ulps can shift Armijo
    # accepts between the sequential and batched engines by a few
    # iterations even though the quantized allocations agree exactly.
    solver_iters: Optional[List[int]] = field(default=None, compare=False)

    @property
    def slo_violation_rate(self) -> float:
        return self.slo_violation_ticks / max(self.ticks, 1)


def tenant_metrics(name: str, steps: Sequence[AllocationMetrics],
                   churns: Sequence[float],
                   churn_violations: Optional[Sequence[float]] = None,
                   solver_iters: Optional[Sequence[int]] = None
                   ) -> TenantReplayMetrics:
    """Integrate one tenant's per-tick snapshot metrics over the trace (see
    the module docstring / docs/fleet.md for each metric's definition).
    ``churn_violations`` are the per-tick ``ControllerStep.churn_violation``
    values — the rounded allocation's excess over ``delta_max`` — omitted
    for baselines that carry no churn bound (the CA replay); likewise
    ``solver_iters`` (the per-tick ``ControllerStep.solver_iters``) feeds
    the fleet-level iteration percentiles and is omitted for baselines."""
    costs = np.asarray([s.total_cost for s in steps], np.float64)
    return TenantReplayMetrics(
        name=name,
        ticks=len(steps),
        cost_integral=float(costs.sum()),
        slo_violation_ticks=int(sum(not s.satisfied for s in steps)),
        total_churn=float(np.sum(churns)),
        mean_utilization_pct=float(np.mean([s.utilization_pct for s in steps])),
        mean_fragmentation=float(np.mean([s.provider_fragmentation
                                          for s in steps])),
        mean_diversity=float(np.mean([s.instance_diversity for s in steps])),
        peak_cost=float(costs.max()),
        max_churn_violation=(float(np.max(churn_violations))
                             if churn_violations is not None
                             and len(churn_violations) else 0.0),
        solver_iters=(None if solver_iters is None
                      else [int(i) for i in solver_iters]),
    )


@dataclass
class FleetReplayMetrics:
    """Aggregate over all tenants; optionally paired with a CA baseline.

    ``replay_mode`` records which engine produced the histories
    ("sequential" or "batched") — the numbers must agree between the two
    (tests/fleet/test_replay.py enforces it), so this is provenance only.
    ``controller`` likewise records which control loop ran ("myopic" or
    "mpc"). ``oracle`` optionally holds the SAME fleet replayed by the MPC
    controller under the ground-truth oracle forecaster
    (``replay_fleet(run_oracle_baseline=True)``) — the regret reference:
    any gap between ``tenants`` and ``oracle`` is what forecast error cost
    (docs/horizon.md, regret definition). ``health`` is the rolled-up
    ``repro.obs.HealthReport`` when the replay ran with a ``HealthMonitor``
    attached (``replay_fleet(health=...)``) — breach/violation/deadline
    counters and the worst committed-tick KKT residual, surfaced by
    ``summary()`` so ``repro.fleet`` users see health without touching
    ``repro.obs`` directly. compare=False: health carries wall-clock-
    dependent observations (deadline misses), which the engine-equivalence
    contract must not include."""

    tenants: List[TenantReplayMetrics]
    baseline: Optional[List[TenantReplayMetrics]] = None
    replay_mode: str = "sequential"
    controller: str = "myopic"
    oracle: Optional[List[TenantReplayMetrics]] = None
    health: Optional[HealthReport] = field(default=None, compare=False)

    @property
    def total_cost_integral(self) -> float:
        return sum(t.cost_integral for t in self.tenants)

    @property
    def total_slo_violation_ticks(self) -> int:
        return sum(t.slo_violation_ticks for t in self.tenants)

    @property
    def total_churn(self) -> float:
        return sum(t.total_churn for t in self.tenants)

    @property
    def mean_fragmentation(self) -> float:
        return float(np.mean([t.mean_fragmentation for t in self.tenants]))

    @property
    def total_tenant_ticks(self) -> int:
        """Sum of per-tenant tick counts — the fleet's unit of replayed work
        (tenant-ticks), well-defined for ragged horizons."""
        return sum(t.ticks for t in self.tenants)

    @property
    def max_churn_violation(self) -> float:
        """Fleet-wide worst per-tick excess of realized churn over the
        churn bound ``delta_max`` (rounding's feasibility-first overshoot).
        MPC-vs-myopic churn comparisons need it to be honest: a controller
        reporting less churn while violating the bound harder isn't
        better."""
        return max((t.max_churn_violation for t in self.tenants), default=0.0)

    @property
    def solver_iters_percentiles(self) -> Optional[dict]:
        """Fleet-wide per-tick PGD iteration percentiles (p50/p95/max) over
        WARM ticks — cold multistart ticks report 0 iterations and are
        excluded so the percentiles describe the incremental engine the
        replay actually spends its time in. None when no tenant recorded
        iteration counts (CA baseline replays) or every tick was cold."""
        vals = [i for t in self.tenants if t.solver_iters is not None
                for i in t.solver_iters if i > 0]
        if not vals:
            return None
        arr = np.asarray(vals, np.float64)
        return {"p50": float(np.percentile(arr, 50)),
                "p95": float(np.percentile(arr, 95)),
                "max": int(arr.max())}

    @property
    def baseline_cost_integral(self) -> Optional[float]:
        if self.baseline is None:
            return None
        return sum(t.cost_integral for t in self.baseline)

    @property
    def oracle_cost_integral(self) -> Optional[float]:
        if self.oracle is None:
            return None
        return sum(t.cost_integral for t in self.oracle)

    @property
    def regret_vs_oracle(self) -> Optional[float]:
        """Cost-integral regret against the oracle-forecast replay of the
        SAME fleet and controller: cost(this run) - cost(oracle run).
        Positive regret is the price of forecast error; the oracle run pays
        only for model limits (horizon, churn bound, convexification)."""
        base = self.oracle_cost_integral
        if base is None:
            return None
        return self.total_cost_integral - base

    @property
    def cost_savings_vs_baseline_pct(self) -> Optional[float]:
        base = self.baseline_cost_integral
        if base is None or base <= 0:
            return None
        return 100.0 * (base - self.total_cost_integral) / base

    def summary(self) -> str:
        # horizons may be ragged — report the range, not tenants[0]'s length
        ticks = sorted({t.ticks for t in self.tenants})
        if not ticks:
            horizon = "0 ticks"
        elif len(ticks) == 1:
            horizon = f"{ticks[0]} ticks"
        else:
            horizon = (f"{self.total_tenant_ticks} tenant-ticks "
                       f"(ragged horizons {ticks[0]}-{ticks[-1]})")
        lines = [
            f"fleet of {len(self.tenants)} tenants, {horizon} "
            f"({self.replay_mode} replay, {self.controller} controller)",
            f"  cost integral      : ${self.total_cost_integral:,.2f}",
            f"  SLO violation ticks: {self.total_slo_violation_ticks}",
            f"  total churn (L1)   : {self.total_churn:,.1f}",
            f"  max churn overrun  : {self.max_churn_violation:.1f} "
            f"(worst per-tick excess over delta_max)",
            f"  mean fragmentation : {self.mean_fragmentation:.2f} providers",
        ]
        pct = self.solver_iters_percentiles
        if pct is not None:
            lines.append(f"  solver iters/tick  : p50 {pct['p50']:.0f}, "
                         f"p95 {pct['p95']:.0f}, max {pct['max']} "
                         f"(warm ticks)")
        if self.baseline is not None:
            lines.append(f"  CA baseline cost   : "
                         f"${self.baseline_cost_integral:,.2f}")
            lines.append(f"  savings vs CA      : "
                         f"{self.cost_savings_vs_baseline_pct:+.1f}%")
        if self.oracle is not None:
            lines.append(f"  oracle-MPC cost    : "
                         f"${self.oracle_cost_integral:,.2f}")
            lines.append(f"  regret vs oracle   : "
                         f"${self.regret_vs_oracle:+,.2f}")
        if self.health is not None:
            lines.extend(self.health.summary_lines())
        return "\n".join(lines)

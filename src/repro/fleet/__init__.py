"""repro.fleet — multi-tenant, time-varying allocation.

The paper optimizes one cluster snapshot; this package makes *fleets* of
tenant clusters a first-class path:

  * batching   — stack heterogeneous AllocationProblems into one padded,
                 masked (B, n_max) pytree; shape-bucketed stacking
                 (``bucket_problems``) groups tenants into power-of-two
                 buckets to cut padding waste on ragged fleets.
  * solver     — solve_fleet: one jitted batched phase-1 -> barrier PGD ->
                 rounding pass over the whole fleet x multi-starts, with the
                 objective+gradient hot loop routed through the
                 kernels.alloc_objective Pallas path; solve_fleet_bucketed
                 solves one batch per shape bucket; solve_fleet_step runs a
                 warm-started incremental tick for every tenant at once.
  * traces     — seedable synthetic demand-trace generators (diurnal, flash
                 crowd, ramp, weekly seasonality).
  * replay     — step every tenant's controller through a trace (warm starts,
                 bounded churn), sequentially or with one batched solve per
                 shape bucket per tick (``replay_mode="batched"``; ragged
                 per-tenant horizons freeze finished tenants via active
                 masks), and run the CA baseline on the same traces — pools
                 sized from each trace's peak demand, replayed by the
                 vectorized lockstep stepper by default. Either engine can
                 drive the myopic controller or the forecast-driven
                 receding-horizon controller (``controller="mpc"``,
                 see ``repro.horizon``).
  * scenarios  — fleet builders for the priced-term objective IR
                 (``repro.core.terms``): SLO-credit pricing, priority
                 classes, and the spot market (discounted catalog twins +
                 interruption risk term + seeded availability overlay).
  * metrics    — fleet/time aggregation: cost integral, SLO-violation ticks,
                 churn, fragmentation.

Documentation: docs/fleet.md (subsystem guide), docs/architecture.md
(package map), docs/math.md (model-to-code mapping).
"""
from .batching import (BucketedFleet, FleetBatch, bucket_dims,
                       bucket_problems, ceil_pow2, embed_solutions,
                       padding_stats, scatter_from_buckets, stack_problems,
                       tenant_problem, union_term_kinds, unstack_solution)
from .solver import (FleetSolveResult, FleetStepResult, make_fleet_starts,
                     solve_fleet, solve_fleet_bucketed, solve_fleet_step)
from .traces import (TRACE_KINDS, constant_trace, diurnal_trace,
                     flash_crowd_trace, make_trace, ramp_trace,
                     spot_interruption_trace, weekly_trace)
from .metrics import FleetReplayMetrics, TenantReplayMetrics
from .replay import FleetReplayResult, TenantSpec, replay_fleet
from .scenarios import (PRIORITY_CLASSES, make_spot_fleet,
                        with_priority_classes, with_slo_pricing)

__all__ = [
    "FleetBatch", "stack_problems", "unstack_solution", "embed_solutions",
    "tenant_problem", "union_term_kinds",
    "BucketedFleet", "bucket_dims", "bucket_problems", "ceil_pow2",
    "scatter_from_buckets", "padding_stats",
    "FleetSolveResult", "solve_fleet", "solve_fleet_bucketed",
    "FleetStepResult", "solve_fleet_step", "make_fleet_starts",
    "diurnal_trace", "flash_crowd_trace", "ramp_trace", "weekly_trace",
    "constant_trace", "spot_interruption_trace", "make_trace", "TRACE_KINDS",
    "TenantSpec", "replay_fleet", "FleetReplayResult",
    "TenantReplayMetrics", "FleetReplayMetrics",
    "PRIORITY_CLASSES", "with_slo_pricing", "with_priority_classes",
    "make_spot_fleet",
]

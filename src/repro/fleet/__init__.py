"""repro.fleet — multi-tenant, time-varying allocation.

The paper optimizes one cluster snapshot; this package makes *fleets* of
tenant clusters a first-class path:

  * batching   — stack heterogeneous AllocationProblems into one padded,
                 masked (B, n_max) pytree.
  * solver     — solve_fleet: one jitted batched phase-1 -> barrier PGD ->
                 rounding pass over the whole fleet x multi-starts, with the
                 objective+gradient hot loop routed through the
                 kernels.alloc_objective Pallas path.
  * traces     — seedable synthetic demand-trace generators (diurnal, flash
                 crowd, ramp, weekly seasonality).
  * replay     — step every tenant's controller through a trace (warm starts,
                 bounded churn) and run the CA baseline on the same traces.
  * metrics    — fleet/time aggregation: cost integral, SLO-violation ticks,
                 churn, fragmentation.
"""
from .batching import FleetBatch, stack_problems, unstack_solution
from .solver import FleetSolveResult, solve_fleet
from .traces import (diurnal_trace, flash_crowd_trace, make_trace, ramp_trace,
                     weekly_trace)
from .metrics import FleetReplayMetrics, TenantReplayMetrics
from .replay import FleetReplayResult, TenantSpec, replay_fleet

__all__ = [
    "FleetBatch", "stack_problems", "unstack_solution",
    "FleetSolveResult", "solve_fleet",
    "diurnal_trace", "flash_crowd_trace", "ramp_trace", "weekly_trace",
    "make_trace",
    "TenantSpec", "replay_fleet", "FleetReplayResult",
    "TenantReplayMetrics", "FleetReplayMetrics",
]

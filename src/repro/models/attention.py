"""Grouped-query attention with RoPE, optional QKV bias, sliding window,
and a paged-free decode path over a preallocated KV cache.

The jnp path here is the reference; `repro.kernels.flash_attention` /
`decode_attention` provide the Pallas TPU implementations (enabled with
``use_pallas=True`` — numerically validated against this path in tests).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .layers import apply_rope, rope_tables
from .param import dense_init, zeros_init

NEG_INF = -1e30


def init_attention(key, cfg, dtype):
    D, H, G, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, dh), ("embed", "heads", None), dtype),
        "wk": dense_init(ks[1], (D, G, dh), ("embed", "kv_heads", None), dtype),
        "wv": dense_init(ks[2], (D, G, dh), ("embed", "kv_heads", None), dtype),
        "wo": dense_init(ks[3], (H, dh, D), ("heads", None, "embed"), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((H, dh), ("heads", None), dtype)
        p["bk"] = zeros_init((G, dh), ("kv_heads", None), dtype)
        p["bv"] = zeros_init((G, dh), ("kv_heads", None), dtype)
    return p


class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, G, S_max, dh)
    v: jnp.ndarray        # (B, G, S_max, dh)

    @classmethod
    def zeros(cls, batch, n_kv, s_max, d_head, dtype):
        shape = (batch, n_kv, s_max, d_head)
        return cls(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _qkv(p, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    cos, sin = rope_tables(positions, cfg.d_head, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "act_heads", None)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q (B,S,H,dh), k/v (B,T,G,dh), mask (B|1,1,S,T) additive."""
    B, S, H, dh = q.shape
    G = k.shape[2]
    q = q.reshape(B, S, G, H // G, dh)
    scores = jnp.einsum("bsgrd,btgd->bgrst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    scores = scores + mask[:, None, :, :, :] if mask.ndim == 4 else scores + mask
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, dh)


Q_CHUNK, KV_CHUNK = 512, 1024


def _chunked_flash(q, k, v, window: int, q_chunk=None, kv_chunk=None,
                   unroll: bool = False, probs_bf16: bool = False):
    """Pure-JAX flash attention (online softmax over KV chunks, scan over Q
    chunks). Memory: O(B * q_chunk * H * kv_chunk) instead of O(B*H*S^2) —
    both scan bodies are jax.checkpoint-ed, so the O(S^2) score blocks are
    recomputed in the backward instead of being saved as scan residuals.
    Causal + optional sliding window, applied via masking (the Pallas kernel
    additionally skips fully-masked blocks)."""
    B, S, H, dh = q.shape
    T, G = k.shape[1], k.shape[2]
    qc = min(q_chunk or Q_CHUNK, S)
    kc = min(kv_chunk or KV_CHUNK, T)
    assert S % qc == 0 and T % kc == 0, (S, qc, T, kc)
    R = H // G
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    q = q.reshape(B, S // qc, qc, G, R, dh)
    k = k.reshape(B, T // kc, kc, G, dh)
    v = v.reshape(B, T // kc, kc, G, dh)

    def q_step(_, qi):
        qblk, qidx = qi                      # (B, qc, G, R, dh), scalar chunk id
        q0 = qidx * qc

        def kv_step(carry, kj):
            m, l, acc = carry
            kblk, vblk, kidx = kj            # (B, kc, G, dh)
            k0 = kidx * kc
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qblk, kblk).astype(jnp.float32)
            s = s * scale
            qpos = q0 + jnp.arange(qc)[:, None]
            kpos = k0 + jnp.arange(kc)[None, :]
            ok = kpos <= qpos
            if window > 0:
                ok &= kpos > qpos - window
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            if probs_bf16:
                # perf lever: post-max-subtraction weights are in [0, 1] —
                # bf16 is safe here and halves the score-chain bytes
                p = p.astype(jnp.bfloat16)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, R, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, qc), jnp.float32)
        a0 = jnp.zeros((B, G, R, qc, dh), v.dtype)
        kv_idx = jnp.arange(T // kc)
        body = kv_step if unroll else jax.checkpoint(kv_step)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.swapaxes(k, 0, 1), jnp.swapaxes(v, 0, 1), kv_idx),
            unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return None, out                      # (B, G, R, qc, dh)

    q_idx = jnp.arange(S // qc)
    q_body = q_step if unroll else jax.checkpoint(q_step)
    _, outs = jax.lax.scan(q_body, None, (jnp.swapaxes(q, 0, 1), q_idx),
                           unroll=unroll)
    # outs: (S//qc, B, G, R, qc, dh) -> (B, S, H, dh)
    outs = jnp.moveaxis(outs, 0, 1)                       # (B, S//qc, G, R, qc, dh)
    outs = jnp.transpose(outs, (0, 1, 4, 2, 3, 5)).reshape(B, S, G * R, dh)
    return outs


def causal_mask(S: int, T: int, offset: int, window: int) -> jnp.ndarray:
    """(1, 1, S, T) additive mask. offset = index of query 0 within keys."""
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF)[None, None].astype(jnp.float32)


def _attend_full(q, k, v, cfg, use_pallas: bool):
    S = q.shape[1]
    if use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, window=cfg.window)
    if S > 1024:
        return _chunked_flash(q, k, v, cfg.window,
                              q_chunk=cfg.attn_q_chunk or None,
                              kv_chunk=cfg.attn_kv_chunk or None,
                              unroll=getattr(cfg, "unroll_inner", False),
                              probs_bf16=getattr(cfg, "attn_probs_bf16", False))
    return _sdpa(q, k, v, causal_mask(S, S, 0, cfg.window))


def attention(p, cfg, x, positions, *, use_pallas: bool = False):
    """Full-sequence (train / prefill) path. x (B, S, D)."""
    B, S, D = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = _attend_full(q, k, v, cfg, use_pallas)
    out = constrain(out, "batch", "seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(y, "batch", "seq", "act_embed")


def prefill_attention(p, cfg, x, positions, cache: KVCache,
                      *, use_pallas: bool = False):
    """Prefill: run full attention AND write k/v into the cache (which may be
    longer than S; ring-buffered when cfg.window > 0 and cache is smaller)."""
    B, S, D = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = _attend_full(q, k, v, cfg, use_pallas)
    s_max = cache.k.shape[2]
    kc = jnp.swapaxes(k, 1, 2)     # (B, G, S, dh)
    vc = jnp.swapaxes(v, 1, 2)
    if s_max < S:                  # sliding-window ring buffer
        assert cfg.window > 0 and s_max >= cfg.window
        tail = s_max
        kc, vc = kc[:, :, -tail:], vc[:, :, -tail:]
        # ring layout: slot = position % s_max
        pos_tail = positions[-tail:] % s_max
        new_k = cache.k.at[:, :, pos_tail].set(kc)
        new_v = cache.v.at[:, :, pos_tail].set(vc)
    else:
        new_k = jax.lax.dynamic_update_slice(cache.k, kc, (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache.v, vc, (0, 0, 0, 0))
    new_k = constrain(new_k, "batch", "kv_heads", "kv_seq", None)
    new_v = constrain(new_v, "batch", "kv_heads", "kv_seq", None)
    out = constrain(out, "batch", "seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(y, "batch", "seq", "act_embed"), KVCache(new_k, new_v)


def decode_attention_step(p, cfg, x, pos, cache: KVCache,
                          *, use_pallas: bool = False):
    """Single-token decode. x (B, 1, D); pos scalar int32 (same for batch).
    Cache is (B, G, S_max, dh); ring-buffered iff cfg.window > 0 and
    S_max == window size."""
    B, S, D = x.shape
    assert S == 1
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _qkv(p, cfg, x, positions.reshape(1))
    s_max = cache.k.shape[2]
    ring = cfg.window > 0 and s_max <= cfg.window
    slot = jnp.where(jnp.asarray(ring), pos % s_max, pos)
    kc = jnp.swapaxes(k, 1, 2)    # (B, G, 1, dh)
    vc = jnp.swapaxes(v, 1, 2)
    new_k = jax.lax.dynamic_update_slice(cache.k, kc, (0, 0, slot, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, vc, (0, 0, slot, 0))
    new_k = constrain(new_k, "batch", "kv_heads", "kv_seq", None)
    new_v = constrain(new_v, "batch", "kv_heads", "kv_seq", None)

    kpos = jnp.arange(s_max)
    if ring:
        valid = jnp.where(pos >= s_max - 1, jnp.ones_like(kpos, bool),
                          kpos <= pos % s_max)
    else:
        valid = kpos <= pos
        if cfg.window > 0:
            valid &= kpos > pos - cfg.window
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, :].astype(jnp.float32)

    if use_pallas:
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q, new_k, new_v, valid)
    else:
        kk = jnp.swapaxes(new_k, 1, 2)    # (B, S_max, G, dh)
        vv = jnp.swapaxes(new_v, 1, 2)
        out = _sdpa(q, kk, vv, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(y, "batch", "seq", "act_embed"), KVCache(new_k, new_v)

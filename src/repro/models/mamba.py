"""Mamba (S6) block for the Jamba hybrid — selective state-space model with
chunked scan (bounded memory: the (B, chunk, d_inner, d_state) intermediate
replaces the full (B, S, d_inner, d_state) tensor).

Decode carries (conv_state (B, d_conv-1, d_inner), ssm_state (B, d_inner, N)).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .param import Boxed, const_init, dense_init, ones_init, zeros_init


class MambaCache(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, d_inner)
    ssm: jnp.ndarray    # (B, d_inner, N)

    @classmethod
    def zeros(cls, batch, cfg, dtype):
        di, N, dc = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
        return cls(jnp.zeros((batch, dc - 1, di), dtype),
                   jnp.zeros((batch, di, N), jnp.float32))


def init_mamba(key, cfg, dtype):
    D, di, N, dc = (cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state,
                    cfg.mamba_d_conv)
    dt_rank = max(1, D // 16)
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di), ("embed", "mamba_inner"), dtype),
        "conv_w": dense_init(ks[1], (dc, di), (None, "mamba_inner"), dtype, scale=0.5),
        "conv_b": zeros_init((di,), ("mamba_inner",), dtype),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * N), ("mamba_inner", None), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, di), (None, "mamba_inner"), dtype),
        "dt_bias": const_init(jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                                jnp.log(1e-3), jnp.log(1e-1))),
                     1e-4, None))).astype(jnp.float32), ("mamba_inner",)),
        "A_log": const_init(jnp.log(A), ("mamba_inner", None)),
        "D": ones_init((di,), ("mamba_inner",), jnp.float32),
        "out_proj": dense_init(ks[5], (di, D), ("mamba_inner", "embed"), dtype),
    }


def _ssm_chunked_scan(u, dt, B_, C_, A, D, chunk: int, init_state=None,
                      unroll: bool = False, scan_bf16: bool = False):
    """u/dt (B, S, di); B_/C_ (B, S, N); A (di, N); D (di,).
    Returns (y (B, S, di), final_state (B, di, N))."""
    Bb, S, di = u.shape
    N = B_.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # identity padding: dt=0 -> dA=1 (no decay), dBu=0 (no injection)
        z2 = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        u, dt, B_, C_ = z2(u), z2(dt), z2(B_), z2(C_)
    nc = (S + pad) // chunk

    # reshape to chunks
    u_c = u.reshape(Bb, nc, chunk, di)
    dt_c = dt.reshape(Bb, nc, chunk, di)
    B_c = B_.reshape(Bb, nc, chunk, N)
    C_c = C_.reshape(Bb, nc, chunk, N)

    def chunk_step(state, args):
        uc, dtc, Bc, Cc = args                                  # (B, chunk, ...)
        # discretize within chunk
        dA_c = jnp.exp(dtc[..., None] * (-A)[None, None])       # (B, c, di, N)
        dBu = (dtc * uc)[..., None] * Bc[:, :, None, :]         # (B, c, di, N)
        if scan_bf16:
            # perf lever: dA in [0,1], dBu bounded — bf16 halves the scan's
            # (B, c, di, N) traffic; the carried state stays f32.
            dA_c = dA_c.astype(jnp.bfloat16)
            dBu = dBu.astype(jnp.bfloat16)
        # h_t = dA_t h_{t-1} + dBu_t  — associative scan over the chunk
        # (pairwise composition keeps every factor <= 1: no overflow).
        def compose(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        At, Bt = jax.lax.associative_scan(compose, (dA_c, dBu), axis=1)
        h = (At.astype(jnp.float32) * state[:, None]
             + Bt.astype(jnp.float32))                          # (B, c, di, N)
        y = jnp.einsum("bcdn,bcn->bcd", h, Cc)
        new_state = h[:, -1]
        return new_state, y

    state0 = (jnp.zeros((Bb, di, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))
    args = (jnp.swapaxes(u_c, 0, 1), jnp.swapaxes(dt_c, 0, 1),
            jnp.swapaxes(B_c, 0, 1), jnp.swapaxes(C_c, 0, 1))
    # checkpoint the chunk body: associative_scan saves per-level residuals
    # ((B, chunk, di, N) x log2(chunk)) otherwise — recompute them in bwd.
    body = chunk_step if unroll else jax.checkpoint(chunk_step)
    final, ys = jax.lax.scan(body, state0, args, unroll=unroll)
    y = jnp.swapaxes(ys, 0, 1).reshape(Bb, nc * chunk, di)[:, :S]
    return y + u[:, :S] * D[None, None], final


def _causal_conv(x, w, b, init_state=None):
    """x (B, S, di); w (dc, di) depthwise causal; returns (y, new_state)."""
    Bb, S, di = x.shape
    dc = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((Bb, dc - 1, di), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                      # (B, S+dc-1, di)
    y = sum(xp[:, i:i + S] * w[i][None, None] for i in range(dc)) + b
    return y, xp[:, -(dc - 1):] if dc > 1 else jnp.zeros((Bb, 0, di), x.dtype)


def mamba_block(p, cfg, x, cache: MambaCache = None):
    """x (B, S, D) -> (y (B, S, D), new_cache)."""
    Bb, S, D = x.shape
    di, N = cfg.mamba_d_inner, cfg.mamba_d_state
    dt_rank = p["dt_proj"].shape[0]

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", "seq", "mamba_inner")

    conv_in = cache.conv if cache is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_in)
    xs = jax.nn.silu(xs)

    proj = jnp.einsum("bsd,dr->bsr", xs, p["x_proj"])
    dt_lo, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_lo, p["dt_proj"])
                         + p["dt_bias"][None, None]).astype(jnp.float32)

    A = jnp.exp(p["A_log"])                                     # (di, N) > 0
    chunk = cfg.scan_chunk or min(256, S)
    init_state = cache.ssm if cache is not None else None
    y, final_state = _ssm_chunked_scan(
        xs.astype(jnp.float32), dt, B_.astype(jnp.float32),
        C_.astype(jnp.float32), A, p["D"], chunk, init_state,
        unroll=cfg.unroll_inner,
        scan_bf16=getattr(cfg, "ssm_scan_bf16", False))
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    out = constrain(out, "batch", "seq", "act_embed")
    new_cache = MambaCache(conv=new_conv, ssm=final_state)
    return out, new_cache


def mamba_decode_step(p, cfg, x, cache: MambaCache):
    """Single-token decode: O(1) state update. x (B, 1, D)."""
    return mamba_block(p, cfg, x, cache)

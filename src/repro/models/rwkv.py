"""RWKV6 "Finch" — attention-free time-mix with data-dependent decay.

Per head h (size hs): state S in R^{hs x hs}, per-token decay w_t in (0,1)^hs:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Training/prefill uses the chunked GLA-style closed form (intra-chunk
attention-like matrix with relative decays + inter-chunk state carry);
decode is the O(1) recurrence. ``repro.kernels.rwkv6_scan`` is the Pallas
version of the chunk kernel; this module is its jnp oracle.

Data-dependent pieces (faithful to RWKV6): five token-shift lerps with
learned mixes; the decay w_t additionally gets a low-rank (LoRA) data path.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .param import const_init, dense_init, ones_init, zeros_init


class RWKVCache(NamedTuple):
    shift_tm: jnp.ndarray   # (B, D)  last token for time-mix shift
    shift_cm: jnp.ndarray   # (B, D)  last token for channel-mix shift
    wkv: jnp.ndarray        # (B, H, hs, hs) state (f32)

    @classmethod
    def zeros(cls, batch, cfg, dtype):
        H, hs = cfg.n_rwkv_heads, cfg.rwkv_head_size
        return cls(jnp.zeros((batch, cfg.d_model), dtype),
                   jnp.zeros((batch, cfg.d_model), dtype),
                   jnp.zeros((batch, H, hs, hs), jnp.float32))


def init_rwkv_time_mix(key, cfg, dtype):
    D, H, hs, r = (cfg.d_model, cfg.n_rwkv_heads, cfg.rwkv_head_size,
                   cfg.rwkv_lora_rank)
    ks = jax.random.split(key, 9)
    return {
        "mix": const_init(0.5 * jnp.ones((5, D), jnp.float32), (None, "act_embed")),
        "w_base": const_init(-6.0 * jnp.ones((D,), jnp.float32) , ("act_embed",)),
        "w_lora_a": dense_init(ks[0], (D, r), ("embed", None), dtype, scale=0.01),
        "w_lora_b": dense_init(ks[1], (r, D), (None, "embed"), dtype, scale=0.01),
        "wr": dense_init(ks[2], (D, D), ("embed", "mlp"), dtype),
        "wk": dense_init(ks[3], (D, D), ("embed", "mlp"), dtype),
        "wv": dense_init(ks[4], (D, D), ("embed", "mlp"), dtype),
        "wg": dense_init(ks[5], (D, D), ("embed", "mlp"), dtype),
        "u": const_init(jnp.zeros((H, hs), jnp.float32), ("rwkv_heads", None)),
        "wo": dense_init(ks[6], (D, D), ("mlp", "embed"), dtype),
        "ln_x": ones_init((D,), ("act_embed",), jnp.float32),
    }


def init_rwkv_channel_mix(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix": const_init(0.5 * jnp.ones((2, D), jnp.float32), (None, "act_embed")),
        "wk": dense_init(ks[0], (D, F), ("embed", "mlp"), dtype),
        "wv": dense_init(ks[1], (F, D), ("mlp", "embed"), dtype),
        "wr": dense_init(ks[2], (D, D), ("embed", "act_embed"), dtype),
    }


def _token_shift(x, last):
    """shifted[t] = x[t-1]; shifted[0] = last (carried state). x (B,S,D)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_chunked(r, k, v, w, u, state, chunk: int, unroll: bool = False):
    """r,k,v (B,S,H,hs); w (B,S,H,hs) decay in (0,1); u (H,hs); state
    (B,H,hs,hs). Returns (y (B,S,H,hs), final_state). Chunked closed form:

      y_t = r_t diag(W_{t-1}) S_0   (inter-chunk)
          + sum_{i<t} (r_t * W_{t-1}/W_i) . k_i  v_i    (intra, strict lower)
          + (r_t . u . k_t) v_t                         (bonus diagonal)
    where W_t = prod_{j<=t} w_j within the chunk (W_0 = w_1? see below: we
    use W at t-1 = product of w_1..w_{t-1}, consistent with S_{t-1}).
    """
    B, S, H, hs = r.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # identity padding: k=0 adds nothing to the state, w=1 leaves it
        # undecayed; outputs for the padded tail are sliced off below.
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    S_pad = S + pad
    nc = S_pad // chunk
    rc = r.reshape(B, nc, chunk, H, hs)
    kc = k.reshape(B, nc, chunk, H, hs)
    vc = v.reshape(B, nc, chunk, H, hs)
    wc = w.reshape(B, nc, chunk, H, hs)
    del S_pad

    def step(S0, args):
        rr, kk, vv, ww = args                 # (B, c, H, hs)
        logw = jnp.log(ww)                    # < 0
        cum = jnp.cumsum(logw, axis=1)        # log W_t (prod up to and incl t)
        Wm1 = jnp.exp(cum - logw)             # W_{t-1} (excl. current)
        r_dec = rr * Wm1                      # (B, c, H, hs)
        # intra-chunk relative decays: exp(cum_{t-1} - cum_i) applied r-side
        # (r * W_{t-1}) . (k / W_i): ratio <= 1 for i < t keeps stability
        # bounded as long as chunk is short (k / W_i can grow; clamp cum).
        k_dec = kk * jnp.exp(-jnp.clip(cum, -60.0, 0.0))
        att = jnp.einsum("bthc,bihc->bhti", r_dec, k_dec)      # (B, H, c, c)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        bonus = jnp.einsum("bthc,bthc->bth", rr * u[None, None], kk)
        y = jnp.einsum("bhti,bihc->bthc", att, vv)
        y = y + bonus[..., None] * vv
        y = y + jnp.einsum("bthc,bhcd->bthd", r_dec, S0)
        # state update: S_end = diag(W_c) S0 + sum_i diag(W_c/W_i) k_i v_i^T
        Wc = jnp.exp(cum[:, -1])                                # (B, H, hs)
        # decay from i to chunk end: exp(cum_end - cum_i) <= 1 (stable)
        k_tail = kk * jnp.exp(cum[:, -1][:, None] - cum)
        S_new = Wc[..., None] * S0 + jnp.einsum("bihc,bihd->bhcd", k_tail, vv)
        return S_new, y

    args = tuple(jnp.swapaxes(a, 0, 1) for a in (rc, kc, vc, wc))
    state = state.astype(jnp.float32)
    # checkpoint the chunk body: the (B, H, c, c) intra-chunk attention-like
    # matrix is recomputed in bwd instead of living as a scan residual.
    body = step if unroll else jax.checkpoint(step)
    final, ys = jax.lax.scan(body, state, args, unroll=unroll)
    y = jnp.swapaxes(ys, 0, 1).reshape(B, nc * chunk, H, hs)[:, :S]
    return y, final


def rwkv_time_mix(p, cfg, x, cache: RWKVCache):
    """x (B, S, D) -> (y, new_cache). cache.shift_tm/wkv used & updated."""
    B, S, D = x.shape
    H, hs = cfg.n_rwkv_heads, cfg.rwkv_head_size
    last = cache.shift_tm if cache is not None else jnp.zeros((B, D), x.dtype)
    xprev = _token_shift(x, last)
    xx = xprev - x
    mixed = (x[:, :, None, :]
             + xx[:, :, None, :] * p["mix"][None, None]).astype(x.dtype)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]  # (B,S,D) each

    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, S, H, hs)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, S, H, hs)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(B, S, H, hs)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))

    # data-dependent decay (the "Finch" contribution): base + low-rank path
    dw = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw), p["w_lora_a"])
    dw = jnp.einsum("bsr,rd->bsd", dw, p["w_lora_b"])
    w = jnp.exp(-jnp.exp((p["w_base"][None, None] + dw).astype(jnp.float32)))
    w = w.reshape(B, S, H, hs)

    state = cache.wkv if cache is not None else jnp.zeros((B, H, hs, hs), jnp.float32)
    chunk = cfg.scan_chunk or min(64, S)
    y, new_state = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), w,
                                p["u"], state, chunk,
                                unroll=cfg.unroll_inner)
    y = y.reshape(B, S, D).astype(x.dtype)
    # group norm over heads (ln_x), then gate and output proj
    y = y.reshape(B, S, H, hs)
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = ((y - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = y * p["ln_x"][None, None]
    y = (y * g).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["wo"])
    out = constrain(out, "batch", "seq", "act_embed")
    new_cache = RWKVCache(shift_tm=x[:, -1, :],
                          shift_cm=(cache.shift_cm if cache is not None
                                    else jnp.zeros((B, D), x.dtype)),
                          wkv=new_state)
    return out, new_cache


def rwkv_channel_mix(p, cfg, x, cache: RWKVCache):
    B, S, D = x.shape
    last = cache.shift_cm if cache is not None else jnp.zeros((B, D), x.dtype)
    xprev = _token_shift(x, last)
    xx = xprev - x
    xk = (x + xx * p["mix"][0][None, None]).astype(x.dtype)
    xr = (x + xx * p["mix"][1][None, None]).astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"])) * kv
    out = constrain(out.astype(x.dtype), "batch", "seq", "act_embed")
    new_cache = cache._replace(shift_cm=x[:, -1, :]) if cache is not None else None
    return out, new_cache

"""Parameter trees with logical sharding axes.

``Boxed(value, axes)`` is a registered pytree node whose AXES ARE STATIC
(aux data): jax transformations (vmap, eval_shape, jit) flow through the
value while the logical axes ride along untouched. ``split(tree)`` separates
a Boxed tree into (values, axes) trees of identical structure — one source of
truth, shapes and shardings can never drift apart. ``jax.eval_shape`` over an
init function therefore yields abstract params WITH their axes — that is the
dry-run path (no allocation).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


class Boxed:
    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Boxed({self.value!r}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    Boxed,
    lambda b: ((b.value,), b.axes),
    lambda axes, children: Boxed(children[0], axes),
)


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def _map_boxed(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_boxed)


def split(tree):
    """(values, axes) trees with identical structure (Boxed nodes removed)."""
    values = _map_boxed(lambda b: b.value if is_boxed(b) else b, tree)
    axes = _map_boxed(lambda b: b.axes if is_boxed(b) else None, tree)
    return values, axes


def prefix_axes(tree, axis: str):
    """Prepend a logical axis to every Boxed leaf (e.g. the stacked "layers"
    dim created by vmapping an init)."""
    return _map_boxed(
        lambda b: Boxed(b.value, (axis,) + b.axes) if is_boxed(b) else b, tree)


def dense_init(key, shape, axes, dtype, scale: Optional[float] = None) -> Boxed:
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(fan_in)
    v = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return Boxed(v.astype(dtype), axes)


def zeros_init(shape, axes, dtype) -> Boxed:
    return Boxed(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype) -> Boxed:
    return Boxed(jnp.ones(shape, dtype), axes)


def const_init(value, axes) -> Boxed:
    return Boxed(value, axes)

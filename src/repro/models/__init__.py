"""Model substrate: layers, attention (GQA/SWA), MoE, Mamba, RWKV6, and the
scanned transformer assembly."""
from .param import Boxed, split, prefix_axes
from .transformer import (init_model, abstract_params, forward, loss_fn,
                          prefill, decode_step, init_caches, cache_axes)
from .attention import KVCache
from .mamba import MambaCache
from .rwkv import RWKVCache

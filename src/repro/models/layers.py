"""Shared layers: norms, rotary embeddings, embedding/unembedding, FFNs."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .param import Boxed, dense_init, ones_init, zeros_init


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": ones_init((d,), ("act_embed",), dtype)}


def rmsnorm(p, x, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_tables(positions: jnp.ndarray, d_head: int, theta: float):
    """positions (...,) int32 -> cos/sin (..., d_head//2) in f32."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (B, S, H, D); cos/sin (S, D/2) (or (B, S, D/2)), broadcast over
    batch and heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos, sin = cos[..., None, :], sin[..., None, :]    # head axis
    while cos.ndim < x.ndim:                           # leading batch axes
        cos, sin = cos[None], sin[None]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d, dtype):
    return {"table": dense_init(key, (vocab, d), ("vocab", "embed"), dtype,
                                scale=1.0)}


@jax.custom_vjp
def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def _embed_fwd(table, tokens):
    # residual carries a zero-width view of the table: shape+dtype metadata
    # as a valid JAX type (static python objects can't be residual leaves)
    return embed_lookup(table, tokens), (tokens, table[:, :0])


def _embed_bwd(res, g):
    """dTable via CHUNKED one-hot matmuls instead of a scatter-add: GSPMD
    cannot shard a dynamic-index scatter over the vocab dim and materializes
    the full (B*S, D) f32 update tensor GLOBALLY (64GiB-class buffers on the
    big-vocab archs). The one-hot dot contracts the (data-sharded) token dims
    into (vocab, d_model)-sharded partials instead."""
    tokens, table_meta = res
    V, dtype = table_meta.shape[0], table_meta.dtype
    B, S = tokens.shape
    D = g.shape[-1]
    chunk = S
    for c in (512, 256, 128, 64):
        if S % c == 0:
            chunk = c
            break
    tok_c = tokens.reshape(B, S // chunk, chunk).swapaxes(0, 1)
    g_c = g.reshape(B, S // chunk, chunk, D).swapaxes(0, 1)

    def step(acc, args):
        tk, gk = args                                  # (B, c), (B, c, D)
        oh = jax.nn.one_hot(tk, V, dtype=gk.dtype)     # (B, c, V)
        oh = constrain(oh, "batch", None, "vocab")
        part = jnp.einsum("bcv,bcd->vd", oh, gk,
                          preferred_element_type=jnp.float32)
        return acc + constrain(part, "vocab", "embed"), None

    acc0 = constrain(jnp.zeros((V, D), jnp.float32), "vocab", "embed")
    dtable, _ = jax.lax.scan(step, acc0, (tok_c, g_c))
    return dtable.astype(dtype), None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def embed(p, tokens):
    out = embed_lookup(p["table"], tokens)
    return constrain(out, "batch", "seq", "act_embed")


def unembed(p, x):
    """x (B, S, D) -> logits (B, S, V)."""
    logits = jnp.einsum("bsd,vd->bsv", x, p["table"])
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Dense FFN variants
# ---------------------------------------------------------------------------

def init_ffn(key, cfg, d_ff: int, dtype):
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.activation in ("swiglu", "geglu")
    p = {"w_up": dense_init(k1, (D, d_ff), ("embed", "mlp"), dtype),
         "w_down": dense_init(k2, (d_ff, D), ("mlp", "embed"), dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, (D, d_ff), ("embed", "mlp"), dtype)
    return p


def _act(name: str, x):
    if name == "swiglu" or name == "silu":
        return jax.nn.silu(x)
    if name == "geglu" or name == "gelu":
        return jax.nn.gelu(x)
    if name == "sqrelu":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def ffn(p, cfg, x):
    """x (B, S, D) -> (B, S, D)."""
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    up = constrain(up, "batch", "seq", "mlp")
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = _act(cfg.activation, gate) * up
    else:
        h = _act(cfg.activation, up)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(out, "batch", "seq", "act_embed")

"""Model assembly: embedding -> scanned layer groups -> final norm -> logits.

Layers are grouped into the config's repeating pattern unit (period) and the
group is ``lax.scan``-ned with stacked parameters — compiled HLO size is
depth-independent (critical for the 80-cell dry-run matrix on one CPU core).

Three entry points: ``forward`` (train/teacher-forcing), ``prefill`` (forward
+ KV/state cache build), ``decode_step`` (one token, O(1) or O(window)/O(S)
per arch family).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from .attention import KVCache
from .layers import (embed, ffn, init_embedding, init_ffn, init_rmsnorm,
                     rmsnorm, unembed)
from .mamba import MambaCache
from .param import Boxed, dense_init, prefix_axes, split
from .rwkv import RWKVCache


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, dtype):
    if kind == "attn":
        return attn_mod.init_attention(key, cfg, dtype)
    if kind == "mamba":
        return mamba_mod.init_mamba(key, cfg, dtype)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_time_mix(key, cfg, dtype)
    raise ValueError(kind)


def _init_ffn(key, cfg: ModelConfig, kind: str, dtype):
    if kind == "dense":
        return init_ffn(key, cfg, cfg.d_ff, dtype)
    if kind == "moe":
        return moe_mod.init_moe(key, cfg, dtype)
    if kind == "rwkv_cm":
        return rwkv_mod.init_rwkv_channel_mix(key, cfg, dtype)
    raise ValueError(kind)


def _init_group(key, cfg: ModelConfig, dtype):
    """One repeat unit: list of (norm1, mix, norm2, ffn) dicts."""
    blocks = []
    for i, (blk, fk) in enumerate(cfg.blocks_in_group):
        k1, k2, key = jax.random.split(key, 3)
        blocks.append({
            "norm1": init_rmsnorm(cfg.d_model, dtype),
            "mix": _init_block(k1, cfg, blk, dtype),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "ffn": _init_ffn(k2, cfg, fk, dtype),
        })
    return blocks


def init_model(cfg: ModelConfig, key) -> Any:
    """Returns a Boxed(value, logical_axes) pytree. Use param.split()."""
    dtype = _dtype(cfg.param_dtype)
    k_emb, k_groups, k_front, k_unemb = jax.random.split(key, 4)
    params = {"embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype)}

    group_keys = jax.random.split(k_groups, cfg.n_groups)
    # vmap the group init to produce stacked [n_groups, ...] leaves; the
    # Boxed axes (static aux data) gain a leading "layers" axis.
    one = functools.partial(_init_group, cfg=cfg, dtype=dtype)
    params["groups"] = prefix_axes(jax.vmap(lambda k: one(k))(group_keys),
                                   "layers")

    params["final_norm"] = init_rmsnorm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(k_unemb, cfg.vocab_size,
                                           cfg.d_model, dtype)
    if cfg.frontend == "vision":
        params["frontend_proj"] = dense_init(
            k_front, (cfg.d_frontend, cfg.d_model), ("frontend", "embed"), dtype)
    return params


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct values, logical axes) — NO allocation (dry-run path).
    Boxed axes are static pytree aux data, so eval_shape preserves them."""
    boxed = jax.eval_shape(
        lambda k: init_model(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return split(boxed)


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, s_max: int, dtype=None):
    """Stacked (n_groups leading dim) cache pytree. s_max is the KV capacity;
    sliding-window archs get min(s_max, window) ring buffers."""
    dtype = dtype or _dtype(cfg.dtype)

    def one_group():
        caches = []
        for (blk, fk) in cfg.blocks_in_group:
            if blk == "attn":
                cap = min(s_max, cfg.window) if cfg.window else s_max
                caches.append(KVCache.zeros(batch, cfg.n_kv_heads, cap,
                                            cfg.d_head, dtype))
            elif blk == "mamba":
                caches.append(MambaCache.zeros(batch, cfg, dtype))
            elif blk == "rwkv":
                caches.append(RWKVCache.zeros(batch, cfg, dtype))
        return caches

    single = one_group()
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape), single)


def cache_axes(cfg: ModelConfig):
    """Logical axes tree matching init_caches output."""
    def kv():  # (G_layers, B, kv_heads, S, dh)
        return KVCache(("layers", "batch", "kv_heads", "kv_seq", None),
                       ("layers", "batch", "kv_heads", "kv_seq", None))

    axes = []
    for (blk, fk) in cfg.blocks_in_group:
        if blk == "attn":
            axes.append(kv())
        elif blk == "mamba":
            axes.append(MambaCache(("layers", "batch", None, "mamba_inner"),
                                   ("layers", "batch", "mamba_inner", None)))
        elif blk == "rwkv":
            axes.append(RWKVCache(("layers", "batch", "act_embed"),
                                  ("layers", "batch", "act_embed"),
                                  ("layers", "batch", "rwkv_heads", None, None)))
    return axes


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _apply_block(p, cfg, kind, x, positions, mode, cache, pos=None,
                 use_pallas=False):
    """Returns (y, new_cache)."""
    if kind == "attn":
        if mode == "train":
            return attn_mod.attention(p, cfg, x, positions,
                                      use_pallas=use_pallas), cache
        if mode == "prefill":
            return attn_mod.prefill_attention(p, cfg, x, positions, cache,
                                              use_pallas=use_pallas)
        return attn_mod.decode_attention_step(p, cfg, x, pos, cache,
                                              use_pallas=use_pallas)
    if kind == "mamba":
        if mode == "train":
            y, _ = mamba_mod.mamba_block(p, cfg, x, None)
            return y, cache
        return mamba_mod.mamba_block(p, cfg, x, cache)
    if kind == "rwkv":
        if mode == "train":
            y, _ = rwkv_mod.rwkv_time_mix(p, cfg, x, None)
            return y, cache
        return rwkv_mod.rwkv_time_mix(p, cfg, x, cache)
    raise ValueError(kind)


def _apply_ffn(p, cfg, kind, x, mode, cache):
    """Returns (y, aux, new_cache). rwkv channel-mix threads the cache."""
    if kind == "dense":
        return ffn(p, cfg, x), jnp.float32(0.0), cache
    if kind == "moe":
        y, aux = moe_mod.moe_ffn(p, cfg, x)
        return y, aux, cache
    if kind == "rwkv_cm":
        y, new_cache = rwkv_mod.rwkv_channel_mix(
            p, cfg, x, cache if mode != "train" else None)
        return y, jnp.float32(0.0), (new_cache if mode != "train" else cache)
    raise ValueError(kind)


def _group_body(cfg: ModelConfig, mode: str, use_pallas: bool):
    kinds = cfg.blocks_in_group

    def body(carry, xs):
        x, positions, pos = carry
        gparams, gcache = xs
        aux_total = jnp.float32(0.0)
        new_caches = []
        for i, (blk, fk) in enumerate(kinds):
            bp = gparams[i]
            c = gcache[i] if gcache is not None else None
            h = rmsnorm(bp["norm1"], x, cfg.norm_eps)
            y, c = _apply_block(bp["mix"], cfg, blk, h, positions, mode, c,
                                pos, use_pallas)
            x = x + y
            h = rmsnorm(bp["norm2"], x, cfg.norm_eps)
            y, aux, c = _apply_ffn(bp["ffn"], cfg, fk, h, mode, c)
            x = x + y
            aux_total = aux_total + aux
            new_caches.append(c)
        return (x, positions, pos), (new_caches, aux_total)

    return body


def _run_groups(cfg, params, x, positions, mode, caches=None, pos=None,
                use_pallas=False):
    body = _group_body(cfg, mode, use_pallas)
    if cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat == "full":
        body = jax.checkpoint(body)

    unroll = cfg.unroll_inner
    if caches is None:
        def scan_body(carry, gparams):
            c, ys = body(carry, (gparams, None))
            return c, ys[1]                      # aux only

        (x, _, _), auxs = jax.lax.scan(scan_body, (x, positions, pos),
                                       params["groups"], unroll=unroll)
        return x, None, jnp.sum(auxs)

    def scan_body(carry, xs):
        c, (new_caches, aux) = body(carry, xs)
        return c, (new_caches, aux)

    (x, _, _), (new_caches, auxs) = jax.lax.scan(
        scan_body, (x, positions, pos), (params["groups"], caches),
        unroll=unroll)
    return x, new_caches, jnp.sum(auxs)


def _embed_inputs(cfg, params, batch):
    x = embed(params["embed"], batch["tokens"])
    if cfg.frontend == "vision":
        fe = batch["frontend_embeds"].astype(x.dtype)
        proj = jnp.einsum("bnf,fd->bnd", fe, params["frontend_proj"])
        n = cfg.n_frontend_tokens
        x = jnp.concatenate([proj, x[:, n:, :]], axis=1)
    return x.astype(_dtype(cfg.dtype))


def forward(cfg: ModelConfig, params, batch, use_pallas: bool = False):
    """Teacher-forcing logits (B, S, V). batch: tokens (B, S) int32
    [+ frontend_embeds]."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    x, _, aux = _run_groups(cfg, params, x, positions, "train",
                            use_pallas=use_pallas)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["unembed"] if "unembed" in params else params["embed"]
    return unembed(table, x), aux


def loss_fn(cfg: ModelConfig, params, batch, use_pallas: bool = False):
    """Chunked cross-entropy (bounds the (B, chunk, V) logits buffer)."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    x, _, aux = _run_groups(cfg, params, x, positions, "train",
                            use_pallas=use_pallas)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = (params["unembed"] if "unembed" in params else params["embed"])["table"]
    labels = batch["labels"]

    chunk = min(cfg.loss_chunk, S)
    assert S % chunk == 0
    xc = x.reshape(B, S // chunk, chunk, -1).swapaxes(0, 1)
    lc = labels.reshape(B, S // chunk, chunk).swapaxes(0, 1)

    def step(tot, args):
        xb, lb = args
        logits = jnp.einsum("bsd,vd->bsv", xb, table,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, "batch", "seq", "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(step, jnp.float32(0.0), (xc, lc),
                            unroll=cfg.unroll_inner)
    loss = total / (B * S)
    return loss + aux, {"xent": loss, "aux": aux}


def prefill(cfg: ModelConfig, params, batch, s_max: int,
            use_pallas: bool = False):
    """Build caches from a full prompt. Returns (last_logits (B, V), caches)."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)
    caches = init_caches(cfg, B, s_max)
    x, caches, _ = _run_groups(cfg, params, x, positions, "prefill", caches,
                               use_pallas=use_pallas)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["unembed"] if "unembed" in params else params["embed"]
    logits = unembed(table, x[:, -1:, :])[:, 0]
    return logits, caches


def decode_step(cfg: ModelConfig, params, caches, tokens, pos,
                use_pallas: bool = False):
    """One decode step. tokens (B, 1) int32; pos scalar int32 (current
    position). Returns (logits (B, V), new_caches)."""
    x = embed(params["embed"], tokens).astype(_dtype(cfg.dtype))
    positions = jnp.asarray(pos)[None]
    x, caches, _ = _run_groups(cfg, params, x, positions, "decode", caches,
                               pos=jnp.asarray(pos), use_pallas=use_pallas)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["unembed"] if "unembed" in params else params["embed"]
    logits = unembed(table, x)[:, 0]
    return logits, caches

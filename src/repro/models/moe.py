"""Mixture-of-experts FFN — Switch/GShard-style scatter dispatch with
capacity, top-k routing, optional shared experts, and the load-balancing
auxiliary loss. Expert dim carries the "expert" logical axis (EP over the
model mesh axis); with tokens sharded over data, XLA inserts the all-to-all.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .layers import _act, init_ffn
from .param import dense_init


def init_moe(key, cfg, dtype):
    D, E = cfg.d_model, cfg.n_experts
    F = cfg.effective_moe_d_ff
    ks = jax.random.split(key, 5)
    gated = cfg.activation in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], (D, E), ("embed", None), dtype, scale=0.02),
        "w_up": dense_init(ks[1], (E, D, F), ("expert", "embed", "mlp"), dtype),
        "w_down": dense_init(ks[2], (E, F, D), ("expert", "mlp", "embed"), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[3], (E, D, F), ("expert", "embed", "mlp"), dtype)
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(ks[4], cfg, F * cfg.n_shared_experts, dtype)
    return p


def moe_ffn(p, cfg, x, no_drop: bool = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar).

    ROW-GROUPED dispatch (GShard/t5x style): routing positions and capacity
    are computed independently PER BATCH ROW, so the dispatch tensor is
    (B, E, C_row, D) with B sharded over data and E over model — expert
    compute stays sharded on BOTH mesh axes. (A flat global-capacity
    dispatch collapses the data-sharded token dim into an unsharded
    capacity dim and silently replicates the expert FFN per data shard —
    16x the compute at mesh data=16.)

    Capacity: with ``no_drop``, C_row = S*K (worst case — exact routing,
    used for decode and small batches where a dropped token corrupts
    generation); otherwise the Switch capacity-factor bound.
    Default: no_drop whenever B*S*K <= 4096 (decode/smoke scale)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if no_drop is None:
        no_drop = B * S * K <= 4096

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss (Switch eq. 4-6) --------------------------
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    assign1 = jax.nn.one_hot(expert_idx[..., 0], E)
    ce = jnp.mean(assign1, axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # ---- per-row capacity-bounded scatter dispatch -------------------------
    A = S * K                                                  # assignments/row
    C = A if no_drop else max(1, int(A * cfg.capacity_factor / E))
    flat_e = expert_idx.reshape(B, A)                          # (B, A)
    flat_g = gate_vals.reshape(B, A)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (B, A, E)
    # segmented cumsum: a flat cumsum along A runs along the (possibly
    # model-sharded) sequence axis and would force an all-gather of the
    # (B, A, E) one-hot; segmenting makes the long cumsum local and the
    # cross-segment offset pass tiny ((B, nseg, E)).
    nseg = 16 if A % 16 == 0 else 1
    oh = onehot.reshape(B, nseg, A // nseg, E)
    within = jnp.cumsum(oh, axis=2)                            # local
    seg_tot = within[:, :, -1, :]                              # (B, nseg, E)
    offs = jnp.cumsum(seg_tot, axis=1) - seg_tot               # exclusive
    pos = (within + offs[:, :, None, :]).reshape(B, A, E) - 1
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = slot < C
    slot = jnp.where(keep, slot, 0)
    gate = jnp.where(keep, flat_g, 0.0)                        # (B, A)

    # token replication for the K assignments is STATIC (repeat), never a
    # dynamic gather along the (sharded) sequence dim — a take_along_axis
    # here makes GSPMD replicate the full residual stream across the mesh.
    # Dispatch/combine address a FLATTENED (E*C) axis with one batched index
    # array: GSPMD keeps the batch dim sharded for this rank-1 batched
    # scatter/gather form, whereas the multi-index [row, e, slot] form was
    # observed to materialize (B*A, D) f32 buffers GLOBALLY (64 GiB-class).
    row = jnp.arange(B)[:, None]                                   # (B, 1)
    xtok = jnp.repeat(x, K, axis=1)                                # (B, A, D)
    idx = flat_e * C + slot                                        # (B, A)
    disp_flat = jnp.zeros((B, E * C, D), x.dtype)
    disp_flat = disp_flat.at[row, idx].add(
        xtok * keep[..., None].astype(x.dtype), mode="drop")
    disp = constrain(disp_flat.reshape(B, E, C, D),
                     "batch", "expert", None, "act_embed")

    # ---- expert FFN (grouped einsum; sharded over batch AND expert) --------
    up = jnp.einsum("becd,edf->becf", disp, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("becd,edf->becf", disp, p["w_gate"])
        h = _act(cfg.activation, g) * up
    else:
        h = _act(cfg.activation, up)
    y_e = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y_e = constrain(y_e, "batch", "expert", None, "act_embed")

    # ---- combine (static: assignments are token-major, so the per-token
    # ---- reduction over K is a reshape+sum, not a scatter) ------------------
    y_flat = y_e.reshape(B, E * C, D)
    gathered = jnp.take_along_axis(y_flat, idx[..., None], axis=1)  # (B, A, D)
    contrib = gathered * gate[..., None].astype(x.dtype)
    out = contrib.reshape(B, S, K, D).sum(axis=2)

    if "shared" in p:
        from .layers import ffn
        out = out + ffn(p["shared"], cfg, x)
    return constrain(out, "batch", "seq", "act_embed"), aux.astype(jnp.float32)

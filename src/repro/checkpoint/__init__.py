from . import checkpoint
from .checkpoint import AsyncCheckpointer, load_latest, save, load

"""Sharded checkpointing: atomic, restart-safe, async-capable.

Layout: <dir>/step_<N>/
    manifest.json            — tree structure, shapes, dtypes, step metadata
    arr_<i>.npy              — one file per leaf (local/addressable data)
    _COMMITTED               — written LAST; absence => partial checkpoint

Restart = load_latest(): picks the newest COMMITTED step. Async mode hands
the (host-synced) arrays to a writer thread — training continues while the
previous step serializes (the standard overlap trick); ``wait()`` joins.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Synchronous sharded save with atomic commit."""
    leaves, treedef = _flatten(tree)
    step_dir = os.path.join(path, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp_dir, f"arr_{i}.npy"), arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp_dir, "_COMMITTED"), "w") as f:
        f.write(str(time.time()))
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)   # atomic publish
    return step_dir


def load(step_dir: str, like: Any) -> Tuple[int, Any, dict]:
    """Load into the structure of ``like`` (shape/dtype-checked)."""
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), (
        f"checkpoint has {manifest['n_leaves']} leaves, expected "
        f"{len(leaves_like)} — incompatible tree")
    leaves = []
    for i, ref in enumerate(leaves_like):
        arr = np.load(os.path.join(step_dir, f"arr_{i}.npy"))
        want = tuple(np.shape(ref))
        assert tuple(arr.shape) == want, (i, arr.shape, want)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return manifest["step"], tree, manifest.get("extra", {})


def latest_step_dir(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp")
                   and os.path.exists(os.path.join(path, d, "_COMMITTED")))
    return os.path.join(path, steps[-1]) if steps else None


def load_latest(path: str, like: Any):
    """Returns (step, tree, extra) or None — the restart entry point."""
    d = latest_step_dir(path)
    if d is None:
        return None
    return load(d, like)


class AsyncCheckpointer:
    """Overlap serialization with compute: save() returns immediately after
    device->host transfer; a single writer thread serializes in order."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # sync copy

        def run():
            try:
                save(self.path, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, d), ignore_errors=True)

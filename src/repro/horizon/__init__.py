"""repro.horizon — forecast-driven receding-horizon (MPC) allocation.

The paper's controller is myopic: each tick solves for the CURRENT demand
under the L1 churn bound, so it pays churn chasing every diurnal swing and
reacts late to flash crowds. This package looks ahead instead:

  * forecast   — demand predictors over the observed trace (last_value,
                 ewma, seasonal holt_winters, and the ground-truth oracle
                 regret reference) behind a ``make_forecaster`` registry.
  * problem    — the time-expanded convex program: H stacked per-tick
                 problems over the plan X ∈ R^{H×n} with smoothed
                 inter-tick L1 churn coupling.
  * solver     — one jitted PGD program per solve (``solve_horizon``), the
                 committed tick hard-projected onto the churn ball by exact
                 ``project_incremental`` chaining; ``vmap``-able across
                 fleet lanes (``solve_horizon_fleet_step``) like
                 ``solve_fleet``.
  * admm       — consensus ADMM over the same program
                 (``solver="admm"``): H independent per-tick prox blocks
                 vmapped per outer iteration, consensus variables carrying
                 the inter-tick churn coupling, primal/dual residual
                 certificates in ``ADMMDiag``/``ADMMTrace``.
  * controller — ``ModelPredictiveController``: forecast H ticks, solve,
                 commit tick 0, roll forward. H=1 reproduces the myopic
                 controller exactly (test-enforced); the fleet replay
                 drives it via ``replay_fleet(controller="mpc", ...)``.

Documentation: docs/horizon.md (forecaster contracts, formulation, regret
definition); benchmarks/horizon_bench.py sweeps H × forecaster × trace.
"""
from .forecast import (FORECASTER_KINDS, EWMAForecaster, Forecaster,
                       HoltWintersForecaster, LastValueForecaster,
                       OracleForecaster, make_forecaster)
from .problem import (DEFAULT_COUPLING_EPS, DEFAULT_COUPLING_W,
                      HorizonProblem, HorizonTermDef, churn_bound_grad,
                      churn_bound_penalty, commit_coupling_grad,
                      commit_coupling_penalty, coupling_grad,
                      coupling_penalty, coupling_term_defs, expand_problems,
                      horizon_objective, horizon_objective_terms,
                      smoothed_churn, tick_problem)
from .admm import (ADMMDiag, ADMMTrace, admm_residual_history,
                   admm_solve_plan)
from .solver import (DEFAULT_DELTA_PENALTY_W, DEFAULT_PENALTY_W,
                     HorizonFleetStepResult, HorizonSolveResult,
                     HorizonSolverConfig, round_committed, solve_horizon,
                     solve_horizon_fleet_step, solve_horizon_info)
from .controller import (ModelPredictiveController, select_window_candidate,
                         window_candidate_scores)

__all__ = [
    "Forecaster", "LastValueForecaster", "EWMAForecaster",
    "HoltWintersForecaster", "OracleForecaster", "FORECASTER_KINDS",
    "make_forecaster",
    "HorizonProblem", "expand_problems", "tick_problem",
    "horizon_objective", "horizon_objective_terms",
    "coupling_penalty", "coupling_grad", "smoothed_churn",
    "HorizonTermDef", "coupling_term_defs",
    "commit_coupling_penalty", "commit_coupling_grad",
    "churn_bound_penalty", "churn_bound_grad",
    "DEFAULT_COUPLING_W", "DEFAULT_COUPLING_EPS", "DEFAULT_PENALTY_W",
    "DEFAULT_DELTA_PENALTY_W",
    "solve_horizon", "solve_horizon_info", "solve_horizon_fleet_step",
    "HorizonFleetStepResult", "HorizonSolveResult", "HorizonSolverConfig",
    "round_committed",
    "ADMMDiag", "ADMMTrace", "admm_solve_plan", "admm_residual_history",
    "ModelPredictiveController", "window_candidate_scores",
    "select_window_candidate",
]

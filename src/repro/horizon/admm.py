"""Consensus ADMM (operator splitting) for the time-expanded horizon program.

The horizon merit (see ``repro.horizon.solver``) is block-separable across
ticks except for the churn coupling — exactly the structure the CvxCluster
line of work exploits for orders-of-magnitude speedups on granular
allocation. This module splits the program accordingly:

    min_X  F(X) + g(Z)   s.t.  X = Z                    (consensus)

    F(X) = Σ_h [ f_h(X_h) + band_h(X_h) ] + Ind_C(X)    per-tick blocks
    g(Z)  = coupling(Z) + commit_coupling(Z_0, x_cur) + churn_bound(Z)

where ``f_h`` is tick h's eq.(1) objective, ``band_h`` the planned-tick
band penalty (h >= 1 only), and ``C`` the per-tick feasible sets — the box
on planned rows, box ∩ L1 churn ball (exact ``project_incremental``
chaining from ``x_current``) on the COMMITTED row, so tick 0 obeys exactly
the bound the myopic controller enforces, same as the monolithic solver.
All inter-tick terms live in ``g``: the smoothed-|·| churn coupling, the
priced committed transition, and the soft churn bound.

Scaled-dual ADMM iteration (Boyd et al. 2011, §3):

    X^{k+1}_h = argmin_{x∈C_h} f_h(x) + band_h(x) + ρ/2 ||x − (Z_h − U_h)||²
    Z^{k+1}   = argmin_Z g(Z) + ρ/2 ||X^{k+1} + U^k − Z||²
    U^{k+1}   = U^k + X^{k+1} − Z^{k+1}

The X-update is H INDEPENDENT single-tick prox subproblems — each one a
strongly-convex (+ρ/2‖·‖²) version of the myopic tick, solved by the SAME
shared BB/Armijo engine (``core.pgd``) with a small ``inner_steps`` budget
and warm-started from the previous sweep. The planned-tick prox sweep is
``vmap``-ed over ticks, so one ADMM iteration costs O(H) PARALLEL small
solves instead of one coupled H×n PGD trajectory — and it composes with the
fleet lane-vmap in ``solve_horizon_fleet_step`` (a batched MPC tick vmaps
this whole loop over (B,) lanes on top of the internal tick-vmap). The
Z-update is a cheap smooth unconstrained solve (the coupling terms involve
no K matmuls): a fixed count of branch-free gradient steps with an analytic
curvature-bound step size — deliberately NOT the line-searched engine,
whose ulp-sensitive accept/reject decisions would break the bit-exact
batched ≡ sequential lane-trajectory contract on this matmul-free graph
(see ``z_update``).

Convergence is certified by the standard scaled residual pair,

    r^k = ||X^k − Z^k||_F                 (primal: consensus violation)
    s^k = ρ ||Z^k − Z^{k-1}||_F           (dual: consensus-variable motion)

both returned in :class:`ADMMDiag` (and per-iteration in :class:`ADMMTrace`
with ``capture_trace``), surfaced as ``horizon/admm_*`` gauges through
``repro.obs``; the loop early-stops when both fall under ``admm_tol``
relative to the iterate scale. The returned plan is the FEASIBLE copy ``X``
(each row lies in its tick's constraint set; the committed row satisfies
the hard churn ball exactly), so rounding/commit machinery downstream is
identical to the other engines.

At H = 1 every term of ``g`` vanishes structurally and the program IS the
myopic warm tick; ``repro.horizon.solver`` dispatches that case to the
exact ``solve_incremental`` merit triple instead of running a degenerate
one-block ADMM, so ``solver="admm"`` at H=1 reduces op-for-op to the
adaptive engine — and therefore to the myopic controller (test-enforced).

Select the engine with ``HorizonSolverConfig(solver="admm", rho=...,
admm_iters=..., inner_steps=...)`` anywhere a config is accepted
(``solve_horizon``, ``solve_horizon_fleet_step``,
``ModelPredictiveController``, ``replay_fleet(solver_config=...)`` — both
replay engines, test-enforced reachability). See docs/math.md for the
formulation and docs/horizon.md for the solver-selection table.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

import repro.core.objective as obj
from repro.core.incremental import project_incremental
from repro.core.pgd import PGDConfig, pgd_minimize

from .problem import (HorizonProblem, coupling_term_defs, smoothed_churn,
                      tick_problem)


class ADMMDiag(NamedTuple):
    """Convergence certificate of one ADMM solve (scalars; (B,) under the
    fleet lane-vmap): the final scaled residual pair and the outer-iteration
    count. ``primal_res`` is the consensus violation ``||X − Z||_F`` — how
    far the per-tick blocks and the coupling copy still disagree — and
    ``dual_res`` the dual residual ``ρ·||Z − Z_prev||_F``; both must shrink
    toward 0 for the split program to agree with the monolithic one
    (property-tested in tests/horizon/test_admm_parity.py)."""

    primal_res: jnp.ndarray    # ||X - Z||_F at the final iterate
    dual_res: jnp.ndarray      # rho * ||Z - Z_prev||_F at the final iterate
    admm_iters: jnp.ndarray    # outer (consensus) iterations actually taken


class ADMMTrace(NamedTuple):
    """Per-outer-iteration residual capture of one traced ADMM solve —
    fixed-size ``(admm_iters,)`` arrays (static shape), jit/vmap-safe like
    ``core.pgd.PGDTrace``: a vmapped traced solve returns ``(B, L)`` leaves.
    Rows at indices ``>= admm_iters_taken`` were never written and hold the
    sentinels NaN / NaN / -1 (consumers slice by validity —
    ``repro.obs.solver_trace.trim_admm_trace``).

    * ``primal`` — primal residual ``||X − Z||_F`` after the iteration.
    * ``dual``   — dual residual ``ρ·||Z − Z_prev||_F`` after the iteration.
    * ``inner``  — inner PGD iterations the sweep spent (committed prox +
      planned prox blocks + Z-update) this outer iteration (-1 sentinel).
    """

    primal: jnp.ndarray     # (L,) float32
    dual: jnp.ndarray       # (L,) float32
    inner: jnp.ndarray      # (L,) int32


def _empty_admm_trace(L: int) -> ADMMTrace:
    return ADMMTrace(primal=jnp.full((L,), jnp.nan, jnp.float32),
                     dual=jnp.full((L,), jnp.nan, jnp.float32),
                     inner=jnp.full((L,), -1, jnp.int32))


#: Over-relaxation factor (Boyd et al. 2011, §3.4.3 recommend 1.5–1.8):
#: the z- and u-updates mix ``alpha·X + (1-alpha)·Z_prev``, which
#: measurably tightens both residuals at equal iteration count here.
ADMM_ALPHA = 1.6


def _sqnorm(a: jnp.ndarray) -> jnp.ndarray:
    """<a, a> over every axis — elementwise multiply + reduce (not vdot) so
    a vmapped call reduces per lane in the same order as a sequential one
    (the bit-exactness convention of ``core.pgd._flat_dot``)."""
    return jnp.sum(a * a)


def admm_solve_plan(hp: HorizonProblem, x_current: jnp.ndarray,
                    delta_max: jnp.ndarray, x_init: jnp.ndarray, *,
                    rho: float, admm_iters: int, inner_steps: int,
                    admm_tol: float, penalty_w: float, delta_penalty_w: float,
                    inner_cfg: PGDConfig, trace: bool = False):
    """One consensus-ADMM solve of the time-expanded program (H >= 2).

    Returns ``(X, total_inner_iters, ADMMDiag)`` — or with ``trace=True``
    ``(X, total_inner_iters, ADMMDiag, ADMMTrace)`` — where ``X`` (H, n) is
    the feasible per-tick-block plan (row 0 exactly inside the hard churn
    ball via ``project_incremental``) and ``total_inner_iters`` sums every
    inner PGD iteration across all prox blocks and Z-updates (the effort
    number ``ControllerStep.solver_iters`` aggregates — comparable to the
    monolithic engines' iteration counts only through the benchmark's wall
    clock, since an inner iteration here touches one tick, not the window).

    Un-jitted on purpose: callers (``repro.horizon.solver``) jit it inside
    their own entry points, and the fleet step vmaps it across lanes —
    ``trace`` is a Python-level flag so the untraced compiled program
    carries no trace state. All shapes are static (fixed ``admm_iters``
    budget, ``lax.while_loop`` early stop), so the loop is jit/vmap-safe.
    """
    prob = hp.problem
    H = hp.H
    assert H >= 2, "admm_solve_plan needs a real window; H=1 dispatches to " \
                   "the solve_incremental triple in repro.horizon.solver"
    p0 = tick_problem(hp, 0)
    rest = jax.tree_util.tree_map(lambda a: a[1:], prob)
    pw = jnp.asarray(penalty_w, jnp.float32)
    dpw = jnp.asarray(delta_penalty_w, jnp.float32)
    rho_ = jnp.asarray(rho, jnp.float32)
    tol = jnp.asarray(admm_tol, jnp.float32)

    def prox_committed(v, x0):
        # tick-0 block: eq.(1) objective + rho/2||x - v||^2 over
        # box ∩ L1 churn ball — the committed chain stays EXACT
        def val(x):
            return obj.objective(p0, x) + 0.5 * rho_ * _sqnorm(x - v)

        def grd(x):
            return obj.grad_objective(p0, x) + rho_ * (x - v)

        def prj(x):
            return project_incremental(p0, x, x_current, delta_max)

        return pgd_minimize(val, grd, prj, x0, inner_cfg)

    def prox_planned(pb, v, x0):
        # planned block: eq.(1) + band penalty + rho/2||x - v||^2 over box
        def val(x):
            return (obj.objective(pb, x) + obj.penalty(pb, x, pw)
                    + 0.5 * rho_ * _sqnorm(x - v))

        def grd(x):
            return (obj.grad_objective(pb, x) + obj.penalty_grad(pb, x, pw)
                    + rho_ * (x - v))

        def prj(x):
            return obj.project(pb, x)

        return pgd_minimize(val, grd, prj, x0, inner_cfg)

    # g(Z)'s gradient is the window-level registry list (coupling, commit,
    # churn bound) accumulated in contractual order — the same definitions
    # every other engine sums, no hand-copied grads
    tdefs = coupling_term_defs(hp, x_current, delta_max, delta_penalty_w)

    def z_grad(Z, W):
        g = tdefs[0].grad(Z)
        for td in tdefs[1:]:
            g = g + td.grad(Z)
        return g + rho_ * (Z - W)

    n = prob.c.shape[1]

    inv_seps = 1.0 / jnp.sqrt(jnp.asarray(hp.coupling_eps, jnp.float32))

    def z_update(W, z0):
        # consensus block: every inter-tick term + rho/2||Z - W||^2, smooth
        # and unconstrained — solved by ``inner_steps`` FIXED gradient steps
        # (inexact ADMM), NOT the BB/Armijo engine. Deliberate: the adaptive
        # ladder's accept/reject decisions bifurcate on the last ulps, and
        # this matmul-free graph is the one spot where XLA's batched
        # lowering differs from the unbatched one in those ulps — a line-
        # searched z-step therefore breaks the bit-exact batched ≡
        # sequential lane-trajectory contract the fleet engines promise
        # (branch-free gradient steps keep it, test-enforced). Each step is
        # 1/(rho + L̂(z)) with L̂ an analytic curvature bound re-evaluated at
        # the current iterate (data-dependent but branch-free): each element
        # sits in <= 2 smoothed-|·| arcs of curvature <= w/sqrt(eps), and
        # the squared-hinge churn bound — whose one-sided Hessian vanishes
        # on the slack side — contributes its gradient-outer-product +
        # hinge·curvature terms 2·dpw·(2n·[ê>0] + ê/sqrt(eps)) only while
        # its excess ê is active.
        def step(z, _):
            e = jnp.max(jnp.maximum(
                smoothed_churn(z, hp.coupling_eps) - delta_max, 0.0))
            act = (e > 0.0).astype(jnp.float32)
            L_hat = (2.0 * hp.coupling_w * inv_seps
                     + 2.0 * dpw * (2.0 * n * act + e * inv_seps))
            return z - (1.0 / (rho_ + L_hat)) * z_grad(z, W), None

        z, _ = jax.lax.scan(step, z0, None, length=inner_steps)
        return z, None, jnp.asarray(inner_steps)

    def cond(state):
        it, done = state[3], state[6]
        return (~done) & (it < admm_iters)

    def body(state):
        X, Z, U, it, inner = state[:5]
        V = Z - U
        x0_new, _, it0 = prox_committed(V[0], X[0])
        Xr, _, itr = jax.vmap(prox_planned)(rest, V[1:], X[1:])
        X_new = jnp.concatenate([x0_new[None], Xr], axis=0)
        # over-relaxation (Boyd §3.4.3): the z- and u-updates see the mix
        # alpha·X + (1-alpha)·Z_prev instead of X; residuals stay on X
        X_hat = ADMM_ALPHA * X_new + (1.0 - ADMM_ALPHA) * Z
        Z_new, _, itz = z_update(X_hat + U, Z)
        U_new = U + X_hat - Z_new
        r = jnp.sqrt(_sqnorm(X_new - Z_new))
        s = rho_ * jnp.sqrt(_sqnorm(Z_new - Z))
        # Boyd §3.3 stopping: residuals relative to the iterate scale
        scale_p = 1.0 + jnp.maximum(jnp.sqrt(_sqnorm(X_new)),
                                    jnp.sqrt(_sqnorm(Z_new)))
        scale_d = 1.0 + rho_ * jnp.sqrt(_sqnorm(U_new))
        done = (r <= tol * scale_p) & (s <= tol * scale_d)
        step_inner = it0 + jnp.sum(itr) + itz
        out = (X_new, Z_new, U_new, it + 1,
               inner + step_inner, (r, s), done)
        if trace:
            tr: ADMMTrace = state[7]
            tr = ADMMTrace(
                primal=tr.primal.at[it].set(r.astype(jnp.float32)),
                dual=tr.dual.at[it].set(s.astype(jnp.float32)),
                inner=tr.inner.at[it].set(step_inner.astype(jnp.int32)))
            return out + (tr,)
        return out

    # init: project the warm start into the per-tick feasible sets; the
    # consensus copy starts in agreement (r_0 = 0) and the dual at rest
    x0 = project_incremental(p0, x_init[0], x_current, delta_max)
    Xr0 = jax.vmap(obj.project)(rest, x_init[1:])
    X0 = jnp.concatenate([x0[None], Xr0], axis=0)
    state = (X0, X0, jnp.zeros_like(X0), jnp.asarray(0), jnp.asarray(0),
             (jnp.asarray(jnp.inf, jnp.float32),
              jnp.asarray(jnp.inf, jnp.float32)),
             jnp.asarray(False))
    if trace:
        state = state + (_empty_admm_trace(admm_iters),)
    final = jax.lax.while_loop(cond, body, state)
    X, it, inner = final[0], final[3], final[4]
    r, s = final[5]
    diag = ADMMDiag(primal_res=r, dual_res=s, admm_iters=it)
    if trace:
        return X, inner, diag, final[7]
    return X, inner, diag


def admm_residual_history(tr: ADMMTrace) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The valid (written) rows of a single-lane trace's residual pair —
    ``(primal, dual)`` trimmed of the NaN sentinel tail. Host-side helper
    for tests/reports; see also ``repro.obs.solver_trace.trim_admm_trace``.
    """
    import numpy as np

    primal = np.asarray(tr.primal)
    valid = ~np.isnan(primal)
    return primal[valid], np.asarray(tr.dual)[valid]

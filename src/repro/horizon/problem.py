"""Time-expanded convex program over a lookahead window of H ticks.

The myopic controller solves one ``AllocationProblem`` per tick; the MPC
controller instead stacks the next H ticks' problems (current observed
demand + H-1 forecast ticks, each built by the SAME ``make_problem``
construction, demand normalization included) into one program over the
plan matrix ``X ∈ R^{H×n}``:

    min_X  Σ_h f_h(X_h)  +  w · Σ_{h=1..H-1} Σ_i s_eps((X_h - X_{h-1})_i)
                         +  w · Σ_i s_eps((X_0 - x_current)_i)
    s.t.   X_h ∈ box_h ∩ mask_h                          (every tick)
           ||X_0 - x_current||_1 <= delta_max            (committed tick)

(the second coupling term — the COMMITTED transition's churn price,
:func:`commit_coupling_penalty` — is assembled by the solver, which holds
``x_current``; like every H>1-only term it is statically absent at H=1)

where f_h is the per-tick eq.(1) objective (cost + consolidation +
volume-discount + log-fragmentation/shortage terms) of that tick's
normalized problem, and s_eps(u) = sqrt(u² + eps) - sqrt(eps) is the
smoothed |u| used for the INTER-TICK churn coupling (the sqrt(eps)
subtraction pins s_eps(0) = 0, so an unchanged plan — padded columns
included — contributes exactly nothing). The coupling between planned ticks is
soft (a smooth penalty the relaxed solve can trade against cost), while the
committed step's churn stays a HARD constraint, enforced by exact
``core.incremental.project_incremental`` chaining from ``x_current`` inside
the solver — so tick 0 obeys exactly the bound the myopic controller obeys.
With w = 0 the program decouples into H independent per-tick problems
(property-tested: :func:`horizon_objective` equals the sum of per-tick
``core.objective.objective`` values).

Representation: the H per-tick problems are stacked with
``repro.fleet.batching.stack_problems`` — the leading axis that machinery
gives a fleet of tenants here indexes lookahead ticks, and the same exact-
padding invariants let the fleet replay pad a window to its tenant's shape
bucket. See docs/horizon.md for the full formulation.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

import repro.core.objective as obj
from repro.core.problem import AllocationProblem
from repro.fleet.batching import stack_problems

# defaults tuned on the horizon_bench diurnal/flash-crowd fleets: the
# coupling must sit on the scale of per-node hourly prices (0.1-1.5 $/hr in
# solver units) — below a node's price the plan tracks every demand wiggle
# (no smoothing), far above it the plan never scales down
DEFAULT_COUPLING_W = 0.3
DEFAULT_COUPLING_EPS = 1e-4


class HorizonProblem(NamedTuple):
    """The time-expanded program: H stacked per-tick problems + coupling.

    ``problem`` is an ``AllocationProblem`` whose leaves carry a leading
    (H,) axis (tick h's problem is slice ``[h]``); ``coupling_w`` and
    ``coupling_eps`` are the smoothed-L1 inter-tick churn weight and
    smoothing epsilon. A pytree — jit/vmap-safe, so the fleet engine maps
    one extra (B,) axis on top for batched MPC replays."""

    problem: AllocationProblem
    coupling_w: jnp.ndarray
    coupling_eps: jnp.ndarray

    @property
    def H(self) -> int:
        """Number of lookahead ticks (leading axis of every problem leaf)."""
        return self.problem.d.shape[0]

    @property
    def n(self) -> int:
        """Variable count per tick (padded, when bucketed by the fleet)."""
        return self.problem.c.shape[-1]


def expand_problems(problems: Sequence[AllocationProblem],
                    coupling_w: float = DEFAULT_COUPLING_W,
                    coupling_eps: float = DEFAULT_COUPLING_EPS,
                    n_max: Optional[int] = None,
                    m_max: Optional[int] = None,
                    p_max: Optional[int] = None) -> HorizonProblem:
    """Stack per-tick problems (tick 0 first) into a HorizonProblem.

    All ticks normally share one catalog, hence one shape, and stack with no
    padding; ``n_max``/``m_max``/``p_max`` let the fleet replay pad the
    window up to its tenant's shape bucket (the stacking's exact-padding
    invariants make the padded program equivalent — see
    ``repro.fleet.batching``)."""
    assert len(problems) >= 1, "empty horizon window"
    batch = stack_problems(list(problems), n_max=n_max, m_max=m_max,
                           p_max=p_max)
    return HorizonProblem(problem=batch.problem,
                          coupling_w=jnp.asarray(coupling_w, jnp.float32),
                          coupling_eps=jnp.asarray(coupling_eps, jnp.float32))


def tick_problem(hp: HorizonProblem, h: int) -> AllocationProblem:
    """Slice tick ``h``'s AllocationProblem back out of the stack."""
    return jax.tree_util.tree_map(lambda a: a[h], hp.problem)


def coupling_penalty(X: jnp.ndarray, w, eps) -> jnp.ndarray:
    """w · Σ_h Σ_i [sqrt((X_h - X_{h-1})_i² + eps) - sqrt(eps)].

    The smoothed inter-tick L1 churn of a plan X (H, n). Subtracting the
    smoothing floor sqrt(eps) makes s(0) = 0 exactly, so a constant plan —
    and every pinned-zero padded column — contributes nothing to the value
    (padding-exactness, property-tested); the gradient is unaffected. Zero
    terms at H = 1 (a single-tick window has no internal churn)."""
    D = X[1:] - X[:-1]
    return w * jnp.sum(jnp.sqrt(D * D + eps) - jnp.sqrt(eps))


def coupling_grad(X: jnp.ndarray, w, eps) -> jnp.ndarray:
    """Analytic gradient of :func:`coupling_penalty` wrt the plan X (H, n).

    Row h receives +s(D_h) from the difference it ends and -s(D_{h+1}) from
    the one it starts, where s(u) = w·u/sqrt(u²+eps)."""
    D = X[1:] - X[:-1]
    S = w * D / jnp.sqrt(D * D + eps)            # (H-1, n)
    Z = jnp.zeros_like(X[:1])
    return jnp.concatenate([Z, S]) - jnp.concatenate([S, Z])


def commit_coupling_penalty(X: jnp.ndarray, x_current: jnp.ndarray,
                            w, eps) -> jnp.ndarray:
    """w · Σ_i s_eps((X_0 − x_current)_i) — the COMMITTED transition's
    churn, priced like every other transition in the window.

    The inter-tick coupling prices churn BETWEEN plan rows, but the
    transition the controller is about to PAY — deployed ``x_current`` to
    committed ``X_0`` — was only hard-bounded (the delta_max ball), never
    priced. A solver that fully converges the relaxed program then chases
    every demand wiggle to the ball boundary: the objective sees no reason
    not to. (The old fixed-step solver hid this by under-converging — its
    laziness acted as an accidental proximal regularizer; the adaptive
    engine converges for real and needs the price made explicit.) Zero at
    H = 1, where the window reduces to the myopic tick and the myopic
    controller's hard-ball-only semantics (paper §III.E) must be exact."""
    D = X[0] - x_current
    return w * jnp.sum(jnp.sqrt(D * D + eps) - jnp.sqrt(eps))


def commit_coupling_grad(X: jnp.ndarray, x_current: jnp.ndarray,
                         w, eps) -> jnp.ndarray:
    """Analytic gradient of :func:`commit_coupling_penalty` wrt the plan X
    (only row 0 is touched; ``x_current`` is a constant)."""
    D = X[0] - x_current
    S = w * D / jnp.sqrt(D * D + eps)
    return jnp.concatenate([S[None], jnp.zeros_like(X[1:])], axis=0)


def smoothed_churn(X: jnp.ndarray, eps) -> jnp.ndarray:
    """Per-transition smoothed L1 churn of a plan: (H-1,) vector of
    Σ_i s_eps((X_h - X_{h-1})_i), the differentiable stand-in for
    ||x_h - x_{h-1}||_1."""
    D = X[1:] - X[:-1]
    return jnp.sum(jnp.sqrt(D * D + eps) - jnp.sqrt(eps), axis=-1)


def churn_bound_penalty(X: jnp.ndarray, delta_max, w, eps) -> jnp.ndarray:
    """w · Σ_h max(smoothed_churn_h − delta_max, 0)² — the soft churn BOUND
    on planned transitions.

    The committed tick's churn is hard-constrained, but a receding-horizon
    controller will be churn-bounded at EVERY future commit too; without
    this term the plan could schedule the whole scale-up in one future tick
    (total L1 churn is the same whether a ramp is early or late, so the
    plain coupling expresses no urgency). Penalizing per-transition excess
    over ``delta_max`` makes bursts that exceed one tick's churn budget
    pull the EARLIER ticks up — pre-provisioning emerges exactly when the
    model says scaling later would be infeasible."""
    excess = jnp.maximum(smoothed_churn(X, eps) - delta_max, 0.0)
    return w * jnp.sum(excess * excess)


def churn_bound_grad(X: jnp.ndarray, delta_max, w, eps) -> jnp.ndarray:
    """Analytic gradient of :func:`churn_bound_penalty` wrt the plan X."""
    D = X[1:] - X[:-1]
    S = D / jnp.sqrt(D * D + eps)                        # ds/du, (H-1, n)
    excess = jnp.maximum(smoothed_churn(X, eps) - delta_max, 0.0)
    G = (2.0 * w * excess)[:, None] * S                  # d/dD, (H-1, n)
    Z = jnp.zeros_like(X[:1])
    return jnp.concatenate([Z, G]) - jnp.concatenate([G, Z])


def horizon_objective(hp: HorizonProblem, X: jnp.ndarray) -> jnp.ndarray:
    """The relaxed time-expanded objective at a plan X (H, n):
    per-tick eq.(1) objectives summed, plus the smoothed churn coupling.

    With ``coupling_w == 0`` this equals ``Σ_h objective(prob_h, X_h)``
    exactly (property-tested in tests/horizon) — the program decouples."""
    per_tick = jax.vmap(obj.objective)(hp.problem, X)
    return jnp.sum(per_tick) + coupling_penalty(X, hp.coupling_w,
                                                hp.coupling_eps)


def horizon_objective_terms(hp: HorizonProblem, X: jnp.ndarray) -> dict:
    """Diagnostic split: {"per_tick": (H,) objectives, "coupling": scalar}.

    The per-tick objectives are full registry sums, so any scenario terms
    attached to the window's problems (``prob.terms``) are included."""
    per_tick = jax.vmap(obj.objective)(hp.problem, X)
    return {"per_tick": per_tick,
            "coupling": coupling_penalty(X, hp.coupling_w, hp.coupling_eps)}


# ---------------------------------------------------------------------------
# Horizon-level term registry
# ---------------------------------------------------------------------------


class HorizonTermDef(NamedTuple):
    """One window-level (inter-tick) objective term: a name plus matched
    value/grad closures over the plan matrix X (H, n).  The horizon
    counterpart of ``core.terms.TermDef`` — per-tick terms live in the core
    registry and flow through ``obj.objective``; terms that couple ticks
    (churn pricing, the committed transition, the soft churn bound) live
    here, so every consumer (merit functions, fixed-step loop, ADMM
    consensus block) sums ONE definition list instead of hand-copying the
    three gradients."""

    name: str
    value: object   # Callable[[X], scalar]
    grad: object    # Callable[[X], (H, n)]


def coupling_term_defs(hp: HorizonProblem, x_current: jnp.ndarray,
                       delta_max, delta_penalty_w):
    """The window-level term list for an H>1 solve, in the contractual
    accumulation order (coupling, commit_coupling, churn_bound).

    Consumers MUST accumulate these onto their existing value/grad in list
    order (``for td in defs: val = val + td.value(X)``) — that preserves
    the seed float-addition association, hence bit-exact solver
    trajectories (the ADMM parity and batched≡sequential suites pin it).
    """
    w, eps = hp.coupling_w, hp.coupling_eps
    dpw = jnp.asarray(delta_penalty_w, jnp.float32)
    return (
        HorizonTermDef("coupling",
                       lambda X: coupling_penalty(X, w, eps),
                       lambda X: coupling_grad(X, w, eps)),
        HorizonTermDef("commit_coupling",
                       lambda X: commit_coupling_penalty(X, x_current, w, eps),
                       lambda X: commit_coupling_grad(X, x_current, w, eps)),
        HorizonTermDef("churn_bound",
                       lambda X: churn_bound_penalty(X, delta_max, dpw, eps),
                       lambda X: churn_bound_grad(X, delta_max, dpw, eps)),
    )

"""Model-predictive (receding-horizon) allocation controller.

``ModelPredictiveController`` extends the paper's myopic
``InfrastructureOptimizationController`` (§III.E) with lookahead: each tick
it (1) feeds the observed demand to its forecaster, (2) builds the H-tick
window [observed demand, H-1 forecast ticks] of per-tick problems with the
SAME ``make_problem`` construction the myopic controller uses, (3) solves
the time-expanded program (``repro.horizon.solver.solve_horizon``), and
(4) COMMITS only tick 0 — rounded by the same ``round_and_polish`` pass and
recorded through the inherited ``apply_counts``, so churn accounting,
metrics and history are directly comparable with the myopic loop. Then the
horizon rolls forward one tick (receding horizon / MPC).

State beyond the myopic controller is exactly two things: the forecaster
(fed the observed demand stream) and the previous relaxed plan, which
warm-starts the next solve shifted one tick (row 0 reset to the deployed
counts — the same warm start the myopic tick uses).

The per-tick solve runs the shared BB/Armijo engine by default
(``HorizonSolverConfig``, see ``repro.horizon.solver``); every recorded
``ControllerStep.solver_iters`` is the iteration count that tick actually
spent — the adaptive-vs-fixed evidence the horizon benchmark aggregates.

Cold start (``cold_start``): the first tick has no allocation, hence no
churn to plan around, so it is always the myopic multistart candidate set.

* ``"myopic"`` (default) — pick the best rounded candidate by TICK-0
  integer merit, identical at every H (and to the batched fleet cold
  start).
* ``"window"`` — score the SAME rounded candidates against the WHOLE
  window's objective (each candidate held constant across the H ticks; the
  coupling term of a constant plan is exactly 0), picking the mix that is
  cheapest for where demand is HEADED rather than where it is. At H=1 the
  window is just tick 0, so the selection — and the myopic equivalence —
  is unchanged.

Equivalences that anchor the design (both test-enforced):

* cold tick — no allocation exists, so there is no churn to plan around;
  the committed tick comes from the myopic multistart candidates.
* ``horizon=1`` — the window is just the observed demand; the solve reduces
  op-for-op to ``solve_incremental`` (see repro.horizon.solver), so the MPC
  controller reproduces the myopic controller's integer allocations exactly
  regardless of forecaster.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

import repro.core.objective as obj
from repro.core.controller import (ControllerStep,
                                   InfrastructureOptimizationController)
from repro.core.multistart import multistart_solve
from repro.core.problem import AllocationProblem
from repro.obs.telemetry import span

from .forecast import Forecaster, LastValueForecaster
from .problem import (DEFAULT_COUPLING_EPS, DEFAULT_COUPLING_W,
                      expand_problems)
from .solver import (DEFAULT_PENALTY_W, HorizonSolverConfig, round_committed,
                     solve_horizon_info)


def window_candidate_scores(probs: List[AllocationProblem],
                            candidates: np.ndarray) -> np.ndarray:
    """Whole-window objective of each cold-start candidate held constant
    across the window: ``scores[s] = Σ_h f_h(candidates[s])``.

    This is exactly the time-expanded objective of the constant plan
    ``tile(candidates[s], (H, 1))`` — the inter-tick coupling of a constant
    plan is 0 by construction (``s_eps(0) = 0``), so no coupling weight
    enters the cold-start selection. Shared by the sequential controller and
    the batched MPC replay so both engines rank candidates identically."""
    import jax

    cands = jnp.asarray(np.asarray(candidates, np.float32))     # (S, n)
    scores = np.zeros(cands.shape[0], np.float64)
    for pb in probs:
        # one vmapped evaluation per tick (not one dispatch per candidate)
        scores += np.asarray(
            jax.vmap(lambda c: obj.objective(pb, c))(cands), np.float64)
    return scores


def select_window_candidate(scores: np.ndarray,
                            feasible: np.ndarray) -> int:
    """Pick the candidate index by window score, tick-0-infeasible ones
    pushed behind every feasible one (the same +1e12 merit convention the
    myopic multistart selection uses — at H=1 the two selections agree
    exactly)."""
    merit = np.where(np.asarray(feasible, bool), scores, scores + 1e12)
    return int(np.argmin(merit))


@dataclass
class ModelPredictiveController(InfrastructureOptimizationController):
    """Receding-horizon controller: forecast H ticks, solve the
    time-expanded program, commit tick 0, roll forward.

    Inherits the myopic controller's fields (catalog, delta_max, params,
    n_starts, allowed_idx, normalize) and all of its state/bookkeeping
    (``x_current``, ``history``, ``apply_counts``). Extra knobs:

    * ``horizon``      — window length H (H=1 ≡ the myopic controller).
    * ``forecaster``   — a ``repro.horizon.forecast.Forecaster`` (default:
                         a fresh ``last_value``).
    * ``coupling_w``   — smoothed inter-tick L1 churn weight of the relaxed
                         program (the committed tick's churn stays a hard
                         ``delta_max`` ball regardless).
    * ``coupling_eps`` — smoothing epsilon of the coupling |·|.
    * ``solver_config``— a ``HorizonSolverConfig``: engine choice
                         (adaptive BB/Armijo vs fixed-step), iteration
                         budget, tolerance, ladder parameters and penalty
                         weights, all in one per-replay object. When given
                         it wins wholesale over the two legacy knobs below.
    * ``solver_steps`` — legacy: PGD budget per tick (600 = the myopic warm
                         tick's ``solve_incremental`` budget; required for
                         the H=1 equivalence).
    * ``penalty_w``    — legacy: band-penalty weight on PLANNED ticks (see
                         repro.horizon.solver; inert at H=1).
    * ``cold_start``   — ``"myopic"`` (tick-0 merit) or ``"window"``
                         (whole-window merit) candidate selection on the
                         cold tick (module docstring).

    ``plan`` holds the last relaxed plan (H, n): rows 1..H-1 are the
    controller's current intentions for the next ticks (useful diagnostics:
    pre-provisioning shows up here before it is committed)."""

    horizon: int = 8
    forecaster: Optional[Forecaster] = None
    coupling_w: float = DEFAULT_COUPLING_W
    coupling_eps: float = DEFAULT_COUPLING_EPS
    solver_config: Optional[HorizonSolverConfig] = None
    solver_steps: int = 600
    penalty_w: float = DEFAULT_PENALTY_W
    cold_start: str = "myopic"
    plan: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        """Default the forecaster; resolve the solver config; validate."""
        assert self.horizon >= 1, self.horizon
        assert self.cold_start in ("myopic", "window"), self.cold_start
        if self.forecaster is None:
            self.forecaster = LastValueForecaster()
        if self.solver_config is None:
            self.solver_config = HorizonSolverConfig(
                steps=int(self.solver_steps), penalty_w=float(self.penalty_w))
        assert self.solver_config.solver in ("adaptive", "fixed", "admm"), \
            self.solver_config.solver

    # -- window construction -------------------------------------------------

    def window_demands(self, demand: np.ndarray) -> np.ndarray:
        """Observe this tick's demand, then assemble the (H, m) window:
        row 0 is the OBSERVED demand (it has arrived — MPC never forecasts
        the present), rows 1..H-1 the forecaster's next H-1 ticks."""
        demand = np.asarray(demand, np.float64)
        self.forecaster.observe(demand)
        if self.horizon == 1:
            return demand[None, :]
        future = self.forecaster.predict(self.horizon - 1)
        return np.concatenate([demand[None, :], future], axis=0)

    def window_problems(self, demands: np.ndarray) -> List[AllocationProblem]:
        """One ``make_problem`` per window tick — identical construction
        (normalization included) to the myopic controller's per-tick
        problem, so tick 0's problem IS the myopic problem."""
        return [self.make_problem(d) for d in demands]

    def shifted_plan(self) -> np.ndarray:
        """The next solve's warm start: the previous plan advanced one tick
        (its guess for tick t+h was row h+1; the horizon's last row repeats)
        with row 0 reset to the DEPLOYED counts — the committed tick warms
        from ``x_current`` exactly like the myopic incremental tick."""
        H = self.horizon
        out = np.empty((H, len(self.x_current)), np.float64)
        out[0] = self.x_current
        for h in range(1, H):
            out[h] = (self.plan[min(h + 1, H - 1)] if self.plan is not None
                      else self.x_current)
        return out

    # -- cold start ----------------------------------------------------------

    def cold_window_counts(self, probs: List[AllocationProblem]) -> np.ndarray:
        """``cold_start="window"``: rank the myopic multistart's rounded
        candidates by the WHOLE window's objective (each candidate held
        constant across the window — the constant plan's coupling is 0) and
        return the winner. Tick-0-infeasible candidates lose to every
        feasible one, so today's demand is still covered; at H=1 the window
        score IS the tick-0 merit and the selection matches
        ``cold_start_counts`` exactly."""
        ms = multistart_solve(probs[0], n_starts=self.n_starts)
        self.last_x_rel = np.asarray(ms.best.x, np.float64)
        cands = np.asarray(ms.x_int_all, np.float64)             # (S, n)
        scores = window_candidate_scores(probs, cands)
        j = select_window_candidate(scores, np.asarray(ms.feas_int_all))
        return cands[j]

    # -- the receding-horizon tick -------------------------------------------

    def plan_counts(self, probs: List[AllocationProblem]) -> np.ndarray:
        """Warm tick: solve the time-expanded program, store the relaxed
        plan (and the engine's iteration count on ``_last_solver_iters``),
        and return the committed tick's rounded counts — rounded
        plan-respectingly when H > 1 (``round_committed``), so the polish
        scale-down cannot strip pre-provisioned capacity. With the inherited
        ``capture_solver_trace`` flag the engine's convergence rows are
        appended to ``solver_traces`` (adaptive engine only). The inherited
        ``anytime`` budget (when enabled) truncates the window solve to its
        best-so-far plan at deadline expiry, recorded on
        ``_last_deadline_hit``."""
        hp = expand_problems(probs, coupling_w=self.coupling_w,
                             coupling_eps=self.coupling_eps)
        with span("mpc/plan", cat="mpc",
                  compile_key=("solve_horizon", self.horizon, self.catalog.n,
                               self.solver_config, self.capture_solver_trace,
                               self.anytime is not None and
                               self.anytime.enabled)) as sp:
            res = solve_horizon_info(
                hp, jnp.asarray(self.x_current, jnp.float32),
                jnp.asarray(self.delta_max, jnp.float32),
                x_init=jnp.asarray(self.shifted_plan(), jnp.float32),
                cfg=self.solver_config,
                capture_trace=self.capture_solver_trace,
                anytime=self.anytime)
            sp.fence(res.plan)
        if res.trace is not None:
            self.solver_traces.append(
                type(res.trace)(*(np.asarray(f) for f in res.trace)))
        self.plan = np.asarray(res.plan, np.float64)
        # the committed tick's relaxed point — what health monitoring
        # certifies through kkt_report (tick 0 of the relaxed plan)
        self.last_x_rel = self.plan[0]
        self._last_solver_iters = int(res.iters)
        self._last_deadline_hit = bool(res.deadline_hit or False)
        with span("mpc/commit", cat="mpc"):
            return np.asarray(round_committed(probs[0], res.plan[0],
                                              respect_plan=(self.horizon > 1)),
                              np.float64)

    def step(self, demand: np.ndarray,
             x_init: Optional[np.ndarray] = None) -> ControllerStep:
        """Advance one tick: forecast, solve the window, commit tick 0.

        ``x_init`` is accepted for interface parity with the myopic
        controller but ignored — the MPC warm start is the shifted plan."""
        demand = np.asarray(demand, np.float64)
        with span("mpc/forecast", cat="mpc"):
            demands = self.window_demands(demand)
        with span("mpc/window", cat="mpc"):
            probs = self.window_problems(demands)
        if self.x_current is None:
            # cold: no churn to couple — the myopic multistart candidates,
            # ranked by tick-0 merit ("myopic", identical at every H and to
            # the batched fleet cold start) or by the whole-window
            # objective ("window")
            x = (self.cold_window_counts(probs)
                 if self.cold_start == "window"
                 else self.cold_start_counts(probs[0]))
            replanned = True
            self._last_solver_iters = 0
            self._last_deadline_hit = False
            self.plan = np.tile(x, (self.horizon, 1))
        else:
            x, replanned = self.plan_counts(probs), False
        return self.apply_counts(demand, x, replanned,
                                 solver_iters=self._last_solver_iters,
                                 deadline_hit=self._last_deadline_hit)

"""Model-predictive (receding-horizon) allocation controller.

``ModelPredictiveController`` extends the paper's myopic
``InfrastructureOptimizationController`` (§III.E) with lookahead: each tick
it (1) feeds the observed demand to its forecaster, (2) builds the H-tick
window [observed demand, H-1 forecast ticks] of per-tick problems with the
SAME ``make_problem`` construction the myopic controller uses, (3) solves
the time-expanded program (``repro.horizon.solver.solve_horizon``), and
(4) COMMITS only tick 0 — rounded by the same ``round_and_polish`` pass and
recorded through the inherited ``apply_counts``, so churn accounting,
metrics and history are directly comparable with the myopic loop. Then the
horizon rolls forward one tick (receding horizon / MPC).

State beyond the myopic controller is exactly two things: the forecaster
(fed the observed demand stream) and the previous relaxed plan, which
warm-starts the next solve shifted one tick (row 0 reset to the deployed
counts — the same warm start the myopic tick uses).

Equivalences that anchor the design (both test-enforced):

* cold tick — no allocation exists, so there is no churn to plan around;
  the committed tick is the myopic multistart cold start, identical at
  every H.
* ``horizon=1`` — the window is just the observed demand; the solve reduces
  op-for-op to ``solve_incremental`` (see repro.horizon.solver), so the MPC
  controller reproduces the myopic controller's integer allocations exactly
  regardless of forecaster.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.controller import (ControllerStep,
                                   InfrastructureOptimizationController)
from repro.core.problem import AllocationProblem

from .forecast import Forecaster, LastValueForecaster
from .problem import (DEFAULT_COUPLING_EPS, DEFAULT_COUPLING_W,
                      expand_problems)
from .solver import DEFAULT_PENALTY_W, round_committed, solve_horizon


@dataclass
class ModelPredictiveController(InfrastructureOptimizationController):
    """Receding-horizon controller: forecast H ticks, solve the
    time-expanded program, commit tick 0, roll forward.

    Inherits the myopic controller's fields (catalog, delta_max, params,
    n_starts, allowed_idx, normalize) and all of its state/bookkeeping
    (``x_current``, ``history``, ``apply_counts``). Extra knobs:

    * ``horizon``      — window length H (H=1 ≡ the myopic controller).
    * ``forecaster``   — a ``repro.horizon.forecast.Forecaster`` (default:
                         a fresh ``last_value``).
    * ``coupling_w``   — smoothed inter-tick L1 churn weight of the relaxed
                         program (the committed tick's churn stays a hard
                         ``delta_max`` ball regardless).
    * ``coupling_eps`` — smoothing epsilon of the coupling |·|.
    * ``solver_steps`` — PGD budget per tick (600 = the myopic warm tick's
                         ``solve_incremental`` budget; required for the
                         H=1 equivalence).
    * ``penalty_w``    — band-penalty weight on PLANNED ticks (see
                         repro.horizon.solver: planned rows need the
                         solver's quadratic coverage penalty because they
                         never receive the feasibility-first rounding;
                         inert at H=1).

    ``plan`` holds the last relaxed plan (H, n): rows 1..H-1 are the
    controller's current intentions for the next ticks (useful diagnostics:
    pre-provisioning shows up here before it is committed)."""

    horizon: int = 8
    forecaster: Optional[Forecaster] = None
    coupling_w: float = DEFAULT_COUPLING_W
    coupling_eps: float = DEFAULT_COUPLING_EPS
    solver_steps: int = 600
    penalty_w: float = DEFAULT_PENALTY_W
    plan: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        """Default the forecaster; validate the window length."""
        assert self.horizon >= 1, self.horizon
        if self.forecaster is None:
            self.forecaster = LastValueForecaster()

    # -- window construction -------------------------------------------------

    def window_demands(self, demand: np.ndarray) -> np.ndarray:
        """Observe this tick's demand, then assemble the (H, m) window:
        row 0 is the OBSERVED demand (it has arrived — MPC never forecasts
        the present), rows 1..H-1 the forecaster's next H-1 ticks."""
        demand = np.asarray(demand, np.float64)
        self.forecaster.observe(demand)
        if self.horizon == 1:
            return demand[None, :]
        future = self.forecaster.predict(self.horizon - 1)
        return np.concatenate([demand[None, :], future], axis=0)

    def window_problems(self, demands: np.ndarray) -> List[AllocationProblem]:
        """One ``make_problem`` per window tick — identical construction
        (normalization included) to the myopic controller's per-tick
        problem, so tick 0's problem IS the myopic problem."""
        return [self.make_problem(d) for d in demands]

    def shifted_plan(self) -> np.ndarray:
        """The next solve's warm start: the previous plan advanced one tick
        (its guess for tick t+h was row h+1; the horizon's last row repeats)
        with row 0 reset to the DEPLOYED counts — the committed tick warms
        from ``x_current`` exactly like the myopic incremental tick."""
        H = self.horizon
        out = np.empty((H, len(self.x_current)), np.float64)
        out[0] = self.x_current
        for h in range(1, H):
            out[h] = (self.plan[min(h + 1, H - 1)] if self.plan is not None
                      else self.x_current)
        return out

    # -- the receding-horizon tick -------------------------------------------

    def plan_counts(self, probs: List[AllocationProblem]) -> np.ndarray:
        """Warm tick: solve the time-expanded program, store the relaxed
        plan, and return the committed tick's rounded counts — rounded
        plan-respectingly when H > 1 (``round_committed``), so the polish
        scale-down cannot strip pre-provisioned capacity."""
        hp = expand_problems(probs, coupling_w=self.coupling_w,
                             coupling_eps=self.coupling_eps)
        X = solve_horizon(hp, jnp.asarray(self.x_current, jnp.float32),
                          jnp.asarray(self.delta_max, jnp.float32),
                          x_init=jnp.asarray(self.shifted_plan(), jnp.float32),
                          steps=self.solver_steps, penalty_w=self.penalty_w)
        self.plan = np.asarray(X, np.float64)
        return np.asarray(round_committed(probs[0], X[0],
                                          respect_plan=(self.horizon > 1)),
                          np.float64)

    def step(self, demand: np.ndarray,
             x_init: Optional[np.ndarray] = None) -> ControllerStep:
        """Advance one tick: forecast, solve the window, commit tick 0.

        ``x_init`` is accepted for interface parity with the myopic
        controller but ignored — the MPC warm start is the shifted plan."""
        demand = np.asarray(demand, np.float64)
        demands = self.window_demands(demand)
        probs = self.window_problems(demands)
        if self.x_current is None:
            # cold: no churn to couple — the myopic multistart cold start,
            # identical at every H (and to the batched fleet cold start)
            x, replanned = self.cold_start_counts(probs[0]), True
            self.plan = np.tile(x, (self.horizon, 1))
        else:
            x, replanned = self.plan_counts(probs), False
        return self.apply_counts(demand, x, replanned)

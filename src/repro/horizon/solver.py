"""Jitted solver for the time-expanded receding-horizon program.

Two entry points, both one compiled program per call:

* :func:`solve_horizon` — one tenant: projected-gradient descent on the
  relaxed time-expanded objective over the plan ``X ∈ R^{H×n}``.
* :func:`solve_horizon_fleet_step` — the fleet analogue of
  ``repro.fleet.solver.solve_fleet_step``: the SAME per-tenant solve
  ``vmap``-ed across a (B,) leading lane axis, plus committed-tick rounding
  and ragged-horizon freezing, so a batched MPC replay issues one program
  per shape bucket per tick.

The default iteration is the shared Barzilai-Borwein + Armijo-ladder
engine (``repro.core.pgd.pgd_minimize`` — the SAME loop the core barrier
solver and ``solve_incremental`` run), applied to the horizon merit

    F(X) = Σ_h f_h(X_h)                       per-tick eq.(1) objectives
         + coupling(X)                        smoothed inter-tick churn
         + commit_coupling(X_0, x_current)    the COMMITTED churn, priced
         + churn_bound(X)                     hinge² excess over delta_max
         + Σ_{h≥1} penalty(prob_h, X_h)       planned-tick band penalty

with a projection Π that applies exact ``project_incremental`` chaining
from ``x_current`` on the COMMITTED tick (hard L1 churn ball — the same
bound the myopic controller enforces) and the box/mask projection on the
planned ticks, whose churn stays soft via the coupling penalty. The BB
step adapts to the window's curvature, so deep windows (H ≥ 8) converge in
a fraction of the fixed-step budget — ``HorizonSolverConfig.steps`` is a
BUDGET, and the solve reports how many iterations it actually took.

``HorizonSolverConfig(solver="fixed")`` keeps the original fixed-step
scheme (``X ← Π(X - ∇F(X)/L)`` with per-tick Lipschitz-ish steps, exactly
the myopic warm tick's iteration) — the baseline the adaptive engine is
benchmarked against in ``benchmarks/horizon_bench.py`` and
``tests/horizon/test_solver_convergence.py``.

Two H>1-only terms make the lookahead real rather than decorative:

* **planned-tick band penalty** — the relaxed eq.(1) objective alone
  under-provisions systematically (the shortage term is soft; in this
  codebase demand COVERAGE is enforced by the feasibility-first greedy
  rounding, which planned rows never receive). Rows 1..H-1 therefore get
  the solver's quadratic band penalty (``core.objective.penalty``, the
  same fallback ``core.solver`` uses when no strict interior exists), so
  the plan settles at true future requirement levels and the coupling
  pulls the committed tick toward the right target.
* **plan-respecting commit rounding** — ``round_and_polish``'s scale-down
  strips every unit the CURRENT tick does not need, which would erase any
  pre-provisioning the plan decided to hold. The committed tick is
  rounded with its lower bound lifted to ``floor(x_rel_0)``
  (:func:`round_committed`): rounding still covers today's demand and
  still rounds fractions, but cannot scale below what the plan asked for.

At H = 1 both terms — and the coupling — vanish STRUCTURALLY (H is static
under jit, so they are absent from the compiled program, not just zero; a
one-tick window has no future to protect) and the tick reduces op-for-op
to ``solve_incremental`` + plain ``round_and_polish``: the same shared
engine on the same merit over the same feasible set, so MPC with a
one-tick window reproduces the myopic controller's allocations exactly
(test-enforced — the equivalence anchor for everything the lookahead
adds).

The COLD start of an MPC replay needs no horizon solve at all: with no
current allocation there is no churn to couple, and the first committed
tick is the same multistart phase1→barrier-PGD→rounding program the myopic
controller (and ``solve_fleet``) runs — the horizon controller reuses those
core/fleet pieces directly rather than duplicating them here. With
``cold_start="window"`` the controller still reuses that multistart
candidate set but scores every rounded candidate against the WHOLE
window's objective instead of tick 0's (see ``repro.horizon.controller``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.objective as obj
from repro.core.incremental import project_incremental
from repro.core.objective import is_feasible, objective
from repro.core.pgd import (AnytimeConfig, PGDConfig, PGDTrace,
                            pgd_chunk_init, pgd_chunk_run, pgd_minimize,
                            pgd_minimize_traced, run_anytime)
from repro.core.rounding import round_and_polish
from repro.obs.telemetry import current_recorder, gauge

from .admm import ADMMDiag, ADMMTrace, admm_solve_plan
from .problem import HorizonProblem, coupling_term_defs, tick_problem

# planned-tick band-penalty weight; matches core.solver.SolverConfig's
# penalty_w — the same quadratic fallback weight the barrier solver uses
DEFAULT_PENALTY_W = 1e3
# soft churn-BOUND weight on planned transitions (problem.churn_bound_penalty).
# Retuned for the ADAPTIVE engine: the seed-era 50.0 was calibrated against
# the fixed-step solver, which moved so little per solve that the hinge
# needed a huge weight to act at all; a solver that actually converges
# obeys it, and at 50.0 it over-pre-provisions (pays cost for bursts the
# per-tick churn budget could absorb on arrival). 10.0 keeps the
# pre-provisioning behavior for genuinely unabsorbable bursts while cutting
# both cost and churn on the horizon_bench flash-crowd fleets.
DEFAULT_DELTA_PENALTY_W = 10.0


class HorizonSolverConfig(NamedTuple):
    """Hashable horizon-solver knobs (static under jit) — the per-replay
    configuration ``replay_fleet(controller="mpc", solver_config=...)``
    plumbs through to every tick's solve.

    ``solver`` picks the engine: ``"adaptive"`` (default) is the shared
    BB/Armijo ladder (``core.pgd``) on the monolithic (H, n) program;
    ``"fixed"`` the original fixed-step scheme; ``"admm"`` the consensus
    operator-splitting solver (``repro.horizon.admm``) whose per-tick prox
    blocks vmap over ticks. ``steps`` is the per-tick iteration budget (the
    adaptive engine early-stops at ``tol``; fixed always runs the full
    count — 600 matches the myopic ``solve_incremental`` budget). ``step0``
    / ``n_backtracks`` / ``backtrack`` / ``armijo_c`` are the adaptive
    ladder's parameters (``core.pgd.PGDConfig``), shared by the ADMM
    engine's inner prox solves; ``step_scale`` scales the fixed engine's
    Lipschitz step only. ``penalty_w`` weights the planned-tick band
    penalty and ``delta_penalty_w`` the soft churn bound on planned
    transitions (both inert at H=1).

    The ``rho`` / ``admm_iters`` / ``inner_steps`` / ``admm_tol`` block
    parameterizes the ADMM engine only: the consensus penalty weight, the
    outer (consensus) iteration budget, the per-block inner PGD budget of
    each prox sweep, and the relative residual tolerance the outer loop
    early-stops at (see ``repro.horizon.admm``). An ADMM solve's total
    compute is roughly ``admm_iters * inner_steps`` single-tick prox
    iterations per tick — the defaults match the adaptive engine's 600
    full-window budget."""

    solver: str = "adaptive"       # "adaptive" (BB/Armijo) | "fixed" | "admm"
    steps: int = 600               # per-tick iteration budget
    tol: float = 1e-6              # adaptive: stop when the move is tiny
    ftol: float = 1e-4             # adaptive: ... or merit progress is flat
    max_flat: int = 10             # adaptive: consecutive flat steps to stop
    step0: float = 1.0             # adaptive: initial/fallback BB step
    n_backtracks: int = 12         # adaptive: Armijo ladder length
    backtrack: float = 0.5         # adaptive: ladder ratio
    armijo_c: float = 1e-4         # adaptive: sufficient-decrease slope
    step_scale: float = 1.0        # fixed: Lipschitz-step scale
    penalty_w: float = DEFAULT_PENALTY_W
    delta_penalty_w: float = DEFAULT_DELTA_PENALTY_W
    rho: float = 4.0               # admm: consensus penalty weight
    admm_iters: int = 30           # admm: outer (consensus) iteration budget
    inner_steps: int = 20          # admm: per-block inner PGD budget
    admm_tol: float = 1e-4         # admm: relative residual stop tolerance

    def pgd(self) -> PGDConfig:
        """The ``core.pgd.PGDConfig`` this config's adaptive fields map to."""
        return PGDConfig(max_iters=self.steps, step0=self.step0,
                         n_backtracks=self.n_backtracks,
                         backtrack=self.backtrack, armijo_c=self.armijo_c,
                         tol=self.tol, ftol=self.ftol,
                         max_flat=self.max_flat)

    def inner_pgd(self) -> PGDConfig:
        """The inner-prox ``PGDConfig`` of the ADMM engine: the same ladder
        as :meth:`pgd` at the small per-block ``inner_steps`` budget, with
        flat-merit early stopping disabled — each prox sweep is already
        budget-capped, and ``ftol`` stopping inside a ~20-step segment
        stalls the warm-started blocks well short of the tick optima."""
        return self.pgd()._replace(max_iters=self.inner_steps, ftol=0.0)


class HorizonSolveResult(NamedTuple):
    """One relaxed horizon solve: the plan plus the iterations it took.

    ``trace`` is None unless the solve ran with ``capture_trace=True``: the
    adaptive engine's per-iteration ``core.pgd.PGDTrace`` with ``cfg.steps``
    fixed-size rows, or — for ``solver="admm"`` at H>1 — the per-outer-
    iteration residual ``repro.horizon.admm.ADMMTrace`` (see
    ``repro.obs.solver_trace``). ``diag`` is the ADMM engine's convergence
    certificate (final primal/dual residuals + outer iterations), None for
    the monolithic engines and for the H=1 dispatch."""

    plan: jnp.ndarray       # (H, n) relaxed time-expanded solution
    iters: jnp.ndarray      # PGD iterations actually taken (== steps, fixed)
    trace: Optional[Union[PGDTrace, ADMMTrace]] = None  # opt-in capture
    diag: Optional[ADMMDiag] = None   # admm-only residual certificate
    deadline_hit: Optional[bool] = None  # anytime solve truncated (None: n/a)


def _tick_lipschitz(prob) -> jnp.ndarray:
    """Per-tick step denominator of the FIXED engine, the exact expression
    the pre-adaptive ``solve_incremental`` used (kept for the fixed-vs-
    adaptive benchmark baseline)."""
    return (2.0 * prob.params.beta3 * jnp.sum(prob.K * prob.K)
            + jnp.linalg.norm(prob.c) + 1e-3)


def _horizon_merit_fns(hp: HorizonProblem, x_current: jnp.ndarray,
                       delta_max: jnp.ndarray, penalty_w: float,
                       delta_penalty_w: float):
    """The (value, grad, project) triple of the time-expanded program, in
    the shape the shared PGD engine consumes. All H>1-only terms are
    STATICALLY absent at H=1 (H is static under jit/vmap tracing), so the
    H=1 triple is exactly ``solve_incremental``'s merit and feasible set."""
    prob = hp.problem
    H = hp.H
    p0 = tick_problem(hp, 0)

    if H == 1:
        # the UNBATCHED per-tick ops, not vmap-over-1: op-for-op (and in
        # practice bit-for-bit) the merit triple solve_incremental hands the
        # shared engine — the adaptive line search is chaotic in the last
        # ulps, so the H=1 ≡ myopic equivalence needs identical op graphs,
        # not just identical math
        def value1(X):
            return obj.objective(p0, X[0])

        def grad1(X):
            return obj.grad_objective(p0, X[0])[None]

        def proj1(X):
            return project_incremental(p0, X[0], x_current, delta_max)[None]

        return value1, grad1, proj1

    rest = jax.tree_util.tree_map(lambda a: a[1:], prob)
    pw = jnp.asarray(penalty_w, jnp.float32)
    # the window-level registry: ONE definition list (coupling, commit,
    # churn bound), accumulated in contractual order — no hand-copied grads
    tdefs = coupling_term_defs(hp, x_current, delta_max, delta_penalty_w)

    def value(X):
        val = jnp.sum(jax.vmap(obj.objective)(prob, X))
        for td in tdefs:
            val = val + td.value(X)
        val = val + jnp.sum(jax.vmap(
            lambda pb, x: obj.penalty(pb, x, pw))(rest, X[1:]))
        return val

    def grad(X):
        G = jax.vmap(obj.grad_objective)(prob, X)
        for td in tdefs:
            G = G + td.grad(X)
        Gp = jax.vmap(
            lambda pb, x: obj.penalty_grad(pb, x, pw))(rest, X[1:])
        return jnp.concatenate([G[:1], G[1:] + Gp])

    def proj(X):
        x0 = project_incremental(p0, X[0], x_current, delta_max)
        rest_X = jax.vmap(obj.project)(rest, X[1:])
        return jnp.concatenate([x0[None], rest_X], axis=0)

    return value, grad, proj


def _solve_horizon_fixed(hp: HorizonProblem, x_current: jnp.ndarray,
                         delta_max: jnp.ndarray, x_init: jnp.ndarray,
                         steps: int, step_scale: float, penalty_w: float,
                         delta_penalty_w: float) -> jnp.ndarray:
    """The original fixed-step PGD loop over one plan X (H, n) — kept as the
    ``solver="fixed"`` baseline the adaptive engine is measured against."""
    prob = hp.problem
    H = hp.H                              # static under jit/vmap tracing
    p0 = tick_problem(hp, 0)
    L = jax.vmap(_tick_lipschitz)(prob)                          # (H,)
    if H > 1:
        rest = jax.tree_util.tree_map(lambda a: a[1:], prob)
        tdefs = coupling_term_defs(hp, x_current, delta_max, delta_penalty_w)
        # curvature of the smoothed |u|: s''(0) = 1/sqrt(eps), two coupling
        # terms touch each row (the committed row's second one is the
        # commit-churn price), plus ~2w per adjacent transition from the
        # churn-bound hinge; planned rows add the band penalty's
        # 2*w*sum(K^2). Statically absent at H=1 so the step size matches
        # the pre-adaptive solve_incremental exactly.
        L = (L + 2.0 * hp.coupling_w / jnp.sqrt(hp.coupling_eps)
             + 4.0 * delta_penalty_w)
        pen_curv = 2.0 * penalty_w * jax.vmap(
            lambda pb: jnp.sum(pb.K * pb.K))(rest)               # (H-1,)
        L = jnp.concatenate([L[:1], L[1:] + pen_curv])

    def proj(X):
        x0 = project_incremental(p0, X[0], x_current, delta_max)
        if H == 1:
            return x0[None]
        rest_X = jax.vmap(obj.project)(rest, X[1:])
        return jnp.concatenate([x0[None], rest_X], axis=0)

    def body(i, X):
        G = jax.vmap(obj.grad_objective)(prob, X)
        if H > 1:
            for td in tdefs:
                G = G + td.grad(X)
            Gp = jax.vmap(lambda pb, x: obj.penalty_grad(
                pb, x, jnp.asarray(penalty_w, jnp.float32)))(rest, X[1:])
            G = jnp.concatenate([G[:1], G[1:] + Gp])
        X = X - step_scale * G / L[:, None]
        return proj(X)

    return jax.lax.fori_loop(0, steps, body, proj(x_init))


def _solve_horizon_body(hp: HorizonProblem, x_current: jnp.ndarray,
                        delta_max: jnp.ndarray, x_init: jnp.ndarray,
                        cfg: HorizonSolverConfig, trace: bool = False):
    """The (un-jitted) solve of one plan X (H, n), dispatching on the
    configured engine — shared by the single-tenant and the vmapped fleet
    entry points. Returns ``(X, iters)``; ``solver="admm"`` at H>1 returns
    ``(X, iters, ADMMDiag)``. With ``trace=True`` the engine's capture is
    appended (``PGDTrace`` for adaptive, ``ADMMTrace`` for admm at H>1; the
    fixed loop has no ladder to record — callers reject that combination
    before tracing)."""
    if cfg.solver == "fixed":
        assert not trace, "solver='fixed' has no convergence trace"
        X = _solve_horizon_fixed(hp, x_current, delta_max, x_init, cfg.steps,
                                 cfg.step_scale, cfg.penalty_w,
                                 cfg.delta_penalty_w)
        return X, jnp.asarray(cfg.steps)
    if cfg.solver == "admm" and hp.H > 1:
        return admm_solve_plan(hp, x_current, delta_max, x_init,
                               rho=cfg.rho, admm_iters=cfg.admm_iters,
                               inner_steps=cfg.inner_steps,
                               admm_tol=cfg.admm_tol,
                               penalty_w=cfg.penalty_w,
                               delta_penalty_w=cfg.delta_penalty_w,
                               inner_cfg=cfg.inner_pgd(), trace=trace)
    # adaptive — and the admm H=1 dispatch: a one-tick window has no
    # coupling to split on, so ADMM reduces to its single prox block, which
    # IS the solve_incremental merit triple the adaptive engine runs
    value, grad, proj = _horizon_merit_fns(hp, x_current, delta_max,
                                           cfg.penalty_w, cfg.delta_penalty_w)
    if trace:
        X, _, iters, tr = pgd_minimize_traced(value, grad, proj, x_init,
                                              cfg.pgd())
        return X, iters, tr
    X, _, iters = pgd_minimize(value, grad, proj, x_init, cfg.pgd())
    return X, iters


@partial(jax.jit, static_argnames=("cfg",))
def _solve_horizon_impl(hp, x_current, delta_max, x_init,
                        cfg: HorizonSolverConfig):
    return _solve_horizon_body(hp, x_current, delta_max, x_init, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def _solve_horizon_traced_impl(hp, x_current, delta_max, x_init,
                               cfg: HorizonSolverConfig):
    """Traced twin of ``_solve_horizon_impl`` (adaptive engine only)."""
    return _solve_horizon_body(hp, x_current, delta_max, x_init, cfg,
                               trace=True)


@partial(jax.jit, static_argnames=("cfg",))
def _horizon_anytime_init_impl(hp, x_current, delta_max, x_init,
                               cfg: HorizonSolverConfig):
    """Chunk-state init of the anytime horizon solve (adaptive engine's
    merit triple — exactly ``_solve_horizon_body``'s adaptive dispatch)."""
    value, grad, proj = _horizon_merit_fns(hp, x_current, delta_max,
                                           cfg.penalty_w, cfg.delta_penalty_w)
    return pgd_chunk_init(value, grad, proj, x_init, cfg.pgd())


@partial(jax.jit, static_argnames=("cfg",))
def _horizon_anytime_chunk_impl(hp, x_current, delta_max, state, it_end,
                                cfg: HorizonSolverConfig):
    """Advance the anytime horizon solve to the traced cap ``it_end``."""
    value, grad, proj = _horizon_merit_fns(hp, x_current, delta_max,
                                           cfg.penalty_w, cfg.delta_penalty_w)
    return pgd_chunk_run(value, grad, proj, state, it_end, cfg.pgd())


def _require_anytime_adaptive(cfg: HorizonSolverConfig,
                              capture_trace: bool) -> None:
    """The anytime contract is defined on the chunked BB/Armijo engine;
    reject the engines (and the trace capture) it cannot truncate."""
    if cfg.solver != "adaptive":
        raise ValueError("anytime deadlines require solver='adaptive' "
                         f"(got {cfg.solver!r}): the fixed and admm "
                         "engines have no chunk-resumable state")
    if capture_trace:
        raise ValueError("anytime deadlines and capture_trace are "
                         "mutually exclusive; drop one")


def _resolve_cfg(cfg: Optional[HorizonSolverConfig], steps: Optional[int],
                 step_scale: Optional[float], penalty_w: Optional[float],
                 delta_penalty_w: Optional[float]) -> HorizonSolverConfig:
    """Merge the legacy per-argument knobs into a HorizonSolverConfig; an
    explicit ``cfg`` wins wholesale (the per-replay plumbing path)."""
    if cfg is not None:
        assert cfg.solver in ("adaptive", "fixed", "admm"), cfg.solver
        return cfg
    out = HorizonSolverConfig()
    if steps is not None:
        out = out._replace(steps=int(steps))
    if step_scale is not None:
        out = out._replace(step_scale=float(step_scale))
    if penalty_w is not None:
        out = out._replace(penalty_w=float(penalty_w))
    if delta_penalty_w is not None:
        out = out._replace(delta_penalty_w=float(delta_penalty_w))
    return out


def solve_horizon_info(hp: HorizonProblem, x_current, delta_max,
                       x_init: Optional[jnp.ndarray] = None,
                       steps: Optional[int] = None,
                       step_scale: Optional[float] = None,
                       penalty_w: Optional[float] = None,
                       delta_penalty_w: Optional[float] = None,
                       cfg: Optional[HorizonSolverConfig] = None,
                       capture_trace: bool = False,
                       anytime: Optional[AnytimeConfig] = None
                       ) -> HorizonSolveResult:
    """:func:`solve_horizon` variant returning the plan AND the iteration
    count the engine actually spent (== ``steps`` for the fixed engine; the
    early-stopping win for the adaptive one — what the benchmark's
    ``solver_iters`` cells aggregate). ``capture_trace=True`` additionally
    fills ``HorizonSolveResult.trace`` with the engine's per-iteration
    convergence rows; the fixed engine has no ladder to trace, so that
    combination raises ``ValueError``.

    An *enabled* ``anytime`` config (``core.pgd.AnytimeConfig`` with
    ``deadline_ms`` set; adaptive engine only) runs the solve chunked
    against the injectable clock and returns the best-so-far plan by merit
    when the budget expires, reporting the truncation in
    ``HorizonSolveResult.deadline_hit``; disabled/absent configs take the
    untruncated path — the exact pre-anytime compiled program."""
    cfg = _resolve_cfg(cfg, steps, step_scale, penalty_w, delta_penalty_w)
    if capture_trace and cfg.solver == "fixed":
        raise ValueError("capture_trace requires the adaptive or admm "
                         "engine; solver='fixed' records no convergence "
                         "trace")
    x_current = jnp.asarray(x_current, jnp.float32)
    delta_max = jnp.asarray(delta_max, jnp.float32)
    if x_init is None:
        x_init = jnp.tile(x_current[None, :], (hp.H, 1))
    x_init = jnp.asarray(x_init, jnp.float32)
    if anytime is not None and anytime.enabled:
        _require_anytime_adaptive(cfg, capture_trace)
        state, report = run_anytime(
            lambda: _horizon_anytime_init_impl(hp, x_current, delta_max,
                                               x_init, cfg),
            lambda s, e: _horizon_anytime_chunk_impl(hp, x_current, delta_max,
                                                     s, e, cfg),
            cfg.pgd(), anytime)
        return HorizonSolveResult(plan=state.x_best, iters=state.it,
                                  deadline_hit=report.deadline_hit)
    has_diag = cfg.solver == "admm" and hp.H > 1
    impl = (_solve_horizon_traced_impl if capture_trace
            else _solve_horizon_impl)
    out = impl(hp, x_current, delta_max, x_init, cfg)
    diag = out[2] if has_diag else None
    tr = out[-1] if capture_trace else None
    _gauge_admm(diag)
    return HorizonSolveResult(plan=out[0], iters=out[1], trace=tr, diag=diag)


def solve_horizon(hp: HorizonProblem, x_current, delta_max,
                  x_init: Optional[jnp.ndarray] = None,
                  steps: Optional[int] = None,
                  step_scale: Optional[float] = None,
                  penalty_w: Optional[float] = None,
                  delta_penalty_w: Optional[float] = None,
                  cfg: Optional[HorizonSolverConfig] = None) -> jnp.ndarray:
    """Solve the relaxed time-expanded program; returns the plan X (H, n).

    ``x_current`` (n,) is the currently deployed allocation the committed
    tick chains from (hard L1 ball of radius ``delta_max``, exact
    ``project_incremental``); ``x_init`` optionally warm-starts the whole
    plan (the MPC controller passes its previous plan shifted one tick,
    with row 0 reset to ``x_current``). ``cfg`` (a
    :class:`HorizonSolverConfig`) selects and parameterizes the engine —
    adaptive BB/Armijo by default, ``solver="fixed"`` for the original
    fixed-step loop; the remaining keyword knobs are legacy per-field
    overrides of the default config (ignored when ``cfg`` is given). The
    default budget (600) matches ``solve_incremental`` so the H=1 program
    is the myopic warm tick op-for-op. Only row 0 is committed — round it
    with :func:`round_committed` on the tick-0 problem. Use
    :func:`solve_horizon_info` to also get the iteration count."""
    return solve_horizon_info(hp, x_current, delta_max, x_init=x_init,
                              steps=steps, step_scale=step_scale,
                              penalty_w=penalty_w,
                              delta_penalty_w=delta_penalty_w, cfg=cfg).plan


def _gauge_admm(diag: Optional[ADMMDiag]) -> None:
    """Surface an ADMM solve's convergence certificate as ``repro.obs``
    telemetry gauges AND (when a ``repro.obs.metrics`` registry is
    installed) as exportable metrics: worst-lane residual gauges plus a
    residual histogram across solves. Batched solves gauge the worst lane —
    the residual that gates the whole bucket's quality. With neither sink
    installed the whole call is skipped BEFORE touching device values (the
    ``float()`` casts would otherwise force a sync the observability-off
    contract forbids)."""
    from repro.obs.metrics import current_metrics

    reg = current_metrics()
    if diag is None or (current_recorder() is None and reg is None):
        return
    primal = float(jnp.max(diag.primal_res))
    dual = float(jnp.max(diag.dual_res))
    iters = float(jnp.max(diag.admm_iters))
    gauge("horizon/admm_primal_res", primal)
    gauge("horizon/admm_dual_res", dual)
    gauge("horizon/admm_iters", iters)
    if reg is not None:
        reg.histogram("horizon/admm_primal_res",
                      lo_exp=-30, hi_exp=10).observe(primal)
        reg.gauge("horizon/admm_dual_res").set(dual)
        reg.gauge("horizon/admm_iters").set(iters)


def round_committed(p0, x_rel0: jnp.ndarray,
                    respect_plan: bool) -> jnp.ndarray:
    """Round the committed tick. With ``respect_plan`` (H>1) the rounding
    problem's lower bound is lifted to ``floor(x_rel0)``, so the polish
    scale-down cannot strip capacity the plan decided to hold for future
    ticks — rounding keeps its feasibility-first behavior for TODAY's
    demand but loses its authority to undo pre-provisioning. With
    ``respect_plan=False`` (H=1) this is plain ``round_and_polish``,
    bit-identical to the myopic controller's commit."""
    if not respect_plan:
        return round_and_polish(p0, x_rel0)
    lb = jnp.clip(jnp.floor(x_rel0), p0.lb, p0.ub)
    return round_and_polish(p0._replace(lb=lb), x_rel0)


# ---------------------------------------------------------------------------
# batched fleet tick (one program per shape bucket per tick, like solve_fleet)
# ---------------------------------------------------------------------------


class HorizonFleetStepResult(NamedTuple):
    """One batched receding-horizon tick over a fleet of lookahead windows.

    ``trace`` is None unless the tick ran with ``capture_trace=True``:
    per-lane ``core.pgd.PGDTrace`` rows with a leading (B,) axis (per-lane
    ``ADMMTrace`` residual rows for ``solver="admm"`` at H>1). ``diag`` is
    the ADMM engine's per-lane residual certificate ((B,) leaves; frozen
    lanes carry the values of the discarded masked solve), None for the
    monolithic engines."""

    plan: jnp.ndarray       # (B, H, n) relaxed plans (frozen: x_current tiled)
    x_int: jnp.ndarray      # (B, n) committed (rounded) tick-0 allocation
    fun_int: jnp.ndarray    # (B,) tick-0 objective at x_int
    feasible: jnp.ndarray   # (B,) tick-0 integer feasibility
    iters: jnp.ndarray      # (B,) PGD iterations per lane (frozen lanes: 0)
    trace: Optional[Union[PGDTrace, ADMMTrace]] = None  # (B, L) rows (opt-in)
    diag: Optional[ADMMDiag] = None   # admm-only per-lane residuals
    deadline_hit: Optional[bool] = None  # anytime tick truncated (None: n/a)


def _horizon_fleet_step_body(hp: HorizonProblem, x_current: jnp.ndarray,
                             delta_max: jnp.ndarray, x_init: jnp.ndarray,
                             active: jnp.ndarray, cfg: HorizonSolverConfig,
                             respect_plan: bool, trace: bool
                             ) -> HorizonFleetStepResult:
    # vmap the SAME body over the (B,) lane axis; vmap preserves per-lane op
    # structure, so each lane matches a sequential solve_horizon call
    solved = jax.vmap(
        lambda pb, xc, dm, xi: _solve_horizon_body(
            HorizonProblem(pb, hp.coupling_w, hp.coupling_eps), xc, dm, xi,
            cfg, trace=trace)
    )(hp.problem, x_current, delta_max, x_init)
    plan, iters = solved[0], solved[1]
    has_diag = cfg.solver == "admm" and hp.problem.d.shape[1] > 1
    diag = solved[2] if has_diag else None
    tr = solved[-1] if trace else None
    p0 = jax.tree_util.tree_map(lambda a: a[:, 0], hp.problem)   # (B, ...)
    x_int = jax.vmap(lambda pb, xr: round_committed(pb, xr, respect_plan)
                     )(p0, plan[:, 0])
    # frozen lanes (expired traces) keep their current allocation untouched
    plan = jnp.where(active[:, None, None], plan,
                     jnp.broadcast_to(x_current[:, None, :], plan.shape))
    x_int = jnp.where(active[:, None], x_int, x_current)
    f_int = jax.vmap(objective)(p0, x_int)
    feas = jax.vmap(lambda pb, xi: is_feasible(pb, xi, 1e-3))(p0, x_int)
    return HorizonFleetStepResult(plan=plan, x_int=x_int, fun_int=f_int,
                                  feasible=feas,
                                  iters=jnp.where(active, iters, 0),
                                  trace=tr, diag=diag)


@partial(jax.jit, static_argnames=("cfg", "respect_plan"))
def _horizon_fleet_step_impl(hp: HorizonProblem, x_current: jnp.ndarray,
                             delta_max: jnp.ndarray, x_init: jnp.ndarray,
                             active: jnp.ndarray, cfg: HorizonSolverConfig,
                             respect_plan: bool) -> HorizonFleetStepResult:
    return _horizon_fleet_step_body(hp, x_current, delta_max, x_init, active,
                                    cfg, respect_plan, trace=False)


@partial(jax.jit, static_argnames=("cfg", "respect_plan"))
def _horizon_fleet_step_traced_impl(hp: HorizonProblem, x_current, delta_max,
                                    x_init, active, cfg: HorizonSolverConfig,
                                    respect_plan: bool
                                    ) -> HorizonFleetStepResult:
    """Traced twin of ``_horizon_fleet_step_impl`` (adaptive engine only)."""
    return _horizon_fleet_step_body(hp, x_current, delta_max, x_init, active,
                                    cfg, respect_plan, trace=True)


@partial(jax.jit, static_argnames=("cfg",))
def _horizon_fleet_anytime_init_impl(hp: HorizonProblem, x_current, delta_max,
                                     x_init, cfg: HorizonSolverConfig):
    """Vmapped chunk-state init of the anytime fleet horizon tick (per-lane
    adaptive merit triples, leaves stacked on a leading (B,) axis)."""
    return jax.vmap(
        lambda pb, xc, dm, xi: pgd_chunk_init(
            *_horizon_merit_fns(HorizonProblem(pb, hp.coupling_w,
                                               hp.coupling_eps),
                                xc, dm, cfg.penalty_w, cfg.delta_penalty_w),
            xi, cfg.pgd())
    )(hp.problem, x_current, delta_max, x_init)


@partial(jax.jit, static_argnames=("cfg",))
def _horizon_fleet_anytime_chunk_impl(hp: HorizonProblem, x_current,
                                      delta_max, state, it_end,
                                      cfg: HorizonSolverConfig):
    """Advance every lane's anytime horizon solve to the traced cap."""
    return jax.vmap(
        lambda pb, xc, dm, s: pgd_chunk_run(
            *_horizon_merit_fns(HorizonProblem(pb, hp.coupling_w,
                                               hp.coupling_eps),
                                xc, dm, cfg.penalty_w, cfg.delta_penalty_w),
            s, it_end, cfg.pgd())
    )(hp.problem, x_current, delta_max, state)


@partial(jax.jit, static_argnames=("respect_plan",))
def _horizon_fleet_anytime_finalize_impl(hp: HorizonProblem, plan, x_current,
                                         active, iters, respect_plan: bool
                                         ) -> HorizonFleetStepResult:
    """The untruncated fleet tick's tail — committed-tick rounding,
    frozen-lane masking, objective and feasibility — applied to the anytime
    best-so-far plans."""
    p0 = jax.tree_util.tree_map(lambda a: a[:, 0], hp.problem)   # (B, ...)
    x_int = jax.vmap(lambda pb, xr: round_committed(pb, xr, respect_plan)
                     )(p0, plan[:, 0])
    plan = jnp.where(active[:, None, None], plan,
                     jnp.broadcast_to(x_current[:, None, :], plan.shape))
    x_int = jnp.where(active[:, None], x_int, x_current)
    f_int = jax.vmap(objective)(p0, x_int)
    feas = jax.vmap(lambda pb, xi: is_feasible(pb, xi, 1e-3))(p0, x_int)
    return HorizonFleetStepResult(plan=plan, x_int=x_int, fun_int=f_int,
                                  feasible=feas,
                                  iters=jnp.where(active, iters, 0))


def solve_horizon_fleet_step(hp: HorizonProblem, x_current: jnp.ndarray,
                             delta_max: Union[float, jnp.ndarray],
                             x_init: Optional[jnp.ndarray] = None,
                             active: Optional[np.ndarray] = None,
                             steps: Optional[int] = None,
                             penalty_w: Optional[float] = None,
                             delta_penalty_w: Optional[float] = None,
                             cfg: Optional[HorizonSolverConfig] = None,
                             capture_trace: bool = False,
                             anytime: Optional[AnytimeConfig] = None
                             ) -> HorizonFleetStepResult:
    """One receding-horizon tick for EVERY tenant lane in one jitted program.

    ``hp.problem`` leaves carry (B, H, ...) axes — B tenant lanes (padded to
    one shape bucket, exactly like ``solve_fleet``), each a stacked H-tick
    window. ``x_current`` (B, n) is the fleet's deployed allocation (the
    committed tick's hard-churn anchor), ``delta_max`` scalar or (B,),
    ``x_init`` (B, H, n) the per-lane plan warm starts (default: x_current
    tiled). ``active`` is the ragged-horizon liveness mask with
    solve_fleet_step semantics: frozen lanes come back with
    ``x_int == x_current``, their plan pinned to it and ``iters == 0``.
    ``cfg`` selects/parameterizes the engine exactly as in
    :func:`solve_horizon` (the legacy keyword knobs override the default
    config when ``cfg`` is omitted). vmap keeps lanes independent, so live
    lanes match sequential :func:`solve_horizon` + ``round_and_polish``
    calls exactly (CPU, test-enforced).

    ``capture_trace=True`` additionally returns per-lane convergence rows
    in ``HorizonFleetStepResult.trace`` (``PGDTrace`` for the adaptive
    engine, ``ADMMTrace`` for admm at H>1; ``solver='fixed'`` raises
    ``ValueError``). ADMM solves also fill the per-lane residual
    certificate ``HorizonFleetStepResult.diag`` and gauge the worst lane's
    residuals (``horizon/admm_*``) when a telemetry recorder is active.

    An *enabled* ``anytime`` config (adaptive engine only) runs the tick
    chunked against the injectable clock and commits each lane's
    best-so-far plan when the fleet-wide budget expires
    (``HorizonFleetStepResult.deadline_hit`` reports the truncation);
    disabled/absent configs take the exact pre-anytime program."""
    cfg = _resolve_cfg(cfg, steps, None, penalty_w, delta_penalty_w)
    if capture_trace and cfg.solver == "fixed":
        raise ValueError("capture_trace requires the adaptive or admm "
                         "engine; solver='fixed' records no convergence "
                         "trace")
    B = hp.problem.c.shape[0]
    H = hp.problem.d.shape[1]
    x_current = jnp.asarray(x_current, jnp.float32)
    delta_max = jnp.broadcast_to(jnp.asarray(delta_max, jnp.float32), (B,))
    if x_init is None:
        x_init = jnp.tile(x_current[:, None, :], (1, H, 1))
    active = (jnp.ones(B, bool) if active is None
              else jnp.asarray(np.asarray(active, bool)))
    if anytime is not None and anytime.enabled:
        _require_anytime_adaptive(cfg, capture_trace)
        x_init = jnp.asarray(x_init, jnp.float32)
        state, report = run_anytime(
            lambda: _horizon_fleet_anytime_init_impl(hp, x_current, delta_max,
                                                     x_init, cfg),
            lambda s, e: _horizon_fleet_anytime_chunk_impl(
                hp, x_current, delta_max, s, e, cfg),
            cfg.pgd(), anytime)
        res = _horizon_fleet_anytime_finalize_impl(
            hp, state.x_best, x_current, active, state.it,
            respect_plan=(H > 1))
        return res._replace(deadline_hit=report.deadline_hit)
    impl = (_horizon_fleet_step_traced_impl if capture_trace
            else _horizon_fleet_step_impl)
    res = impl(hp, x_current, delta_max, jnp.asarray(x_init, jnp.float32),
               active, cfg, respect_plan=(H > 1))
    _gauge_admm(res.diag)
    return res

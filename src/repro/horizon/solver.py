"""Jitted solver for the time-expanded receding-horizon program.

Two entry points, both one compiled program per call:

* :func:`solve_horizon` — one tenant: projected-gradient descent on the
  relaxed time-expanded objective over the plan ``X ∈ R^{H×n}``.
* :func:`solve_horizon_fleet_step` — the fleet analogue of
  ``repro.fleet.solver.solve_fleet_step``: the SAME per-tenant solve
  ``vmap``-ed across a (B,) leading lane axis, plus committed-tick rounding
  and ragged-horizon freezing, so a batched MPC replay issues one program
  per shape bucket per tick.

The iteration mirrors ``core.incremental.solve_incremental`` (the myopic
controller's warm tick) tick-by-tick:

    X ← Π( X - ∇F(X) / L )

with ∇F = per-tick analytic eq.(1) gradients (``core.objective``) plus the
smoothed inter-tick churn coupling, per-tick Lipschitz-ish steps L_h, and a
projection Π that applies exact ``project_incremental`` chaining from
``x_current`` on the COMMITTED tick (hard L1 churn ball — the same bound
the myopic controller enforces) and the box/mask projection on the planned
ticks, whose churn stays soft via the coupling penalty.

Two H>1-only terms make the lookahead real rather than decorative:

* **planned-tick band penalty** — the relaxed eq.(1) objective alone
  under-provisions systematically (the shortage term is soft; in this
  codebase demand COVERAGE is enforced by the feasibility-first greedy
  rounding, which planned rows never receive). Rows 1..H-1 therefore get
  the solver's quadratic band penalty (``core.objective.penalty``, the
  same fallback ``core.solver`` uses when no strict interior exists), so
  the plan settles at true future requirement levels and the coupling
  pulls the committed tick toward the right target.
* **plan-respecting commit rounding** — ``round_and_polish``'s scale-down
  strips every unit the CURRENT tick does not need, which would erase any
  pre-provisioning the plan decided to hold. The committed tick is
  rounded with its lower bound lifted to ``floor(x_rel_0)``
  (:func:`round_committed`): rounding still covers today's demand and
  still rounds fractions, but cannot scale below what the plan asked for.

At H = 1 both terms — and the coupling — vanish STRUCTURALLY (H is static
under jit, so they are absent from the compiled program, not just zero; a
one-tick window has no future to protect) and the tick reduces op-for-op
to ``solve_incremental`` + plain ``round_and_polish``: MPC with a one-tick
window reproduces the myopic controller's allocations exactly
(test-enforced — the equivalence anchor for everything the lookahead
adds).

The COLD start of an MPC replay needs no horizon solve at all: with no
current allocation there is no churn to couple, and the first committed
tick is the same multistart phase1→barrier-PGD→rounding program the myopic
controller (and ``solve_fleet``) runs — the horizon controller reuses those
core/fleet pieces directly rather than duplicating them here.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.objective as obj
from repro.core.incremental import project_incremental
from repro.core.objective import is_feasible, objective
from repro.core.rounding import round_and_polish

from .problem import (HorizonProblem, churn_bound_grad, coupling_grad,
                      tick_problem)

# planned-tick band-penalty weight; matches core.solver.SolverConfig's
# penalty_w — the same quadratic fallback weight the barrier solver uses
DEFAULT_PENALTY_W = 1e3
# soft churn-BOUND weight on planned transitions (problem.churn_bound_penalty)
# — strong enough that a one-tick excess of 1 node costs ~a node-hour, weak
# enough that the committed tick's step size stays usable
DEFAULT_DELTA_PENALTY_W = 50.0


def _tick_lipschitz(prob) -> jnp.ndarray:
    """Per-tick step denominator, the exact expression solve_incremental
    uses (required for the H=1 op-for-op equivalence)."""
    return (2.0 * prob.params.beta3 * jnp.sum(prob.K * prob.K)
            + jnp.linalg.norm(prob.c) + 1e-3)


def _solve_horizon_body(hp: HorizonProblem, x_current: jnp.ndarray,
                        delta_max: jnp.ndarray, x_init: jnp.ndarray,
                        steps: int, step_scale: float, penalty_w: float,
                        delta_penalty_w: float) -> jnp.ndarray:
    """The (un-jitted) PGD loop over one plan X (H, n) — shared by the
    single-tenant and the vmapped fleet entry points."""
    prob = hp.problem
    H = hp.H                              # static under jit/vmap tracing
    p0 = tick_problem(hp, 0)
    L = jax.vmap(_tick_lipschitz)(prob)                          # (H,)
    if H > 1:
        rest = jax.tree_util.tree_map(lambda a: a[1:], prob)
        # curvature of the smoothed |u|: s''(0) = 1/sqrt(eps), two coupling
        # terms touch each row, plus ~2w per adjacent transition from the
        # churn-bound hinge; planned rows add the band penalty's
        # 2*w*sum(K^2). Statically absent at H=1 so the step size matches
        # solve_incremental exactly.
        L = (L + 2.0 * hp.coupling_w / jnp.sqrt(hp.coupling_eps)
             + 4.0 * delta_penalty_w)
        pen_curv = 2.0 * penalty_w * jax.vmap(
            lambda pb: jnp.sum(pb.K * pb.K))(rest)               # (H-1,)
        L = jnp.concatenate([L[:1], L[1:] + pen_curv])

    def proj(X):
        x0 = project_incremental(p0, X[0], x_current, delta_max)
        if H == 1:
            return x0[None]
        rest_X = jax.vmap(obj.project)(rest, X[1:])
        return jnp.concatenate([x0[None], rest_X], axis=0)

    def body(i, X):
        G = jax.vmap(obj.grad_objective)(prob, X)
        if H > 1:
            G = G + coupling_grad(X, hp.coupling_w, hp.coupling_eps)
            G = G + churn_bound_grad(X, delta_max,
                                     jnp.asarray(delta_penalty_w, jnp.float32),
                                     hp.coupling_eps)
            Gp = jax.vmap(lambda pb, x: obj.penalty_grad(
                pb, x, jnp.asarray(penalty_w, jnp.float32)))(rest, X[1:])
            G = jnp.concatenate([G[:1], G[1:] + Gp])
        X = X - step_scale * G / L[:, None]
        return proj(X)

    return jax.lax.fori_loop(0, steps, body, proj(x_init))


@partial(jax.jit, static_argnames=("steps",))
def _solve_horizon_impl(hp, x_current, delta_max, x_init, steps, step_scale,
                        penalty_w, delta_penalty_w):
    return _solve_horizon_body(hp, x_current, delta_max, x_init, steps,
                               step_scale, penalty_w, delta_penalty_w)


def solve_horizon(hp: HorizonProblem, x_current, delta_max,
                  x_init: Optional[jnp.ndarray] = None, steps: int = 600,
                  step_scale: float = 1.0,
                  penalty_w: float = DEFAULT_PENALTY_W,
                  delta_penalty_w: float = DEFAULT_DELTA_PENALTY_W
                  ) -> jnp.ndarray:
    """Solve the relaxed time-expanded program; returns the plan X (H, n).

    ``x_current`` (n,) is the currently deployed allocation the committed
    tick chains from (hard L1 ball of radius ``delta_max``, exact
    ``project_incremental``); ``x_init`` optionally warm-starts the whole
    plan (the MPC controller passes its previous plan shifted one tick,
    with row 0 reset to ``x_current``). ``penalty_w`` is the planned-tick
    band-penalty weight and ``delta_penalty_w`` the soft churn-bound weight
    on planned transitions (module docstring; both inert at H=1). Defaults:
    ``x_init`` = x_current tiled; ``steps`` = 600, matching
    ``solve_incremental`` so the H=1 program is the myopic warm tick
    op-for-op. Only row 0 is committed — round it with
    :func:`round_committed` on the tick-0 problem."""
    x_current = jnp.asarray(x_current, jnp.float32)
    delta_max = jnp.asarray(delta_max, jnp.float32)
    if x_init is None:
        x_init = jnp.tile(x_current[None, :], (hp.H, 1))
    return _solve_horizon_impl(hp, x_current, delta_max,
                               jnp.asarray(x_init, jnp.float32), int(steps),
                               float(step_scale), float(penalty_w),
                               float(delta_penalty_w))


def round_committed(p0, x_rel0: jnp.ndarray,
                    respect_plan: bool) -> jnp.ndarray:
    """Round the committed tick. With ``respect_plan`` (H>1) the rounding
    problem's lower bound is lifted to ``floor(x_rel0)``, so the polish
    scale-down cannot strip capacity the plan decided to hold for future
    ticks — rounding keeps its feasibility-first behavior for TODAY's
    demand but loses its authority to undo pre-provisioning. With
    ``respect_plan=False`` (H=1) this is plain ``round_and_polish``,
    bit-identical to the myopic controller's commit."""
    if not respect_plan:
        return round_and_polish(p0, x_rel0)
    lb = jnp.clip(jnp.floor(x_rel0), p0.lb, p0.ub)
    return round_and_polish(p0._replace(lb=lb), x_rel0)


# ---------------------------------------------------------------------------
# batched fleet tick (one program per shape bucket per tick, like solve_fleet)
# ---------------------------------------------------------------------------


class HorizonFleetStepResult(NamedTuple):
    """One batched receding-horizon tick over a fleet of lookahead windows."""

    plan: jnp.ndarray       # (B, H, n) relaxed plans (frozen: x_current tiled)
    x_int: jnp.ndarray      # (B, n) committed (rounded) tick-0 allocation
    fun_int: jnp.ndarray    # (B,) tick-0 objective at x_int
    feasible: jnp.ndarray   # (B,) tick-0 integer feasibility


@partial(jax.jit, static_argnames=("steps", "respect_plan"))
def _horizon_fleet_step_impl(hp: HorizonProblem, x_current: jnp.ndarray,
                             delta_max: jnp.ndarray, x_init: jnp.ndarray,
                             active: jnp.ndarray, steps: int,
                             penalty_w: jnp.ndarray,
                             delta_penalty_w: jnp.ndarray, respect_plan: bool
                             ) -> HorizonFleetStepResult:
    # vmap the SAME body over the (B,) lane axis; vmap preserves per-lane op
    # structure, so each lane matches a sequential solve_horizon call
    plan = jax.vmap(
        lambda pb, xc, dm, xi: _solve_horizon_body(
            HorizonProblem(pb, hp.coupling_w, hp.coupling_eps), xc, dm, xi,
            steps, 1.0, penalty_w, delta_penalty_w)
    )(hp.problem, x_current, delta_max, x_init)
    p0 = jax.tree_util.tree_map(lambda a: a[:, 0], hp.problem)   # (B, ...)
    x_int = jax.vmap(lambda pb, xr: round_committed(pb, xr, respect_plan)
                     )(p0, plan[:, 0])
    # frozen lanes (expired traces) keep their current allocation untouched
    plan = jnp.where(active[:, None, None], plan,
                     jnp.broadcast_to(x_current[:, None, :], plan.shape))
    x_int = jnp.where(active[:, None], x_int, x_current)
    f_int = jax.vmap(objective)(p0, x_int)
    feas = jax.vmap(lambda pb, xi: is_feasible(pb, xi, 1e-3))(p0, x_int)
    return HorizonFleetStepResult(plan=plan, x_int=x_int, fun_int=f_int,
                                  feasible=feas)


def solve_horizon_fleet_step(hp: HorizonProblem, x_current: jnp.ndarray,
                             delta_max: Union[float, jnp.ndarray],
                             x_init: Optional[jnp.ndarray] = None,
                             active: Optional[np.ndarray] = None,
                             steps: int = 600,
                             penalty_w: float = DEFAULT_PENALTY_W,
                             delta_penalty_w: float = DEFAULT_DELTA_PENALTY_W
                             ) -> HorizonFleetStepResult:
    """One receding-horizon tick for EVERY tenant lane in one jitted program.

    ``hp.problem`` leaves carry (B, H, ...) axes — B tenant lanes (padded to
    one shape bucket, exactly like ``solve_fleet``), each a stacked H-tick
    window. ``x_current`` (B, n) is the fleet's deployed allocation (the
    committed tick's hard-churn anchor), ``delta_max`` scalar or (B,),
    ``x_init`` (B, H, n) the per-lane plan warm starts (default: x_current
    tiled). ``active`` is the ragged-horizon liveness mask with
    solve_fleet_step semantics: frozen lanes come back with
    ``x_int == x_current`` and their plan pinned to it. vmap keeps lanes
    independent, so live lanes match sequential :func:`solve_horizon` +
    ``round_and_polish`` calls exactly (CPU, test-enforced)."""
    B = hp.problem.c.shape[0]
    H = hp.problem.d.shape[1]
    x_current = jnp.asarray(x_current, jnp.float32)
    delta_max = jnp.broadcast_to(jnp.asarray(delta_max, jnp.float32), (B,))
    if x_init is None:
        x_init = jnp.tile(x_current[:, None, :], (1, H, 1))
    active = (jnp.ones(B, bool) if active is None
              else jnp.asarray(np.asarray(active, bool)))
    return _horizon_fleet_step_impl(hp, x_current, delta_max,
                                    jnp.asarray(x_init, jnp.float32), active,
                                    int(steps),
                                    jnp.asarray(penalty_w, jnp.float32),
                                    jnp.asarray(delta_penalty_w, jnp.float32),
                                    respect_plan=(H > 1))

"""Demand forecasters for receding-horizon (MPC) allocation.

A forecaster is a tiny stateful object fed the OBSERVED demand stream one
tick at a time (:meth:`Forecaster.observe`) and asked for the next ``k``
ticks (:meth:`Forecaster.predict`) — the lookahead window the MPC controller
plans over. The contract (see docs/horizon.md):

* ``observe(d_t)`` is called exactly once per tick, in trace order, BEFORE
  any ``predict`` for that tick, with the raw ``(m,)`` demand vector.
* ``predict(k)`` returns a ``(k, m)`` float64 array forecasting ticks
  ``t+1 .. t+k`` (one-step-ahead first). It must not mutate state — calling
  it twice returns the same array.
* Forecasts are strictly positive (clamped at a small floor) so the
  demand-normalized problem construction stays well conditioned.
* Everything is deterministic given the observation stream: replaying the
  same trace through the same forecaster kind yields the same forecasts,
  which is what makes MPC replays reproducible (the same property the
  ``make_trace`` generators have for a given seed).

Kinds (registry :data:`FORECASTER_KINDS`, entry point
:func:`make_forecaster`, mirroring ``repro.fleet.traces.make_trace``):

* ``last_value``   — persistence: tomorrow looks like today. The H=1
                     reference (MPC with it reproduces the myopic
                     controller; test-enforced).
* ``ewma``         — exponentially weighted moving average; flat forecast
                     at the smoothed level (noise-robust persistence).
* ``holt_winters`` — additive Holt-Winters with level/trend/seasonal
                     components; ``period`` matches the trace generators
                     (24 for diurnal, 168 for weekly).
* ``oracle``       — ground truth: reads the future straight from the
                     tenant's trace. Physically unrealizable; it is the
                     regret reference (docs/horizon.md) every real
                     forecaster is measured against.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

# forecasts are clamped elementwise at this floor: the solver normalizes
# K rows by 1/max(d, 1e-9), so a zero/negative forecast would blow up the
# conditioning of the time-expanded program
FORECAST_FLOOR = 1e-3


class Forecaster:
    """Base class defining the observe/predict contract (module docstring)."""

    def observe(self, demand: np.ndarray) -> None:
        """Feed one observed ``(m,)`` demand vector, in trace order."""
        raise NotImplementedError

    def predict(self, steps: int) -> np.ndarray:
        """Forecast the next ``steps`` ticks as a ``(steps, m)`` array."""
        raise NotImplementedError


def _clamp(pred: np.ndarray) -> np.ndarray:
    return np.maximum(np.asarray(pred, np.float64), FORECAST_FLOOR)


class LastValueForecaster(Forecaster):
    """Persistence forecast: every future tick equals the last observation."""

    def __init__(self) -> None:
        self._last: Optional[np.ndarray] = None

    def observe(self, demand: np.ndarray) -> None:
        """Record the latest demand vector."""
        self._last = np.asarray(demand, np.float64).copy()

    def predict(self, steps: int) -> np.ndarray:
        """(steps, m) copies of the last observation."""
        assert self._last is not None, "predict before any observe"
        return _clamp(np.tile(self._last, (steps, 1)))


class EWMAForecaster(Forecaster):
    """Exponentially weighted moving average; flat forecast at the level.

    ``alpha`` is the usual smoothing weight on the newest observation
    (alpha=1 degenerates to ``last_value``)."""

    def __init__(self, alpha: float = 0.3) -> None:
        assert 0.0 < alpha <= 1.0, alpha
        self.alpha = float(alpha)
        self._level: Optional[np.ndarray] = None

    def observe(self, demand: np.ndarray) -> None:
        """Fold the observation into the running level."""
        d = np.asarray(demand, np.float64)
        if self._level is None:
            self._level = d.copy()
        else:
            self._level = self.alpha * d + (1.0 - self.alpha) * self._level

    def predict(self, steps: int) -> np.ndarray:
        """(steps, m) copies of the smoothed level."""
        assert self._level is not None, "predict before any observe"
        return _clamp(np.tile(self._level, (steps, 1)))


class HoltWintersForecaster(Forecaster):
    """Additive Holt-Winters: level + trend + additive seasonal profile.

    ``period`` must match the trace's seasonality (24 ticks for the diurnal
    generators, 168 for weekly). Seasonal slots start at zero and are
    learned online, so the first period behaves like double-exponential
    smoothing and the seasonal shape sharpens from the second cycle on —
    no batch initialization pass is needed."""

    def __init__(self, period: int = 24, alpha: float = 0.35,
                 beta: float = 0.05, gamma: float = 0.25) -> None:
        assert period >= 1, period
        self.period = int(period)
        self.alpha, self.beta, self.gamma = float(alpha), float(beta), float(gamma)
        self._level: Optional[np.ndarray] = None
        self._trend: Optional[np.ndarray] = None
        self._season: Optional[np.ndarray] = None   # (period, m)
        self._t = 0                                 # observations so far

    def observe(self, demand: np.ndarray) -> None:
        """Standard additive Holt-Winters recurrences, one tick."""
        y = np.asarray(demand, np.float64)
        if self._level is None:
            self._level = y.copy()
            self._trend = np.zeros_like(y)
            self._season = np.zeros((self.period, len(y)), np.float64)
        else:
            slot = self._t % self.period
            s = self._season[slot]
            prev = self._level
            self._level = (self.alpha * (y - s)
                           + (1.0 - self.alpha) * (self._level + self._trend))
            self._trend = (self.beta * (self._level - prev)
                           + (1.0 - self.beta) * self._trend)
            self._season[slot] = (self.gamma * (y - self._level)
                                  + (1.0 - self.gamma) * s)
        self._t += 1

    def predict(self, steps: int) -> np.ndarray:
        """level + h*trend + the matching seasonal slot, h = 1..steps."""
        assert self._level is not None, "predict before any observe"
        h = np.arange(1, steps + 1, dtype=np.float64)
        # observation i lands in slot i % period; the h-step-ahead tick has
        # index (t-1) + h, hence slot (t - 1 + h) % period
        slots = (self._t - 1 + np.arange(1, steps + 1)) % self.period
        pred = (self._level[None, :] + h[:, None] * self._trend[None, :]
                + self._season[slots])
        return _clamp(pred)


class OracleForecaster(Forecaster):
    """Ground-truth forecast straight from the tenant's own trace.

    The regret reference: an MPC controller driven by the oracle pays only
    for the model's limits (horizon length, churn bound, convexification),
    never for forecast error. Past the end of the trace the last row is
    repeated (the controller never acts on those ticks anyway)."""

    def __init__(self, trace: np.ndarray) -> None:
        trace = np.asarray(trace, np.float64)
        assert trace.ndim == 2 and trace.shape[0] >= 1, trace.shape
        self.trace = trace
        self._t = 0                                 # observations so far

    def observe(self, demand: np.ndarray) -> None:
        """Advance the cursor (the trace itself already holds the value)."""
        self._t += 1

    def predict(self, steps: int) -> np.ndarray:
        """trace[t+1 .. t+steps], repeating the final row past the end."""
        assert self._t >= 1, "predict before any observe"
        idx = np.minimum(np.arange(self._t, self._t + steps),
                         self.trace.shape[0] - 1)
        return _clamp(self.trace[idx])


FORECASTER_KINDS: Dict[str, Callable] = {
    "last_value": LastValueForecaster,
    "ewma": EWMAForecaster,
    "holt_winters": HoltWintersForecaster,
    "oracle": OracleForecaster,
}


def make_forecaster(kind: str, *, trace: Optional[np.ndarray] = None,
                    **kwargs) -> Forecaster:
    """Registry entry point, mirroring ``make_trace``:
    ``make_forecaster("holt_winters", period=24)``.

    ``trace`` is consumed only by the ``"oracle"`` kind (which must read the
    future from somewhere); the real forecasters ignore it, so replay code
    can pass it unconditionally."""
    try:
        cls = FORECASTER_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown forecaster kind {kind!r}; "
                         f"choose from {sorted(FORECASTER_KINDS)}") from None
    if kind == "oracle":
        if trace is None:
            raise ValueError("oracle forecaster requires trace= (the ground-"
                             "truth demand it reads the future from)")
        return cls(trace, **kwargs)
    return cls(**kwargs)

"""Bench regression sentinel: schema-validate and compare BENCH_*.json.

The repo's BENCH files are its performance/quality trajectory, but until
now they were write-only — nothing caught a 30% steady-state slowdown or a
quietly worse objective between PRs. This module is the comparison engine
behind ``tools/bench_compare.py`` (and ``make bench-check`` in CI):

* :func:`validate_bench` — structural gate: a BENCH doc must carry a
  provenance block and at least one numeric metric.
* :func:`compare_bench` — walks the two docs' shared numeric leaves,
  classifies each metric path (timing / throughput / objective / quality —
  see :func:`classify_metric`), and checks the candidate against the
  baseline under PER-CLASS relative tolerances: timings may regress by
  ``timing_rtol`` (noisy), objectives only by ``objective_rtol`` (a worse
  J is a solver bug, not noise). Improvements never fail.

**Provenance-aware refusal**: tolerances are meaningless across different
experiments or machines, so the comparison REFUSES (distinct from failing)
when the two provenance blocks' ``config_digest`` differ (always — a
different config is a different workload) or when platform/backend differ
(unless ``allow_cross_platform=True``, which skips TIMING comparisons but
still compares the deterministic objective metrics — the mode CI uses,
since its runners don't match the machine that wrote the golden).

Unclassified metric paths are reported as skipped, never silently dropped
— a comparison that ignored half the file must say so.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["classify_metric", "validate_bench", "compare_bench",
           "MetricDelta", "BenchComparison", "numeric_leaves"]

# subtrees that are provenance/config, not metrics
_META_KEYS = ("provenance", "config")

# classification tables: (substring, class). First match on the FULL
# dotted path (lowercased) wins; later entries are fallbacks on the leaf
# key. Classes: lower-better "timing"/"objective", higher-better
# "throughput"/"quality".
_LEAF_RULES: Tuple[Tuple[str, str], ...] = (
    ("speedup", "throughput"),
    ("ticks_per_s", "throughput"),
    ("per_sec", "throughput"),
    ("savings_vs_ca_pct", "quality"),
    ("cost_savings", "quality"),
    ("t_compile", "timing"),
    ("t_execute", "timing"),
    ("t_replay", "timing"),
    ("t_fleet", "timing"),
    ("t_naive", "timing"),
    ("compile_ms", "timing"),
    ("execute_ms", "timing"),
    ("steady_ms", "timing"),
    ("tick_ms", "timing"),
    ("_ms", "timing"),
    ("t_total", "timing"),
    ("objective", "objective"),
    ("cost_integral", "objective"),
    ("slo_ticks", "objective"),
    ("slo_violation", "objective"),
    ("slo_breach", "objective"),
    ("nonfinite", "objective"),
    ("stall", "objective"),
    ("interruption", "objective"),
    ("churn", "objective"),
    ("regret", "objective"),
    ("fun_int", "objective"),
    ("cost", "objective"),
)


def classify_metric(path: str) -> Optional[str]:
    """Classify a dotted metric path: ``"timing"`` / ``"throughput"``
    (wall-clock, noisy, lower/higher-better), ``"objective"`` /
    ``"quality"`` (deterministic solution metrics, lower/higher-better),
    or None (not compared; reported as skipped)."""
    lower = path.lower()
    leaf = lower.rsplit(".", 1)[-1]
    for pat, cls in _LEAF_RULES:
        if pat in leaf:
            return cls
    # path-level fallback: a leaf nested under a timing-ish section
    for pat, cls in _LEAF_RULES:
        if pat in lower:
            return cls
    return None


def numeric_leaves(doc: Any, prefix: str = "") -> Dict[str, float]:
    """Flatten a BENCH doc to ``{dotted.path: value}`` over its numeric
    leaves, skipping the provenance/config subtrees and booleans. List
    elements use their index as a path segment."""
    out: Dict[str, float] = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            if not prefix and k in _META_KEYS:
                continue
            p = f"{prefix}.{k}" if prefix else str(k)
            out.update(numeric_leaves(v, p))
    elif isinstance(doc, (list, tuple)):
        for i, v in enumerate(doc):
            out.update(numeric_leaves(v, f"{prefix}.{i}" if prefix
                                      else str(i)))
    elif isinstance(doc, (int, float)) and not isinstance(doc, bool):
        out[prefix] = float(doc)
    return out


def validate_bench(doc: Any) -> List[str]:
    """Structural validation of one BENCH doc; returns problems (empty =
    valid). Required: a dict with a ``provenance`` dict carrying at least
    ``platform`` and ``backend`` keys, and >= 1 numeric metric leaf."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        return ["BENCH doc is not a JSON object"]
    prov = doc.get("provenance")
    if not isinstance(prov, dict):
        problems.append("missing provenance block")
    else:
        for key in ("platform", "backend"):
            if key not in prov:
                problems.append(f"provenance missing {key!r}")
    if not numeric_leaves(doc):
        problems.append("no numeric metric leaves found")
    return problems


@dataclass
class MetricDelta:
    """One compared metric: baseline/candidate values, relative change
    (positive = regression direction for its class) and pass/fail."""

    path: str
    kind: str           # timing | throughput | objective | quality
    base: float
    cand: float
    rel_change: float   # signed; > 0 means WORSE for this class
    rtol: float
    ok: bool


@dataclass
class BenchComparison:
    """The outcome of one baseline-vs-candidate comparison.

    ``refusals`` non-empty means the pair was NOT comparable (exit 2 in
    the CLI) — distinct from ``ok=False`` (comparable, and regressed)."""

    ok: bool
    deltas: List[MetricDelta] = field(default_factory=list)
    skipped: List[str] = field(default_factory=list)
    refusals: List[str] = field(default_factory=list)

    @property
    def regressions(self) -> List[MetricDelta]:
        """The failing deltas, worst relative change first."""
        return sorted((d for d in self.deltas if not d.ok),
                      key=lambda d: -d.rel_change)

    def summary(self) -> str:
        """Human-readable report (the CLI's output)."""
        if self.refusals:
            return "REFUSED:\n" + "\n".join(f"  {r}" for r in self.refusals)
        lines = [f"compared {len(self.deltas)} metrics "
                 f"({len(self.skipped)} unclassified skipped)"]
        for d in self.regressions:
            lines.append(f"  REGRESSION {d.path} [{d.kind}]: "
                         f"{d.base:g} -> {d.cand:g} "
                         f"({d.rel_change * 100:+.1f}%, rtol "
                         f"{d.rtol * 100:.0f}%)")
        if not self.regressions:
            worst = max(self.deltas, key=lambda d: d.rel_change,
                        default=None)
            if worst is not None:
                lines.append(f"  OK — worst delta {worst.path} "
                             f"{worst.rel_change * 100:+.1f}% "
                             f"(rtol {worst.rtol * 100:.0f}%)")
            else:
                lines.append("  OK — no shared classified metrics")
        return "\n".join(lines)


def _provenance_refusals(base: Dict, cand: Dict,
                         allow_cross_platform: bool) -> Tuple[List[str], bool]:
    """Compare the two provenance blocks; returns ``(refusals,
    skip_timing)``. Config-digest mismatch always refuses; platform or
    backend mismatch refuses unless ``allow_cross_platform`` — which
    instead drops every timing/throughput comparison (deterministic
    objective metrics survive a machine change; wall time does not)."""
    bp = base.get("provenance") or {}
    cp = cand.get("provenance") or {}
    refusals: List[str] = []
    bd, cd = bp.get("config_digest"), cp.get("config_digest")
    if bd is not None and cd is not None and bd != cd:
        refusals.append(f"config_digest mismatch ({bd} vs {cd}): the two "
                        f"runs measured different experiments")
    skip_timing = False
    for key in ("platform", "backend"):
        bv, cv = bp.get(key), cp.get(key)
        if bv is not None and cv is not None and bv != cv:
            if allow_cross_platform:
                skip_timing = True
            else:
                refusals.append(
                    f"{key} mismatch ({bv!r} vs {cv!r}): timings are not "
                    f"comparable across machines (pass "
                    f"--allow-cross-platform to compare objective metrics "
                    f"only)")
    return refusals, skip_timing


def compare_bench(base: Dict, cand: Dict, *, timing_rtol: float = 0.2,
                  objective_rtol: float = 0.01,
                  allow_cross_platform: bool = False) -> BenchComparison:
    """Compare candidate BENCH doc against a baseline (module docstring).

    A metric FAILS when its regression-direction relative change exceeds
    its class tolerance: timings/throughput vs ``timing_rtol``,
    objective/quality vs ``objective_rtol``. Metrics present in only one
    doc, or unclassified, are reported in ``skipped``."""
    problems = [f"baseline: {p}" for p in validate_bench(base)]
    problems += [f"candidate: {p}" for p in validate_bench(cand)]
    if problems:
        return BenchComparison(ok=False, refusals=problems)
    refusals, skip_timing = _provenance_refusals(base, cand,
                                                 allow_cross_platform)
    if refusals:
        return BenchComparison(ok=False, refusals=refusals)

    b_leaves = numeric_leaves(base)
    c_leaves = numeric_leaves(cand)
    deltas: List[MetricDelta] = []
    skipped: List[str] = []
    for path in sorted(set(b_leaves) | set(c_leaves)):
        if path not in b_leaves or path not in c_leaves:
            skipped.append(f"{path} (only in "
                           f"{'baseline' if path in b_leaves else 'candidate'})")
            continue
        kind = classify_metric(path)
        if kind is None:
            skipped.append(f"{path} (unclassified)")
            continue
        if skip_timing and kind in ("timing", "throughput"):
            skipped.append(f"{path} (timing skipped: cross-platform)")
            continue
        bv, cv = b_leaves[path], c_leaves[path]
        denom = max(abs(bv), 1e-12)
        # signed change, oriented so positive == regression for the class
        if kind in ("timing", "objective"):      # lower is better
            rel = (cv - bv) / denom
        else:                                    # higher is better
            rel = (bv - cv) / denom
        rtol = (timing_rtol if kind in ("timing", "throughput")
                else objective_rtol)
        deltas.append(MetricDelta(path=path, kind=kind, base=bv, cand=cv,
                                  rel_change=rel, rtol=rtol,
                                  ok=rel <= rtol))
    return BenchComparison(ok=all(d.ok for d in deltas), deltas=deltas,
                           skipped=skipped)

"""Provenance stamping for benchmark artifacts.

Every ``BENCH_*.json`` the repo emits embeds :func:`provenance_block` so a
number can always be traced back to the code and machine that produced it
— git SHA (+dirty flag), jax/jaxlib versions, the active JAX backend,
platform string, CPU count, UTC timestamp and the CLI args the run was
invoked with. Bench trajectories across PRs and machines are only
comparable when this block says they are.

Everything degrades to ``None`` rather than raising (e.g. git absent, or
running from an sdist without a work tree): provenance must never be the
reason a benchmark fails.

The block also carries a **config digest** (:func:`config_digest` — a
sha256 over the run's canonicalized configuration: bench args + solver
config) and the **seed list** the run consumed. ``tools/bench_compare.py``
refuses to compare two BENCH files whose digests differ — a tolerance
policy is meaningless across different workloads, and "the numbers moved"
must never be confused with "the experiment changed".
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import subprocess
import sys
from datetime import datetime, timezone
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["git_sha", "config_digest", "provenance_block"]


def git_sha(repo_dir: Optional[str] = None) -> Optional[str]:
    """Current commit SHA, suffixed ``+dirty`` when the tree has
    uncommitted changes; None when git/worktree is unavailable."""
    try:
        kw: Dict[str, Any] = {"stderr": subprocess.DEVNULL, "text": True}
        if repo_dir is not None:
            kw["cwd"] = repo_dir
        sha = subprocess.check_output(
            ["git", "rev-parse", "HEAD"], **kw).strip()
        dirty = subprocess.check_output(
            ["git", "status", "--porcelain"], **kw).strip()
        return sha + ("+dirty" if dirty else "")
    except (OSError, subprocess.CalledProcessError):
        return None


def _canonical(obj: Any) -> Any:
    """Coerce a config value into a JSON-stable form: numpy scalars/arrays
    to Python numbers/lists, tuples to lists, anything exotic to its repr —
    so the digest depends on VALUES, not container or dtype identity."""
    if isinstance(obj, dict):
        return {str(k): _canonical(v) for k, v in sorted(obj.items(),
                                                         key=lambda kv:
                                                         str(kv[0]))}
    if isinstance(obj, (list, tuple)):
        return [_canonical(v) for v in obj]
    if isinstance(obj, (str, bool)) or obj is None:
        return obj
    if isinstance(obj, (int, float)):
        return obj
    if hasattr(obj, "item") and getattr(obj, "shape", None) == ():
        return obj.item()                     # numpy/jax scalar
    if hasattr(obj, "tolist"):
        return obj.tolist()                   # numpy/jax array
    return repr(obj)


def config_digest(config: Any) -> str:
    """A short sha256 hex digest of the run's canonicalized configuration
    (bench args + solver config). Two BENCH files are comparable only when
    their digests match — ``bench_compare`` refuses otherwise. Dict key
    order, tuple-vs-list and numpy-vs-Python scalar types do not affect
    the digest; values do."""
    blob = json.dumps(_canonical(config), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def provenance_block(argv: Optional[List[str]] = None,
                     config: Any = None,
                     seeds: Optional[Sequence[int]] = None
                     ) -> Dict[str, Any]:
    """The provenance dict embedded in every emitted BENCH JSON.

    ``argv`` should be the CLI args the bench was invoked with (defaults
    to ``sys.argv[1:]``). ``config`` is the run's full configuration (bench
    parameters + solver config), digested via :func:`config_digest` so
    ``bench_compare`` can refuse cross-config comparisons; ``seeds`` the
    RNG seeds the run consumed. Both stamp ``None`` when omitted (older
    BENCH files simply lack the keys)."""
    try:
        import jax
        import jaxlib
        jax_version = jax.__version__
        jaxlib_version = jaxlib.__version__
        backend = jax.default_backend()
    except Exception:  # jax import/init failure — stamp what we can
        jax_version = jaxlib_version = backend = None
    return {
        "git_sha": git_sha(),
        "jax": jax_version,
        "jaxlib": jaxlib_version,
        "backend": backend,
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "cpu_count": os.cpu_count(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(),
        "argv": list(sys.argv[1:] if argv is None else argv),
        "config_digest": None if config is None else config_digest(config),
        "seeds": None if seeds is None else [int(s) for s in seeds],
    }

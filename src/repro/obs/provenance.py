"""Provenance stamping for benchmark artifacts.

Every ``BENCH_*.json`` the repo emits embeds :func:`provenance_block` so a
number can always be traced back to the code and machine that produced it
— git SHA (+dirty flag), jax/jaxlib versions, the active JAX backend,
platform string, CPU count, UTC timestamp and the CLI args the run was
invoked with. Bench trajectories across PRs and machines are only
comparable when this block says they are.

Everything degrades to ``None`` rather than raising (e.g. git absent, or
running from an sdist without a work tree): provenance must never be the
reason a benchmark fails.
"""
from __future__ import annotations

import os
import platform
import subprocess
import sys
from datetime import datetime, timezone
from typing import Any, Dict, List, Optional

__all__ = ["git_sha", "provenance_block"]


def git_sha(repo_dir: Optional[str] = None) -> Optional[str]:
    """Current commit SHA, suffixed ``+dirty`` when the tree has
    uncommitted changes; None when git/worktree is unavailable."""
    try:
        kw: Dict[str, Any] = {"stderr": subprocess.DEVNULL, "text": True}
        if repo_dir is not None:
            kw["cwd"] = repo_dir
        sha = subprocess.check_output(
            ["git", "rev-parse", "HEAD"], **kw).strip()
        dirty = subprocess.check_output(
            ["git", "status", "--porcelain"], **kw).strip()
        return sha + ("+dirty" if dirty else "")
    except (OSError, subprocess.CalledProcessError):
        return None


def provenance_block(argv: Optional[List[str]] = None) -> Dict[str, Any]:
    """The provenance dict embedded in every emitted BENCH JSON.

    ``argv`` should be the CLI args the bench was invoked with (defaults
    to ``sys.argv[1:]``)."""
    try:
        import jax
        import jaxlib
        jax_version = jax.__version__
        jaxlib_version = jaxlib.__version__
        backend = jax.default_backend()
    except Exception:  # jax import/init failure — stamp what we can
        jax_version = jaxlib_version = backend = None
    return {
        "git_sha": git_sha(),
        "jax": jax_version,
        "jaxlib": jaxlib_version,
        "backend": backend,
        "platform": platform.platform(),
        "python": sys.version.split()[0],
        "cpu_count": os.cpu_count(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(),
        "argv": list(sys.argv[1:] if argv is None else argv),
    }

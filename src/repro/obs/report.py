"""``ReplayReport`` — aggregate an instrumented replay into numbers.

The instrumented call sites (``fleet/replay.py``, ``fleet/batching.py``,
``horizon/controller.py``) emit spans under a small stable namespace:

* ``replay/tick`` — one span per replayed tick (both engines), tagged with
  ``tick`` / ``engine`` / ``controller``;
* ``replay/stack``, ``replay/solve``, ``replay/round``,
  ``replay/metrics`` — the phases inside a tick (solve spans carry a
  ``compile_key`` so their first occurrence is tagged ``phase="compile"``);
* gauge ``stack/padding_waste`` — padded-cell waste fraction per stacked
  bucket; gauge ``replay/solver_iters`` — per-tick summed PGD iterations.

:class:`ReplayReport` rolls a recorder up along those conventions: per-name
phase stats with compile/execute split and p50/p95/p99 over steady-state
spans, per-tick latency percentiles, padding-waste and solver-iters
distributions. It renders as a text table (``render()``) and exports as a
JSON-ready dict (``to_dict()`` — the ``telemetry`` section of the BENCH
JSONs). It degrades gracefully: a recorder with none of the replay spans
produces an empty-but-valid report, so the aggregation works for any
instrumented region, not just replays.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .telemetry import Recorder

__all__ = ["PhaseStats", "ReplayReport", "percentiles"]


def percentiles(values: Sequence[float],
                qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p95": ...}`` over ``values`` (empty dict if none)."""
    arr = np.asarray(list(values), dtype=float)
    if arr.size == 0:
        return {}
    return {f"p{int(q)}": float(np.percentile(arr, q)) for q in qs}


@dataclass
class PhaseStats:
    """Rollup of all spans sharing one name.

    ``compile_ms`` sums spans tagged ``phase="compile"`` (first call per
    ``compile_key`` — includes XLA compilation); ``execute_ms`` sums the
    steady-state rest. The percentile fields are over steady-state spans
    only (compile outliers would swamp them); when a name never declared a
    compile key every span counts as steady-state."""

    name: str
    count: int
    total_ms: float
    compile_ms: float
    execute_ms: float
    p50_ms: Optional[float] = None
    p95_ms: Optional[float] = None
    p99_ms: Optional[float] = None

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict of the rollup."""
        return {"name": self.name, "count": self.count,
                "total_ms": self.total_ms, "compile_ms": self.compile_ms,
                "execute_ms": self.execute_ms, "p50_ms": self.p50_ms,
                "p95_ms": self.p95_ms, "p99_ms": self.p99_ms}


@dataclass
class ReplayReport:
    """Aggregated view of one instrumented run (see module docstring)."""

    n_ticks: int = 0
    tick_ms: Dict[str, float] = field(default_factory=dict)
    phases: List[PhaseStats] = field(default_factory=list)
    compile_ms: float = 0.0
    execute_ms: float = 0.0
    padding_waste: Dict[str, float] = field(default_factory=dict)
    solver_iters: Dict[str, float] = field(default_factory=dict)
    counters: Dict[str, float] = field(default_factory=dict)

    @classmethod
    def from_recorder(cls, rec: Recorder) -> "ReplayReport":
        """Build the report by rolling up a recorder's spans and gauges."""
        by_name: Dict[str, list] = {}
        for e in rec.events:
            by_name.setdefault(e.name, []).append(e)

        phases = []
        for name in sorted(by_name):
            evs = by_name[name]
            comp = [e for e in evs if e.phase == "compile"]
            steady = [e for e in evs if e.phase != "compile"]
            pcts = percentiles([e.dur_us / 1e3 for e in steady],
                               (50, 95, 99))
            phases.append(PhaseStats(
                name=name, count=len(evs),
                total_ms=sum(e.dur_us for e in evs) / 1e3,
                compile_ms=sum(e.dur_us for e in comp) / 1e3,
                execute_ms=sum(e.dur_us for e in steady) / 1e3,
                p50_ms=pcts.get("p50"), p95_ms=pcts.get("p95"),
                p99_ms=pcts.get("p99")))

        ticks = by_name.get("replay/tick", [])
        waste = [v for _, v in rec.gauges.get("stack/padding_waste", [])]
        iters = [v for _, v in rec.gauges.get("replay/solver_iters", [])]
        iters_stats = percentiles(iters, (50, 95))
        if iters:
            iters_stats["max"] = float(max(iters))
            iters_stats["total"] = float(sum(iters))
        waste_stats: Dict[str, float] = {}
        if waste:
            waste_stats = {"mean": float(np.mean(waste)),
                           "max": float(max(waste))}
        return cls(
            n_ticks=len(ticks),
            tick_ms=percentiles([e.dur_us / 1e3 for e in ticks],
                                (50, 95, 99)),
            phases=phases,
            compile_ms=sum(p.compile_ms for p in phases),
            execute_ms=sum(p.execute_ms for p in phases),
            padding_waste=waste_stats,
            solver_iters=iters_stats,
            counters=dict(rec.counters))

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict — embedded as the BENCH ``telemetry`` section."""
        return {
            "n_ticks": self.n_ticks,
            "tick_ms": self.tick_ms,
            "compile_ms": self.compile_ms,
            "execute_ms": self.execute_ms,
            "phases": [p.to_dict() for p in self.phases],
            "padding_waste": self.padding_waste,
            "solver_iters": self.solver_iters,
            "counters": self.counters,
        }

    def render(self) -> str:
        """Human-readable text summary of the run."""
        lines = [f"replay report: {self.n_ticks} ticks, "
                 f"compile {self.compile_ms:.1f}ms, "
                 f"execute {self.execute_ms:.1f}ms"]
        if self.tick_ms:
            lines.append(
                "  tick latency  p50 {p50:.2f}ms  p95 {p95:.2f}ms  "
                "p99 {p99:.2f}ms".format(**self.tick_ms))
        if self.phases:
            lines.append(f"  {'phase':<28s} {'n':>5s} {'total':>10s} "
                         f"{'compile':>9s} {'p50':>8s} {'p99':>8s}")
            for p in self.phases:
                p50 = f"{p.p50_ms:.2f}" if p.p50_ms is not None else "-"
                p99 = f"{p.p99_ms:.2f}" if p.p99_ms is not None else "-"
                lines.append(f"  {p.name:<28s} {p.count:>5d} "
                             f"{p.total_ms:>8.1f}ms {p.compile_ms:>7.1f}ms "
                             f"{p50:>8s} {p99:>8s}")
        if self.padding_waste:
            lines.append("  padding waste  mean {mean:.1%}  max {max:.1%}"
                         .format(**self.padding_waste))
        if self.solver_iters:
            si = self.solver_iters
            lines.append(f"  solver iters/tick  p50 {si.get('p50', 0):.0f}"
                         f"  p95 {si.get('p95', 0):.0f}"
                         f"  max {si.get('max', 0):.0f}"
                         f"  total {si.get('total', 0):.0f}")
        for name in sorted(self.counters):
            lines.append(f"  counter {name:<24s} {self.counters[name]:g}")
        return "\n".join(lines)

"""repro.obs — JIT-aware observability: spans, metrics, health, reports.

The layer every perf claim in this repo must be able to back up:

* :mod:`repro.obs.telemetry` — contextvar-scoped nested timing spans,
  counters and gauges; zero-overhead no-op when disabled; compile-vs-
  execute tagging and ``block_until_ready`` fencing for jitted calls.
* :mod:`repro.obs.metrics` — typed metric registry (counters, gauges,
  fixed-bucket log2 histograms with p50/p95/p99), jit/vmap-safe hot-path
  accumulation (``bucket_counts`` + host-side merge per tick), Prometheus
  textfile + JSON snapshot exporters; no-op when disabled.
* :mod:`repro.obs.health` — per-tick fleet health monitoring for
  ``replay_fleet``: committed-tick KKT gauges, SLO/churn/spot breach
  counters, solver stall detection, non-finite guards, deadline budget.
* :mod:`repro.obs.solver_trace` — per-iteration PGD convergence capture
  (vmap-safe fixed-size arrays) and host-side analysis helpers.
* :mod:`repro.obs.export` — JSONL and Perfetto-loadable Chrome trace
  export, plus the schema validators ``make trace-demo`` gates on.
* :mod:`repro.obs.report` — ``ReplayReport``: per-phase compile/execute
  split, p50/p95/p99 tick latency, padding waste, solver-iters stats.
* :mod:`repro.obs.provenance` — the provenance block (git SHA, versions,
  config digest, seeds) stamped into every BENCH JSON.
* :mod:`repro.obs.regress` — the bench regression sentinel behind
  ``tools/bench_compare.py`` / ``make bench-check``: provenance-aware
  BENCH-vs-BENCH comparison with per-class tolerances.

Design rule (test-enforced): observability may measure the system but
never participate in it — allocations are bit-identical with telemetry,
metrics and health monitoring on or off.
"""
from .telemetry import (Recorder, Span, SpanEvent, counter, current_recorder,
                        gauge, span, telemetry)
from .metrics import (Counter, Gauge, HistCounts, Histogram, MetricRegistry,
                      bucket_counts, collect_metrics, current_metrics, inc,
                      observe, observe_counts, set_gauge)
from .health import HealthEvent, HealthMonitor, HealthReport
from .solver_trace import (SolverTrace, admm_trace_summary, lane_trace,
                           trace_length, trace_summary, traces_to_dict,
                           trim_admm_trace, trim_trace)
from .export import (events_to_dicts, to_chrome_trace, validate_chrome_trace,
                     validate_jsonl, write_chrome_trace, write_jsonl)
from .report import PhaseStats, ReplayReport, percentiles
from .provenance import config_digest, git_sha, provenance_block
from .regress import (BenchComparison, MetricDelta, classify_metric,
                      compare_bench, numeric_leaves, validate_bench)


def __getattr__(name: str):
    # ADMMTrace is re-exported lazily (see solver_trace.__getattr__): the
    # record lives in repro.horizon.admm, which transitively imports this
    # package — an eager import here would be circular.
    if name == "ADMMTrace":
        from .solver_trace import ADMMTrace
        return ADMMTrace
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Recorder", "Span", "SpanEvent", "telemetry", "current_recorder",
    "span", "counter", "gauge",
    "Counter", "Gauge", "Histogram", "HistCounts", "MetricRegistry",
    "bucket_counts", "collect_metrics", "current_metrics", "inc",
    "set_gauge", "observe", "observe_counts",
    "HealthEvent", "HealthMonitor", "HealthReport",
    "SolverTrace", "trace_length", "lane_trace", "trim_trace",
    "trace_summary", "traces_to_dict",
    "ADMMTrace", "trim_admm_trace", "admm_trace_summary",
    "events_to_dicts", "write_jsonl", "to_chrome_trace",
    "write_chrome_trace", "validate_chrome_trace", "validate_jsonl",
    "PhaseStats", "ReplayReport", "percentiles",
    "git_sha", "provenance_block", "config_digest",
    "BenchComparison", "MetricDelta", "classify_metric", "compare_bench",
    "numeric_leaves", "validate_bench",
]

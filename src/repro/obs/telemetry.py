"""Nested timing spans, counters and gauges behind a contextvar Recorder.

The observability contract of this repo, in one sentence: **telemetry may
measure the system but never participate in it**. Concretely:

* When no recorder is installed (the default), every instrumentation point
  degenerates to one ``ContextVar.get`` returning ``None`` plus a shared
  no-op context manager — no allocation, no clock read, no fencing. The
  instrumented code paths are the production code paths.
* When a recorder IS installed, spans read the monotonic clock and
  (optionally) fence JAX async dispatch with ``block_until_ready`` — which
  forces *completion*, never *recomputation*: device values are untouched,
  so per-tenant integer allocations are bit-identical with telemetry on or
  off (test-enforced in ``tests/obs/test_telemetry.py``).

Span model
----------

A span is a named wall-clock interval with a category, free-form tags and
an implicit parent (the innermost open span on the recorder's stack —
spans nest like call frames; export reconstructs the tree from interval
containment). Spans that wrap jitted calls should:

1. pass ``fence=...`` (any pytree of JAX arrays) or call ``Span.fence(x)``
   before the span closes, so async dispatch cannot leak the device time
   into whatever span comes next, and
2. pass a hashable ``compile_key`` identifying the compiled program
   (function name + static shapes/args). The FIRST span per recorder to
   see a given key is tagged ``phase="compile"`` (its duration includes
   XLA compilation); later spans with the same key are ``phase="execute"``
   (steady state). Aggregations (``repro.obs.report``) use the tag to
   split compile time from execute time — the split every speedup claim
   in ``benchmarks/`` must be able to back up.

Counters are monotonic sums (``counter("replay/solver_iters", 42)``);
gauges are timestamped samples (``gauge("stack/padding_waste", 0.37)``).
Both land in the export stream alongside spans.

Usage::

    from repro.obs import telemetry, span

    with telemetry() as rec:
        with span("replay/solve", compile_key=("warm", 32, 4)) as sp:
            res = solve_fleet_step(batch, X, delta)
            sp.fence(res.x_int)
    print(rec.summary())

All timestamps are microseconds since the recorder was installed
(monotonic, ``time.perf_counter_ns`` based) — the unit Chrome trace events
use natively.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = ["Recorder", "SpanEvent", "Span", "telemetry", "current_recorder",
           "span", "counter", "gauge"]


@dataclass
class SpanEvent:
    """One closed span: a named wall-clock interval plus its context.

    ``ts_us``/``dur_us`` are microseconds (start relative to the recorder's
    installation, duration of the interval). ``depth`` is the nesting level
    at open time (0 = top-level). ``phase`` is ``"compile"`` for the first
    span of a ``compile_key``, ``"execute"`` for repeats, and ``None`` for
    spans that never declared a key (pure-host work). ``tags`` carries the
    caller's free-form annotations (bucket dims, tick index, engine...)."""

    name: str
    cat: str
    ts_us: float
    dur_us: float
    depth: int
    phase: Optional[str] = None
    tags: Dict[str, Any] = field(default_factory=dict)


class Span:
    """An OPEN span handle (yielded by :func:`span` while recording).

    ``fence(x)`` blocks until every JAX array in the pytree ``x`` is ready
    and returns ``x`` unchanged — call it on the jitted call's result so
    the span measures completed device work, not dispatch. ``tag(k, v)``
    attaches tags after opening."""

    __slots__ = ("_rec", "name", "cat", "tags", "_t0", "_depth", "phase")

    def __init__(self, rec: "Recorder", name: str, cat: str,
                 tags: Dict[str, Any], depth: int, phase: Optional[str]):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.tags = tags
        self._depth = depth
        self.phase = phase
        self._t0 = time.perf_counter_ns()

    def fence(self, x):
        """Block until every JAX array in ``x`` is ready; returns ``x``."""
        import jax
        jax.block_until_ready(x)
        return x

    def tag(self, **kv) -> "Span":
        """Attach tags to the open span; returns self for chaining."""
        self.tags.update(kv)
        return self

    def _close(self) -> None:
        t1 = time.perf_counter_ns()
        self._rec._events.append(SpanEvent(
            name=self.name, cat=self.cat,
            ts_us=(self._t0 - self._rec._t0_ns) / 1e3,
            dur_us=(t1 - self._t0) / 1e3,
            depth=self._depth, phase=self.phase, tags=self.tags))


class _NoopSpan:
    """The shared do-nothing span handle returned while telemetry is off.

    ``fence`` is a true no-op: with no recorder there is nothing to time,
    so the production path never pays a ``block_until_ready``."""

    __slots__ = ()

    def fence(self, x):
        """Return ``x`` untouched (no sync — telemetry is off)."""
        return x

    def tag(self, **kv) -> "_NoopSpan":
        """Ignore tags; returns self."""
        return self


_NOOP_SPAN = _NoopSpan()


class Recorder:
    """Collects spans, counters and gauges for one instrumented region.

    Install via :func:`telemetry`; read back through ``events`` /
    ``counters`` / ``gauges``, aggregate with ``repro.obs.report``, export
    with ``repro.obs.export``. Not thread-safe by design — one recorder
    instruments one (single-threaded) replay/bench run; the contextvar
    scoping keeps concurrent asyncio tasks from sharing one by accident."""

    def __init__(self) -> None:
        self._t0_ns = time.perf_counter_ns()
        self._events: List[SpanEvent] = []
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, List[Tuple[float, float]]] = {}
        self._depth = 0
        self._seen_keys: set = set()

    # -- recording ----------------------------------------------------------

    @contextmanager
    def span(self, name: str, cat: str = "span",
             compile_key: Optional[Any] = None,
             fence: Optional[Any] = None, **tags) -> Iterator[Span]:
        """Open a nested span; see module docstring for the span model.

        ``compile_key`` (hashable) tags this span ``phase="compile"`` the
        first time the key is seen by this recorder, ``"execute"`` after.
        ``fence`` optionally names a pytree to ``block_until_ready`` at
        close (equivalent to calling ``Span.fence`` last)."""
        phase = None
        if compile_key is not None:
            first = compile_key not in self._seen_keys
            if first:
                self._seen_keys.add(compile_key)
            phase = "compile" if first else "execute"
        sp = Span(self, name, cat, dict(tags), self._depth, phase)
        self._depth += 1
        try:
            yield sp
        finally:
            if fence is not None:
                sp.fence(fence)
            self._depth -= 1
            sp._close()

    def counter(self, name: str, inc: float = 1.0) -> None:
        """Add ``inc`` to the monotonic counter ``name``."""
        self.counters[name] = self.counters.get(name, 0.0) + float(inc)

    def gauge(self, name: str, value: float) -> None:
        """Record a timestamped sample of gauge ``name``."""
        now = (time.perf_counter_ns() - self._t0_ns) / 1e3
        self.gauges.setdefault(name, []).append((now, float(value)))

    # -- reading back -------------------------------------------------------

    @property
    def events(self) -> List[SpanEvent]:
        """All closed spans, in close order."""
        return list(self._events)

    def spans(self, name: Optional[str] = None,
              phase: Optional[str] = None) -> List[SpanEvent]:
        """Closed spans filtered by exact name and/or phase."""
        return [e for e in self._events
                if (name is None or e.name == name)
                and (phase is None or e.phase == phase)]

    def total_us(self, name: str, phase: Optional[str] = None) -> float:
        """Summed duration (µs) of all spans named ``name``."""
        return sum(e.dur_us for e in self.spans(name, phase))

    def summary(self) -> str:
        """A quick per-name rollup (count, total ms, compile/execute split)
        for interactive use; ``repro.obs.report.ReplayReport`` is the full
        replay-aware aggregation."""
        by_name: Dict[str, List[SpanEvent]] = {}
        for e in self._events:
            by_name.setdefault(e.name, []).append(e)
        lines = [f"telemetry: {len(self._events)} spans, "
                 f"{len(self.counters)} counters, {len(self.gauges)} gauges"]
        for name in sorted(by_name):
            evs = by_name[name]
            tot = sum(e.dur_us for e in evs) / 1e3
            comp = sum(e.dur_us for e in evs if e.phase == "compile") / 1e3
            line = f"  {name:<28s} n={len(evs):<5d} total {tot:9.1f}ms"
            if comp:
                line += f"  (compile {comp:.1f}ms)"
            lines.append(line)
        for name in sorted(self.counters):
            lines.append(f"  counter {name:<20s} {self.counters[name]:g}")
        return "\n".join(lines)


_RECORDER: ContextVar[Optional[Recorder]] = ContextVar(
    "repro_obs_recorder", default=None)


def current_recorder() -> Optional[Recorder]:
    """The recorder installed in this context, or None (telemetry off)."""
    return _RECORDER.get()


@contextmanager
def telemetry(enabled: bool = True) -> Iterator[Optional[Recorder]]:
    """Install a fresh :class:`Recorder` for the enclosed block.

    ``with telemetry() as rec: ...`` — every :func:`span` / :func:`counter`
    / :func:`gauge` call inside the block (any module, any call depth)
    records into ``rec``. ``telemetry(enabled=False)`` is an explicit
    no-op scope (yields None), handy for flag-driven call sites. Nested
    ``telemetry()`` blocks shadow the outer recorder and restore it on
    exit (contextvar token reset)."""
    if not enabled:
        yield None
        return
    rec = Recorder()
    token = _RECORDER.set(rec)
    try:
        yield rec
    finally:
        _RECORDER.reset(token)


def span(name: str, cat: str = "span", compile_key: Optional[Any] = None,
         fence: Optional[Any] = None, **tags):
    """Module-level span entry point — THE instrumentation call sites use.

    With a recorder installed this is ``recorder.span(...)``; without one
    it returns a shared no-op context manager whose ``fence`` does nothing
    — the disabled cost is one contextvar read. See the module docstring
    for ``compile_key`` (compile-vs-execute tagging) and fencing."""
    rec = _RECORDER.get()
    if rec is None:
        return _NOOP_CM
    return rec.span(name, cat=cat, compile_key=compile_key, fence=fence,
                    **tags)


class _NoopContext:
    """Reusable, reentrant no-op context manager (the disabled span path:
    no generator, no allocation — one shared instance serves every call)."""

    __slots__ = ()

    def __enter__(self) -> _NoopSpan:
        return _NOOP_SPAN

    def __exit__(self, *exc) -> bool:
        return False


_NOOP_CM = _NoopContext()


def counter(name: str, inc: float = 1.0) -> None:
    """Bump counter ``name`` on the installed recorder (no-op when off)."""
    rec = _RECORDER.get()
    if rec is not None:
        rec.counter(name, inc)


def gauge(name: str, value: float) -> None:
    """Sample gauge ``name`` on the installed recorder (no-op when off)."""
    rec = _RECORDER.get()
    if rec is not None:
        rec.gauge(name, value)

"""Per-tick fleet health monitoring for ``replay_fleet``.

``repro.obs.telemetry`` measures *time*; this module watches *health*: is
the solver actually solving, are SLOs holding, did a NaN sneak into an
allocation, did a tick blow its latency budget? A :class:`HealthMonitor`
rides along a replay (``replay_fleet(..., health=monitor)``) and, per
committed (tenant, tick):

* **KKT-residual gauges** — every ``kkt_every`` ticks the committed tick's
  RELAXED solution is certified through :func:`repro.core.kkt.kkt_report`
  (the paper's eq. 8-11 residuals; cold multistart ticks included). The
  worst stationarity residual and its (tenant, tick, solver) provenance are
  tracked — the continuous version of the one-off KKT certificate tests.
  Integer allocations are deliberately NOT certified: rounding leaves any
  integer point a bounded distance from stationarity, so its residual
  measures the grid, not the solver.
* **Breach counters** — SLO-breach ticks (the snapshot metric's
  ``satisfied`` flag), churn-bound violations
  (``ControllerStep.churn_violation > 0``) and spot-interruption ticks
  (any spot twin unavailable this tick).
* **Stall detection** — a warm solve whose merit went flat for
  ``stall_window`` trailing iterations (adaptive/fixed PGD traces), or an
  ADMM solve whose primal residual was non-decreasing for ``stall_window``
  trailing outer iterations (checked against its ``ADMMDiag`` certificate's
  final residual): both emit ``stall`` :class:`HealthEvent`\\ s — budget
  that bought nothing is a misconfiguration signal, not an error.
* **Non-finite guards** — NaN/Inf anywhere in the committed counts, the
  relaxed solution, or the KKT stationarity residual (the residual sees the
  gradient, so a non-finite gradient is caught here even when the iterate
  stayed finite) emits an ``error``-severity event with full provenance
  instead of silently propagating.
* **Deadline budget** — an observe-only per-tick ``deadline_ms``: tick
  durations (measured by the ENGINE via ``monitor.clock``, injectable for
  deterministic tests) land in a latency histogram and every overrun bumps
  a deadline-miss counter. Nothing is interrupted — the ENFORCED budget is
  ``core.pgd.AnytimeConfig`` (``replay_fleet(..., anytime=...)``,
  ``repro.serve``); ticks whose solve that budget truncated are counted
  separately as ``deadline_truncated_ticks``. Ticks that are the FIRST
  sighting of their engine's ``compile_key`` pay one-off XLA compilation,
  not steady-state solve latency; they are excluded from the miss counter
  and counted (and histogrammed) separately as ``compile_excluded_ticks``
  — before this split, every first warm tick after any jit cache miss was
  reported as a deadline miss.

Everything is observe-only: the monitor never touches solver state, so
per-tenant integer allocations are bit-identical with health monitoring on
or off (test-enforced in ``tests/obs/test_health.py``). Events are
structured :class:`HealthEvent` records with lane/tick/solver provenance;
:meth:`HealthMonitor.report` rolls everything into a :class:`HealthReport`
that ``FleetReplayMetrics.summary()`` surfaces. When a
:class:`repro.obs.metrics.MetricRegistry` is attached (``registry=``), the
same signals are mirrored as ``health/*`` counters/gauges/histograms for
the Prometheus/JSON exporters.

Usage::

    from repro.obs import HealthMonitor

    mon = HealthMonitor(deadline_ms=50.0)
    result = replay_fleet(catalog, tenants, replay_mode="batched",
                          health=mon)
    print(result.metrics.summary())        # includes the health section
    for ev in mon.report().events:
        print(ev.severity, ev.kind, ev.tenant, ev.tick, ev.message)
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .metrics import MetricRegistry

__all__ = ["HealthEvent", "HealthMonitor", "HealthReport"]

# cap on stored events: a pathological replay (every tick NaN) must not
# turn the monitor into an unbounded memory leak; counters keep counting.
DEFAULT_MAX_EVENTS = 1000


@dataclass(frozen=True)
class HealthEvent:
    """One structured health incident with full replay provenance.

    ``kind`` is the signal (``non_finite``, ``stall``, ``kkt_residual``);
    ``severity`` is ``"warn"`` or ``"error"``. ``lane`` is the batch lane
    (batched engines) or None (sequential). ``value`` carries the
    triggering number (residual, streak length, ...)."""

    kind: str
    severity: str
    tenant: str
    tick: int
    solver: str
    lane: Optional[int] = None
    value: Optional[float] = None
    message: str = ""

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict (numpy scalars coerced to Python floats)."""
        return {"kind": self.kind, "severity": self.severity,
                "tenant": self.tenant, "tick": self.tick,
                "solver": self.solver, "lane": self.lane,
                "value": None if self.value is None else float(self.value),
                "message": self.message}


@dataclass
class HealthReport:
    """The rolled-up output of one monitored replay (see module docstring).

    ``worst_kkt_stationarity`` is the max stationarity residual over every
    certified committed tick (None when no tick was certified);
    ``worst_kkt`` carries its (tenant, tick, solver) provenance.
    ``deadline_miss_ticks``/``deadline_ms`` are populated only when the
    monitor ran with a deadline budget."""

    events: List[HealthEvent] = field(default_factory=list)
    slo_breach_ticks: int = 0
    churn_violation_ticks: int = 0
    spot_interruption_ticks: int = 0
    deadline_miss_ticks: int = 0
    # first-sighting-of-compile-key ticks: their duration is dominated by
    # one-off XLA compilation, so they are excluded from the miss counter
    compile_excluded_ticks: int = 0
    # committed steps whose solve an ENFORCED anytime budget truncated
    deadline_truncated_ticks: int = 0
    stall_events: int = 0
    nonfinite_events: int = 0
    ticks_observed: int = 0
    kkt_ticks_certified: int = 0
    worst_kkt_stationarity: Optional[float] = None
    worst_kkt: Optional[Dict[str, Any]] = None
    deadline_ms: Optional[float] = None

    @property
    def error_count(self) -> int:
        """Number of error-severity events recorded (capped storage does
        not affect this — it counts emissions, not retained records)."""
        return self.nonfinite_events

    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict for BENCH files and snapshots."""
        return {
            "slo_breach_ticks": self.slo_breach_ticks,
            "churn_violation_ticks": self.churn_violation_ticks,
            "spot_interruption_ticks": self.spot_interruption_ticks,
            "deadline_miss_ticks": self.deadline_miss_ticks,
            "compile_excluded_ticks": self.compile_excluded_ticks,
            "deadline_truncated_ticks": self.deadline_truncated_ticks,
            "stall_events": self.stall_events,
            "nonfinite_events": self.nonfinite_events,
            "ticks_observed": self.ticks_observed,
            "kkt_ticks_certified": self.kkt_ticks_certified,
            "worst_kkt_stationarity": self.worst_kkt_stationarity,
            "worst_kkt": self.worst_kkt,
            "deadline_ms": self.deadline_ms,
            "events": [e.to_dict() for e in self.events],
        }

    def summary_lines(self) -> List[str]:
        """The health section ``FleetReplayMetrics.summary()`` prints."""
        lines = [
            f"  health: SLO breaches  : {self.slo_breach_ticks} ticks",
            f"  health: churn overrun : {self.churn_violation_ticks} ticks",
        ]
        if self.spot_interruption_ticks:
            lines.append(f"  health: spot outages  : "
                         f"{self.spot_interruption_ticks} ticks")
        if self.deadline_ms is not None:
            lines.append(f"  health: deadline miss : "
                         f"{self.deadline_miss_ticks} ticks "
                         f"(budget {self.deadline_ms:g} ms, "
                         f"{self.compile_excluded_ticks} compile ticks "
                         f"excluded)")
        if self.deadline_truncated_ticks:
            lines.append(f"  health: anytime trunc : "
                         f"{self.deadline_truncated_ticks} steps")
        if self.worst_kkt_stationarity is not None:
            prov = self.worst_kkt or {}
            lines.append(
                f"  health: worst KKT stat: "
                f"{self.worst_kkt_stationarity:.3e} "
                f"(tenant {prov.get('tenant', '?')}, "
                f"tick {prov.get('tick', '?')})")
        if self.stall_events:
            lines.append(f"  health: solver stalls : {self.stall_events}")
        if self.nonfinite_events:
            lines.append(f"  health: NON-FINITE    : "
                         f"{self.nonfinite_events} events (ERROR)")
        return lines


def _finite_streak_tail(values: np.ndarray) -> np.ndarray:
    """Strip the fixed-shape trace's sentinel tail: keep the finite prefix
    (traces pad unused rows with NaN)."""
    v = np.asarray(values, np.float64).ravel()
    finite = np.isfinite(v)
    if finite.all():
        return v
    # the finite prefix ends at the first non-finite row
    end = int(np.argmin(finite))
    return v[:end]


def _flat_merit_streak(merit: np.ndarray, rtol: float = 1e-9) -> int:
    """Length of the TRAILING run of iterations that improved nothing:
    rows whose merit is not below the best merit seen before them (within
    ``rtol`` relative slack). A solve that converged early and sat at its
    solution also reports a long streak — the point: budget spent past this
    row bought nothing."""
    m = _finite_streak_tail(merit)
    if m.size < 2:
        return 0
    best = np.minimum.accumulate(m)
    tol = rtol * np.maximum(np.abs(best), 1.0)
    # row i "improved" iff it beat the best of rows [0, i)
    improved = m[1:] < best[:-1] - tol[:-1]
    streak = 0
    for flag in improved[::-1]:
        if flag:
            break
        streak += 1
    return streak


def _nondecreasing_tail(res: np.ndarray) -> int:
    """Length of the trailing run of NON-decreasing residuals (each row >=
    its predecessor) — ADMM's stall signature: outer iterations that are
    not contracting the primal residual."""
    r = _finite_streak_tail(res)
    if r.size < 2:
        return 0
    streak = 0
    for i in range(r.size - 1, 0, -1):
        if r[i] >= r[i - 1]:
            streak += 1
        else:
            break
    return streak


class HealthMonitor:
    """Observe-only per-tick health monitor for ``replay_fleet`` (module
    docstring has the full signal list).

    Knobs:

    * ``deadline_ms`` — per-tick latency budget; ticks over it bump the
      deadline-miss counter (observe-only: nothing is interrupted). None
      disables the budget (durations are still histogrammed).
    * ``kkt_every`` — certify every k-th committed tick per tenant through
      ``kkt_report`` (1 = every tick; 0 disables KKT entirely).
    * ``kkt_warn`` — optional stationarity threshold; residuals above it
      emit ``kkt_residual`` warn events (worst-residual tracking happens
      regardless).
    * ``stall_window`` — trailing no-improvement (PGD) or non-decrease
      (ADMM) streak length that counts as a stall.
    * ``registry`` — optional :class:`repro.obs.metrics.MetricRegistry` to
      mirror every signal into (``health/*`` metrics for the exporters).
    * ``clock`` — the monotonic-seconds callable the ENGINES use to time
      ticks (``time.perf_counter`` by default; inject a fake for
      deterministic deadline tests).
    """

    def __init__(self, *, deadline_ms: Optional[float] = None,
                 kkt_every: int = 1, kkt_warn: Optional[float] = None,
                 stall_window: int = 20,
                 registry: Optional[MetricRegistry] = None,
                 max_events: int = DEFAULT_MAX_EVENTS,
                 clock: Callable[[], float] = time.perf_counter):
        if kkt_every < 0:
            raise ValueError(f"kkt_every must be >= 0, got {kkt_every}")
        if stall_window < 2:
            raise ValueError(f"stall_window must be >= 2, got {stall_window}")
        self.deadline_ms = deadline_ms
        self.kkt_every = int(kkt_every)
        self.kkt_warn = kkt_warn
        self.stall_window = int(stall_window)
        self.registry = registry
        self.max_events = int(max_events)
        self.clock = clock
        self._report = HealthReport(deadline_ms=deadline_ms)
        self._dropped_events = 0
        # engine compile keys already sighted once; the first tick carrying
        # a new key pays one-off XLA compilation and is excluded from the
        # deadline budget (see observe_tick)
        self._compile_seen: set = set()

    # -- event plumbing -----------------------------------------------------

    def _emit(self, ev: HealthEvent) -> None:
        if len(self._report.events) < self.max_events:
            self._report.events.append(ev)
        else:
            self._dropped_events += 1

    def _inc(self, name: str, v: float = 1.0) -> None:
        if self.registry is not None:
            self.registry.counter(name).inc(v)

    # -- per-committed-(tenant, tick) observation ---------------------------

    def observe_step(self, *, tenant: str, tick: int, step: Any, solver: str,
                     lane: Optional[int] = None, prob: Any = None,
                     x_rel: Optional[np.ndarray] = None, trace: Any = None,
                     diag: Any = None, spot_unavailable: int = 0) -> None:
        """Observe one committed (tenant, tick): ``step`` is the recorded
        ``ControllerStep``; ``prob``/``x_rel`` (this tick's problem and the
        solve's RELAXED solution) enable the KKT certificate;
        ``trace``/``diag`` (the solve's convergence rows / ADMM residual
        certificate, when captured) enable stall detection;
        ``spot_unavailable`` is the number of spot twins interrupted this
        tick. All optional inputs degrade gracefully — a monitor attached
        to an untraced replay still counts breaches and guards NaNs."""
        rep = self._report
        # breach counters ---------------------------------------------------
        if getattr(step, "deadline_hit", False):
            rep.deadline_truncated_ticks += 1
            self._inc("health/deadline_truncated_ticks")
        if not step.metrics.satisfied:
            rep.slo_breach_ticks += 1
            self._inc("health/slo_breach_ticks")
        if step.churn_violation > 0:
            rep.churn_violation_ticks += 1
            self._inc("health/churn_violation_ticks")
        if spot_unavailable > 0:
            rep.spot_interruption_ticks += 1
            self._inc("health/spot_interruption_ticks")
        # non-finite guards -------------------------------------------------
        counts = np.asarray(step.counts, np.float64)
        if not np.all(np.isfinite(counts)):
            self._nonfinite(tenant, tick, solver, lane,
                            "committed counts contain NaN/Inf")
        if x_rel is not None:
            xr = np.asarray(x_rel, np.float64)
            if not np.all(np.isfinite(xr)):
                self._nonfinite(tenant, tick, solver, lane,
                                "relaxed solution contains NaN/Inf")
                xr = None  # certifying a NaN iterate adds nothing
            x_rel = xr
        # KKT certificate on the committed tick's relaxed solution ----------
        if (prob is not None and x_rel is not None and self.kkt_every > 0
                and tick % self.kkt_every == 0):
            self._certify(tenant, tick, solver, lane, prob, x_rel)
        # stall detection ---------------------------------------------------
        if trace is not None:
            self._check_stall(tenant, tick, solver, lane, trace, diag)

    def _nonfinite(self, tenant: str, tick: int, solver: str,
                   lane: Optional[int], message: str,
                   value: Optional[float] = None) -> None:
        self._report.nonfinite_events += 1
        self._inc("health/nonfinite_events")
        self._emit(HealthEvent(kind="non_finite", severity="error",
                               tenant=tenant, tick=tick, solver=solver,
                               lane=lane, value=value, message=message))

    def _certify(self, tenant: str, tick: int, solver: str,
                 lane: Optional[int], prob: Any, x_rel: np.ndarray) -> None:
        """Run the jitted KKT certificate and track the worst residual.
        The stationarity residual evaluates the objective GRADIENT at the
        iterate, so a non-finite gradient (e.g. a NaN scenario-term price)
        surfaces here even when the iterate itself stayed finite."""
        import jax.numpy as jnp

        from repro.core.kkt import kkt_report

        rep = kkt_report(prob, jnp.asarray(x_rel, jnp.float32))
        stat = float(rep.stationarity)
        self._report.kkt_ticks_certified += 1
        if not math.isfinite(stat):
            self._nonfinite(tenant, tick, solver, lane,
                            "KKT stationarity residual is NaN/Inf "
                            "(non-finite objective gradient)", value=stat)
            return
        if self.registry is not None:
            self.registry.histogram("health/kkt_stationarity").observe(stat)
            self.registry.gauge("health/worst_kkt_stationarity").set(
                max(stat, self._report.worst_kkt_stationarity or 0.0))
        if (self._report.worst_kkt_stationarity is None
                or stat > self._report.worst_kkt_stationarity):
            self._report.worst_kkt_stationarity = stat
            self._report.worst_kkt = {"tenant": tenant, "tick": tick,
                                      "solver": solver, "lane": lane}
        if self.kkt_warn is not None and stat > self.kkt_warn:
            self._emit(HealthEvent(kind="kkt_residual", severity="warn",
                                   tenant=tenant, tick=tick, solver=solver,
                                   lane=lane, value=stat,
                                   message=f"stationarity {stat:.3e} above "
                                           f"threshold {self.kkt_warn:g}"))

    def _check_stall(self, tenant: str, tick: int, solver: str,
                     lane: Optional[int], trace: Any, diag: Any) -> None:
        """Duck-typed stall check: PGD traces carry ``merit`` rows, ADMM
        traces carry ``primal`` residual rows (duck typing avoids importing
        either solver module here)."""
        if hasattr(trace, "primal"):
            streak = _nondecreasing_tail(np.asarray(trace.primal))
            if streak >= self.stall_window:
                final = (float(np.asarray(diag.primal_res))
                         if diag is not None else None)
                self._stall(tenant, tick, solver, lane, streak,
                            f"ADMM primal residual non-decreasing for "
                            f"{streak} trailing outer iterations"
                            + (f" (certificate primal_res {final:.3e})"
                               if final is not None else ""))
        elif hasattr(trace, "merit"):
            streak = _flat_merit_streak(np.asarray(trace.merit))
            if streak >= self.stall_window:
                self._stall(tenant, tick, solver, lane, streak,
                            f"merit flat for {streak} trailing iterations")

    def _stall(self, tenant: str, tick: int, solver: str,
               lane: Optional[int], streak: int, message: str) -> None:
        self._report.stall_events += 1
        self._inc("health/stall_events")
        self._emit(HealthEvent(kind="stall", severity="warn", tenant=tenant,
                               tick=tick, solver=solver, lane=lane,
                               value=float(streak), message=message))

    # -- per-tick latency ---------------------------------------------------

    def observe_tick(self, tick: int, duration_ms: float,
                     compile_key=None) -> None:
        """Record one tick's wall-clock duration (measured by the engine via
        ``self.clock``; fleet-wide tick in the batched engines, per-tenant
        tick in the sequential engine) against the deadline budget.

        ``compile_key`` is the engine's tick-level jit-program identity (a
        hashable tuple, same convention as telemetry span compile keys). A
        tick carrying a key this monitor has NOT seen before is a
        compile tick: its raw wall time is dominated by one-off XLA
        compilation, not the solve the budget governs, so it is counted
        (and histogrammed) under ``compile_excluded_ticks`` instead of
        being compared against ``deadline_ms``. Before this split the
        first warm tick after ANY jit cache miss — a new bucket shape, a
        fresh process, an evicted program — was reported as a deadline
        miss."""
        self._report.ticks_observed += 1
        if compile_key is not None and compile_key not in self._compile_seen:
            self._compile_seen.add(compile_key)
            self._report.compile_excluded_ticks += 1
            self._inc("health/compile_excluded_ticks")
            if self.registry is not None:
                self.registry.histogram(
                    "health/tick_compile_ms").observe(duration_ms)
            return
        if self.registry is not None:
            self.registry.histogram("health/tick_ms").observe(duration_ms)
        if self.deadline_ms is not None and duration_ms > self.deadline_ms:
            self._report.deadline_miss_ticks += 1
            self._inc("health/deadline_miss_ticks")

    # -- reading back -------------------------------------------------------

    def report(self) -> HealthReport:
        """The rolled-up :class:`HealthReport` (live object: a monitor can
        be read mid-replay)."""
        return self._report

"""Typed metric registry: counters, gauges and fixed-bucket log2 histograms.

``repro.obs.telemetry`` answers "where did the wall time go" for ONE
instrumented run; this module is the production-metrics counterpart — the
numbers a fleet operator would scrape: monotonically increasing counters
(SLO-breach ticks, deadline misses), last-value gauges (worst KKT residual)
and latency/effort HISTOGRAMS with p50/p95/p99 estimation, exported as a
Prometheus textfile or a JSON snapshot.

Design rules (shared with the rest of ``repro.obs``, test-enforced):

* **No-op when disabled.** Module-level helpers (:func:`inc`,
  :func:`set_gauge`, :func:`observe`, :func:`observe_counts`) cost one
  ``ContextVar.get`` returning ``None`` when no registry is installed —
  the instrumented paths are the production paths, and per-tenant integer
  allocations are bit-identical with metrics on or off.
* **Jit/vmap-safe hot path.** Histogram accumulation inside compiled code
  uses :func:`bucket_counts`: a pure-jnp fixed-shape reduction (scatter-add
  into ``(n_buckets,)``) that can ride through ``jit``/``vmap``/scan
  carries unchanged. The replay loops merge the fixed-shape counts into the
  host-side :class:`Histogram` once per tick (:func:`Histogram.merge` /
  :func:`observe_counts`) — device code never touches Python metric state.
* **Fixed log2 buckets.** Bucket ``i`` (``1 <= i <= n_core``) covers
  ``[2^(lo_exp+i-1), 2^(lo_exp+i))``; bucket 0 is underflow (``v <
  2^lo_exp``, zeros and negatives included), the last bucket overflow
  (``v >= 2^hi_exp``). Fixed edges mean histograms from different ticks,
  lanes or processes merge by vector addition — no rebinning, ever.

Quantile estimates interpolate linearly inside the containing bucket and
are clamped to the observed ``[min, max]``, so they are exact for constant
streams and within one log2 bucket of the true quantile otherwise
(test-enforced against ``numpy.quantile`` in ``tests/obs/test_metrics.py``).

Prometheus naming scheme (see docs/observability.md): every exported
series is ``repro_<name>`` with ``.``/``/`` mapped to ``_``; counters get
a ``_total`` suffix; histograms emit cumulative ``_bucket{le=...}`` rows
plus ``_sum``/``_count``. Units are part of the metric name (``_ms``,
``_ticks``, ``_iters``).

Usage::

    from repro.obs import collect_metrics, observe, inc

    with collect_metrics() as reg:
        inc("replay/slo_breach_ticks")
        observe("replay/tick_ms", 12.5)
    print(reg.to_prometheus())
"""
from __future__ import annotations

import json
import math
import re
from contextlib import contextmanager
from contextvars import ContextVar
from pathlib import Path
from typing import Any, Dict, Iterator, List, NamedTuple, Optional, Union

import numpy as np

__all__ = ["HistCounts", "bucket_counts", "Counter", "Gauge", "Histogram",
           "MetricRegistry", "collect_metrics", "current_metrics", "inc",
           "set_gauge", "observe", "observe_counts", "DEFAULT_LO_EXP",
           "DEFAULT_HI_EXP"]

# Default bucket range: 2^-10 (~1e-3) .. 2^20 (~1e6) — covers sub-ms tick
# latencies up to million-scale iteration counts with 30 log2 buckets.
DEFAULT_LO_EXP = -10
DEFAULT_HI_EXP = 20


class HistCounts(NamedTuple):
    """Fixed-shape histogram accumulation state (device- or host-side).

    ``counts`` has ``hi_exp - lo_exp + 2`` entries (underflow + log2 core
    + overflow); ``total``/``n`` are the sum and count of FINITE observed
    values, ``vmin``/``vmax`` their range (+inf/-inf when none), and
    ``nonfinite`` the number of NaN/Inf samples excluded from every other
    field. All leaves are arrays so the record can be a jit/vmap carry."""

    counts: Any      # (n_buckets,) int32
    total: Any       # () float32 sum of finite values
    n: Any           # () int32 count of finite values
    vmin: Any        # () float32 min of finite values (+inf when none)
    vmax: Any        # () float32 max of finite values (-inf when none)
    nonfinite: Any   # () int32 count of NaN/Inf samples


def _n_buckets(lo_exp: int, hi_exp: int) -> int:
    return hi_exp - lo_exp + 2


def bucket_counts(values, lo_exp: int = DEFAULT_LO_EXP,
                  hi_exp: int = DEFAULT_HI_EXP) -> HistCounts:
    """Jit/vmap-safe fixed-shape histogram pass over ``values`` (any shape).

    Pure ``jax.numpy``: output shapes depend only on ``(lo_exp, hi_exp)``
    (static), never on the data, so the call composes with ``jit``,
    ``vmap`` and scan carries. Non-finite samples are excluded from the
    buckets/sum/min/max and tallied in ``nonfinite``. Merge the result into
    a host :class:`Histogram` with :func:`Histogram.merge` (or the
    module-level :func:`observe_counts`) once per tick — the host-side
    merge is the ONLY place Python metric state is touched."""
    import jax.numpy as jnp

    nb = _n_buckets(lo_exp, hi_exp)
    v = jnp.asarray(values, jnp.float32).ravel()
    finite = jnp.isfinite(v)
    vf = jnp.where(finite, v, 0.0)
    # exponent -> bucket index; underflow (v < 2^lo, zeros/negatives) -> 0,
    # overflow (v >= 2^hi) -> nb-1. max() keeps log2's domain safe.
    e = jnp.floor(jnp.log2(jnp.maximum(vf, 2.0 ** (lo_exp - 1))))
    idx = jnp.clip(e.astype(jnp.int32) - lo_exp + 1, 0, nb - 1)
    idx = jnp.where(vf < 2.0 ** lo_exp, 0, idx)
    w = finite.astype(jnp.int32)
    counts = jnp.zeros(nb, jnp.int32).at[idx].add(w)
    big = jnp.float32(jnp.inf)
    return HistCounts(
        counts=counts,
        total=jnp.sum(vf),
        n=jnp.sum(w),
        vmin=jnp.min(jnp.where(finite, v, big)),
        vmax=jnp.max(jnp.where(finite, v, -big)),
        nonfinite=jnp.sum(1 - w),
    )


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Map a registry metric name to a legal Prometheus series name:
    ``repro_`` prefix, path separators and other illegal chars -> ``_``."""
    clean = _NAME_RE.sub("_", name)
    if not clean.startswith("repro_"):
        clean = "repro_" + clean
    return clean


class Counter:
    """A monotonically increasing sum (exported as ``<name>_total``)."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        """Add ``v`` (must be >= 0: counters only go up)."""
        v = float(v)
        if v < 0:
            raise ValueError(f"counter {self.name!r}: negative increment {v}")
        self.value += v


class Gauge:
    """A last-value sample with running min/max/n (exported as-is)."""

    __slots__ = ("name", "help", "value", "vmin", "vmax", "n")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value: Optional[float] = None
        self.vmin = math.inf
        self.vmax = -math.inf
        self.n = 0

    def set(self, v: float) -> None:
        """Record a sample; ``value`` keeps the last one."""
        v = float(v)
        self.value = v
        if math.isfinite(v):
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)
        self.n += 1


class Histogram:
    """Fixed-bucket log2 histogram with quantile estimation.

    Host-side accumulation via :meth:`observe` (scalar or array) or
    :meth:`merge` (a device-computed :class:`HistCounts`). Bucket layout is
    identical to :func:`bucket_counts`, so the two paths agree exactly."""

    __slots__ = ("name", "help", "lo_exp", "hi_exp", "counts", "total",
                 "vmin", "vmax", "nonfinite")

    def __init__(self, name: str, help: str = "",
                 lo_exp: int = DEFAULT_LO_EXP, hi_exp: int = DEFAULT_HI_EXP):
        if hi_exp <= lo_exp:
            raise ValueError(f"histogram {name!r}: hi_exp must exceed lo_exp")
        self.name = name
        self.help = help
        self.lo_exp = lo_exp
        self.hi_exp = hi_exp
        self.counts = np.zeros(_n_buckets(lo_exp, hi_exp), np.int64)
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.nonfinite = 0

    # -- accumulation -------------------------------------------------------

    def observe(self, values) -> None:
        """Host-side accumulation of a scalar or array of samples."""
        v = np.asarray(values, np.float64).ravel()
        finite = np.isfinite(v)
        self.nonfinite += int((~finite).sum())
        v = v[finite]
        if v.size == 0:
            return
        nb = self.counts.shape[0]
        with np.errstate(divide="ignore"):
            e = np.floor(np.log2(np.maximum(v, 2.0 ** (self.lo_exp - 1))))
        idx = np.clip(e.astype(np.int64) - self.lo_exp + 1, 0, nb - 1)
        idx[v < 2.0 ** self.lo_exp] = 0
        np.add.at(self.counts, idx, 1)
        self.total += float(v.sum())
        self.vmin = min(self.vmin, float(v.min()))
        self.vmax = max(self.vmax, float(v.max()))

    def merge(self, hc: HistCounts) -> None:
        """Merge a device-computed fixed-shape :class:`HistCounts` (from
        :func:`bucket_counts` with the SAME bucket range) — the host-side
        per-tick merge of the jit-safe hot path."""
        counts = np.asarray(hc.counts, np.int64)
        if counts.shape != self.counts.shape:
            raise ValueError(
                f"histogram {self.name!r}: merge got {counts.shape[0]} "
                f"buckets, layout has {self.counts.shape[0]} (lo_exp/hi_exp "
                f"must match bucket_counts)")
        self.counts += counts
        self.total += float(hc.total)
        self.nonfinite += int(hc.nonfinite)
        if int(np.asarray(hc.n)) > 0:
            self.vmin = min(self.vmin, float(hc.vmin))
            self.vmax = max(self.vmax, float(hc.vmax))

    # -- reading back -------------------------------------------------------

    @property
    def count(self) -> int:
        """Number of finite samples observed."""
        return int(self.counts.sum())

    @property
    def edges(self) -> List[float]:
        """Upper bucket edges (``le`` values): ``2^lo_exp .. 2^hi_exp``;
        the final overflow bucket's edge is +inf."""
        return [2.0 ** e for e in range(self.lo_exp, self.hi_exp + 1)] \
            + [math.inf]

    def quantile(self, q: float) -> Optional[float]:
        """Estimate the ``q``-quantile (``q`` in [0, 100]) by linear
        interpolation inside the containing log2 bucket, clamped to the
        observed ``[min, max]`` — exact for constant streams, within one
        bucket otherwise. None when empty."""
        total = self.count
        if total == 0:
            return None
        target = (q / 100.0) * total
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = (self.vmin if i == 0
                      else 2.0 ** (self.lo_exp + i - 1))
                hi = (self.vmax if i == self.counts.shape[0] - 1
                      else 2.0 ** (self.lo_exp + i))
                frac = (target - cum) / c
                est = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                return float(min(max(est, self.vmin), self.vmax))
            cum += c
        return float(self.vmax)

    def percentiles(self) -> Dict[str, Optional[float]]:
        """The standard p50/p95/p99 triple."""
        return {"p50": self.quantile(50), "p95": self.quantile(95),
                "p99": self.quantile(99)}


class MetricRegistry:
    """Get-or-create store of named metrics plus the two exporters.

    One registry instruments one run (like ``telemetry``'s Recorder).
    Re-requesting a name returns the SAME metric object; requesting an
    existing name as a different type raises."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}

    def _get(self, name: str, cls, **kwargs):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kwargs)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        """Get-or-create the counter ``name``."""
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get-or-create the gauge ``name``."""
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  lo_exp: int = DEFAULT_LO_EXP,
                  hi_exp: int = DEFAULT_HI_EXP) -> Histogram:
        """Get-or-create the histogram ``name`` (bucket range is fixed at
        creation; later calls ignore ``lo_exp``/``hi_exp``)."""
        return self._get(name, Histogram, help=help, lo_exp=lo_exp,
                         hi_exp=hi_exp)

    # -- exporters ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready snapshot: counters/gauges as numbers, histograms as
        bucket vectors plus count/sum/min/max and the p50/p95/p99 triple."""
        out: Dict[str, Any] = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = {
                    "value": m.value, "n": m.n,
                    "min": None if m.n == 0 or not math.isfinite(m.vmin)
                    else m.vmin,
                    "max": None if m.n == 0 or not math.isfinite(m.vmax)
                    else m.vmax}
            else:
                pct = m.percentiles()
                out["histograms"][name] = {
                    "lo_exp": m.lo_exp, "hi_exp": m.hi_exp,
                    "counts": [int(c) for c in m.counts],
                    "count": m.count, "sum": m.total,
                    "nonfinite": m.nonfinite,
                    "min": None if m.count == 0 else m.vmin,
                    "max": None if m.count == 0 else m.vmax,
                    **pct}
        return out

    def write_snapshot(self, path: Union[str, Path]) -> Path:
        """Write :meth:`snapshot` as JSON; returns the path written."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.snapshot(), indent=1, sort_keys=True)
                        + "\n")
        return path

    def to_prometheus(self) -> str:
        """Render every metric in the Prometheus text exposition format
        (textfile-collector ready): ``# HELP``/``# TYPE`` headers, counters
        as ``_total``, histograms as cumulative ``_bucket{le=...}`` rows
        plus ``_sum``/``_count``."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pname = _prom_name(name)
            if isinstance(m, Counter):
                if not pname.endswith("_total"):
                    pname += "_total"
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value:g}")
            elif isinstance(m, Gauge):
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} gauge")
                val = m.value if m.value is not None else math.nan
                lines.append(f"{pname} {val:g}")
            else:
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for edge, c in zip(m.edges, m.counts):
                    cum += int(c)
                    le = "+Inf" if math.isinf(edge) else f"{edge:g}"
                    lines.append(f'{pname}_bucket{{le="{le}"}} {cum}')
                lines.append(f"{pname}_sum {m.total:g}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_prometheus(self, path: Union[str, Path]) -> Path:
        """Write :meth:`to_prometheus` to a textfile; returns the path."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_prometheus())
        return path


# ---------------------------------------------------------------------------
# contextvar scoping — the no-op disabled path (mirrors obs.telemetry)
# ---------------------------------------------------------------------------

_METRICS: ContextVar[Optional[MetricRegistry]] = ContextVar(
    "repro_obs_metrics", default=None)


def current_metrics() -> Optional[MetricRegistry]:
    """The registry installed in this context, or None (metrics off)."""
    return _METRICS.get()


@contextmanager
def collect_metrics(enabled: bool = True,
                    registry: Optional[MetricRegistry] = None
                    ) -> Iterator[Optional[MetricRegistry]]:
    """Install a :class:`MetricRegistry` for the enclosed block.

    ``with collect_metrics() as reg:`` — every module-level :func:`inc` /
    :func:`set_gauge` / :func:`observe` / :func:`observe_counts` call
    inside the block records into ``reg``. Pass ``registry=`` to install an
    existing registry (e.g. one shared with a
    :class:`repro.obs.health.HealthMonitor`); ``enabled=False`` is an
    explicit no-op scope. Nested scopes shadow and restore, exactly like
    ``repro.obs.telemetry``."""
    if not enabled:
        yield None
        return
    reg = registry if registry is not None else MetricRegistry()
    token = _METRICS.set(reg)
    try:
        yield reg
    finally:
        _METRICS.reset(token)


def inc(name: str, v: float = 1.0) -> None:
    """Bump counter ``name`` on the installed registry (no-op when off)."""
    reg = _METRICS.get()
    if reg is not None:
        reg.counter(name).inc(v)


def set_gauge(name: str, value: float) -> None:
    """Sample gauge ``name`` on the installed registry (no-op when off)."""
    reg = _METRICS.get()
    if reg is not None:
        reg.gauge(name).set(value)


def observe(name: str, values) -> None:
    """Host-side histogram observation (scalar or array; no-op when off)."""
    reg = _METRICS.get()
    if reg is not None:
        reg.histogram(name).observe(values)


def observe_counts(name: str, hc: HistCounts) -> None:
    """Merge device-computed :func:`bucket_counts` into histogram ``name``
    (the per-tick host-side merge of the jit path; no-op when off)."""
    reg = _METRICS.get()
    if reg is not None:
        reg.histogram(name).merge(hc)

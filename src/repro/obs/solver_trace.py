"""Convergence traces from the shared PGD engine, and helpers to read them.

The capture itself lives in the engine (``repro.core.pgd``): an opt-in,
fixed-size per-iteration log carried through the ``lax.while_loop`` so it
stays jit/vmap-safe — under ``vmap`` every fleet lane gets its own rows.
This module re-exports that record as :data:`SolverTrace` and provides the
host-side analysis helpers: trimming the fixed-size arrays to the
iterations actually taken, slicing one lane out of a batched capture, and
summarising a trajectory for reports.

Schema (one row per PGD iteration, ``L = PGDConfig.max_iters`` rows total;
rows at index >= iters hold sentinels — NaN / False / -1):

========  =======  ====================================================
field     dtype    meaning
========  =======  ====================================================
merit     float32  objective value after the iteration's accepted point
step      float32  Barzilai-Borwein base step proposed this iteration
accepted  bool     True if any Armijo ladder rung passed
rung      int32    index of the accepted backtracking rung (-1 = none)
move      float32  max|dx| of the accepted move (0 when rejected)
========  =======  ====================================================

The ADMM horizon engine (``repro.horizon.admm``) records a second schema,
:data:`ADMMTrace` (one row per OUTER consensus iteration: primal/dual
residual pair + inner PGD iterations spent), re-exported here with its own
``trim_admm_trace`` / ``admm_trace_summary`` helpers; ``lane_trace`` slices
both schemas.

Capture is opt-in end to end: ``pgd_minimize_traced`` at the engine,
``capture_trace=True`` on ``solve_incremental_info`` / ``solve_fleet_step``
/ ``solve_horizon_fleet_step``, ``capture_solver_trace=True`` on the
controllers and ``replay_fleet``. The untraced paths run the exact
pre-existing compiled graph, so traced and untraced solves agree on
``(x, fx, iters)`` — test-enforced in ``tests/obs/test_solver_trace.py``.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional

import numpy as np

from repro.core.pgd import PGDTrace

if TYPE_CHECKING:  # pragma: no cover
    from repro.horizon.admm import ADMMTrace

# The engine's trace record IS the public solver-trace schema.
SolverTrace = PGDTrace

__all__ = ["SolverTrace", "ADMMTrace", "trace_length", "lane_trace",
           "trim_trace", "trace_summary", "traces_to_dict",
           "trim_admm_trace", "admm_trace_summary"]


def __getattr__(name: str):
    # Lazy re-export: repro.horizon.problem imports repro.fleet which
    # imports this package, so an eager `from repro.horizon.admm import
    # ADMMTrace` here would close an import cycle. The record still lives
    # with its engine (like PGDTrace in core.pgd); we only defer the lookup.
    if name == "ADMMTrace":
        from repro.horizon.admm import ADMMTrace
        return ADMMTrace
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def trace_length(trace: PGDTrace) -> int:
    """Number of rows (the engine's ``max_iters`` budget, not iters taken)."""
    return int(trace.merit.shape[-1])


def lane_trace(trace, lane: int):
    """Slice one lane out of a batched ``(B, L)`` capture (from a vmapped
    fleet solve) as a plain ``(L,)`` record. Works for both trace schemas
    (:data:`SolverTrace` and :data:`ADMMTrace`) — the record type is
    preserved."""
    if np.asarray(trace[0]).ndim < 2:
        raise ValueError("lane_trace expects a batched (B, L) trace; "
                         "this capture is already single-lane")
    return type(trace)(*(np.asarray(f)[lane] for f in trace))


def trim_trace(trace: PGDTrace, iters: Optional[int] = None) -> PGDTrace:
    """Drop the sentinel tail: return the first ``iters`` rows as numpy.

    When ``iters`` is None it is inferred as the number of non-NaN merit
    rows (the engine writes merit every executed iteration)."""
    merit = np.asarray(trace.merit)
    if merit.ndim != 1:
        raise ValueError("trim_trace expects a single-lane (L,) trace; "
                         "use lane_trace first")
    if iters is None:
        iters = int(np.sum(~np.isnan(merit)))
    iters = int(iters)
    return PGDTrace(*(np.asarray(f)[:iters] for f in trace))


def trace_summary(trace: PGDTrace, iters: Optional[int] = None) -> Dict:
    """Summarise one lane's convergence trajectory as plain floats/ints.

    Keys: ``iters`` (rows executed), ``merit_first``/``merit_final``
    (objective at iteration 1 / at stop), ``merit_drop`` (first - final),
    ``accept_rate`` (share of iterations whose Armijo ladder accepted),
    ``mean_rung`` (mean accepted rung index — 0 means the BB step passes
    untouched; higher means heavy backtracking), ``max_move`` (largest
    accepted coordinate move)."""
    t = trim_trace(trace, iters)
    n = int(t.merit.shape[0])
    if n == 0:
        return {"iters": 0, "merit_first": None, "merit_final": None,
                "merit_drop": None, "accept_rate": None, "mean_rung": None,
                "max_move": None}
    acc = np.asarray(t.accepted, bool)
    rungs = np.asarray(t.rung)[acc]
    return {
        "iters": n,
        "merit_first": float(t.merit[0]),
        "merit_final": float(t.merit[-1]),
        "merit_drop": float(t.merit[0] - t.merit[-1]),
        "accept_rate": float(acc.mean()),
        "mean_rung": float(rungs.mean()) if rungs.size else None,
        "max_move": float(np.asarray(t.move).max()),
    }


def trim_admm_trace(trace: "ADMMTrace",
                    iters: Optional[int] = None) -> "ADMMTrace":
    """Drop the sentinel tail of a single-lane ADMM capture: return the
    first ``iters`` outer-iteration rows as numpy. When ``iters`` is None it
    is inferred as the number of non-NaN primal-residual rows (the loop
    writes the residual pair every executed outer iteration)."""
    primal = np.asarray(trace.primal)
    if primal.ndim != 1:
        raise ValueError("trim_admm_trace expects a single-lane (L,) trace; "
                         "use lane_trace first")
    if iters is None:
        iters = int(np.sum(~np.isnan(primal)))
    iters = int(iters)
    return type(trace)(*(np.asarray(f)[:iters] for f in trace))


def admm_trace_summary(trace: "ADMMTrace",
                       iters: Optional[int] = None) -> Dict:
    """Summarise one lane's ADMM residual trajectory as plain floats/ints.

    Keys: ``admm_iters`` (outer iterations executed), ``primal_first`` /
    ``primal_final`` and ``dual_first`` / ``dual_final`` (residuals after
    the first / last outer iteration), ``inner_total`` (inner PGD
    iterations summed over the run)."""
    t = trim_admm_trace(trace, iters)
    n = int(t.primal.shape[0])
    if n == 0:
        return {"admm_iters": 0, "primal_first": None, "primal_final": None,
                "dual_first": None, "dual_final": None, "inner_total": 0}
    return {
        "admm_iters": n,
        "primal_first": float(t.primal[0]),
        "primal_final": float(t.primal[-1]),
        "dual_first": float(t.dual[0]),
        "dual_final": float(t.dual[-1]),
        "inner_total": int(np.asarray(t.inner).sum()),
    }


def traces_to_dict(traces: List[PGDTrace]) -> List[Dict]:
    """JSON-ready dump of a list of single-lane traces (trimmed rows as
    lists) — the shape ``ReplayReport.to_dict`` and the bench JSONs embed."""
    out = []
    for tr in traces:
        t = trim_trace(tr)
        out.append({
            "iters": int(t.merit.shape[0]),
            "merit": [float(v) for v in np.asarray(t.merit)],
            "step": [float(v) for v in np.asarray(t.step)],
            "accepted": [bool(v) for v in np.asarray(t.accepted)],
            "rung": [int(v) for v in np.asarray(t.rung)],
            "move": [float(v) for v in np.asarray(t.move)],
        })
    return out

"""Export a :class:`~repro.obs.telemetry.Recorder` to JSONL / Chrome trace.

Two formats:

* **JSONL** (:func:`write_jsonl`) — one JSON object per line, one line per
  span/counter/gauge, in close order. Grep-able, diff-able, no schema
  beyond "each line is an event".
* **Chrome trace events** (:func:`to_chrome_trace` /
  :func:`write_chrome_trace`) — the ``chrome://tracing`` / Perfetto JSON
  array format. Spans become complete events (``"ph": "X"``, with
  microsecond ``ts``/``dur``); gauges become counter events
  (``"ph": "C"``). Open https://ui.perfetto.dev and drop the file in, or
  load it at ``chrome://tracing``. Nesting is reconstructed by Perfetto
  from interval containment on a single pid/tid, which matches how the
  recorder's span stack works (one single-threaded instrumented run).

:func:`validate_chrome_trace` and :func:`validate_jsonl` are the schema
gates ``make trace-demo`` runs: each re-parses its emitted file and checks
the fields its consumer actually requires (Perfetto's ``ph``/``ts``/``dur``
/pid/tid; the JSONL event-type schemas) — so neither export path can rot
silently.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Union

from .telemetry import Recorder

__all__ = ["events_to_dicts", "write_jsonl", "to_chrome_trace",
           "write_chrome_trace", "validate_chrome_trace", "validate_jsonl"]

_PID = 1      # one instrumented process...
_TID = 1      # ...single-threaded by Recorder design


def events_to_dicts(rec: Recorder) -> List[Dict[str, Any]]:
    """Flatten a recorder into plain dicts (spans, then counters, then
    gauges) — the JSONL line set."""
    out: List[Dict[str, Any]] = []
    for e in rec.events:
        out.append({"type": "span", "name": e.name, "cat": e.cat,
                    "ts_us": e.ts_us, "dur_us": e.dur_us, "depth": e.depth,
                    "phase": e.phase, "tags": e.tags})
    for name, total in sorted(rec.counters.items()):
        out.append({"type": "counter", "name": name, "total": total})
    for name, samples in sorted(rec.gauges.items()):
        for ts, v in samples:
            out.append({"type": "gauge", "name": name, "ts_us": ts,
                        "value": v})
    return out


def write_jsonl(rec: Recorder, path: Union[str, Path]) -> Path:
    """Write the recorder as JSON-lines; returns the path written."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as fh:
        for d in events_to_dicts(rec):
            fh.write(json.dumps(d) + "\n")
    return path


def to_chrome_trace(rec: Recorder) -> List[Dict[str, Any]]:
    """Render the recorder as a Chrome trace-event array (JSON-ready).

    Spans map to complete events (``ph="X"``) with their phase and tags in
    ``args``; gauge samples map to counter events (``ph="C"``). Timestamps
    are already microseconds relative to recorder install, which is the
    unit the format expects."""
    events: List[Dict[str, Any]] = []
    for e in rec.events:
        args = dict(e.tags)
        if e.phase is not None:
            args["phase"] = e.phase
        events.append({"name": e.name, "cat": e.cat, "ph": "X",
                       "ts": e.ts_us, "dur": e.dur_us,
                       "pid": _PID, "tid": _TID, "args": args})
    for name, samples in sorted(rec.gauges.items()):
        for ts, v in samples:
            events.append({"name": name, "ph": "C", "ts": ts,
                           "pid": _PID, "tid": _TID,
                           "args": {"value": v}})
    # Chrome sorts by ts itself, but emitting sorted keeps diffs stable.
    events.sort(key=lambda d: d["ts"])
    return events


def write_chrome_trace(rec: Recorder, path: Union[str, Path]) -> Path:
    """Write a Perfetto-loadable ``trace.json``; returns the path."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as fh:
        json.dump({"traceEvents": to_chrome_trace(rec),
                   "displayTimeUnit": "ms"}, fh, indent=1)
    return path


def validate_chrome_trace(path: Union[str, Path]) -> List[str]:
    """Re-parse an emitted trace file and return schema problems (empty
    list = valid). Checks the fields Perfetto actually requires: a
    ``traceEvents`` array; per event a string ``name``, a known ``ph``,
    numeric non-negative ``ts``; ``dur`` present and non-negative on
    complete events; integer ``pid``/``tid``."""
    problems: List[str] = []
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as exc:
        return [f"unreadable trace: {exc}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["missing/invalid traceEvents array"]
    if not events:
        problems.append("trace has zero events")
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            problems.append(f"{where}: missing name")
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "C", "i", "M"):
            problems.append(f"{where}: bad ph {ph!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: complete event with bad dur "
                                f"{dur!r}")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                problems.append(f"{where}: missing {k}")
    return problems


# per-type required fields of the JSONL event stream (events_to_dicts):
# field -> allowed types; None values are never emitted except span.phase
_JSONL_SCHEMAS: Dict[str, Dict[str, tuple]] = {
    "span": {"name": (str,), "cat": (str,), "ts_us": (int, float),
             "dur_us": (int, float), "depth": (int,), "tags": (dict,)},
    "counter": {"name": (str,), "total": (int, float)},
    "gauge": {"name": (str,), "ts_us": (int, float), "value": (int, float)},
}


def validate_jsonl(path: Union[str, Path]) -> List[str]:
    """Re-parse an emitted JSONL event log and return schema problems
    (empty list = valid) — the JSONL counterpart of
    :func:`validate_chrome_trace`. Every line must parse as a JSON object
    with a known ``type`` and that type's required fields
    (:func:`events_to_dicts` is the emitter being checked); span
    durations/timestamps must be non-negative and ``phase`` one of
    compile/execute/None."""
    problems: List[str] = []
    try:
        lines = Path(path).read_text().splitlines()
    except OSError as exc:
        return [f"unreadable event log: {exc}"]
    if not lines:
        problems.append("event log has zero lines")
    for i, line in enumerate(lines):
        where = f"line[{i}]"
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as exc:
            problems.append(f"{where}: not JSON ({exc})")
            continue
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        schema = _JSONL_SCHEMAS.get(ev.get("type"))
        if schema is None:
            problems.append(f"{where}: unknown type {ev.get('type')!r}")
            continue
        for fld, types in schema.items():
            val = ev.get(fld)
            # bool is an int subclass; never a valid numeric field here
            if not isinstance(val, types) or isinstance(val, bool):
                problems.append(f"{where}: bad {fld} {val!r}")
        for fld in ("ts_us", "dur_us"):
            val = ev.get(fld)
            if isinstance(val, (int, float)) and val < 0:
                problems.append(f"{where}: negative {fld} {val!r}")
        if ev.get("type") == "span" and ev.get("phase") not in (
                None, "compile", "execute"):
            problems.append(f"{where}: bad phase {ev.get('phase')!r}")
    return problems

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production mesh, extract memory/cost/collective analysis, write JSON records.

MUST be run as a module entry point (python -m repro.launch.dryrun ...);
the XLA device-count override below happens before ANY other import.
"""
# --- these two lines MUST come before any other import (jax locks device
# --- count on first init) -------------------------------------------------
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, list_archs            # noqa: E402
from repro.distributed import sharding as shd                # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_context  # noqa: E402
from repro.launch.shapes import (SHAPES, batch_axes, cell_applicable,  # noqa: E402
                                 input_specs, ruleset_name)
from repro.launch.steps import (make_decode_step, make_prefill_step,   # noqa: E402
                                make_train_step)
from repro.models import abstract_params                     # noqa: E402
from repro.models.transformer import cache_axes              # noqa: E402
from repro.optim import adamw                                # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")

# hardware constants (given): TPU v5e-class chip
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link; 4 links usable per chip
ICI_LINKS = 4

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str):
    """Sum result-operand sizes of every collective op in the compiled HLO.
    '-start' variants counted once ('-done' carries no shape work)."""
    per_op = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        if m.group(0).find(op + "-done(") >= 0:
            continue
        per_op[op] = per_op.get(op, 0) + _shape_bytes(shape_txt)
    per_op["total"] = sum(v for k, v in per_op.items())
    return per_op


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*tokens (train) / 2*N_active*tokens (fwd)."""
    total, active = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * active * tokens
    return 2.0 * active * shape.batch        # one token per sequence


def _compile_step(cfg, shape, mesh, rules):
    """Build + jit + lower + compile the step for one cell. Returns
    (compiled, lower_s, compile_s)."""
    t0 = time.time()
    params_sds, param_axes = abstract_params(cfg)
    param_sh = shd.make_shardings(param_axes, mesh, rules, params_sds)
    inputs = input_specs(cfg, shape)
    in_axes = batch_axes(cfg, shape)
    input_sh = shd.make_shardings(in_axes, mesh, rules, inputs)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_sds = adamw.abstract_state(params_sds)
        opt_axes = adamw.state_axes(param_axes)
        opt_sh = shd.make_shardings(opt_axes, mesh, rules, opt_sds)
        step = make_train_step(cfg, opt_cfg)
        jitted = jax.jit(step,
                         in_shardings=(param_sh, opt_sh, input_sh["batch"]),
                         out_shardings=(param_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        args = (params_sds, opt_sds, inputs["batch"])
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, s_max=shape.seq)
        cache_sds = jax.eval_shape(
            lambda: __import__("repro.models", fromlist=["init_caches"])
            .init_caches(cfg, shape.batch, shape.seq, jnp.bfloat16))
        cache_sh = shd.make_shardings(cache_axes(cfg), mesh, rules, cache_sds)
        jitted = jax.jit(step,
                         in_shardings=(param_sh, input_sh["batch"]),
                         out_shardings=(repl, cache_sh))
        args = (params_sds, inputs["batch"])
    else:
        step = make_decode_step(cfg)
        cache_sh = shd.make_shardings(cache_axes(cfg), mesh, rules,
                                      inputs["caches"])
        logits_spec = shd.make_specs({"x": ("batch", "vocab")}, mesh, rules,
                                     {"x": jax.ShapeDtypeStruct(
                                         (shape.batch, cfg.vocab_size),
                                         jnp.float32)})["x"]
        jitted = jax.jit(step,
                         in_shardings=(param_sh, cache_sh,
                                       input_sh["tokens"], repl),
                         out_shardings=(NamedSharding(mesh, logits_spec),
                                        cache_sh),
                         donate_argnums=(1,))
        args = (params_sds, inputs["caches"], inputs["tokens"], inputs["pos"])

    with mesh_context(mesh), shd.use_rules(rules):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _cost_terms(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    coll = parse_collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]),
            "coll_by_op": coll}


def roofline_terms_extrapolated(arch: str, shape, mesh, rules,
                                cfg_overrides=None):
    """XLA's HLO cost analysis counts loop bodies ONCE (trip counts are not
    modelled), so a rolled scan-over-layers under-reports FLOPs. We therefore
    compile two short UNROLLED variants (depth = period and 2*period, inner
    scans at trip count 1) and extrapolate linearly:

        total(L groups) = once + L * per_group
        once + per_group  = cost(depth=period, unrolled)
        once + 2*per_group = cost(depth=2*period, unrolled)

    Exact for everything linear in depth; chunked-linear algorithms (loss
    chunking, flash attention, mamba scan) are trip-1-exact because their
    total work is chunk-size-invariant. (rwkv6's intra-chunk term is
    quadratic in chunk size: trip-1 overstates it — noted in EXPERIMENTS.)
    """
    cfg0 = get_config(arch)
    S = shape.seq
    # chunk policy for the exact-count compiles: every inner scan is unrolled,
    # so cap trip counts at <=4 bodies (1-core compile-time budget) while
    # keeping chunks as close to production as possible. Total FLOPs of the
    # loss / flash / mamba scans are chunk-size invariant; rwkv's intra-chunk
    # term grows with chunk and is an upper bound — noted in EXPERIMENTS.md.
    scan_chunk = max(256 if "mamba" in cfg0.block_pattern else 64, S // 4)
    mk = lambda groups: cfg0.scaled(
        dtype="bfloat16", param_dtype="bfloat16",
        n_layers=cfg0.period * groups, unroll_inner=True,
        scan_chunk=min(scan_chunk, S), loss_chunk=S,
        attn_q_chunk=max(512, S // 2), attn_kv_chunk=max(1024, S // 2),
        **(cfg_overrides or {}))
    c1, *_ = _compile_step(mk(1), shape, mesh, rules)
    c2, *_ = _compile_step(mk(2), shape, mesh, rules)
    t1, t2 = _cost_terms(c1), _cost_terms(c2)
    n = cfg0.n_groups
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_group = t2[k] - t1[k]
        out[k] = t1[k] + (n - 1) * per_group
        out[k + "_per_group"] = per_group
        out[k + "_once"] = t1[k] - per_group
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_override=None, save_hlo: bool = False,
               extrapolate: bool = True, cfg_overrides=None):
    """Returns the JSON record for one cell. ``cfg_overrides`` is the perf-
    iteration hook (EXPERIMENTS.md §Perf): dataclass field overrides applied
    to both the full compile and the roofline extrapolation compiles."""
    shape = SHAPES[shape_name]
    # loss_chunk=seq: sequence-chunked loss only helps when activations are
    # replicated along S; under the production seq/act_embed sharding the
    # un-chunked loss is sharded anyway, and the chunk reshape would CROSS
    # shard boundaries (all-gathering a global-batch f32 cotangent).
    kw = dict(dtype="bfloat16", param_dtype="bfloat16", loss_chunk=shape.seq)
    kw.update(cfg_overrides or {})          # overrides win
    cfg = get_config(arch).scaled(**kw)
    skip = cell_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"cell": f"{arch}__{shape_name}", "arch": arch, "shape": shape_name,
           "mesh": mesh_name, "kind": shape.kind}
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = (rules_override or shd.RULESETS[ruleset_name(shape)])(mesh, cfg)

    compiled, t_lower, t_compile = _compile_step(cfg, shape, mesh, rules)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)

    raw_flops_dev = float(cost.get("flops", 0.0))
    raw_bytes_dev = float(cost.get("bytes accessed", 0.0))
    if extrapolate and not multi_pod:
        ext = roofline_terms_extrapolated(arch, shape, mesh, rules,
                                          cfg_overrides=cfg_overrides)
        flops_dev, bytes_dev = ext["flops"], ext["bytes"]
        coll_total = ext["coll"]
    else:
        ext = None
        flops_dev, bytes_dev, coll_total = (raw_flops_dev, raw_bytes_dev,
                                            float(coll["total"]))
    model_flops = _model_flops(cfg, shape)
    total_p, active_p = cfg.param_counts()

    bytes_per_device = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec.update(
        status="ok",
        devices=int(n_dev),
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops=flops_dev,                       # per-device (SPMD program)
        bytes_accessed=bytes_dev,              # per-device
        collective_bytes=coll_total,           # per-device program
        collectives=coll,                      # raw (rolled-scan) breakdown
        raw_flops=raw_flops_dev,               # uncorrected cost_analysis
        raw_bytes_accessed=raw_bytes_dev,
        extrapolation=ext,
        memory={
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        bytes_per_device=int(bytes_per_device),
        model_flops_global=model_flops,
        model_flops_per_device=model_flops / n_dev,
        params_total=int(total_p),
        params_active=int(active_p),
        roofline={
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_total / (ICI_BW * ICI_LINKS),
            "useful_flops_ratio": (model_flops / n_dev) / max(flops_dev, 1.0),
        },
    )
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: rec["roofline"][k])
    rec["roofline"]["dominant"] = dom
    if save_hlo:
        hlo_path = os.path.join(ARTIFACT_DIR,
                                f"{arch}__{shape_name}__{mesh_name}.hlo")
        with open(hlo_path, "w") as f:
            f.write(hlo)
        rec["hlo_path"] = hlo_path
    return rec


def run_cells(cells, out_dir: str, save_hlo: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    records = []
    for arch, shape_name, multi_pod in cells:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        tag = f"{arch}__{shape_name}__{mesh_name}"
        path = os.path.join(out_dir, tag + ".json")
        try:
            rec = lower_cell(arch, shape_name, multi_pod, save_hlo=save_hlo)
        except Exception as e:  # a failing cell is a bug in the system
            rec = {"cell": f"{arch}__{shape_name}", "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f"compile={rec['compile_s']:.0f}s dom={r['dominant']} "
                     f"comp={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms "
                     f"coll={r['collective_s']*1e3:.1f}ms "
                     f"useful={r['useful_flops_ratio']:.2f} "
                     f"hbm={rec['bytes_per_device']/2**30:.2f}GiB")
        elif status == "error":
            extra = rec["error"][:160]
        else:
            extra = "SKIP: " + rec["reason"][:120]
        print(f"[dryrun] {tag}: {status} {extra}", flush=True)
        records.append(rec)
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None],
                    help="shape name (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out-dir", default=ARTIFACT_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = [(a, s, mp) for a in archs for s in shapes for mp in pods]
    records = run_cells(cells, args.out_dir, save_hlo=args.save_hlo)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()

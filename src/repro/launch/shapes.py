"""Assigned input shapes and abstract input specs (ShapeDtypeStruct — no
allocation) for every (arch x shape) dry-run cell."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import init_caches


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int
    long: bool = False


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1, long=True),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.long and not cfg.sub_quadratic:
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is pure full-attention (skip per assignment)")
    return None


def ruleset_name(shape: ShapeSpec) -> str:
    if shape.kind == "train":
        return "train"
    if shape.kind == "prefill":
        return "prefill"
    return "long" if shape.long else "decode"


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract batch for the step function of this cell. For decode this
    includes the KV/state caches (built via eval_shape — no allocation)."""
    B, S = shape.batch, shape.seq
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    if shape.kind == "train":
        batch = {"tokens": tok((B, S)), "labels": tok((B, S))}
        if cfg.frontend == "vision":
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.bfloat16)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": tok((B, S))}
        if cfg.frontend == "vision":
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.bfloat16)
        return {"batch": batch}
    # decode: one new token against a seq-long cache
    caches = jax.eval_shape(lambda: init_caches(cfg, B, S, jnp.bfloat16))
    return {"caches": caches,
            "tokens": tok((B, 1)),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_axes(cfg: ModelConfig, shape: ShapeSpec):
    """Logical axes for the abstract inputs above."""
    if shape.kind == "train":
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        if cfg.frontend == "vision":
            axes["frontend_embeds"] = ("batch", None, "frontend")
        return {"batch": axes}
    if shape.kind == "prefill":
        axes = {"tokens": ("batch", "seq")}
        if cfg.frontend == "vision":
            axes["frontend_embeds"] = ("batch", None, "frontend")
        return {"batch": axes}
    from repro.models.transformer import cache_axes
    return {"caches": cache_axes(cfg),
            "tokens": ("batch", None),
            "pos": None}

"""Production mesh builders. Functions, NOT module-level constants — importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` appeared in jax 0.5.x; older jax (0.4.37 in the image)
    has neither ``jax.sharding.AxisType`` nor the ``make_mesh`` kwarg — all
    axes are implicitly Auto there, so omitting it is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, examples, elastic rescale)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where it exists (jax >= 0.5.x); on older jax
    the Mesh object itself is the context manager with the same effect."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh

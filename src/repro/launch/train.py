"""Distributed training launcher.

    python -m repro.launch.train --arch qwen1.5-4b --steps 100 \
        [--mesh 4x2] [--reduced] [--policy deadline] [--compress-grads]

On a real TPU fleet this runs under one process per host with the same code
path (jax.distributed.initialize + the production mesh); on CPU it runs the
reduced config on a 1-device mesh, exercising the identical train_step,
sharding rules, checkpointing, and supervisor wiring.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import get_config, list_archs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh, mesh_context
from repro.launch.steps import make_train_step
from repro.models import abstract_params, init_model, split
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 16x16")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().scaled(loss_chunk=min(64, args.seq))
    data_shape, model_shape = (int(v) for v in args.mesh.split("x"))
    mesh = make_mesh((data_shape, model_shape), ("data", "model"))
    rules = shd.base_rules(mesh, cfg)
    print(f"[launch] arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.axis_sizes))}")

    boxed = init_model(cfg, jax.random.PRNGKey(0))
    params, axes = split(boxed)
    opt_state = adamw.init(params)
    param_sh = shd.make_shardings(axes, mesh, rules, params)
    opt_sh = shd.make_shardings(adamw.state_axes(axes), mesh, rules, opt_state)
    params = jax.device_put(params, param_sh)
    opt_state = jax.device_put(opt_state, opt_sh)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)
    step_fn = make_train_step(cfg, opt_cfg)
    batch_sh = shd.make_shardings(
        {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}, mesh, rules,
        {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
         "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)})
    jitted = jax.jit(step_fn, in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, None),
                     donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=2)
    t0 = time.time()
    with mesh_context(mesh), shd.use_rules(rules):
        for step in range(args.steps):
            b = data.global_batch(step)
            batch = {k: jax.device_put(jnp.asarray(v), batch_sh[k])
                     for k, v in b.items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if step % 20 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss={float(metrics['loss']):7.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"{(step+1)/(time.time()-t0):5.2f} it/s")
            if step > 0 and step % args.ckpt_every == 0:
                saver.save(step, {"p": params, "o": opt_state},
                           extra={"loss": float(metrics["loss"])})
    saver.wait()
    print("[launch] done")


if __name__ == "__main__":
    main()

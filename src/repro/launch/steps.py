"""Step functions (train / prefill / decode) shared by the real launchers and
the dry-run: one definition, jit-ed with explicit in/out shardings."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step as model_decode_step
from repro.models import loss_fn, prefill as model_prefill
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    use_pallas: bool = False):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, use_pallas=use_pallas),
            has_aux=True)(params)
        new_params, new_state, om = adamw.update(opt_cfg, grads, opt_state, params)
        return new_params, new_state, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, s_max: int, use_pallas: bool = False):
    def prefill_step(params, batch):
        return model_prefill(cfg, params, batch, s_max=s_max,
                             use_pallas=use_pallas)

    return prefill_step


def make_decode_step(cfg: ModelConfig, use_pallas: bool = False):
    def serve_step(params, caches, tokens, pos):
        return model_decode_step(cfg, params, caches, tokens, pos,
                                 use_pallas=use_pallas)

    return serve_step

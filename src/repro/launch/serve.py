"""Batched serving launcher: prefill + decode loop under the decode sharding
rules (the decode_32k / long_500k dry-run cells lower exactly this path).

    python -m repro.launch.serve --arch mixtral-8x22b --batch 4 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh, mesh_context
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_model, split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    d, m = (int(v) for v in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    rules = shd.decode_rules(mesh, cfg)
    params, _ = split(init_model(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    s_max = P + args.tokens
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_frontend_tokens, cfg.d_frontend)),
            jnp.float32)

    prefill_fn = jax.jit(make_prefill_step(cfg, s_max=s_max))
    decode_fn = jax.jit(make_decode_step(cfg))
    with mesh_context(mesh), shd.use_rules(rules):
        t0 = time.time()
        logits, caches = prefill_fn(params, batch)
        print(f"[serve] prefill {B}x{P} in {time.time()-t0:.2f}s")
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for i in range(args.tokens - 1):
            logits, caches = decode_fn(params, caches, tok,
                                       jnp.asarray(P + i, jnp.int32))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        dt = time.time() - t0
    print(f"[serve] {args.tokens-1} decode steps x {B} seqs: "
          f"{B*(args.tokens-1)/dt:.1f} tok/s")


if __name__ == "__main__":
    main()

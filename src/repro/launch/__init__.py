from . import mesh, shapes, steps

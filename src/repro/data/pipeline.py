"""Deterministic, shardable synthetic token pipeline.

Production framing: each (host, data-shard) pulls only ITS slice of the
global batch — `global_batch(step)` is pure in (step, seed), so any worker
can (re)materialize any step's data after restart or membership change
(deterministic data re-sharding is the fault-tolerance primitive).

The synthetic stream is a Zipf-ish unigram mix with short-range repetition
structure (so a small LM's loss actually decreases — used by the examples
and integration tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    repeat_prob: float = 0.35     # next-token = earlier token (structure)
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-ish unigram distribution, deterministic in seed
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def _gen(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        c = self.cfg
        toks = rng.choice(c.vocab_size, size=(batch, c.seq_len + 1),
                          p=self._probs)
        # structured repetition: with prob repeat_prob, copy a recent token
        rep = rng.random((batch, c.seq_len + 1)) < c.repeat_prob
        back = rng.integers(1, 8, size=(batch, c.seq_len + 1))
        idx = np.maximum(np.arange(c.seq_len + 1)[None, :] - back, 0)
        toks = np.where(rep, np.take_along_axis(toks, idx, axis=1), toks)
        return self._perm[toks].astype(np.int32)

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step) — restart-safe."""
        rng = np.random.default_rng((self.cfg.seed, step))
        toks = self._gen(rng, self.cfg.global_batch)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def shard_batch(self, step: int, shard: int, num_shards: int
                    ) -> Dict[str, np.ndarray]:
        """This worker's slice of the step's global batch. Changing
        num_shards (elastic resize) re-slices the SAME global stream."""
        assert self.cfg.global_batch % num_shards == 0
        per = self.cfg.global_batch // num_shards
        full = self.global_batch(step)
        sl = slice(shard * per, (shard + 1) * per)
        return {k: v[sl] for k, v in full.items()}

    def iter_batches(self, start_step: int = 0, shard: int = 0,
                     num_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.shard_batch(step, shard, num_shards)
            step += 1

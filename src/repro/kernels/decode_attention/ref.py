"""Oracle for the decode-attention kernel: one query token per sequence
against a (possibly partially-valid) KV cache.

q (B, 1, H, dh); k/v caches (B, G, S, dh); valid (S,) bool -> (B, 1, H, dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, valid):
    B, _, H, dh = q.shape
    G = k_cache.shape[1]
    R = H // G
    qr = q.reshape(B, G, R, dh)
    s = jnp.einsum("bgrd,bgsd->bgrs", qr, k_cache).astype(jnp.float32)
    s = s / jnp.sqrt(dh).astype(jnp.float32)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, dh)

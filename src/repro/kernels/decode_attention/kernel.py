"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Grid (B, H, n_kv) with the KV-block axis innermost (sequential on-core):
the online-softmax accumulator lives in VMEM scratch across KV blocks —
the classic memory-bound decode shape, where the KV cache stream IS the
roofline. Validity masking (cache may be part-filled / ring-buffered)
comes in as an int32 vector blocked alongside KV.

Block tiling: k/v (B, G, S, dh) -> (1, 1, bk, dh) @ (b, h // R, ik, 0);
VMEM per program ~ 2*bk*dh f32 + bk scores: bk=512, dh=128 -> ~0.6MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc_ref, m_ref, l_ref,
            *, n_kv: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, 0, :].astype(jnp.float32)            # (dh,)
    k = k_ref[0, 0, :, :].astype(jnp.float32)            # (bk, dh)
    v = v_ref[0, 0, :, :]                                # (bk, dh)
    ok = valid_ref[0, :] > 0                             # (bk,)

    s = jnp.sum(k * q[None, :], axis=1) * scale          # (bk,)
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                               # (bk,)
    corr = jnp.exp(m_prev - m_new)
    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    pv = jnp.sum(p[:, None].astype(jnp.float32) * v.astype(jnp.float32),
                 axis=0)                                 # (dh,)
    acc_ref[0, :] = acc_ref[0, :] * corr + pv
    m_ref[0, 0] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0, 0, 0, :] = (acc_ref[0, :]
                             / jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, valid, *, block_k: int = 512,
                            interpret: bool = True):
    """q (B, 1, H, dh); k/v (B, G, S, dh); valid (S,) bool/int.
    Returns (B, 1, H, dh)."""
    B, _, H, dh = q.shape
    G, S = k_cache.shape[1], k_cache.shape[2]
    R = H // G
    bk = min(block_k, S)
    assert S % bk == 0
    n_kv = S // bk
    scale = 1.0 / (dh ** 0.5)
    valid_i = valid.astype(jnp.int32)[None, :]           # (1, S)

    from jax.experimental.pallas import tpu as pltpu
    kern = functools.partial(_kernel, n_kv=n_kv, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, H, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, ik: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, R=R: (b, h // R, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, R=R: (b, h // R, ik, 0)),
            pl.BlockSpec((1, bk), lambda b, h, ik: (0, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh), lambda b, h, ik: (b, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, H, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, valid_i)

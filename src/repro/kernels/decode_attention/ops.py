"""jit'd public wrapper for the flash-decode kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import decode_attention_pallas


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, valid, block_k: int = 512,
                     interpret: bool = True):
    """One-token decode attention. q (B,1,H,dh); caches (B,G,S,dh);
    valid (S,)."""
    return decode_attention_pallas(q, k_cache, v_cache, valid,
                                   block_k=block_k, interpret=interpret)

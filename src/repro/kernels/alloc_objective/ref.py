"""Pure-jnp oracle: batched (multi-start) objective + gradient of eq. (1).

Shapes: X (S, n) batch of allocation vectors; K (m, n); E (p, n); c (n,);
d (m,); params scalars. Returns (f (S,), grad (S, n)).
"""
from __future__ import annotations

import jax.numpy as jnp


def alloc_objective_ref(X, K, E, c, d, alpha, beta1, beta2, beta3, gamma):
    X = X.astype(jnp.float32)
    KX = jnp.einsum("mn,sn->sm", K, X)               # (S, m)
    EX = jnp.einsum("pn,sn->sp", E, X)               # (S, p)
    p = E.shape[0]

    base = X @ c                                      # (S,)
    consol = alpha * (p - jnp.sum(jnp.exp(-beta1 * EX), axis=-1))
    volume = -gamma * jnp.sum(jnp.log1p(beta2 * EX), axis=-1)
    short = jnp.maximum(d[None, :] - KX, 0.0)         # (S, m)
    shortage = beta3 * jnp.sum(short**2, axis=-1)
    f = base + consol + volume + shortage

    g_consol = alpha * beta1 * jnp.einsum("sp,pn->sn", jnp.exp(-beta1 * EX), E)
    g_volume = -gamma * beta2 * jnp.einsum(
        "sp,pn->sn", 1.0 / (1.0 + beta2 * EX), E)
    g_short = -2.0 * beta3 * jnp.einsum("sm,mn->sn", short, K)
    grad = c[None, :] + g_consol + g_volume + g_short
    return f, grad


def _fleet_forward(X, K, E, c, d, alpha, beta1, beta2, beta3, gamma):
    """Shared value computation + the intermediates the gradient reuses."""
    X = X.astype(jnp.float32)
    KX = jnp.einsum("bmn,btn->btm", K, X)            # (B, T, m)
    EX = jnp.einsum("bpn,btn->btp", E, X)            # (B, T, p)

    al = alpha[:, None]
    b1 = beta1[:, None]
    b2 = beta2[:, None]
    b3 = beta3[:, None]
    ga = gamma[:, None]

    base = jnp.einsum("btn,bn->bt", X, c)             # (B, T)
    exp_term = jnp.exp(-b1[..., None] * EX)           # (B, T, p)
    # padded (all-zero) E rows give 1 - exp(0) = 0, so summing 1-exp over the
    # PADDED p axis equals the true per-problem consolidation term
    consol = al * jnp.sum(1.0 - exp_term, axis=-1)
    volume = -ga * jnp.sum(jnp.log1p(b2[..., None] * EX), axis=-1)
    short = jnp.maximum(d[:, None, :] - KX, 0.0)      # (B, T, m)
    shortage = b3 * jnp.sum(short**2, axis=-1)
    f = base + consol + volume + shortage
    return f, EX, exp_term, short


def alloc_objective_fleet_value(X, K, E, c, d, alpha, beta1, beta2, beta3,
                                gamma):
    """Values only — the fleet solver's Armijo-ladder evaluation."""
    return _fleet_forward(X, K, E, c, d, alpha, beta1, beta2, beta3, gamma)[0]


def alloc_objective_fleet_ref(X, K, E, c, d, alpha, beta1, beta2, beta3, gamma):
    """Fleet oracle: per-problem matrices. X (B, T, n); K (B, m, n);
    E (B, p, n); c (B, n); d (B, m); params (B,) each.
    Returns (f (B, T), grad (B, T, n))."""
    f, EX, exp_term, short = _fleet_forward(X, K, E, c, d, alpha, beta1,
                                            beta2, beta3, gamma)
    al = alpha[:, None]
    b1 = beta1[:, None]
    b2 = beta2[:, None]
    b3 = beta3[:, None]
    ga = gamma[:, None]
    g_consol = al[..., None] * b1[..., None] * jnp.einsum(
        "btp,bpn->btn", exp_term, E)
    g_volume = -ga[..., None] * b2[..., None] * jnp.einsum(
        "btp,bpn->btn", 1.0 / (1.0 + b2[..., None] * EX), E)
    g_short = -2.0 * b3[..., None] * jnp.einsum("btm,bmn->btn", short, K)
    grad = c[:, None, :] + g_consol + g_volume + g_short
    return f, grad

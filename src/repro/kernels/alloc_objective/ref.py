"""Pure-jnp oracle: batched (multi-start) objective + gradient of eq. (1).

Shapes: X (S, n) batch of allocation vectors; K (m, n); E (p, n); c (n,);
d (m,); params scalars. Returns (f (S,), grad (S, n)).
"""
from __future__ import annotations

import jax.numpy as jnp


def alloc_objective_ref(X, K, E, c, d, alpha, beta1, beta2, beta3, gamma):
    X = X.astype(jnp.float32)
    KX = jnp.einsum("mn,sn->sm", K, X)               # (S, m)
    EX = jnp.einsum("pn,sn->sp", E, X)               # (S, p)
    p = E.shape[0]

    base = X @ c                                      # (S,)
    consol = alpha * (p - jnp.sum(jnp.exp(-beta1 * EX), axis=-1))
    volume = -gamma * jnp.sum(jnp.log1p(beta2 * EX), axis=-1)
    short = jnp.maximum(d[None, :] - KX, 0.0)         # (S, m)
    shortage = beta3 * jnp.sum(short**2, axis=-1)
    f = base + consol + volume + shortage

    g_consol = alpha * beta1 * jnp.einsum("sp,pn->sn", jnp.exp(-beta1 * EX), E)
    g_volume = -gamma * beta2 * jnp.einsum(
        "sp,pn->sn", 1.0 / (1.0 + beta2 * EX), E)
    g_short = -2.0 * beta3 * jnp.einsum("sm,mn->sn", short, K)
    grad = c[None, :] + g_consol + g_volume + g_short
    return f, grad

"""Fused multi-start objective+gradient Pallas TPU kernel.

The solver's hot loop evaluates f(x) and grad f(x) for a BATCH of starts every
PGD iteration. The jnp path materializes ~8 (S, n)/(S, m) intermediates in
HBM; this kernel keeps everything for a block of starts resident in VMEM and
writes only (f_block, grad_block) back.

TPU adaptation (vs the paper's CPU/GLPK setting):
  * n (instance types, ~1.9k) padded to the 128-lane boundary, resident as a
    (block_s, n) VMEM tile — 128 x 2048 f32 = 1MB, well under VMEM.
  * K (m, n) and E (p, n) are small (m=4, p=2) and broadcast to every block.
  * grid over the start dimension only: one program computes a whole block's
    objective terms AND the analytic gradient in registers/VMEM.

Masking: padded columns carry K=E=c=0 so they contribute nothing; the caller
slices the padded gradient back to n columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, k_ref, e_ref, c_ref, d_ref, scal_ref, f_ref, g_ref):
    """Block shapes: x (bs, n), k (m, n), e (p, n), c (1, n), d (1, m),
    scal (1, 8) = [alpha, beta1, beta2, beta3, gamma, p_count, 0, 0],
    outputs f (bs, 1), g (bs, n)."""
    x = x_ref[...].astype(jnp.float32)              # (bs, n)
    K = k_ref[...].astype(jnp.float32)              # (m, n)
    E = e_ref[...].astype(jnp.float32)              # (p, n)
    c = c_ref[...].astype(jnp.float32)              # (1, n)
    d = d_ref[...].astype(jnp.float32)              # (1, m)
    alpha = scal_ref[0, 0]
    beta1 = scal_ref[0, 1]
    beta2 = scal_ref[0, 2]
    beta3 = scal_ref[0, 3]
    gamma = scal_ref[0, 4]
    p_cnt = scal_ref[0, 5]

    # contractions against the small K/E matrices use the MXU via dot_general
    KX = jax.lax.dot_general(x, K, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (bs, m)
    EX = jax.lax.dot_general(x, E, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (bs, p)

    base = jnp.sum(x * c, axis=1)                                   # (bs,)
    exp_term = jnp.exp(-beta1 * EX)                                 # (bs, p)
    consol = alpha * (p_cnt - jnp.sum(exp_term, axis=1))
    volume = -gamma * jnp.sum(jnp.log1p(beta2 * EX), axis=1)
    short = jnp.maximum(d - KX, 0.0)                                # (bs, m)
    shortage = beta3 * jnp.sum(short * short, axis=1)
    f_ref[...] = (base + consol + volume + shortage)[:, None]

    g_consol = alpha * beta1 * jax.lax.dot_general(
        exp_term, E, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                         # (bs, n)
    g_volume = -gamma * beta2 * jax.lax.dot_general(
        1.0 / (1.0 + beta2 * EX), E, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    g_short = -2.0 * beta3 * jax.lax.dot_general(
        short, K, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    g_ref[...] = c + g_consol + g_volume + g_short


def alloc_objective_pallas(X, K, E, c, d, scalars, *, block_s: int = 128,
                           interpret: bool = True):
    """X (S, n_pad); K (m, n_pad); E (p, n_pad); c (n_pad,); d (m,);
    scalars (8,) f32. Returns (f (S,), grad (S, n_pad))."""
    S, n = X.shape
    m, p = K.shape[0], E.shape[0]
    assert S % block_s == 0, (S, block_s)
    grid = (S // block_s,)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),    # x block
            pl.BlockSpec((m, n), lambda i: (0, 0)),          # K broadcast
            pl.BlockSpec((p, n), lambda i: (0, 0)),          # E broadcast
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # c
            pl.BlockSpec((1, m), lambda i: (0, 0)),          # d
            pl.BlockSpec((1, 8), lambda i: (0, 0)),          # scalars
        ],
        out_specs=[
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),    # f
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),    # grad
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, n), jnp.float32),
        ],
        interpret=interpret,
    )(X, K, E, c[None, :], d[None, :], scalars[None, :])
    f, g = out
    return f[:, 0], g

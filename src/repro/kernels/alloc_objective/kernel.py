"""Fused multi-start objective+gradient Pallas TPU kernel.

The solver's hot loop evaluates f(x) and grad f(x) for a BATCH of starts every
PGD iteration. The jnp path materializes ~8 (S, n)/(S, m) intermediates in
HBM; this kernel keeps everything for a block of starts resident in VMEM and
writes only (f_block, grad_block) back.

TPU adaptation (vs the paper's CPU/GLPK setting):
  * n (instance types, ~1.9k) padded to the 128-lane boundary, resident as a
    (block_s, n) VMEM tile — 128 x 2048 f32 = 1MB, well under VMEM.
  * K (m, n) and E (p, n) are small (m=4, p=2) and broadcast to every block.
  * grid over the start dimension only: one program computes a whole block's
    objective terms AND the analytic gradient in registers/VMEM.

Two entry points share the same math:
  * ``alloc_objective_pallas``       — one problem, (S, n) starts (multistart).
  * ``alloc_objective_fleet_pallas`` — B problems with per-problem K/E/c/d,
    (B, T, n) candidates; the grid grows a leading batch axis and the problem
    data blocks are indexed by it. This is the fleet solver's hot loop: the
    whole multi-tenant batch is one pallas_call.

Masking: padded columns carry K=E=c=0 so they contribute nothing; padded
E rows are all-zero so their exp(-b1*0)=1 cancels against the padded p_count
(the caller passes the PADDED provider count); the caller slices the padded
gradient back to n columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _objective_math(x, K, E, c, d, scal):
    """Shared eq.(1) objective + analytic gradient for one block.

    x (bs, n), K (m, n), E (p, n), c (1, n), d (1, m), scal (1, 8) =
    [alpha, beta1, beta2, beta3, gamma, p_count, 0, 0].
    Returns f (bs,), g (bs, n).
    """
    alpha = scal[0, 0]
    beta1 = scal[0, 1]
    beta2 = scal[0, 2]
    beta3 = scal[0, 3]
    gamma = scal[0, 4]
    p_cnt = scal[0, 5]

    # contractions against the small K/E matrices use the MXU via dot_general
    KX = jax.lax.dot_general(x, K, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (bs, m)
    EX = jax.lax.dot_general(x, E, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (bs, p)

    base = jnp.sum(x * c, axis=1)                                   # (bs,)
    exp_term = jnp.exp(-beta1 * EX)                                 # (bs, p)
    consol = alpha * (p_cnt - jnp.sum(exp_term, axis=1))
    volume = -gamma * jnp.sum(jnp.log1p(beta2 * EX), axis=1)
    short = jnp.maximum(d - KX, 0.0)                                # (bs, m)
    shortage = beta3 * jnp.sum(short * short, axis=1)
    f = base + consol + volume + shortage

    g_consol = alpha * beta1 * jax.lax.dot_general(
        exp_term, E, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                         # (bs, n)
    g_volume = -gamma * beta2 * jax.lax.dot_general(
        1.0 / (1.0 + beta2 * EX), E, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    g_short = -2.0 * beta3 * jax.lax.dot_general(
        short, K, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    g = c + g_consol + g_volume + g_short
    return f, g


def _kernel(x_ref, k_ref, e_ref, c_ref, d_ref, scal_ref, f_ref, g_ref):
    """Block shapes: x (bs, n), k (m, n), e (p, n), c (1, n), d (1, m),
    scal (1, 8); outputs f (bs, 1), g (bs, n)."""
    f, g = _objective_math(x_ref[...].astype(jnp.float32),
                           k_ref[...].astype(jnp.float32),
                           e_ref[...].astype(jnp.float32),
                           c_ref[...].astype(jnp.float32),
                           d_ref[...].astype(jnp.float32),
                           scal_ref[...])
    f_ref[...] = f[:, None]
    g_ref[...] = g


def _fleet_kernel(x_ref, k_ref, e_ref, c_ref, d_ref, scal_ref, f_ref, g_ref):
    """Same math with a leading singleton batch-block axis on every ref."""
    f, g = _objective_math(x_ref[0].astype(jnp.float32),
                           k_ref[0].astype(jnp.float32),
                           e_ref[0].astype(jnp.float32),
                           c_ref[0].astype(jnp.float32),
                           d_ref[0].astype(jnp.float32),
                           scal_ref[0])
    f_ref[0] = f[:, None]
    g_ref[0] = g


def alloc_objective_pallas(X, K, E, c, d, scalars, *, block_s: int = 128,
                           interpret: bool = True):
    """X (S, n_pad); K (m, n_pad); E (p, n_pad); c (n_pad,); d (m,);
    scalars (8,) f32. Returns (f (S,), grad (S, n_pad))."""
    S, n = X.shape
    m, p = K.shape[0], E.shape[0]
    assert S % block_s == 0, (S, block_s)
    grid = (S // block_s,)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),    # x block
            pl.BlockSpec((m, n), lambda i: (0, 0)),          # K broadcast
            pl.BlockSpec((p, n), lambda i: (0, 0)),          # E broadcast
            pl.BlockSpec((1, n), lambda i: (0, 0)),          # c
            pl.BlockSpec((1, m), lambda i: (0, 0)),          # d
            pl.BlockSpec((1, 8), lambda i: (0, 0)),          # scalars
        ],
        out_specs=[
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),    # f
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),    # grad
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, n), jnp.float32),
        ],
        interpret=interpret,
    )(X, K, E, c[None, :], d[None, :], scalars[None, :])
    f, g = out
    return f[:, 0], g


def alloc_objective_fleet_pallas(X, K, E, c, d, scalars, *,
                                 block_t: int = 128, interpret: bool = True):
    """Fleet (multi-tenant) batch: per-problem matrices indexed by the grid.

    X (B, T, n_pad); K (B, m, n_pad); E (B, p, n_pad); c (B, n_pad);
    d (B, m); scalars (B, 8) with scalars[:, 5] the PADDED provider count.
    Returns (f (B, T), grad (B, T, n_pad)).
    """
    B, T, n = X.shape
    m, p = K.shape[1], E.shape[1]
    assert T % block_t == 0, (T, block_t)
    grid = (B, T // block_t)

    out = pl.pallas_call(
        _fleet_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, n), lambda b, i: (b, i, 0)),  # x block
            pl.BlockSpec((1, m, n), lambda b, i: (b, 0, 0)),        # K[b]
            pl.BlockSpec((1, p, n), lambda b, i: (b, 0, 0)),        # E[b]
            pl.BlockSpec((1, 1, n), lambda b, i: (b, 0, 0)),        # c[b]
            pl.BlockSpec((1, 1, m), lambda b, i: (b, 0, 0)),        # d[b]
            pl.BlockSpec((1, 1, 8), lambda b, i: (b, 0, 0)),        # scalars[b]
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, 1), lambda b, i: (b, i, 0)),  # f
            pl.BlockSpec((1, block_t, n), lambda b, i: (b, i, 0)),  # grad
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, T, n), jnp.float32),
        ],
        interpret=interpret,
    )(X, K, E, c[:, None, :], d[:, None, :], scalars[:, None, :])
    f, g = out
    return f[:, :, 0], g

"""Public wrapper: pads n to the 128-lane boundary and the start batch to the
block size, dispatches to the Pallas kernel, slices back. ``interpret=True``
on CPU (validation); on TPU pass interpret=False for the compiled kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.problem import AllocationProblem
from .kernel import alloc_objective_pallas


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def batched_value_and_grad(prob: AllocationProblem, X: jnp.ndarray,
                           block_s: int = 128, interpret: bool = True):
    """(f (S,), grad (S, n)) for a batch of allocations X (S, n)."""
    S, n = X.shape
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_s, 0)
    Kp = _pad_to(prob.K.astype(jnp.float32), 128, 1)
    Ep = _pad_to(prob.E.astype(jnp.float32), 128, 1)
    cp = _pad_to(prob.c.astype(jnp.float32), 128, 0)
    P = prob.params
    scalars = jnp.stack([P.alpha, P.beta1, P.beta2, P.beta3, P.gamma,
                         jnp.float32(prob.p), jnp.float32(0), jnp.float32(0)])
    f, g = alloc_objective_pallas(Xp, Kp, Ep, cp, prob.d.astype(jnp.float32),
                                  scalars.astype(jnp.float32),
                                  block_s=block_s, interpret=interpret)
    return f[:S], g[:S, :n]

"""Public wrapper: pads n to the 128-lane boundary and the start batch to the
block size, dispatches to the Pallas kernel, slices back. ``interpret=True``
on CPU (validation); on TPU pass interpret=False for the compiled kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.problem import AllocationProblem
from .kernel import alloc_objective_fleet_pallas, alloc_objective_pallas
from .ref import alloc_objective_fleet_ref


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def batched_value_and_grad(prob: AllocationProblem, X: jnp.ndarray,
                           block_s: int = 128, interpret: bool = True):
    """(f (S,), grad (S, n)) for a batch of allocations X (S, n)."""
    S, n = X.shape
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 1), block_s, 0)
    Kp = _pad_to(prob.K.astype(jnp.float32), 128, 1)
    Ep = _pad_to(prob.E.astype(jnp.float32), 128, 1)
    cp = _pad_to(prob.c.astype(jnp.float32), 128, 0)
    P = prob.params
    scalars = jnp.stack([P.alpha, P.beta1, P.beta2, P.beta3, P.gamma,
                         jnp.float32(prob.p), jnp.float32(0), jnp.float32(0)])
    f, g = alloc_objective_pallas(Xp, Kp, Ep, cp, prob.d.astype(jnp.float32),
                                  scalars.astype(jnp.float32),
                                  block_s=block_s, interpret=interpret)
    return f[:S], g[:S, :n]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret", "use_kernel"))
def fleet_value_and_grad(prob: AllocationProblem, X: jnp.ndarray,
                         block_t: int = 128, interpret: bool = True,
                         use_kernel: bool = True):
    """(f (B, T), grad (B, T, n)) for a fleet batch.

    ``prob`` is a STACKED AllocationProblem (leaves carry a leading (B,) axis,
    see repro.fleet.batching.stack_problems); X is (B, T, n) — T candidate
    allocations per tenant. With ``use_kernel`` the evaluation dispatches to
    the batched Pallas kernel (grid over tenants x candidate blocks); without
    it, to the einsum oracle (the faster path on CPU where Pallas runs in
    interpret mode).
    """
    B, T, n = X.shape
    P = prob.params
    if not use_kernel:
        return alloc_objective_fleet_ref(
            X.astype(jnp.float32), prob.K, prob.E, prob.c, prob.d,
            P.alpha, P.beta1, P.beta2, P.beta3, P.gamma)
    # don't inflate a short candidate axis (e.g. T = n_starts = 4 at the
    # per-iterate gradient call) to a full 128-row block — shrink the block
    # to the next sublane multiple of 8 instead
    block_t = min(block_t, max(8, -(-T // 8) * 8))
    Xp = _pad_to(_pad_to(X.astype(jnp.float32), 128, 2), block_t, 1)
    Kp = _pad_to(prob.K.astype(jnp.float32), 128, 2)
    Ep = _pad_to(prob.E.astype(jnp.float32), 128, 2)
    cp = _pad_to(prob.c.astype(jnp.float32), 128, 1)
    # padded (all-zero) E rows contribute exp(0)=1 each; passing the PADDED
    # provider count makes p_cnt - sum(exp) telescope to the true term
    p_pad = jnp.full((B,), float(prob.E.shape[1]), jnp.float32)
    zeros = jnp.zeros((B,), jnp.float32)
    scalars = jnp.stack([P.alpha, P.beta1, P.beta2, P.beta3, P.gamma,
                         p_pad, zeros, zeros], axis=1)
    f, g = alloc_objective_fleet_pallas(Xp, Kp, Ep, cp,
                                        prob.d.astype(jnp.float32),
                                        scalars.astype(jnp.float32),
                                        block_t=block_t, interpret=interpret)
    return f[:, :T], g[:, :T, :n]

"""jit'd public wrapper for the RWKV6 WKV chunk kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import rwkv6_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0, chunk: int = 64, interpret: bool = True):
    """RWKV6 WKV: r,k,v,w (B,S,H,hs); u (H,hs); s0 (B,H,hs,hs)."""
    return rwkv6_scan_pallas(r, k, v, w, u, s0, chunk=chunk,
                             interpret=interpret)

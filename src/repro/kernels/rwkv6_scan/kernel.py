"""RWKV6 WKV chunked-scan Pallas TPU kernel.

Grid (B, H, n_chunks), chunk axis innermost/sequential: the (hs, hs) state
matrix lives in VMEM scratch across chunks. Within a chunk the GLA-style
closed form turns the recurrence into two small MXU matmuls plus the
decay-weighted intra-chunk attention matrix (c x c) — TPU-native (systolic
matmuls over hs=64..128-wide tiles) instead of the CUDA per-timestep loop.

  y_t = (r_t . W_{t-1}) S_0
      + sum_{i<t} [(r_t . W_{t-1}) . (k_i / W_i)] v_i
      + (r_t . u . k_t) v_t
  S'  = diag(W_c) S_0 + sum_i (k_i . W_c/W_i) v_i^T

W_t = prod_{j<=t} w_j (cumprod in log space; the k/W ratio is clamped to
exp(60) — contributions beyond that decay window are below f32 resolution).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sf_ref,
            state_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    rr = r_ref[0, :, 0, :].astype(jnp.float32)            # (c, hs)
    kk = k_ref[0, :, 0, :].astype(jnp.float32)
    vv = v_ref[0, :, 0, :].astype(jnp.float32)
    ww = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)                   # (hs,)
    S0 = state_ref[...]                                   # (hs, hs)

    logw = jnp.log(ww)
    cum = jnp.cumsum(logw, axis=0)                        # (c, hs)
    Wm1 = jnp.exp(cum - logw)                             # W_{t-1}
    r_dec = rr * Wm1
    k_dec = kk * jnp.exp(-jnp.clip(cum, -60.0, 0.0))

    att = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (c, c)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(tj < ti, att, 0.0)                    # strict lower
    bonus = jnp.sum(rr * u[None, :] * kk, axis=1)         # (c,)

    y = jax.lax.dot_general(att, vv, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + bonus[:, None] * vv
    y = y + jax.lax.dot_general(r_dec, S0, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    Wc = jnp.exp(cum[-1])                                 # (hs,)
    k_tail = kk * jnp.exp(cum[-1][None, :] - cum)
    S_new = (Wc[:, None] * S0
             + jax.lax.dot_general(k_tail, vv, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    state_ref[...] = S_new

    @pl.when(ic == n_chunks - 1)
    def _final():
        sf_ref[0, 0] = S_new.astype(sf_ref.dtype)


def rwkv6_scan_pallas(r, k, v, w, u, s0, *, chunk: int = 64,
                      interpret: bool = True):
    """r,k,v,w (B, S, H, hs); u (H, hs); s0 (B, H, hs, hs).
    Returns (y (B, S, H, hs), s_final (B, H, hs, hs))."""
    B, S, H, hs = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    from jax.experimental.pallas import tpu as pltpu
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, sf = pl.pallas_call(
        kern,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hs), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, hs), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, hs), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, hs), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, hs), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hs), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hs), r.dtype),
            jax.ShapeDtypeStruct((B, H, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sf

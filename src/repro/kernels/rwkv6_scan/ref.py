"""Oracle for the RWKV6 WKV kernel: naive sequential recurrence.

r,k,v,w (B, S, H, hs); u (H, hs); s0 (B, H, hs, hs) ->
  (y (B, S, H, hs), s_final (B, H, hs, hs))

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, s0):
    B, S, H, hs = r.shape

    def step(state, t):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], w[:, t]   # (B, H, hs)
        bonus = jnp.einsum("bhc,bhc->bh", rt * u[None], kt)
        y = (jnp.einsum("bhc,bhcd->bhd", rt, state)
             + bonus[..., None] * vt)
        state = wt[..., None] * state + jnp.einsum("bhc,bhd->bhcd", kt, vt)
        return state, y

    s_final, ys = jax.lax.scan(step, s0.astype(jnp.float32),
                               jnp.arange(S))
    return jnp.swapaxes(ys, 0, 1), s_final

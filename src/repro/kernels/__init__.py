"""Pallas TPU kernels for the perf-critical layers. Each kernel package has:
  kernel.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target),
  ops.py    — jit'd public wrapper (interpret=True on CPU for validation),
  ref.py    — pure-jnp oracle the kernel is tested against.
"""
